//! Cross-path equality tests for the hot-path kernels: the dispatched
//! (AVX2-where-available) binning kernel, the floor-cache scalar kernel
//! and the per-point reference loop must agree bit-for-bit on every
//! shape and every value class (including NaN / ±∞ / overflow-range
//! inputs); the quantized CMS counters must be indistinguishable from
//! plain u32 counters through both width promotions; and the packed
//! artifact codec, the fused execution plan and the sharded serving
//! front-end must all leave score bits unchanged.

use std::collections::HashMap;

use sparx::api::{registry, Detector as _, FittedModel as _, SparxBuilder};
use sparx::cluster::ClusterConfig;
use sparx::data::generators::GisetteGen;
use sparx::data::{StreamGen, UpdateTriple};
use sparx::hash::bin_hash;
use sparx::sparx::chain::Binner;
use sparx::sparx::{
    kernel_path, tile_bins_reference, tile_bins_scalar, ChainParams, CountMinSketch, ExecMode,
    NativeBinner, ServeOptions, ServedEnsemble, ShardedStreamScorer, SparxModel, SparxParams,
    StreamScorer,
};
use sparx::util::codec::{Decoder, Encoder};
use sparx::util::Rng;

/// Reference loop, scalar kernel and runtime-dispatched kernel agree
/// bit-for-bit across shapes chosen to straddle the SIMD lane width
/// (K = 1..33 around the 8-lane boundary), degenerate tiles (n = 0, 1)
/// and hostile value classes (NaN, ±∞, values past the i32 cast range).
#[test]
fn kernels_agree_bitwise_across_edge_shapes() {
    let mut rng = Rng::new(0xD15);
    let shapes = [
        (1, 1, 1),
        (1, 4, 3),
        (7, 3, 5),
        (8, 1, 2),
        (9, 20, 1),
        (16, 8, 8),
        (33, 5, 17),
        (4, 2, 0),
    ];
    for &(k, l, n) in &shapes {
        for case in 0..4 {
            let delta: Vec<f32> = (0..k).map(|_| rng.range_f64(0.25, 4.0) as f32).collect();
            let chain = ChainParams::sample(&delta, l, &mut rng);
            let mut s: Vec<f32> = (0..n * k).map(|_| (rng.normal() * 3.0) as f32).collect();
            if case == 3 && s.len() >= 4 {
                s[0] = f32::NAN;
                s[1] = f32::INFINITY;
                s[2] = f32::NEG_INFINITY;
                s[3] = 3.0e38;
            }
            let reference = tile_bins_reference(&chain, &s, n);
            let scalar = tile_bins_scalar(&chain, &s, n);
            let dispatched = NativeBinner.tile_bins(&chain, &s, n).unwrap();
            assert_eq!(scalar, reference, "scalar: K={k} L={l} n={n} case={case}");
            assert_eq!(
                dispatched,
                reference,
                "dispatched ({}): K={k} L={l} n={n} case={case}",
                kernel_path()
            );
        }
    }
}

/// The fused executors hand `tile_bins_multi` chains of *different*
/// depths after per-chain subsampling; the chain-major output must equal
/// the per-chain reference loop, concatenated.
#[test]
fn tile_bins_multi_matches_per_chain_reference_with_mixed_depths() {
    let mut rng = Rng::new(0xB00);
    let k = 13;
    let delta: Vec<f32> = (0..k).map(|_| rng.range_f64(0.5, 2.0) as f32).collect();
    let chains: Vec<ChainParams> =
        [1usize, 3, 8, 20, 5].iter().map(|&l| ChainParams::sample(&delta, l, &mut rng)).collect();
    let refs: Vec<&ChainParams> = chains.iter().collect();
    let n = 19;
    let s: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
    let multi = NativeBinner.tile_bins_multi(&refs, &s, n).unwrap();
    let mut concat = Vec::new();
    for c in &chains {
        concat.extend(tile_bins_reference(c, &s, n));
    }
    assert_eq!(multi, concat);
}

/// Quantized counters (u8 → u16 → u32 promote-on-overflow) report the
/// exact same counts as unbounded arithmetic through both promotion
/// boundaries, and the batched query path agrees with the pointwise one.
#[test]
fn quantized_counters_match_exact_counts_through_promotions() {
    // one hot key pushed through 255 (u8 edge) and 65535 (u16 edge)
    let mut cms = CountMinSketch::new(4, 32);
    let hot = vec![3i32, -7, 11];
    let h = bin_hash(&hot);
    for milestone in [255u32, 256, 65_535, 65_536, 70_000] {
        while cms.query(&hot) < milestone {
            cms.insert(&hot);
        }
        assert_eq!(cms.query(&hot), milestone, "promotion changed a count");
        let mut out = [0u32; 1];
        cms.query_many(&[h], &mut out);
        assert_eq!(out[0], milestone, "batched query diverged at {milestone}");
    }

    // a random workload: batched == pointwise, and never underestimates
    let mut rng = Rng::new(0x5EED);
    let mut cms = CountMinSketch::new(6, 128);
    let mut truth: HashMap<Vec<i32>, u32> = HashMap::new();
    let keys: Vec<Vec<i32>> =
        (0..80).map(|_| (0..4).map(|_| rng.below(30) as i32 - 15).collect()).collect();
    for _ in 0..3000 {
        let key = &keys[rng.below(80) as usize];
        cms.insert(key);
        *truth.entry(key.clone()).or_insert(0) += 1;
    }
    let hashes: Vec<_> = keys.iter().map(|b| bin_hash(b)).collect();
    let mut out = vec![0u32; keys.len()];
    cms.query_many(&hashes, &mut out);
    for (i, key) in keys.iter().enumerate() {
        assert_eq!(out[i], cms.query(key), "batched vs pointwise at key {i}");
        assert!(out[i] >= truth.get(key).copied().unwrap_or(0), "underestimate at key {i}");
    }
}

/// The packed (varint + zero-RLE) count codec round-trips arbitrary
/// spiky count vectors and actually compresses the sparse ones.
#[test]
fn packed_count_codec_round_trips_and_compresses() {
    let mut rng = Rng::new(0xC0DE);
    for case in 0..50 {
        let n = rng.below(3000) as usize;
        let counts: Vec<u32> = (0..n)
            .map(|_| match rng.below(10) {
                0..=6 => 0,
                7 => rng.below(200) as u32,
                8 => rng.below(70_000) as u32,
                _ => u32::MAX - rng.below(3) as u32,
            })
            .collect();
        let mut enc = Encoder::new();
        enc.put_u32_slice_packed(&counts);
        let bytes = enc.into_bytes();
        let mut dec = Decoder::new(&bytes);
        let back = dec.u32_vec_packed(n).unwrap();
        assert_eq!(back, counts, "case {case} (n={n})");
        assert_eq!(dec.remaining(), 0, "case {case}: trailing bytes");
    }
    // mostly-zero vectors (the CMS regime) must shrink well below 4B/cell
    let sparse = vec![0u32; 10_000];
    let mut enc = Encoder::new();
    enc.put_u32_slice_packed(&sparse);
    assert!(enc.into_bytes().len() < 16, "zero-run encoding regressed");
}

/// End-to-end codec contract on the public API: a model saved through
/// the v3 (packed-count) artifact format scores bit-identically after
/// `registry::load_bytes`, and `model_bytes` matches the payload it
/// ships.
#[test]
fn scores_survive_artifact_roundtrip_bit_identically() {
    let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
    let ld = GisetteGen { n: 400, d: 48, ..Default::default() }.generate(&ctx).unwrap();
    let det = SparxBuilder::new().k(12).chains(8).depth(6).build().unwrap();
    let model = det.fit(&ctx, &ld.dataset).unwrap();
    let before = model.score(&ctx, &ld.dataset).unwrap();

    let art = model.to_artifact().unwrap();
    assert_eq!(art.payload.len(), model.model_bytes(), "model_bytes contract");
    let bytes = art.to_bytes();
    let loaded = registry::load_bytes(&bytes).unwrap();
    let after = loaded.score(&ctx, &ld.dataset).unwrap();
    assert_eq!(before.len(), after.len());
    for (b, a) in before.iter().zip(&after) {
        assert_eq!(b.0, a.0, "row ids must line up");
        assert_eq!(b.1.to_bits(), a.1.to_bits(), "score bits changed for id {}", b.0);
    }
}

/// The fused single-pass plan and the legacy one-round-per-chain plan
/// produce bit-identical scores (re-run on top of the batched CMS and
/// dispatched binning kernels).
#[test]
fn fused_and_per_chain_plans_score_identically() {
    let ctx = ClusterConfig { num_partitions: 6, num_workers: 3, ..Default::default() }.build();
    let ld = GisetteGen { n: 500, d: 64, ..Default::default() }.generate(&ctx).unwrap();
    let mut outs = Vec::new();
    for mode in ExecMode::ALL {
        let p = SparxParams {
            k: 16,
            num_chains: 12,
            depth: 8,
            exec_mode: mode,
            ..Default::default()
        };
        let model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
        outs.push(model.score_dataset(&ctx, &ld.dataset).unwrap());
    }
    assert_eq!(outs[0], outs[1], "fused vs per-chain scores diverged");
}

/// Sharded serving determinism, re-run on top of the new kernels: per-ID
/// score sequences at S = 4 are bit-identical to the single-threaded
/// scorer in the no-eviction regime.
#[test]
fn sharded_per_id_scores_still_bit_identical_over_new_kernels() {
    let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
    let ld = GisetteGen { n: 400, d: 24, ..Default::default() }.generate(&ctx).unwrap();
    let model = SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 12, num_chains: 10, depth: 6, ..Default::default() },
    )
    .unwrap();
    let names: Vec<String> = (0..24).map(|j| format!("f{j}")).collect();
    let mut gen = StreamGen::new(200, names, 0xFACE);
    let updates: Vec<UpdateTriple> = (0..4000).map(|_| gen.next_update()).collect();

    let mut reference = StreamScorer::new(&model, 4096).unwrap();
    let mut want: HashMap<u64, Vec<u64>> = HashMap::new();
    for u in &updates {
        let s = reference.update(u);
        want.entry(s.id).or_default().push(s.outlierness.to_bits());
    }
    assert_eq!(reference.evictions(), 0, "harness requires the no-eviction regime");

    let mut scorer = ShardedStreamScorer::from_ensemble(
        std::sync::Arc::new(ServedEnsemble::new(&model).unwrap()),
        ServeOptions::new().shards(4).cache(4096).record(true),
        None,
    )
    .unwrap();
    for u in updates.clone() {
        scorer.submit(u);
    }
    let report = scorer.finish();
    assert_eq!(report.processed(), updates.len() as u64);
    let mut got: HashMap<u64, Vec<u64>> = HashMap::new();
    for (_, s) in report.scores.into_iter().flatten() {
        got.entry(s.id).or_default().push(s.outlierness.to_bits());
    }
    assert_eq!(got, want, "sharded per-ID score bits diverged from S=1");
}
