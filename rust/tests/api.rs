//! Integration tests of the unified detector API: the registry-driven
//! Sparx run is bit-identical to the direct `SparxModel::fit` path,
//! invalid hyperparameters surface as typed `SparxError::InvalidParams`
//! instead of panicking, every registered detector returns exactly one
//! aligned score per point, and — the lifecycle acceptance criteria —
//! fit → `to_artifact` → `registry::load_bytes` → score round trips
//! bit-identically for every detector, with corrupt / truncated /
//! wrong-version artifacts failing typed.

use sparx::api::{
    registry, Detector as _, DetectorSpec, FittedModel as _, ModelArtifact, SparxBuilder,
    SparxError,
};
use sparx::baselines::dbscout::{Dbscout, DbscoutParams};
use sparx::baselines::{Spif, SpifParams, XStream, XStreamParams};
use sparx::cluster::ClusterConfig;
use sparx::data::generators::{GisetteGen, OsmGen, SpamUrlGen};
use sparx::sparx::{SparxModel, SparxParams};

fn local(parts: usize) -> sparx::ClusterContext {
    ClusterConfig { num_partitions: parts, num_workers: 4, num_threads: 4, ..Default::default() }
        .build()
}

fn small_osm() -> OsmGen {
    OsmGen { n_inliers: 1500, n_outliers: 15, roads: 8, cities: 3, ..Default::default() }
}

#[test]
fn registry_sparx_is_bit_identical_to_direct_path() {
    let ctx = local(4);
    let ld = GisetteGen { n: 600, d: 32, ..Default::default() }.generate(&ctx).unwrap();
    let p = SparxParams { k: 12, num_chains: 8, depth: 6, sample_rate: 0.5, ..Default::default() };
    // the pre-redesign path: fit + score on the model directly
    let direct_model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
    let direct = direct_model.score_dataset(&ctx, &ld.dataset).unwrap();
    // the registry-driven path the CLI uses
    let spec = DetectorSpec {
        k: Some(p.k),
        components: Some(p.num_chains),
        depth: Some(p.depth),
        sample_rate: Some(p.sample_rate),
        ..Default::default()
    };
    let det = registry::build("sparx", &spec).unwrap();
    let via_registry =
        det.fit(&ctx, &ld.dataset).unwrap().score(&ctx, &ld.dataset).unwrap();
    assert_eq!(direct, via_registry, "registry run must be bit-identical to the direct path");
    // and the typed-builder path
    let built = SparxBuilder::new().params(p).build().unwrap();
    let via_builder =
        built.fit(&ctx, &ld.dataset).unwrap().score(&ctx, &ld.dataset).unwrap();
    assert_eq!(direct, via_builder, "builder run must be bit-identical to the direct path");
}

#[test]
fn baseline_detectors_match_their_direct_paths() {
    let ctx = local(4);
    let ld = small_osm().generate(&ctx).unwrap();

    // xstream: direct sequential reference vs the Detector adapter
    let rows = ld.dataset.rows.collect(&ctx).unwrap();
    let xp = XStreamParams { k: 8, num_chains: 6, depth: 5, ..Default::default() };
    let direct = XStream::fit(&rows, &ld.dataset.schema.names, &xp).score(&rows);
    let spec = DetectorSpec {
        k: Some(8),
        components: Some(6),
        depth: Some(5),
        ..Default::default()
    };
    let api = registry::build("xstream", &spec)
        .unwrap()
        .fit(&ctx, &ld.dataset)
        .unwrap()
        .score(&ctx, &ld.dataset)
        .unwrap();
    assert_eq!(direct, api, "xstream adapter diverges from the direct path");

    // spif
    let sp = SpifParams { num_trees: 6, max_depth: 6, sample_rate: 0.5, ..Default::default() };
    let direct =
        Spif::fit(&ctx, &ld.dataset, &sp).unwrap().score_dataset(&ctx, &ld.dataset).unwrap();
    let spec = DetectorSpec {
        components: Some(6),
        depth: Some(6),
        sample_rate: Some(0.5),
        ..Default::default()
    };
    let api = registry::build("spif", &spec)
        .unwrap()
        .fit(&ctx, &ld.dataset)
        .unwrap()
        .score(&ctx, &ld.dataset)
        .unwrap();
    assert_eq!(direct, api, "spif adapter diverges from the direct path");

    // dbscout: binary verdicts surface as 1.0 / 0.0
    let dp = DbscoutParams { eps: 1.0, min_pts: 4, ..Default::default() };
    let verdict = Dbscout::run(&ctx, &ld.dataset, &dp).unwrap();
    let direct: Vec<(u64, f64)> = verdict
        .pred
        .iter()
        .map(|&(id, o)| (id, if o { 1.0 } else { 0.0 }))
        .collect();
    let spec = DetectorSpec { eps: Some(1.0), min_pts: Some(4), ..Default::default() };
    let api = registry::build("dbscout", &spec)
        .unwrap()
        .fit(&ctx, &ld.dataset)
        .unwrap()
        .score(&ctx, &ld.dataset)
        .unwrap();
    assert_eq!(direct, api, "dbscout adapter diverges from the direct path");
}

#[test]
fn every_registered_detector_scores_every_point() {
    for name in registry::detector_names() {
        let ctx = local(4);
        let ld = small_osm().generate(&ctx).unwrap();
        let spec = DetectorSpec {
            k: Some(8),
            components: Some(8),
            depth: Some(5),
            sample_rate: Some(0.5),
            eps: Some(1.0),
            min_pts: Some(4),
            ..Default::default()
        };
        let det = registry::build(name, &spec).unwrap();
        let model = det.fit(&ctx, &ld.dataset).unwrap();
        assert_eq!(model.name(), name);
        let scores = model.score(&ctx, &ld.dataset).unwrap();
        assert_eq!(scores.len(), ld.dataset.len(), "{name} must score every point");
        let mut seen = vec![false; ld.dataset.len()];
        for &(id, s) in &scores {
            assert!(s.is_finite(), "{name}: non-finite score for id {id}");
            assert!(!seen[id as usize], "{name}: duplicate score for id {id}");
            seen[id as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "{name}: some ids never scored");
    }
}

#[test]
fn invalid_params_are_typed_errors_not_panics() {
    // the satellite cases: depth=0, cms_rows=0, sample_rate>1
    for (what, res) in [
        ("depth=0", SparxBuilder::new().depth(0).build().map(|_| ())),
        ("cms_rows=0", SparxBuilder::new().cms(0, 100).build().map(|_| ())),
        ("sample_rate>1", SparxBuilder::new().sample_rate(1.5).build().map(|_| ())),
    ] {
        assert!(
            matches!(res, Err(SparxError::InvalidParams(_))),
            "{what} must be InvalidParams, got {:?}",
            res.err()
        );
    }
    // the raw library entry point also fails typed (no deep panic)
    let ctx = local(2);
    let ld = GisetteGen { n: 200, d: 8, ..Default::default() }.generate(&ctx).unwrap();
    let p = SparxParams { depth: 0, ..Default::default() };
    assert!(matches!(
        SparxModel::fit(&ctx, &ld.dataset, &p),
        Err(sparx::ClusterError::Invalid(_))
    ));
}

#[test]
fn unknown_detector_suggests_the_right_name() {
    let e = registry::build("sparks", &DetectorSpec::default()).unwrap_err();
    assert_eq!(e.exit_code(), 2);
    match e {
        SparxError::UnknownDetector(msg) => assert!(msg.contains("sparx"), "{msg}"),
        other => panic!("expected UnknownDetector, got {other:?}"),
    }
}

#[test]
fn stream_scorer_supported_only_by_sparx() {
    let ctx = local(2);
    let ld = GisetteGen { n: 300, d: 16, ..Default::default() }.generate(&ctx).unwrap();
    let spec = DetectorSpec {
        k: Some(8),
        components: Some(4),
        depth: Some(4),
        sample_rate: Some(0.5),
        ..Default::default()
    };
    let sparx_model =
        registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
    assert!(sparx_model.stream_scorer(64).is_ok());
    let spif_model = registry::build("spif", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
    assert!(matches!(spif_model.stream_scorer(64), Err(SparxError::Unsupported(_))));
}

#[test]
fn dense_only_baselines_reject_sparse_input() {
    let ctx = local(2);
    let ld = SpamUrlGen { n: 300, d: 5000, mean_nnz: 20, ..Default::default() }
        .generate(&ctx)
        .unwrap();
    let spec = DetectorSpec {
        components: Some(4),
        sample_rate: Some(0.5),
        eps: Some(1.0),
        min_pts: Some(4),
        ..Default::default()
    };
    for name in ["spif", "dbscout"] {
        let r = registry::build(name, &spec).unwrap().fit(&ctx, &ld.dataset);
        assert!(
            matches!(r, Err(SparxError::Unsupported(_))),
            "{name} must reject sparse rows with a typed error, got {:?}",
            r.err().map(|e| e.to_string())
        );
    }
}

/// The lifecycle acceptance criterion: for every detector (and both
/// Sparx execution plans), fit → `to_artifact` → `to_bytes` →
/// `registry::load_bytes` → score is **bit-identical** to scoring the
/// in-memory model.
#[test]
fn artifact_round_trip_is_bit_identical_for_every_detector() {
    use sparx::sparx::ExecMode;
    for exec in [ExecMode::Fused, ExecMode::PerChain] {
        let ctx = local(4);
        let ld = GisetteGen { n: 400, d: 24, ..Default::default() }.generate(&ctx).unwrap();
        let spec = DetectorSpec {
            k: Some(8),
            components: Some(6),
            depth: Some(5),
            sample_rate: Some(0.5),
            exec_mode: exec,
            ..Default::default()
        };
        let model = registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
        let direct = model.score(&ctx, &ld.dataset).unwrap();
        let bytes = model.to_artifact().unwrap().to_bytes();
        let loaded = registry::load_bytes(&bytes).unwrap();
        assert_eq!(loaded.name(), "sparx");
        let rescored = loaded.score(&ctx, &ld.dataset).unwrap();
        assert_eq!(direct, rescored, "sparx[{}] round trip must be bit-identical", exec.tag());
        // a loaded model opens the §3.5 stream front-end too
        assert!(loaded.stream_scorer(16).is_ok());
    }
    for name in ["xstream", "spif", "dbscout"] {
        let ctx = local(4);
        let ld = small_osm().generate(&ctx).unwrap();
        let spec = DetectorSpec {
            k: Some(8),
            components: Some(6),
            depth: Some(5),
            sample_rate: Some(0.5),
            eps: Some(1.0),
            min_pts: Some(4),
            ..Default::default()
        };
        let model = registry::build(name, &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
        let direct = model.score(&ctx, &ld.dataset).unwrap();
        let loaded = registry::load_bytes(&model.to_artifact().unwrap().to_bytes()).unwrap();
        assert_eq!(loaded.name(), name);
        let rescored = loaded.score(&ctx, &ld.dataset).unwrap();
        assert_eq!(direct, rescored, "{name} round trip must be bit-identical");
    }
}

/// The footprint we report must be the footprint we ship: for every
/// registered detector, `model_bytes()` equals the artifact payload
/// length — before framing, after framing, and after a reload.
#[test]
fn model_bytes_is_the_shipped_artifact_payload_length() {
    for name in registry::detector_names() {
        let ctx = local(2);
        let ld = small_osm().generate(&ctx).unwrap();
        let spec = DetectorSpec {
            k: Some(8),
            components: Some(4),
            depth: Some(4),
            sample_rate: Some(0.5),
            eps: Some(1.0),
            min_pts: Some(4),
            ..Default::default()
        };
        let model = registry::build(name, &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
        let art = model.to_artifact().unwrap();
        assert!(model.model_bytes() > 0, "{name}: footprint must be non-zero");
        assert_eq!(
            model.model_bytes(),
            art.payload.len(),
            "{name}: reported footprint must equal the shipped payload"
        );
        let loaded = registry::load_bytes(&art.to_bytes()).unwrap();
        assert_eq!(
            loaded.model_bytes(),
            art.payload.len(),
            "{name}: loaded model must report the same footprint"
        );
    }
}

#[test]
fn corrupt_truncated_and_wrong_version_artifacts_fail_typed() {
    let ctx = local(2);
    let ld = small_osm().generate(&ctx).unwrap();
    let spec = DetectorSpec { eps: Some(1.0), min_pts: Some(4), ..Default::default() };
    let model = registry::build("dbscout", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
    let art = model.to_artifact().unwrap();
    let bytes = art.to_bytes();
    // truncated
    let r = registry::load_bytes(&bytes[..bytes.len() - 3]);
    assert!(matches!(r, Err(SparxError::MissingArtifact(_))), "truncated: {:?}", r.err());
    // bit flip anywhere → checksum catches it
    let mut corrupt = bytes.clone();
    let mid = corrupt.len() / 2;
    corrupt[mid] ^= 0x10;
    let r = registry::load_bytes(&corrupt);
    assert!(
        matches!(r, Err(SparxError::MissingArtifact(_))),
        "corrupt: {:?}",
        r.as_ref().err()
    );
    assert_eq!(r.unwrap_err().exit_code(), 1, "artifact damage is a runtime failure");
    // not an artifact at all
    assert!(matches!(
        registry::load_bytes(b"definitely not a model"),
        Err(SparxError::MissingArtifact(_))
    ));
    // wrong format version
    let mut wrong = art.clone();
    wrong.version = 77;
    let r = registry::load_bytes(&wrong.to_bytes());
    assert!(matches!(r, Err(SparxError::MissingArtifact(_))), "version: {:?}", r.err());
    // intact framing, unknown detector name
    let alien = ModelArtifact::new("florp", Vec::new(), Vec::new());
    let r = registry::load_bytes(&alien.to_bytes());
    assert!(
        matches!(r, Err(SparxError::UnknownDetector(_))),
        "alien: {:?}",
        r.as_ref().err()
    );
    assert_eq!(r.unwrap_err().exit_code(), 2, "unknown detector is a usage failure");
}

/// A checksum-valid artifact whose blocks disagree (CRC-32 is
/// integrity, not authentication) must fail typed at load, not index
/// out of bounds at score time.
#[test]
fn inconsistent_artifact_blocks_fail_typed() {
    let ctx = local(2);
    let ld = GisetteGen { n: 150, d: 8, ..Default::default() }.generate(&ctx).unwrap();
    let spec = DetectorSpec {
        k: Some(4),
        components: Some(3),
        depth: Some(3),
        sample_rate: Some(1.0),
        ..Default::default()
    };
    let model = registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
    let art = model.to_artifact().unwrap();
    // bump the declared projection width k (first u64 of the param
    // block) without touching the payload: the file checksum is
    // recomputed by to_bytes, so only the cross-block check can catch it
    let mut tampered = art.clone();
    tampered.params[0] = tampered.params[0].wrapping_add(1); // k: 4 -> 5
    let r = registry::load_bytes(&tampered.to_bytes());
    assert!(
        matches!(r, Err(SparxError::InvalidParams(_))),
        "tampered k must fail typed: {:?}",
        r.as_ref().err()
    );
}

/// With the fit/score split, the scored dataset can differ from the
/// fitted one — mismatched dense widths must fail typed, not panic in
/// the projection.
#[test]
fn dense_dimension_mismatch_fails_typed_after_reload() {
    let ctx = local(2);
    let osm = small_osm().generate(&ctx).unwrap();
    let gisette = GisetteGen { n: 100, d: 8, ..Default::default() }.generate(&ctx).unwrap();
    // identity projector (k=0): raw 2-d features feed the chains directly
    let spec = DetectorSpec {
        k: Some(0),
        components: Some(4),
        depth: Some(4),
        sample_rate: Some(1.0),
        ..Default::default()
    };
    let model = registry::build("sparx", &spec).unwrap().fit(&ctx, &osm.dataset).unwrap();
    let loaded = registry::load_bytes(&model.to_artifact().unwrap().to_bytes()).unwrap();
    let r = loaded.score(&ctx, &gisette.dataset);
    assert!(matches!(r, Err(SparxError::InvalidParams(_))), "identity: {:?}", r.err());
    // hashing projector with a materialised 2-column dense schema
    let spec = DetectorSpec {
        k: Some(4),
        components: Some(4),
        depth: Some(4),
        ..Default::default()
    };
    let model = registry::build("xstream", &spec).unwrap().fit(&ctx, &osm.dataset).unwrap();
    let loaded = registry::load_bytes(&model.to_artifact().unwrap().to_bytes()).unwrap();
    let r = loaded.score(&ctx, &gisette.dataset);
    assert!(matches!(r, Err(SparxError::InvalidParams(_))), "xstream: {:?}", r.err());
}

#[test]
fn save_load_file_round_trip_and_missing_file_is_io() {
    let ctx = local(2);
    let ld = GisetteGen { n: 200, d: 8, ..Default::default() }.generate(&ctx).unwrap();
    let spec = DetectorSpec {
        k: Some(4),
        components: Some(3),
        depth: Some(3),
        sample_rate: Some(1.0),
        ..Default::default()
    };
    let model = registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
    let path = std::env::temp_dir().join(format!("sparx-api-test-{}.sparx", std::process::id()));
    let path = path.to_str().expect("utf-8 temp path").to_string();
    model.to_artifact().unwrap().save(&path).unwrap();
    let loaded = registry::load(&path).unwrap();
    assert_eq!(
        model.score(&ctx, &ld.dataset).unwrap(),
        loaded.score(&ctx, &ld.dataset).unwrap(),
        "file round trip must score identically"
    );
    std::fs::remove_file(&path).unwrap();
    assert!(matches!(registry::load(&path), Err(SparxError::Io(_))));
}

/// The ROADMAP "backend override at load" quick win: scores are
/// backend-identical, so a PJRT-tagged artifact must load under a
/// `Backend::Native` override and score **bit-identically** to the
/// original native model. Without the override the stored backend wins
/// — and in this build (no `pjrt` feature) that is a typed
/// `MissingArtifact`, which is exactly the situation the override
/// exists to rescue.
#[cfg(not(feature = "pjrt"))]
#[test]
fn backend_override_loads_pjrt_tagged_artifacts_and_scores_identically() {
    use sparx::api::Backend;
    let ctx = local(2);
    let ld = GisetteGen { n: 300, d: 16, ..Default::default() }.generate(&ctx).unwrap();
    let spec = DetectorSpec {
        k: Some(8),
        components: Some(4),
        depth: Some(4),
        sample_rate: Some(1.0),
        ..Default::default()
    };
    let model = registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
    let want = model.score(&ctx, &ld.dataset).unwrap();
    let mut art = model.to_artifact().unwrap();
    // rewrite the stored backend to PJRT/"gisette". Param block layout:
    // sparx hyperparameters, then the backend u8, then the
    // u32-length-prefixed variant string ("" for native), so the native
    // tail is exactly 5 bytes: tag at len-5, then the zero length.
    let n = art.params.len();
    assert_eq!(art.params[n - 5], 0, "expected the native backend tag at params[len-5]");
    let mut tampered = art.params[..n - 5].to_vec();
    tampered.push(1); // backend tag: PJRT
    tampered.extend_from_slice(&7u32.to_le_bytes());
    tampered.extend_from_slice(b"gisette");
    art.params = tampered;
    let bytes = art.to_bytes();
    // stored backend wins without an override → needs the PJRT engine
    assert!(matches!(
        registry::load_bytes(&bytes),
        Err(SparxError::MissingArtifact(_))
    ));
    // …but the native override loads it and scores bit-identically
    let loaded = registry::load_bytes_with_backend(&bytes, Some(Backend::Native)).unwrap();
    assert_eq!(loaded.score(&ctx, &ld.dataset).unwrap(), want, "override must not move scores");
    // a native override on a native artifact is a no-op
    let native_bytes = model.to_artifact().unwrap().to_bytes();
    let renative = registry::load_bytes_with_backend(&native_bytes, Some(Backend::Native));
    assert_eq!(renative.unwrap().score(&ctx, &ld.dataset).unwrap(), want);
    // the override is sparx-only: other detectors reject it typed
    let xspec = DetectorSpec { components: Some(4), depth: Some(4), ..Default::default() };
    let xmodel = registry::build("xstream", &xspec).unwrap().fit(&ctx, &ld.dataset).unwrap();
    let xbytes = xmodel.to_artifact().unwrap().to_bytes();
    let r = registry::load_bytes_with_backend(&xbytes, Some(Backend::Native));
    assert!(matches!(r, Err(SparxError::Unsupported(_))), "{:?}", r.err());
    // and the reverse direction is shape-unsafe: a native artifact
    // stores no AOT variant, so forcing pjrt is rejected typed rather
    // than guessing which compiled tile shapes to run
    let native_again = model.to_artifact().unwrap().to_bytes();
    let r = registry::load_bytes_with_backend(&native_again, Some(Backend::Pjrt));
    assert!(matches!(r, Err(SparxError::Unsupported(_))), "{:?}", r.err());
}

#[test]
fn seeded_runs_reproduce_and_seeds_differentiate() {
    let ctx = local(4);
    let ld = GisetteGen { n: 400, d: 16, ..Default::default() }.generate(&ctx).unwrap();
    let spec = |seed| DetectorSpec {
        k: Some(8),
        components: Some(6),
        depth: Some(5),
        seed: Some(seed),
        ..Default::default()
    };
    let run = |s: u64| {
        registry::build("sparx", &spec(s))
            .unwrap()
            .fit(&ctx, &ld.dataset)
            .unwrap()
            .score(&ctx, &ld.dataset)
            .unwrap()
    };
    assert_eq!(run(7), run(7), "same seed must reproduce bit for bit");
    assert_ne!(run(7), run(8), "different seeds must sample different ensembles");
}
