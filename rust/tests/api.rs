//! Integration tests of the unified detector API (the ISSUE 2 acceptance
//! criteria): the registry-driven Sparx run is bit-identical to the
//! direct `SparxModel::fit` path, invalid hyperparameters surface as
//! typed `SparxError::InvalidParams` instead of panicking, and every
//! registered detector returns exactly one aligned score per point.

use sparx::api::{
    registry, Detector as _, DetectorSpec, FittedModel as _, SparxBuilder, SparxError,
};
use sparx::baselines::dbscout::{Dbscout, DbscoutParams};
use sparx::baselines::{Spif, SpifParams, XStream, XStreamParams};
use sparx::cluster::ClusterConfig;
use sparx::data::generators::{GisetteGen, OsmGen, SpamUrlGen};
use sparx::sparx::{SparxModel, SparxParams};

fn local(parts: usize) -> sparx::ClusterContext {
    ClusterConfig { num_partitions: parts, num_workers: 4, num_threads: 4, ..Default::default() }
        .build()
}

fn small_osm() -> OsmGen {
    OsmGen { n_inliers: 1500, n_outliers: 15, roads: 8, cities: 3, ..Default::default() }
}

#[test]
fn registry_sparx_is_bit_identical_to_direct_path() {
    let ctx = local(4);
    let ld = GisetteGen { n: 600, d: 32, ..Default::default() }.generate(&ctx).unwrap();
    let p = SparxParams { k: 12, num_chains: 8, depth: 6, sample_rate: 0.5, ..Default::default() };
    // the pre-redesign path: fit + score on the model directly
    let direct_model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
    let direct = direct_model.score_dataset(&ctx, &ld.dataset).unwrap();
    // the registry-driven path the CLI uses
    let spec = DetectorSpec {
        k: Some(p.k),
        components: Some(p.num_chains),
        depth: Some(p.depth),
        sample_rate: Some(p.sample_rate),
        ..Default::default()
    };
    let det = registry::build("sparx", &spec).unwrap();
    let via_registry =
        det.fit(&ctx, &ld.dataset).unwrap().score(&ctx, &ld.dataset).unwrap();
    assert_eq!(direct, via_registry, "registry run must be bit-identical to the direct path");
    // and the typed-builder path
    let built = SparxBuilder::new().params(p).build().unwrap();
    let via_builder =
        built.fit(&ctx, &ld.dataset).unwrap().score(&ctx, &ld.dataset).unwrap();
    assert_eq!(direct, via_builder, "builder run must be bit-identical to the direct path");
}

#[test]
fn baseline_detectors_match_their_direct_paths() {
    let ctx = local(4);
    let ld = small_osm().generate(&ctx).unwrap();

    // xstream: direct sequential reference vs the Detector adapter
    let rows = ld.dataset.rows.collect(&ctx).unwrap();
    let xp = XStreamParams { k: 8, num_chains: 6, depth: 5, ..Default::default() };
    let direct = XStream::fit(&rows, &ld.dataset.schema.names, &xp).score(&rows);
    let spec = DetectorSpec {
        k: Some(8),
        components: Some(6),
        depth: Some(5),
        ..Default::default()
    };
    let api = registry::build("xstream", &spec)
        .unwrap()
        .fit(&ctx, &ld.dataset)
        .unwrap()
        .score(&ctx, &ld.dataset)
        .unwrap();
    assert_eq!(direct, api, "xstream adapter diverges from the direct path");

    // spif
    let sp = SpifParams { num_trees: 6, max_depth: 6, sample_rate: 0.5, ..Default::default() };
    let direct =
        Spif::fit(&ctx, &ld.dataset, &sp).unwrap().score_dataset(&ctx, &ld.dataset).unwrap();
    let spec = DetectorSpec {
        components: Some(6),
        depth: Some(6),
        sample_rate: Some(0.5),
        ..Default::default()
    };
    let api = registry::build("spif", &spec)
        .unwrap()
        .fit(&ctx, &ld.dataset)
        .unwrap()
        .score(&ctx, &ld.dataset)
        .unwrap();
    assert_eq!(direct, api, "spif adapter diverges from the direct path");

    // dbscout: binary verdicts surface as 1.0 / 0.0
    let dp = DbscoutParams { eps: 1.0, min_pts: 4, ..Default::default() };
    let verdict = Dbscout::run(&ctx, &ld.dataset, &dp).unwrap();
    let direct: Vec<(u64, f64)> = verdict
        .pred
        .iter()
        .map(|&(id, o)| (id, if o { 1.0 } else { 0.0 }))
        .collect();
    let spec = DetectorSpec { eps: Some(1.0), min_pts: Some(4), ..Default::default() };
    let api = registry::build("dbscout", &spec)
        .unwrap()
        .fit(&ctx, &ld.dataset)
        .unwrap()
        .score(&ctx, &ld.dataset)
        .unwrap();
    assert_eq!(direct, api, "dbscout adapter diverges from the direct path");
}

#[test]
fn every_registered_detector_scores_every_point() {
    for name in registry::detector_names() {
        let ctx = local(4);
        let ld = small_osm().generate(&ctx).unwrap();
        let spec = DetectorSpec {
            k: Some(8),
            components: Some(8),
            depth: Some(5),
            sample_rate: Some(0.5),
            eps: Some(1.0),
            min_pts: Some(4),
            ..Default::default()
        };
        let det = registry::build(name, &spec).unwrap();
        let model = det.fit(&ctx, &ld.dataset).unwrap();
        assert_eq!(model.name(), name);
        let scores = model.score(&ctx, &ld.dataset).unwrap();
        assert_eq!(scores.len(), ld.dataset.len(), "{name} must score every point");
        let mut seen = vec![false; ld.dataset.len()];
        for &(id, s) in &scores {
            assert!(s.is_finite(), "{name}: non-finite score for id {id}");
            assert!(!seen[id as usize], "{name}: duplicate score for id {id}");
            seen[id as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "{name}: some ids never scored");
    }
}

#[test]
fn invalid_params_are_typed_errors_not_panics() {
    // the satellite cases: depth=0, cms_rows=0, sample_rate>1
    for (what, res) in [
        ("depth=0", SparxBuilder::new().depth(0).build().map(|_| ())),
        ("cms_rows=0", SparxBuilder::new().cms(0, 100).build().map(|_| ())),
        ("sample_rate>1", SparxBuilder::new().sample_rate(1.5).build().map(|_| ())),
    ] {
        assert!(
            matches!(res, Err(SparxError::InvalidParams(_))),
            "{what} must be InvalidParams, got {:?}",
            res.err()
        );
    }
    // the raw library entry point also fails typed (no deep panic)
    let ctx = local(2);
    let ld = GisetteGen { n: 200, d: 8, ..Default::default() }.generate(&ctx).unwrap();
    let p = SparxParams { depth: 0, ..Default::default() };
    assert!(matches!(
        SparxModel::fit(&ctx, &ld.dataset, &p),
        Err(sparx::ClusterError::Invalid(_))
    ));
}

#[test]
fn unknown_detector_suggests_the_right_name() {
    let e = registry::build("sparks", &DetectorSpec::default()).unwrap_err();
    assert_eq!(e.exit_code(), 2);
    match e {
        SparxError::UnknownDetector(msg) => assert!(msg.contains("sparx"), "{msg}"),
        other => panic!("expected UnknownDetector, got {other:?}"),
    }
}

#[test]
fn stream_scorer_supported_only_by_sparx() {
    let ctx = local(2);
    let ld = GisetteGen { n: 300, d: 16, ..Default::default() }.generate(&ctx).unwrap();
    let spec = DetectorSpec {
        k: Some(8),
        components: Some(4),
        depth: Some(4),
        sample_rate: Some(0.5),
        ..Default::default()
    };
    let sparx_model =
        registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
    assert!(sparx_model.stream_scorer(64).is_ok());
    let spif_model = registry::build("spif", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
    assert!(matches!(spif_model.stream_scorer(64), Err(SparxError::Unsupported(_))));
}

#[test]
fn dense_only_baselines_reject_sparse_input() {
    let ctx = local(2);
    let ld = SpamUrlGen { n: 300, d: 5000, mean_nnz: 20, ..Default::default() }
        .generate(&ctx)
        .unwrap();
    let spec = DetectorSpec {
        components: Some(4),
        sample_rate: Some(0.5),
        eps: Some(1.0),
        min_pts: Some(4),
        ..Default::default()
    };
    for name in ["spif", "dbscout"] {
        let r = registry::build(name, &spec).unwrap().fit(&ctx, &ld.dataset);
        assert!(
            matches!(r, Err(SparxError::Unsupported(_))),
            "{name} must reject sparse rows with a typed error, got {:?}",
            r.err().map(|e| e.to_string())
        );
    }
}

#[test]
fn seeded_runs_reproduce_and_seeds_differentiate() {
    let ctx = local(4);
    let ld = GisetteGen { n: 400, d: 16, ..Default::default() }.generate(&ctx).unwrap();
    let spec = |seed| DetectorSpec {
        k: Some(8),
        components: Some(6),
        depth: Some(5),
        seed: Some(seed),
        ..Default::default()
    };
    let run = |s: u64| {
        registry::build("sparx", &spec(s))
            .unwrap()
            .fit(&ctx, &ld.dataset)
            .unwrap()
            .score(&ctx, &ld.dataset)
            .unwrap()
    };
    assert_eq!(run(7), run(7), "same seed must reproduce bit for bit");
    assert_ne!(run(7), run(8), "different seeds must sample different ensembles");
}
