//! End-to-end integration tests: the full pipeline over every dataset
//! regime, backend equality through the AOT artifacts, and failure
//! injection against the cluster budgets.

use sparx::baselines::dbscout::{Dbscout, DbscoutParams};
use sparx::baselines::{Spif, SpifParams, XStream, XStreamParams};
use sparx::cluster::{ClusterConfig, ClusterError, DistVec};
use sparx::config::presets;
use sparx::data::generators::{GisetteGen, OsmGen, SpamUrlGen};
use sparx::experiments::align_scores;
use sparx::metrics::{auroc, f1_binary, RankMetrics};
use sparx::sparx::{project_dataset, SparxModel, SparxParams};

fn local(parts: usize) -> sparx::ClusterContext {
    ClusterConfig { num_partitions: parts, num_workers: 4, num_threads: 4, ..Default::default() }
        .build()
}

#[test]
fn gisette_regime_end_to_end() {
    let ctx = local(8);
    let ld = GisetteGen { n: 2000, d: 128, ..Default::default() }.generate(&ctx).unwrap();
    let p =
        SparxParams { k: 25, num_chains: 25, depth: 10, sample_rate: 0.5, ..Default::default() };
    let model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
    let scores = model.score_dataset(&ctx, &ld.dataset).unwrap();
    let m = RankMetrics::compute(&align_scores(&scores, ld.labels.len()), &ld.labels);
    assert!(m.auroc > 0.6, "gisette AUROC {}", m.auroc);
    assert!(m.auprc > ld.outlier_rate(), "AUPRC below prevalence");
}

#[test]
fn osm_regime_end_to_end_no_projection() {
    let ctx = local(8);
    let ld = OsmGen {
        n_inliers: 30_000,
        n_outliers: 60,
        roads: 40,
        cities: 10,
        ..Default::default()
    }
    .generate(&ctx)
    .unwrap();
    let p = SparxParams { k: 0, num_chains: 10, depth: 10, sample_rate: 0.1, ..Default::default() };
    let model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
    let scores = model.score_dataset(&ctx, &ld.dataset).unwrap();
    let m = RankMetrics::compute(&align_scores(&scores, ld.labels.len()), &ld.labels);
    // isolated injected outliers in empty cells are easy for density OD
    assert!(m.auroc > 0.9, "osm AUROC {}", m.auroc);
}

#[test]
fn spamurl_regime_end_to_end_sparse() {
    let ctx = local(8);
    let ld = SpamUrlGen { n: 3000, d: 50_000, mean_nnz: 60, ..Default::default() }
        .generate(&ctx)
        .unwrap();
    let p =
        SparxParams { k: 50, num_chains: 20, depth: 10, sample_rate: 0.5, ..Default::default() };
    let model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
    let scores = model.score_dataset(&ctx, &ld.dataset).unwrap();
    let m = RankMetrics::compute(&align_scores(&scores, ld.labels.len()), &ld.labels);
    assert!(m.auroc > 0.55, "spamurl AUROC {}", m.auroc);
}

#[test]
fn scores_invariant_to_partitioning_at_full_rate() {
    // at sample_rate=1 the distributed result must not depend on how the
    // data is partitioned (data-parallel correctness)
    let p = SparxParams { k: 12, num_chains: 8, depth: 6, sample_rate: 1.0, ..Default::default() };
    // one fixed dataset, repartitioned three ways (the generators are
    // partition-local, so the raw rows must be shared explicitly)
    let base = local(4);
    let ld = GisetteGen { n: 600, d: 32, ..Default::default() }.generate(&base).unwrap();
    let rows = ld.dataset.rows.collect(&base).unwrap();
    let mut all = Vec::new();
    for parts in [2usize, 7, 16] {
        let ctx = local(parts);
        let dv = DistVec::from_vec(&ctx, rows.clone()).unwrap();
        let ds = sparx::data::Dataset::new(
            sparx::data::Schema::positional(32),
            dv,
        );
        let model = SparxModel::fit(&ctx, &ds, &p).unwrap();
        let mut scores = model.score_dataset(&ctx, &ds).unwrap();
        scores.sort_by_key(|(id, _)| *id);
        all.push(scores);
    }
    assert_eq!(all[0], all[1], "2 vs 7 partitions diverge");
    assert_eq!(all[1], all[2], "7 vs 16 partitions diverge");
}

#[test]
fn pjrt_backend_end_to_end_equals_native() {
    let dir = sparx::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = sparx::runtime::PjrtEngine::start_default().unwrap();
    let ctx = local(4);
    // gisette artifact is compiled for K=50 L=20
    let ld = GisetteGen { n: 1000, d: 512, ..Default::default() }.generate(&ctx).unwrap();
    let p = SparxParams { k: 50, num_chains: 6, depth: 20, sample_rate: 1.0, ..Default::default() };
    let native_model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
    let binner = sparx::runtime::PjrtBinner { engine: &engine, variant: "gisette".into() };
    let pjrt_model = SparxModel::fit_with(&ctx, &ld.dataset, &p, &binner).unwrap();
    // identical CMS counts → identical fitted state
    for (a, b) in native_model.chains.iter().zip(&pjrt_model.chains) {
        assert_eq!(a.params, b.params);
        let mismatched = a.cms.iter().zip(&b.cms).filter(|(x, y)| x != y).count();
        assert!(mismatched <= 1, "fitted CMS diverge in {mismatched} levels");
    }
    // scores agree through either scoring backend
    let proj = project_dataset(&ctx, &ld.dataset, &native_model.projector).unwrap();
    let ns = native_model.score_sketches(&ctx, &proj).unwrap();
    let ps = native_model.score_sketches_with(&ctx, &proj, &binner).unwrap();
    let max_dev = ns
        .iter()
        .zip(&ps)
        .map(|((_, a), (_, b))| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(max_dev < 1e-9, "score deviation {max_dev}");
}

#[test]
fn sparx_and_xstream_agree_and_spif_detects() {
    let ctx = local(4);
    let ld = GisetteGen { n: 800, d: 64, ..Default::default() }.generate(&ctx).unwrap();
    let rows = ld.dataset.rows.collect(&ctx).unwrap();
    // xStream (single machine)
    let xs = XStream::fit(
        &rows,
        &ld.dataset.schema.names,
        &XStreamParams { k: 16, num_chains: 10, depth: 8, ..Default::default() },
    );
    let xscores: Vec<f64> = {
        let mut v = vec![0.0; rows.len()];
        for (id, s) in xs.score(&rows) {
            v[id as usize] = s;
        }
        v
    };
    assert!(auroc(&xscores, &ld.labels) > 0.55);
    // SPIF
    let spif = Spif::fit(
        &ctx,
        &ld.dataset,
        &SpifParams { num_trees: 25, max_depth: 10, sample_rate: 0.5, ..Default::default() },
    )
    .unwrap();
    let sscores = align_scores(&spif.score_dataset(&ctx, &ld.dataset).unwrap(), rows.len());
    assert!(auroc(&sscores, &ld.labels) > 0.5);
}

#[test]
fn dbscout_f1_reasonable_on_osm_like() {
    let ctx = local(8);
    let ld = OsmGen {
        n_inliers: 20_000,
        n_outliers: 40,
        roads: 30,
        cities: 8,
        ..Default::default()
    }
    .generate(&ctx)
    .unwrap();
    // eps via the paper's elbow heuristic — a fixed eps is meaningless
    // across densities
    let eps = Dbscout::choose_eps(&ctx, &ld.dataset, 8, 400).unwrap();
    let v = Dbscout::run(
        &ctx,
        &ld.dataset,
        &DbscoutParams { eps, min_pts: 8, ..Default::default() },
    )
    .unwrap();
    let mut pred = vec![false; ld.labels.len()];
    for (id, o) in v.pred {
        pred[id as usize] = o;
    }
    let f1 = f1_binary(&pred, &ld.labels);
    assert!(f1 > 0.3, "DBSCOUT F1 on its home turf: {f1}");
}

#[test]
fn deadline_failure_injection_mid_job() {
    let ctx = ClusterConfig {
        num_partitions: 8,
        num_workers: 2,
        num_threads: 2,
        deadline_secs: Some(0.0), // everything is too late
        ..Default::default()
    }
    .build();
    // generation uses pool paths that don't check the deadline, but fit must die
    let ld = GisetteGen { n: 500, d: 16, ..Default::default() }.generate(&ctx).unwrap();
    let r = SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 8, num_chains: 4, depth: 4, ..Default::default() },
    );
    assert!(matches!(r, Err(ClusterError::DeadlineExceeded { .. })));
}

#[test]
fn driver_budget_failure_injection() {
    let ctx = ClusterConfig {
        num_partitions: 4,
        num_workers: 2,
        num_threads: 2,
        driver_mem_bytes: 1024, // driver can't hold the collected CMS maps
        ..Default::default()
    }
    .build();
    let ld = GisetteGen { n: 500, d: 16, ..Default::default() }.generate(&ctx).unwrap();
    let r = SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 8, num_chains: 4, depth: 4, ..Default::default() },
    );
    assert!(matches!(
        r,
        Err(ClusterError::DriverMemExceeded { .. }) | Err(ClusterError::MemExceeded { .. })
    ));
}

#[test]
fn presets_run_the_pipeline() {
    for preset in [presets::config_mod(), presets::config_gen()] {
        let ctx = preset.build();
        let ld = GisetteGen { n: 400, d: 32, ..Default::default() }.generate(&ctx).unwrap();
        let p = SparxParams { k: 8, num_chains: 4, depth: 4, ..Default::default() };
        let model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
        assert_eq!(model.score_dataset(&ctx, &ld.dataset).unwrap().len(), 400);
    }
}

#[test]
fn csv_roundtrip_through_detection() {
    let dir = std::env::temp_dir().join("sparx_it_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("data.csv");
    // write a small labeled dataset, reload it, detect on it
    let ctx = local(4);
    let ld = GisetteGen { n: 300, d: 8, ..Default::default() }.generate(&ctx).unwrap();
    let rows = ld.dataset.rows.collect(&ctx).unwrap();
    use std::io::Write;
    let mut f = std::fs::File::create(&path).unwrap();
    writeln!(f, "{},label", ld.dataset.schema.names.join(",")).unwrap();
    for r in &rows {
        let cells: Vec<String> =
            r.features.as_dense().iter().map(|x| x.to_string()).collect();
        writeln!(f, "{},{}", cells.join(","), u8::from(ld.labels[r.id as usize])).unwrap();
    }
    drop(f);
    let reloaded = sparx::data::loader::load_csv(&ctx, &path, Some(8)).unwrap();
    assert_eq!(reloaded.dataset.len(), 300);
    assert_eq!(reloaded.labels, ld.labels);
    let p = SparxParams { k: 8, num_chains: 6, depth: 5, ..Default::default() };
    let model = SparxModel::fit(&ctx, &reloaded.dataset, &p).unwrap();
    assert_eq!(model.score_dataset(&ctx, &reloaded.dataset).unwrap().len(), 300);
}
