//! End-to-end CLI tests: spawn the built `sparx` binary and assert the
//! documented exit codes — `0` success, `2` usage/validation, `1`
//! runtime — for the serve-input grammar (malformed triples, `old->new`
//! substitutions, `#` comments, empty files), the `--backend` override
//! at load, the sharded serve path, and the TCP ingress
//! (`serve --listen`): wire-grammar errors, oversized lines,
//! half-closed sockets, interleaved clients, and kill→`--resume` with
//! score logs bit-identical to the stdin path throughout.

use std::io::{BufRead as _, BufReader, Read as _, Write as _};
use std::net::TcpStream;
use std::process::{Command, Stdio};
use std::sync::OnceLock;

use sparx::api::{registry, Detector as _, DetectorSpec, FittedModel as _};
use sparx::cluster::ClusterConfig;
use sparx::data::generators::GisetteGen;

/// Run the CLI with `args` (and optional stdin), returning
/// (exit code, stdout, stderr).
fn run_sparx(args: &[&str], stdin: Option<&str>) -> (i32, String, String) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sparx"));
    cmd.args(args).stdout(Stdio::piped()).stderr(Stdio::piped());
    cmd.stdin(if stdin.is_some() { Stdio::piped() } else { Stdio::null() });
    let mut child = cmd.spawn().expect("spawn the sparx binary");
    if let Some(input) = stdin {
        let mut pipe = child.stdin.take().expect("stdin was piped");
        pipe.write_all(input.as_bytes()).expect("write stdin");
        // pipe drops here → EOF for the child
    }
    let out = child.wait_with_output().expect("wait for sparx");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// Fit one small sparx model per test process and save its artifact;
/// every test serves/scores this file. The Gisette shape (d=512) matches
/// what `--dataset gisette` generates, so `sparx score` round trips.
fn model_path() -> &'static str {
    static PATH: OnceLock<String> = OnceLock::new();
    PATH.get_or_init(|| {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 200, d: 512, ..Default::default() }.generate(&ctx).unwrap();
        let spec = DetectorSpec {
            k: Some(8),
            components: Some(4),
            depth: Some(4),
            sample_rate: Some(1.0),
            ..Default::default()
        };
        let model = registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
        let path = std::env::temp_dir().join(format!("sparx-cli-{}.sparx", std::process::id()));
        let path = path.to_str().expect("utf-8 temp path").to_string();
        model.to_artifact().unwrap().save(&path).unwrap();
        path
    })
}

/// Write an updates file with unique name; returns its path.
fn write_updates(content: &str) -> String {
    static N: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);
    let n = N.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let path = std::env::temp_dir()
        .join(format!("sparx-cli-updates-{}-{n}.txt", std::process::id()));
    std::fs::write(&path, content).expect("write updates file");
    path.to_str().expect("utf-8 temp path").to_string()
}

/// `sparx serve` on the shared model reading the given updates file,
/// which is deleted afterwards (no temp-dir accumulation across runs).
fn run_serve_updates(file: &str) -> (i32, String, String) {
    let out = run_sparx(&["serve", "--model", model_path(), "--updates", file], None);
    let _ = std::fs::remove_file(file);
    out
}

// ------------------------------------------------- serve-input parsing

#[test]
fn serve_parses_comments_blanks_numeric_and_substitution_lines() {
    let file = write_updates("# hdr\n\n1 f0 1.5\n2 loc ->NYC\n2 loc NYC->Austin\n1 f1 -0.25\n");
    let args = ["serve", "--model", model_path(), "--updates", &file, "--shards", "1"];
    let (code, out, err) = run_sparx(&args, None);
    let _ = std::fs::remove_file(&file);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 4 δ-updates"), "{out}");
}

#[test]
fn serve_empty_update_file_is_a_no_op_success() {
    let file = write_updates("");
    let (code, out, err) = run_serve_updates(&file);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 0 δ-updates"), "{out}");
}

#[test]
fn serve_malformed_triple_is_usage_error_naming_the_line() {
    let file = write_updates("1 f0 1.0\n2 f0\n");
    let (code, _out, err) = run_serve_updates(&file);
    assert_eq!(code, 2, "stderr: {err}");
    assert!(err.contains("update line 2"), "{err}");
}

#[test]
fn serve_bad_id_bad_delta_and_empty_new_value_fail_typed() {
    for line in ["abc f0 1.0", "1 f0 north", "1 loc NYC->"] {
        let file = write_updates(&format!("{line}\n"));
        let (code, _out, err) = run_serve_updates(&file);
        assert_eq!(code, 2, "line {line:?} must exit 2; stderr: {err}");
        assert!(err.contains("update line 1"), "line {line:?}: {err}");
    }
}

#[test]
fn serve_reads_updates_from_stdin() {
    let args = ["serve", "--model", model_path(), "--updates", "-", "--shards", "2"];
    let (code, out, err) = run_sparx(&args, Some("1 f0 1.0\n2 f0 2.0\n3 f0 3.0\n"));
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 3 δ-updates"), "{out}");
}

#[test]
fn serve_count_alongside_an_updates_file_is_rejected() {
    let file = write_updates("1 f0 1.0\n");
    let args = ["serve", "--model", model_path(), "--updates", &file, "--count", "5"];
    let (code, _out, err) = run_sparx(&args, None);
    let _ = std::fs::remove_file(&file);
    assert_eq!(code, 2);
    assert!(err.contains("--count"), "{err}");
}

// ------------------------------------------------------- sharded serve

#[test]
fn serve_sharded_synthetic_stream_reports_per_shard_counters() {
    let args = ["serve", "--model", model_path(), "--count", "500", "--shards", "4"];
    let (code, out, err) = run_sparx(&args, None);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 500 δ-updates"), "{out}");
    assert!(out.contains("shard 0:"), "{out}");
    assert!(out.contains("shard 3:"), "{out}");
}

#[test]
fn serve_shards_zero_is_a_usage_error() {
    let args = ["serve", "--model", model_path(), "--count", "1", "--shards", "0"];
    let (code, _out, err) = run_sparx(&args, None);
    assert_eq!(code, 2);
    assert!(err.contains("--shards"), "{err}");
}

// ------------------------------- checkpoint / resume / reload lifecycle

/// Unique temp path for artifacts produced by these tests.
fn temp_file(tag: &str) -> String {
    static N: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);
    let n = N.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir()
        .join(format!("sparx-cli-{tag}-{}-{n}", std::process::id()))
        .to_str()
        .expect("utf-8 temp path")
        .to_string()
}

/// A deterministic update file in the serve line grammar; returns
/// (path, lines).
fn synth_updates_file(count: usize, seed: u64) -> (String, Vec<String>) {
    use sparx::data::StreamGen;
    let names: Vec<String> = (0..32).map(|j| format!("f{j}")).collect();
    let mut gen = StreamGen::new(200, names, seed);
    let lines: Vec<String> =
        (0..count).map(|_| gen.next_update().to_line().expect("synthetic update renders")).collect();
    let path = write_updates(&(lines.join("\n") + "\n"));
    (path, lines)
}

/// The acceptance criterion, end to end through the real binary: fit →
/// serve with periodic checkpoints → process exit ("kill") → `--resume`
/// → serve the rest, and the concatenated score logs diff clean against
/// an uninterrupted run — bit for bit, absorb mode on, order included.
#[test]
fn serve_checkpoint_kill_resume_reproduces_the_uninterrupted_score_log() {
    let (full_file, lines) = synth_updates_file(600, 0xE2E);
    let cut = 300;
    let first_file = write_updates(&(lines[..cut].join("\n") + "\n"));
    let rest_file = write_updates(&(lines[cut..].join("\n") + "\n"));
    let (full_log, p1_log, p2_log) =
        (temp_file("full.log"), temp_file("p1.log"), temp_file("p2.log"));
    let ckpt = temp_file("ck.sparx");

    // uninterrupted reference run
    let (code, _out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--updates", &full_file, "--shards", "3",
            "--cache", "64", "--absorb", "--score-log", &full_log,
        ],
        None,
    );
    assert_eq!(code, 0, "full run failed: {err}");
    // first half, checkpointing every 100 updates and at stream end,
    // then the process exits — that's the kill
    let (code, out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--updates", &first_file, "--shards", "3",
            "--cache", "64", "--absorb", "--checkpoint-out", &ckpt, "--checkpoint-every",
            "100", "--score-log", &p1_log,
        ],
        None,
    );
    assert_eq!(code, 0, "first half failed: {err}");
    assert!(out.contains("checkpoint written"), "{out}");
    // resume adopts --shards/--cache from the checkpoint
    let (code, out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--updates", &rest_file, "--resume", &ckpt,
            "--absorb", "--score-log", &p2_log,
        ],
        None,
    );
    assert_eq!(code, 0, "resumed half failed: {err}");
    assert!(out.contains("resumed from checkpoint"), "{out}");
    assert!(out.contains("600 total"), "lifetime counter must span the restart: {out}");

    let full = std::fs::read_to_string(&full_log).unwrap();
    let p1 = std::fs::read_to_string(&p1_log).unwrap();
    let p2 = std::fs::read_to_string(&p2_log).unwrap();
    assert_eq!(full.lines().count(), 600);
    assert_eq!(
        format!("{p1}{p2}"),
        full,
        "resumed score log must diff clean against the uninterrupted run"
    );
    for f in [full_file, first_file, rest_file, full_log, p1_log, p2_log, ckpt] {
        let _ = std::fs::remove_file(f);
    }
}

#[test]
fn serve_resume_accepts_layout_changes_and_rejects_model_confusion_typed() {
    let (file, _) = synth_updates_file(120, 7);
    let ckpt = temp_file("mismatch.sparx");
    let (code, _out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--updates", &file, "--shards", "2", "--cache",
            "32", "--checkpoint-out", &ckpt,
        ],
        None,
    );
    assert_eq!(code, 0, "checkpoint run failed: {err}");
    // the v4 checkpoint is layout-independent: a different shard count
    // or cache budget resumes fine and the lifetime counter carries over
    for extra in [["--shards", "5"], ["--cache", "99"]] {
        let (code, out, err) = run_sparx(
            &[
                "serve", "--model", model_path(), "--count", "10", "--resume", &ckpt,
                extra[0], extra[1],
            ],
            None,
        );
        assert_eq!(code, 0, "{extra:?} must resume from v4 on; stderr: {err}");
        assert!(out.contains("resumed from checkpoint"), "{out}");
        assert!(out.contains("130 total"), "lifetime counter must span the restart: {out}");
    }
    // an absorb-mode mismatch would silently diverge the continued
    // stream — still rejected typed (the capture ran absorb-off)
    let (code, _out, err) = run_sparx(
        &["serve", "--model", model_path(), "--count", "10", "--resume", &ckpt, "--absorb"],
        None,
    );
    assert_eq!(code, 2, "absorb mismatch must be a usage error; stderr: {err}");
    assert!(err.contains("absorb"), "{err}");
    // a checkpoint is not a model
    let (code, _out, err) =
        run_sparx(&["serve", "--model", &ckpt, "--count", "10"], None);
    assert_eq!(code, 2, "stderr: {err}");
    assert!(err.contains("--resume"), "must point at the right flag: {err}");
    // a model is not a checkpoint
    let (code, _out, err) = run_sparx(
        &["serve", "--model", model_path(), "--count", "10", "--resume", model_path()],
        None,
    );
    assert_eq!(code, 2, "stderr: {err}");
    let _ = std::fs::remove_file(&file);
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn serve_watch_and_checkpoint_flags_run_on_the_synthetic_stream() {
    let ckpt = temp_file("watch.sparx");
    let (code, out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--count", "400", "--shards", "2", "--cache",
            "64", "--watch", "--absorb", "--checkpoint-out", &ckpt,
        ],
        None,
    );
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("resident ensemble"), "{out}");
    assert!(out.contains("400 absorbed"), "{out}");
    assert!(out.contains("checkpoint written"), "{out}");
    // --checkpoint-every without --checkpoint-out is a usage error
    let (code, _out, err) = run_sparx(
        &["serve", "--model", model_path(), "--count", "10", "--checkpoint-every", "5"],
        None,
    );
    assert_eq!(code, 2);
    assert!(err.contains("checkpoint-out"), "{err}");
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn serve_score_log_to_stdout_is_machine_clean() {
    let args =
        ["serve", "--model", model_path(), "--count", "20", "--shards", "2", "--score-log", "-"];
    let (code, out, err) = run_sparx(&args, None);
    assert_eq!(code, 0, "stderr: {err}");
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 20, "stdout must carry only the score log: {out:?}");
    for l in &lines {
        let mut it = l.split(' ');
        let id = it.next().unwrap_or("");
        let bits = it.next().unwrap_or("");
        assert!(it.next().is_none(), "line has extra fields: {l:?}");
        assert!(!id.is_empty() && id.chars().all(|c| c.is_ascii_digit()), "{l:?}");
        assert_eq!(bits.len(), 16, "{l:?}");
        assert!(bits.chars().all(|c| c.is_ascii_hexdigit()), "{l:?}");
    }
    assert!(err.contains("serving sparx model"), "human output must land on stderr: {err}");
}

#[test]
fn generate_stream_emits_lines_serve_accepts() {
    let out_file = temp_file("updates.txt");
    let (code, out, err) =
        run_sparx(&["generate", "--stream", "80", "--seed", "5", "--out", &out_file], None);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("80 update triples"), "{out}");
    let content = std::fs::read_to_string(&out_file).unwrap();
    assert_eq!(content.lines().count(), 80);
    for (i, line) in content.lines().enumerate() {
        let parsed = sparx::data::parse_update_line(i + 1, line).unwrap();
        assert!(parsed.is_some(), "line {i} must be a real update: {line:?}");
    }
    // and the real binary serves the file
    let (code, out, err) = run_sparx(
        &["serve", "--model", model_path(), "--updates", &out_file, "--shards", "2"],
        None,
    );
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 80 δ-updates"), "{out}");
    let _ = std::fs::remove_file(&out_file);
}

// ------------------------------------------------ backend override

/// `sparx score` on the shared model with a small generated batch and
/// the given `--backend` override.
fn run_score_with_backend(backend: &str) -> (i32, String, String) {
    let base = ["score", "--model", model_path(), "--dataset", "gisette", "--scale", "0.01"];
    let mut args: Vec<&str> = base.to_vec();
    args.extend(["--backend", backend]);
    run_sparx(&args, None)
}

#[test]
fn score_accepts_a_native_backend_override() {
    let (code, out, err) = run_score_with_backend("native");
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("backend overridden"), "{out}");
    assert!(out.contains("AUROC"), "{out}");
}

#[test]
fn score_pjrt_override_on_a_native_artifact_is_rejected_typed() {
    // a native artifact stores no AOT variant, so forcing pjrt cannot
    // know which compiled tile shapes to run — usage error, exit 2
    let (code, _out, err) = run_score_with_backend("pjrt");
    assert_eq!(code, 2, "stderr: {err}");
    assert!(err.contains("PJRT variant"), "{err}");
}

#[test]
fn score_unknown_backend_is_a_usage_error() {
    let (code, _out, err) = run_score_with_backend("cuda");
    assert_eq!(code, 2);
    assert!(err.contains("backend"), "{err}");
}

#[test]
fn serve_accepts_a_native_backend_override() {
    let args = ["serve", "--model", model_path(), "--count", "50", "--backend", "native"];
    let (code, out, err) = run_sparx(&args, None);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 50 δ-updates"), "{out}");
}

// ------------------------------------------ TCP ingress (serve --listen)

/// Spawn `sparx serve --listen 127.0.0.1:0 …` on the shared model,
/// parse the OS-assigned address from the `listening on` stderr line,
/// and keep draining stderr on a side thread so the child can never
/// block on a full pipe. The drain handle returns the remaining stderr.
fn spawn_listen(
    extra: &[&str],
) -> (std::process::Child, String, std::thread::JoinHandle<String>) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sparx"));
    cmd.args(["serve", "--model", model_path(), "--listen", "127.0.0.1:0"])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .stdin(Stdio::null());
    let mut child = cmd.spawn().expect("spawn sparx serve --listen");
    let stderr = child.stderr.take().expect("stderr was piped");
    let mut reader = BufReader::new(stderr);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read server stderr");
        assert!(n > 0, "server exited before announcing its address");
        if let Some(rest) = line.trim().strip_prefix("listening on ") {
            break rest.to_string();
        }
    };
    let drain = std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = reader.read_to_string(&mut rest);
        rest
    });
    (child, addr, drain)
}

/// One line-protocol exchange: write `payload`, half-close the send
/// side, then read every response line until the server closes the
/// socket. (A half-close without `QUIT` is itself the graceful way to
/// end a batch — the server still answers everything it accepted.)
fn tcp_exchange(addr: &str, payload: &str) -> Vec<String> {
    let mut sock = TcpStream::connect(addr).expect("connect to sparx serve");
    sock.write_all(payload.as_bytes()).expect("write request payload");
    sock.shutdown(std::net::Shutdown::Write).expect("half-close the send side");
    let mut out = String::new();
    BufReader::new(sock).read_to_string(&mut out).expect("read responses to EOF");
    out.lines().map(str::to_owned).collect()
}

/// The `(id, score-bits)` pairs among `lines` — update replies only
/// (`OK <id> <hex>`); control acknowledgements like `OK bye` and
/// `OK reshard 4` have a non-numeric second token and drop out.
fn ok_scores(lines: &[String]) -> Vec<(u64, String)> {
    lines
        .iter()
        .filter_map(|l| {
            let mut it = l.split(' ');
            match (it.next(), it.next(), it.next(), it.next()) {
                (Some("OK"), Some(id), Some(bits), None) => {
                    id.parse().ok().map(|id| (id, bits.to_string()))
                }
                _ => None,
            }
        })
        .collect()
}

/// Parse a `--score-log` file into the same `(id, score-bits)` pairs.
fn log_pairs(path: &str) -> Vec<(u64, String)> {
    std::fs::read_to_string(path)
        .expect("read score log")
        .lines()
        .map(|l| {
            let mut it = l.split(' ');
            let id = it.next().and_then(|t| t.parse().ok()).expect("score-log id");
            let bits = it.next().expect("score-log bits").to_string();
            (id, bits)
        })
        .collect()
}

/// Group reply pairs into per-ID score sequences (order preserved).
fn by_id(pairs: &[(u64, String)]) -> std::collections::HashMap<u64, Vec<String>> {
    let mut m: std::collections::HashMap<u64, Vec<String>> = std::collections::HashMap::new();
    for (id, bits) in pairs {
        m.entry(*id).or_default().push(bits.clone());
    }
    m
}

/// One client driving the full stream over TCP reproduces the stdin
/// path bit for bit — same submit order, so the same scores, under
/// eviction churn with absorb on — and the control verbs (`STATS`,
/// `METRICS`, `SCORE`, `QUIT`) answer in their documented shapes on
/// the same connection.
#[test]
fn serve_listen_single_client_is_bit_identical_to_the_stdin_path() {
    let (file, lines) = synth_updates_file(400, 0x7C9);
    let reference_log = temp_file("tcp-ref.log");
    let (code, _out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--updates", &file, "--shards", "3", "--cache",
            "64", "--absorb", "--score-log", &reference_log,
        ],
        None,
    );
    assert_eq!(code, 0, "reference run failed: {err}");
    let want = log_pairs(&reference_log);
    assert_eq!(want.len(), 400);

    let (mut child, addr, drain) =
        spawn_listen(&["--shards", "3", "--cache", "64", "--absorb"]);
    let last_id = want.last().expect("reference has scores").0;
    let payload =
        lines.join("\n") + &format!("\nSTATS\nMETRICS\nSCORE {last_id}\nQUIT\n");
    let replies = tcp_exchange(&addr, &payload);
    assert_eq!(
        ok_scores(&replies),
        want,
        "TCP path must be bit-identical to the stdin path"
    );
    let stats = replies.iter().find(|l| l.starts_with("STATS {")).expect("STATS reply");
    assert!(stats.contains("\"processed\":400"), "{stats}");
    assert!(stats.contains("\"resident_bytes\":"), "{stats}");
    assert!(replies.iter().any(|l| l == "sparx_processed_total 400"), "{replies:?}");
    assert!(replies.iter().any(|l| l == "# EOF"), "metrics dump must be EOF-terminated");
    assert!(
        replies.iter().any(|l| l.starts_with(&format!("SCORE {last_id} "))),
        "the just-updated ID must be resident: {replies:?}"
    );
    assert!(replies.iter().any(|l| l == "OK bye"), "{replies:?}");

    assert_eq!(tcp_exchange(&addr, "SHUTDOWN\n"), ["OK shutdown".to_string()]);
    assert!(child.wait().expect("server exit").success());
    drop(drain.join());
    let _ = std::fs::remove_file(&file);
    let _ = std::fs::remove_file(&reference_log);
}

/// Two clients submitting disjoint ID sets concurrently: global arrival
/// order is nondeterministic, but per-ID score sequences must equal the
/// stdin path's bit for bit (no-eviction regime, absorb off — exactly
/// the invariant the sharded scorer guarantees under re-interleaving).
#[test]
fn serve_listen_interleaved_clients_match_the_stdin_path_per_id() {
    let (file, lines) = synth_updates_file(300, 0xAB1);
    let reference_log = temp_file("tcp-interleave-ref.log");
    let (code, _out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--updates", &file, "--shards", "2", "--cache",
            "4096", "--score-log", &reference_log,
        ],
        None,
    );
    assert_eq!(code, 0, "reference run failed: {err}");
    let want = by_id(&log_pairs(&reference_log));

    let (mut child, addr, drain) = spawn_listen(&["--shards", "2", "--cache", "4096"]);
    let id_of = |line: &str| -> u64 {
        line.split(' ').next().and_then(|t| t.parse().ok()).expect("update line id")
    };
    let parts: Vec<Vec<String>> = (0..2)
        .map(|p| lines.iter().filter(|l| id_of(l) % 2 == p).cloned().collect())
        .collect();
    let handles: Vec<_> = parts
        .into_iter()
        .map(|part| {
            let addr = addr.clone();
            std::thread::spawn(move || tcp_exchange(&addr, &(part.join("\n") + "\nQUIT\n")))
        })
        .collect();
    let mut got: std::collections::HashMap<u64, Vec<String>> = std::collections::HashMap::new();
    let mut replies_total = 0usize;
    for h in handles {
        let replies = h.join().expect("client thread");
        let pairs = ok_scores(&replies);
        replies_total += pairs.len();
        for (id, seq) in by_id(&pairs) {
            got.insert(id, seq);
        }
    }
    assert_eq!(replies_total, 300, "every accepted update must be answered");
    assert_eq!(got, want, "per-ID sequences must match the stdin path bit for bit");

    assert_eq!(tcp_exchange(&addr, "SHUTDOWN\n"), ["OK shutdown".to_string()]);
    assert!(child.wait().expect("server exit").success());
    drop(drain.join());
    let _ = std::fs::remove_file(&file);
    let _ = std::fs::remove_file(&reference_log);
}

/// Wire-grammar failures answer typed `ERR` lines naming the offending
/// line — malformed verbs, degenerate reshards, oversized lines
/// (rejected, never truncated) — and the connection stays open for
/// well-formed requests afterwards.
#[test]
fn serve_listen_malformed_and_oversized_lines_fail_typed_and_keep_the_connection() {
    let (mut child, addr, drain) = spawn_listen(&[]);
    let long = "9".repeat(9000); // > MAX_LINE_BYTES, no inner newline
    let payload = format!("score 42\nRESHARD 0\n{long}\n# comment\n\n1 f0 0.5\n17 f1\nQUIT\n");
    let replies = tcp_exchange(&addr, &payload);

    let errs: Vec<&String> = replies.iter().filter(|l| l.starts_with("ERR ")).collect();
    assert_eq!(errs.len(), 4, "exactly the four bad lines answer ERR: {replies:?}");
    // verbs are case-sensitive: `score` falls through to the update
    // grammar and fails there, naming its line
    assert!(errs.iter().any(|e| e.contains("line 1")), "{errs:?}");
    assert!(
        errs.iter().any(|e| e.contains("request line 2") && e.contains("≥ 1")),
        "{errs:?}"
    );
    assert!(
        errs.iter().any(|e| e.contains("request line 3")
            && e.contains("exceeds 8192 bytes")
            && e.contains("rejected, not truncated")),
        "{errs:?}"
    );
    assert!(errs.iter().any(|e| e.contains("line 7")), "{errs:?}");
    // line 6 still scored: comments/blanks skipped, errors non-fatal
    let scored = ok_scores(&replies);
    assert_eq!(scored.len(), 1, "{replies:?}");
    assert_eq!(scored[0].0, 1);
    assert!(replies.iter().any(|l| l == "OK bye"), "{replies:?}");

    assert_eq!(tcp_exchange(&addr, "SHUTDOWN\n"), ["OK shutdown".to_string()]);
    assert!(child.wait().expect("server exit").success());
    drop(drain.join());
}

/// A half-closed socket ends a batch gracefully (every accepted update
/// is still answered), and an idle parked connection neither blocks
/// other clients nor dies — slow consumers stall only themselves.
#[test]
fn serve_listen_half_close_drains_replies_and_idle_peers_do_not_interfere() {
    let (mut child, addr, drain) = spawn_listen(&["--shards", "2"]);
    // park an idle connection first: it must not stall anyone
    let mut idle = TcpStream::connect(&addr).expect("connect idle client");

    let (file, lines) = synth_updates_file(50, 0x1D7E);
    let _ = std::fs::remove_file(&file);
    // no QUIT: the half-close inside tcp_exchange ends the batch
    let replies = tcp_exchange(&addr, &(lines.join("\n") + "\n"));
    assert_eq!(ok_scores(&replies).len(), 50, "every update answered after half-close");
    assert!(!replies.iter().any(|l| l.starts_with("ERR")), "{replies:?}");

    // the parked connection still speaks after the other client is gone
    idle.write_all(b"QUIT\n").expect("write on idle connection");
    let mut rest = String::new();
    BufReader::new(idle).read_to_string(&mut rest).expect("read idle replies");
    assert_eq!(rest, "OK bye\n");

    assert_eq!(tcp_exchange(&addr, "SHUTDOWN\n"), ["OK shutdown".to_string()]);
    assert!(child.wait().expect("server exit").success());
    drop(drain.join());
}

/// The elastic-serving acceptance path end to end over TCP: serve →
/// `CHECKPOINT` verb → SIGKILL → `--resume` (adopting the captured
/// layout) → `RESHARD` mid-stream — and the scores a client collects
/// across both incarnations are bit-identical to one uninterrupted
/// stdin run, eviction churn and absorb on throughout.
#[test]
fn serve_listen_checkpoint_kill_resume_and_reshard_reproduce_the_stdin_run() {
    let (file, lines) = synth_updates_file(600, 0x8E7A);
    let reference_log = temp_file("tcp-resume-ref.log");
    let (code, _out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--updates", &file, "--shards", "3", "--cache",
            "64", "--absorb", "--score-log", &reference_log,
        ],
        None,
    );
    assert_eq!(code, 0, "reference run failed: {err}");
    let want = log_pairs(&reference_log);
    assert_eq!(want.len(), 600);

    // incarnation 1: first half over TCP, checkpoint via the verb, then
    // SIGKILL — the hard-kill half of the lifecycle
    let ckpt = temp_file("tcp-resume.sparx");
    let (mut child, addr, drain) = spawn_listen(&[
        "--shards", "3", "--cache", "64", "--absorb", "--checkpoint-out", &ckpt,
    ]);
    let replies = tcp_exchange(&addr, &(lines[..300].join("\n") + "\nCHECKPOINT\n"));
    assert_eq!(ok_scores(&replies), want[..300], "first incarnation diverged");
    assert!(
        replies.iter().any(|l| l == "OK checkpoint 300"),
        "checkpoint must cover all 300 submits: {replies:?}"
    );
    child.kill().expect("kill the first server");
    let _ = child.wait();
    drop(drain.join());

    // incarnation 2: --resume adopts shards/cache/absorb from the
    // checkpoint; a live RESHARD 3→5 lands mid-stream, dropping nothing
    let (mut child, addr, drain) = spawn_listen(&["--resume", &ckpt]);
    let payload =
        lines[300..450].join("\n") + "\nRESHARD 5\n" + &lines[450..].join("\n") + "\nQUIT\n";
    let replies = tcp_exchange(&addr, &payload);
    assert_eq!(
        ok_scores(&replies),
        want[300..],
        "resumed + resharded incarnation diverged from the uninterrupted run"
    );
    assert!(replies.iter().any(|l| l == "OK reshard 5"), "{replies:?}");

    assert_eq!(tcp_exchange(&addr, "SHUTDOWN\n"), ["OK shutdown".to_string()]);
    assert!(child.wait().expect("server exit").success());
    drop(drain.join());
    for f in [file, reference_log, ckpt] {
        let _ = std::fs::remove_file(f);
    }
}

/// `--listen` replaces the file/synthetic stream and the between-update
/// polling hooks; combining it with flags that drive those is a usage
/// error, not a silent ignore.
#[test]
fn serve_listen_rejects_stream_driving_flags_typed() {
    for extra in [["--count", "5"], ["--updates", "some-file.txt"]] {
        let (code, _out, err) = run_sparx(
            &[
                "serve", "--model", model_path(), "--listen", "127.0.0.1:0", extra[0],
                extra[1],
            ],
            None,
        );
        assert_eq!(code, 2, "{extra:?} must be rejected with --listen; stderr: {err}");
        assert!(err.contains(extra[0]), "{err}");
        assert!(err.contains("--listen"), "{err}");
    }
}
