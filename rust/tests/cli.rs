//! End-to-end CLI tests: spawn the built `sparx` binary and assert the
//! documented exit codes — `0` success, `2` usage/validation, `1`
//! runtime — for the serve-input grammar (malformed triples, `old->new`
//! substitutions, `#` comments, empty files), the `--backend` override
//! at load, and the sharded serve path.

use std::io::Write as _;
use std::process::{Command, Stdio};
use std::sync::OnceLock;

use sparx::api::{registry, Detector as _, DetectorSpec, FittedModel as _};
use sparx::cluster::ClusterConfig;
use sparx::data::generators::GisetteGen;

/// Run the CLI with `args` (and optional stdin), returning
/// (exit code, stdout, stderr).
fn run_sparx(args: &[&str], stdin: Option<&str>) -> (i32, String, String) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sparx"));
    cmd.args(args).stdout(Stdio::piped()).stderr(Stdio::piped());
    cmd.stdin(if stdin.is_some() { Stdio::piped() } else { Stdio::null() });
    let mut child = cmd.spawn().expect("spawn the sparx binary");
    if let Some(input) = stdin {
        let mut pipe = child.stdin.take().expect("stdin was piped");
        pipe.write_all(input.as_bytes()).expect("write stdin");
        // pipe drops here → EOF for the child
    }
    let out = child.wait_with_output().expect("wait for sparx");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// Fit one small sparx model per test process and save its artifact;
/// every test serves/scores this file. The Gisette shape (d=512) matches
/// what `--dataset gisette` generates, so `sparx score` round trips.
fn model_path() -> &'static str {
    static PATH: OnceLock<String> = OnceLock::new();
    PATH.get_or_init(|| {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 200, d: 512, ..Default::default() }.generate(&ctx).unwrap();
        let spec = DetectorSpec {
            k: Some(8),
            components: Some(4),
            depth: Some(4),
            sample_rate: Some(1.0),
            ..Default::default()
        };
        let model = registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
        let path = std::env::temp_dir().join(format!("sparx-cli-{}.sparx", std::process::id()));
        let path = path.to_str().expect("utf-8 temp path").to_string();
        model.to_artifact().unwrap().save(&path).unwrap();
        path
    })
}

/// Write an updates file with unique name; returns its path.
fn write_updates(content: &str) -> String {
    static N: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);
    let n = N.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let path = std::env::temp_dir()
        .join(format!("sparx-cli-updates-{}-{n}.txt", std::process::id()));
    std::fs::write(&path, content).expect("write updates file");
    path.to_str().expect("utf-8 temp path").to_string()
}

/// `sparx serve` on the shared model reading the given updates file,
/// which is deleted afterwards (no temp-dir accumulation across runs).
fn run_serve_updates(file: &str) -> (i32, String, String) {
    let out = run_sparx(&["serve", "--model", model_path(), "--updates", file], None);
    let _ = std::fs::remove_file(file);
    out
}

// ------------------------------------------------- serve-input parsing

#[test]
fn serve_parses_comments_blanks_numeric_and_substitution_lines() {
    let file = write_updates("# hdr\n\n1 f0 1.5\n2 loc ->NYC\n2 loc NYC->Austin\n1 f1 -0.25\n");
    let args = ["serve", "--model", model_path(), "--updates", &file, "--shards", "1"];
    let (code, out, err) = run_sparx(&args, None);
    let _ = std::fs::remove_file(&file);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 4 δ-updates"), "{out}");
}

#[test]
fn serve_empty_update_file_is_a_no_op_success() {
    let file = write_updates("");
    let (code, out, err) = run_serve_updates(&file);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 0 δ-updates"), "{out}");
}

#[test]
fn serve_malformed_triple_is_usage_error_naming_the_line() {
    let file = write_updates("1 f0 1.0\n2 f0\n");
    let (code, _out, err) = run_serve_updates(&file);
    assert_eq!(code, 2, "stderr: {err}");
    assert!(err.contains("update line 2"), "{err}");
}

#[test]
fn serve_bad_id_bad_delta_and_empty_new_value_fail_typed() {
    for line in ["abc f0 1.0", "1 f0 north", "1 loc NYC->"] {
        let file = write_updates(&format!("{line}\n"));
        let (code, _out, err) = run_serve_updates(&file);
        assert_eq!(code, 2, "line {line:?} must exit 2; stderr: {err}");
        assert!(err.contains("update line 1"), "line {line:?}: {err}");
    }
}

#[test]
fn serve_reads_updates_from_stdin() {
    let args = ["serve", "--model", model_path(), "--updates", "-", "--shards", "2"];
    let (code, out, err) = run_sparx(&args, Some("1 f0 1.0\n2 f0 2.0\n3 f0 3.0\n"));
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 3 δ-updates"), "{out}");
}

#[test]
fn serve_count_alongside_an_updates_file_is_rejected() {
    let file = write_updates("1 f0 1.0\n");
    let args = ["serve", "--model", model_path(), "--updates", &file, "--count", "5"];
    let (code, _out, err) = run_sparx(&args, None);
    let _ = std::fs::remove_file(&file);
    assert_eq!(code, 2);
    assert!(err.contains("--count"), "{err}");
}

// ------------------------------------------------------- sharded serve

#[test]
fn serve_sharded_synthetic_stream_reports_per_shard_counters() {
    let args = ["serve", "--model", model_path(), "--count", "500", "--shards", "4"];
    let (code, out, err) = run_sparx(&args, None);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 500 δ-updates"), "{out}");
    assert!(out.contains("shard 0:"), "{out}");
    assert!(out.contains("shard 3:"), "{out}");
}

#[test]
fn serve_shards_zero_is_a_usage_error() {
    let args = ["serve", "--model", model_path(), "--count", "1", "--shards", "0"];
    let (code, _out, err) = run_sparx(&args, None);
    assert_eq!(code, 2);
    assert!(err.contains("--shards"), "{err}");
}

// ------------------------------- checkpoint / resume / reload lifecycle

/// Unique temp path for artifacts produced by these tests.
fn temp_file(tag: &str) -> String {
    static N: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);
    let n = N.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir()
        .join(format!("sparx-cli-{tag}-{}-{n}", std::process::id()))
        .to_str()
        .expect("utf-8 temp path")
        .to_string()
}

/// A deterministic update file in the serve line grammar; returns
/// (path, lines).
fn synth_updates_file(count: usize, seed: u64) -> (String, Vec<String>) {
    use sparx::data::StreamGen;
    let names: Vec<String> = (0..32).map(|j| format!("f{j}")).collect();
    let mut gen = StreamGen::new(200, names, seed);
    let lines: Vec<String> = (0..count).map(|_| gen.next_update().to_line()).collect();
    let path = write_updates(&(lines.join("\n") + "\n"));
    (path, lines)
}

/// The acceptance criterion, end to end through the real binary: fit →
/// serve with periodic checkpoints → process exit ("kill") → `--resume`
/// → serve the rest, and the concatenated score logs diff clean against
/// an uninterrupted run — bit for bit, absorb mode on, order included.
#[test]
fn serve_checkpoint_kill_resume_reproduces_the_uninterrupted_score_log() {
    let (full_file, lines) = synth_updates_file(600, 0xE2E);
    let cut = 300;
    let first_file = write_updates(&(lines[..cut].join("\n") + "\n"));
    let rest_file = write_updates(&(lines[cut..].join("\n") + "\n"));
    let (full_log, p1_log, p2_log) =
        (temp_file("full.log"), temp_file("p1.log"), temp_file("p2.log"));
    let ckpt = temp_file("ck.sparx");

    // uninterrupted reference run
    let (code, _out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--updates", &full_file, "--shards", "3",
            "--cache", "64", "--absorb", "--score-log", &full_log,
        ],
        None,
    );
    assert_eq!(code, 0, "full run failed: {err}");
    // first half, checkpointing every 100 updates and at stream end,
    // then the process exits — that's the kill
    let (code, out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--updates", &first_file, "--shards", "3",
            "--cache", "64", "--absorb", "--checkpoint-out", &ckpt, "--checkpoint-every",
            "100", "--score-log", &p1_log,
        ],
        None,
    );
    assert_eq!(code, 0, "first half failed: {err}");
    assert!(out.contains("checkpoint written"), "{out}");
    // resume adopts --shards/--cache from the checkpoint
    let (code, out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--updates", &rest_file, "--resume", &ckpt,
            "--absorb", "--score-log", &p2_log,
        ],
        None,
    );
    assert_eq!(code, 0, "resumed half failed: {err}");
    assert!(out.contains("resumed from checkpoint"), "{out}");
    assert!(out.contains("600 total"), "lifetime counter must span the restart: {out}");

    let full = std::fs::read_to_string(&full_log).unwrap();
    let p1 = std::fs::read_to_string(&p1_log).unwrap();
    let p2 = std::fs::read_to_string(&p2_log).unwrap();
    assert_eq!(full.lines().count(), 600);
    assert_eq!(
        format!("{p1}{p2}"),
        full,
        "resumed score log must diff clean against the uninterrupted run"
    );
    for f in [full_file, first_file, rest_file, full_log, p1_log, p2_log, ckpt] {
        let _ = std::fs::remove_file(f);
    }
}

#[test]
fn serve_resume_with_mismatched_layout_or_model_is_rejected_typed() {
    let (file, _) = synth_updates_file(120, 7);
    let ckpt = temp_file("mismatch.sparx");
    let (code, _out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--updates", &file, "--shards", "2", "--cache",
            "32", "--checkpoint-out", &ckpt,
        ],
        None,
    );
    assert_eq!(code, 0, "checkpoint run failed: {err}");
    // wrong shard count
    let (code, _out, err) = run_sparx(
        &["serve", "--model", model_path(), "--count", "10", "--resume", &ckpt, "--shards", "5"],
        None,
    );
    assert_eq!(code, 2, "shard mismatch must be a usage error; stderr: {err}");
    assert!(err.contains("shard"), "{err}");
    // wrong cache capacity
    let (code, _out, err) = run_sparx(
        &["serve", "--model", model_path(), "--count", "10", "--resume", &ckpt, "--cache", "99"],
        None,
    );
    assert_eq!(code, 2, "cache mismatch must be a usage error; stderr: {err}");
    // a checkpoint is not a model
    let (code, _out, err) =
        run_sparx(&["serve", "--model", &ckpt, "--count", "10"], None);
    assert_eq!(code, 2, "stderr: {err}");
    assert!(err.contains("--resume"), "must point at the right flag: {err}");
    // a model is not a checkpoint
    let (code, _out, err) = run_sparx(
        &["serve", "--model", model_path(), "--count", "10", "--resume", model_path()],
        None,
    );
    assert_eq!(code, 2, "stderr: {err}");
    let _ = std::fs::remove_file(&file);
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn serve_watch_and_checkpoint_flags_run_on_the_synthetic_stream() {
    let ckpt = temp_file("watch.sparx");
    let (code, out, err) = run_sparx(
        &[
            "serve", "--model", model_path(), "--count", "400", "--shards", "2", "--cache",
            "64", "--watch", "--absorb", "--checkpoint-out", &ckpt,
        ],
        None,
    );
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("resident ensemble"), "{out}");
    assert!(out.contains("400 absorbed"), "{out}");
    assert!(out.contains("checkpoint written"), "{out}");
    // --checkpoint-every without --checkpoint-out is a usage error
    let (code, _out, err) = run_sparx(
        &["serve", "--model", model_path(), "--count", "10", "--checkpoint-every", "5"],
        None,
    );
    assert_eq!(code, 2);
    assert!(err.contains("checkpoint-out"), "{err}");
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn serve_score_log_to_stdout_is_machine_clean() {
    let args =
        ["serve", "--model", model_path(), "--count", "20", "--shards", "2", "--score-log", "-"];
    let (code, out, err) = run_sparx(&args, None);
    assert_eq!(code, 0, "stderr: {err}");
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 20, "stdout must carry only the score log: {out:?}");
    for l in &lines {
        let mut it = l.split(' ');
        let id = it.next().unwrap_or("");
        let bits = it.next().unwrap_or("");
        assert!(it.next().is_none(), "line has extra fields: {l:?}");
        assert!(!id.is_empty() && id.chars().all(|c| c.is_ascii_digit()), "{l:?}");
        assert_eq!(bits.len(), 16, "{l:?}");
        assert!(bits.chars().all(|c| c.is_ascii_hexdigit()), "{l:?}");
    }
    assert!(err.contains("serving sparx model"), "human output must land on stderr: {err}");
}

#[test]
fn generate_stream_emits_lines_serve_accepts() {
    let out_file = temp_file("updates.txt");
    let (code, out, err) =
        run_sparx(&["generate", "--stream", "80", "--seed", "5", "--out", &out_file], None);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("80 update triples"), "{out}");
    let content = std::fs::read_to_string(&out_file).unwrap();
    assert_eq!(content.lines().count(), 80);
    for (i, line) in content.lines().enumerate() {
        let parsed = sparx::data::parse_update_line(i + 1, line).unwrap();
        assert!(parsed.is_some(), "line {i} must be a real update: {line:?}");
    }
    // and the real binary serves the file
    let (code, out, err) = run_sparx(
        &["serve", "--model", model_path(), "--updates", &out_file, "--shards", "2"],
        None,
    );
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 80 δ-updates"), "{out}");
    let _ = std::fs::remove_file(&out_file);
}

// ------------------------------------------------ backend override

/// `sparx score` on the shared model with a small generated batch and
/// the given `--backend` override.
fn run_score_with_backend(backend: &str) -> (i32, String, String) {
    let base = ["score", "--model", model_path(), "--dataset", "gisette", "--scale", "0.01"];
    let mut args: Vec<&str> = base.to_vec();
    args.extend(["--backend", backend]);
    run_sparx(&args, None)
}

#[test]
fn score_accepts_a_native_backend_override() {
    let (code, out, err) = run_score_with_backend("native");
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("backend overridden"), "{out}");
    assert!(out.contains("AUROC"), "{out}");
}

#[test]
fn score_pjrt_override_on_a_native_artifact_is_rejected_typed() {
    // a native artifact stores no AOT variant, so forcing pjrt cannot
    // know which compiled tile shapes to run — usage error, exit 2
    let (code, _out, err) = run_score_with_backend("pjrt");
    assert_eq!(code, 2, "stderr: {err}");
    assert!(err.contains("PJRT variant"), "{err}");
}

#[test]
fn score_unknown_backend_is_a_usage_error() {
    let (code, _out, err) = run_score_with_backend("cuda");
    assert_eq!(code, 2);
    assert!(err.contains("backend"), "{err}");
}

#[test]
fn serve_accepts_a_native_backend_override() {
    let args = ["serve", "--model", model_path(), "--count", "50", "--backend", "native"];
    let (code, out, err) = run_sparx(&args, None);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 50 δ-updates"), "{out}");
}
