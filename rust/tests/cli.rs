//! End-to-end CLI tests: spawn the built `sparx` binary and assert the
//! documented exit codes — `0` success, `2` usage/validation, `1`
//! runtime — for the serve-input grammar (malformed triples, `old->new`
//! substitutions, `#` comments, empty files), the `--backend` override
//! at load, and the sharded serve path.

use std::io::Write as _;
use std::process::{Command, Stdio};
use std::sync::OnceLock;

use sparx::api::{registry, Detector as _, DetectorSpec, FittedModel as _};
use sparx::cluster::ClusterConfig;
use sparx::data::generators::GisetteGen;

/// Run the CLI with `args` (and optional stdin), returning
/// (exit code, stdout, stderr).
fn run_sparx(args: &[&str], stdin: Option<&str>) -> (i32, String, String) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sparx"));
    cmd.args(args).stdout(Stdio::piped()).stderr(Stdio::piped());
    cmd.stdin(if stdin.is_some() { Stdio::piped() } else { Stdio::null() });
    let mut child = cmd.spawn().expect("spawn the sparx binary");
    if let Some(input) = stdin {
        let mut pipe = child.stdin.take().expect("stdin was piped");
        pipe.write_all(input.as_bytes()).expect("write stdin");
        // pipe drops here → EOF for the child
    }
    let out = child.wait_with_output().expect("wait for sparx");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// Fit one small sparx model per test process and save its artifact;
/// every test serves/scores this file. The Gisette shape (d=512) matches
/// what `--dataset gisette` generates, so `sparx score` round trips.
fn model_path() -> &'static str {
    static PATH: OnceLock<String> = OnceLock::new();
    PATH.get_or_init(|| {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 200, d: 512, ..Default::default() }.generate(&ctx).unwrap();
        let spec = DetectorSpec {
            k: Some(8),
            components: Some(4),
            depth: Some(4),
            sample_rate: Some(1.0),
            ..Default::default()
        };
        let model = registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
        let path = std::env::temp_dir().join(format!("sparx-cli-{}.sparx", std::process::id()));
        let path = path.to_str().expect("utf-8 temp path").to_string();
        model.to_artifact().unwrap().save(&path).unwrap();
        path
    })
}

/// Write an updates file with unique name; returns its path.
fn write_updates(content: &str) -> String {
    static N: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);
    let n = N.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let path = std::env::temp_dir()
        .join(format!("sparx-cli-updates-{}-{n}.txt", std::process::id()));
    std::fs::write(&path, content).expect("write updates file");
    path.to_str().expect("utf-8 temp path").to_string()
}

/// `sparx serve` on the shared model reading the given updates file,
/// which is deleted afterwards (no temp-dir accumulation across runs).
fn run_serve_updates(file: &str) -> (i32, String, String) {
    let out = run_sparx(&["serve", "--model", model_path(), "--updates", file], None);
    let _ = std::fs::remove_file(file);
    out
}

// ------------------------------------------------- serve-input parsing

#[test]
fn serve_parses_comments_blanks_numeric_and_substitution_lines() {
    let file = write_updates("# hdr\n\n1 f0 1.5\n2 loc ->NYC\n2 loc NYC->Austin\n1 f1 -0.25\n");
    let args = ["serve", "--model", model_path(), "--updates", &file, "--shards", "1"];
    let (code, out, err) = run_sparx(&args, None);
    let _ = std::fs::remove_file(&file);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 4 δ-updates"), "{out}");
}

#[test]
fn serve_empty_update_file_is_a_no_op_success() {
    let file = write_updates("");
    let (code, out, err) = run_serve_updates(&file);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 0 δ-updates"), "{out}");
}

#[test]
fn serve_malformed_triple_is_usage_error_naming_the_line() {
    let file = write_updates("1 f0 1.0\n2 f0\n");
    let (code, _out, err) = run_serve_updates(&file);
    assert_eq!(code, 2, "stderr: {err}");
    assert!(err.contains("update line 2"), "{err}");
}

#[test]
fn serve_bad_id_bad_delta_and_empty_new_value_fail_typed() {
    for line in ["abc f0 1.0", "1 f0 north", "1 loc NYC->"] {
        let file = write_updates(&format!("{line}\n"));
        let (code, _out, err) = run_serve_updates(&file);
        assert_eq!(code, 2, "line {line:?} must exit 2; stderr: {err}");
        assert!(err.contains("update line 1"), "line {line:?}: {err}");
    }
}

#[test]
fn serve_reads_updates_from_stdin() {
    let args = ["serve", "--model", model_path(), "--updates", "-", "--shards", "2"];
    let (code, out, err) = run_sparx(&args, Some("1 f0 1.0\n2 f0 2.0\n3 f0 3.0\n"));
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 3 δ-updates"), "{out}");
}

#[test]
fn serve_count_alongside_an_updates_file_is_rejected() {
    let file = write_updates("1 f0 1.0\n");
    let args = ["serve", "--model", model_path(), "--updates", &file, "--count", "5"];
    let (code, _out, err) = run_sparx(&args, None);
    let _ = std::fs::remove_file(&file);
    assert_eq!(code, 2);
    assert!(err.contains("--count"), "{err}");
}

// ------------------------------------------------------- sharded serve

#[test]
fn serve_sharded_synthetic_stream_reports_per_shard_counters() {
    let args = ["serve", "--model", model_path(), "--count", "500", "--shards", "4"];
    let (code, out, err) = run_sparx(&args, None);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 500 δ-updates"), "{out}");
    assert!(out.contains("shard 0:"), "{out}");
    assert!(out.contains("shard 3:"), "{out}");
}

#[test]
fn serve_shards_zero_is_a_usage_error() {
    let args = ["serve", "--model", model_path(), "--count", "1", "--shards", "0"];
    let (code, _out, err) = run_sparx(&args, None);
    assert_eq!(code, 2);
    assert!(err.contains("--shards"), "{err}");
}

// ------------------------------------------------ backend override

/// `sparx score` on the shared model with a small generated batch and
/// the given `--backend` override.
fn run_score_with_backend(backend: &str) -> (i32, String, String) {
    let base = ["score", "--model", model_path(), "--dataset", "gisette", "--scale", "0.01"];
    let mut args: Vec<&str> = base.to_vec();
    args.extend(["--backend", backend]);
    run_sparx(&args, None)
}

#[test]
fn score_accepts_a_native_backend_override() {
    let (code, out, err) = run_score_with_backend("native");
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("backend overridden"), "{out}");
    assert!(out.contains("AUROC"), "{out}");
}

#[test]
fn score_pjrt_override_on_a_native_artifact_is_rejected_typed() {
    // a native artifact stores no AOT variant, so forcing pjrt cannot
    // know which compiled tile shapes to run — usage error, exit 2
    let (code, _out, err) = run_score_with_backend("pjrt");
    assert_eq!(code, 2, "stderr: {err}");
    assert!(err.contains("PJRT variant"), "{err}");
}

#[test]
fn score_unknown_backend_is_a_usage_error() {
    let (code, _out, err) = run_score_with_backend("cuda");
    assert_eq!(code, 2);
    assert!(err.contains("backend"), "{err}");
}

#[test]
fn serve_accepts_a_native_backend_override() {
    let args = ["serve", "--model", model_path(), "--count", "50", "--backend", "native"];
    let (code, out, err) = run_sparx(&args, None);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("processed 50 δ-updates"), "{out}");
}
