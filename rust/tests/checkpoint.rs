//! Durable serving-state harness: checkpoint layout-independence, file
//! round trips, kill→resume bit-identity at a *different* shard count,
//! live re-sharding mid-stream, typed failure of damaged or mismatched
//! checkpoint files, and hot ensemble swaps.
//!
//! The two load-bearing properties of the v4 format:
//!
//! 1. **Layout independence** — the checkpoint cut after the same
//!    submit sequence is bit-identical at *any* shard count (modulo the
//!    informational `shards` field): same global LRU→MRU entry list
//!    with the same recency tags, same visible overlay, same merged
//!    pending overlay, same summed counters. Eviction and absorb
//!    decisions live feeder-side, so the shard layout can never leak
//!    into the persisted state.
//! 2. **Resume property** — checkpoint → new process → `--resume`
//!    continues the stream bit-for-bit **even when the shard count
//!    changes**: the concatenated score logs of a run interrupted at
//!    S=3 and resumed at S=5 (or S=1) equal the uninterrupted S=1
//!    run's log, order included, under eviction churn with absorb on.

use std::sync::Arc;

use sparx::api::{registry, SparxError};
use sparx::cluster::ClusterConfig;
use sparx::data::generators::GisetteGen;
use sparx::data::{StreamGen, UpdateTriple};
use sparx::sparx::{
    AbsorbCheckpoint, ServeOptions, ServedEnsemble, ShardedStreamScorer, SparxModel, SparxParams,
    StreamScore, SwapCarry,
};

fn fitted(seed: u64) -> SparxModel {
    let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
    let ld = GisetteGen { n: 350, d: 20, ..Default::default() }.generate(&ctx).unwrap();
    SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 8, num_chains: 6, depth: 5, seed, ..Default::default() },
    )
    .unwrap()
}

fn synth_updates(ids: u64, count: usize, seed: u64) -> Vec<UpdateTriple> {
    let names: Vec<String> = (0..20).map(|j| format!("f{j}")).collect();
    let mut gen = StreamGen::new(ids, names, seed);
    (0..count).map(|_| gen.next_update()).collect()
}

fn temp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("sparx-ckpt-test-{}-{tag}.sparx", std::process::id()))
        .to_str()
        .expect("utf-8 temp path")
        .to_string()
}

/// Property 1: the same submit sequence yields the same checkpoint at
/// any shard count — entries (with recency tags), overlays and counters
/// all bit-identical; only the informational `shards` field records the
/// capture-time layout. Runs mid-epoch (3000 % 256 ≠ 0) with real LRU
/// churn so the pending overlay and the eviction path are both live.
#[test]
fn checkpoint_is_identical_at_every_shard_count() {
    let model = fitted(0x5AB4);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let updates = synth_updates(300, 3000, 0xAB50);
    let cache = 96usize; // < 300 distinct IDs: the eviction regime
    let opts = ServeOptions { record: false, absorb: true, ..Default::default() };

    let cut = |shards: usize| -> AbsorbCheckpoint {
        let mut scorer =
            ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(shards).cache(cache),
        None,
    ).unwrap();
        for u in &updates {
            scorer.submit(u.clone());
        }
        let ckpt = scorer.checkpoint().unwrap();
        let report = scorer.finish();
        assert_eq!(report.processed(), updates.len() as u64, "S={shards}: lost updates");
        ckpt
    };

    let want = cut(1);
    assert_eq!(want.shards, 1);
    assert_eq!(want.submitted, updates.len() as u64);
    assert!(want.evicted > 0, "harness requires the eviction regime");
    assert_eq!(want.entries.len(), cache, "directory must sit at its budget");
    assert!(want.visible.iter().any(|l| !l.is_empty()), "epochs must have published");
    assert!(
        want.pending.iter().any(|l| !l.is_empty()),
        "a mid-epoch cut must carry unpublished increments"
    );
    let want_bytes = want.to_artifact().to_bytes();

    for shards in [2usize, 3, 5] {
        let mut got = cut(shards);
        assert_eq!(got.shards, shards as u32, "capture-time layout is recorded");
        got.shards = want.shards; // the one (informational) field allowed to differ
        assert_eq!(got, want, "S={shards}: checkpoint state leaked the shard layout");
        assert_eq!(
            got.to_artifact().to_bytes(),
            want_bytes,
            "S={shards}: serialized form must be byte-identical too"
        );
    }
}

/// Property 2 — the acceptance bar for elastic serving: checkpoint a
/// run at S=3 mid-stream (and mid-epoch), tear it down, restore from
/// the **file** at S=5 and at S=1, continue — each concatenated score
/// log is bit-identical to the uninterrupted S=1 run. Absorb on, real
/// LRU churn across the cut.
#[test]
fn file_checkpoint_resumes_bit_identically_at_a_different_shard_count() {
    let model = fitted(0x7E57);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let updates = synth_updates(500, 4000, 0xFEED5);
    let cache = 64usize; // small: real LRU churn crosses the checkpoint
    let opts = ServeOptions { record: true, absorb: true, ..Default::default() };

    // uninterrupted single-shard reference run
    let mut full = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(1).cache(cache),
        None,
    ).unwrap();
    for u in &updates {
        full.submit(u.clone());
    }
    let full_report = full.finish();
    assert!(full_report.evictions() > 0, "harness requires the eviction regime");
    let want: Vec<StreamScore> = full_report.merged_scores();

    // interrupted run at S=3: first half, checkpoint to a file, tear down
    let cut = updates.len() / 2; // 2000 % 256 != 0: a mid-epoch cut
    let mut first = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(3).cache(cache),
        None,
    ).unwrap();
    for u in &updates[..cut] {
        first.submit(u.clone());
    }
    let ckpt = first.checkpoint().unwrap();
    let path = temp_path("resume");
    ckpt.save(&path, ckpt.manifest_for("in-memory")).unwrap();
    let part1 = first.finish().merged_scores();

    // "new process": reload the file and continue at a different S
    let loaded = AbsorbCheckpoint::load(&path).unwrap();
    assert_eq!(loaded, ckpt, "file round trip must be exact");
    std::fs::remove_file(&path).unwrap();
    for resume_shards in [5usize, 1] {
        let mut second = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(resume_shards).cache(cache),
        Some(&loaded),
    )
        .unwrap();
        assert_eq!(second.submitted(), cut as u64, "resume continues the submit sequence");
        for u in &updates[cut..] {
            second.submit(u.clone());
        }
        let second_report = second.finish();
        assert_eq!(
            second_report.processed(),
            updates.len() as u64,
            "S=3→S={resume_shards}: lifetime total"
        );
        let part2 = second_report.merged_scores();
        assert_eq!(part1.len() + part2.len(), want.len());
        let resumed: Vec<StreamScore> = part1.iter().cloned().chain(part2).collect();
        for (i, (got, wanted)) in resumed.iter().zip(&want).enumerate() {
            assert_eq!(got, wanted, "S=3→S={resume_shards}: diverged at submit #{i}");
        }
    }

    // shrinking the budget on resume sheds from the LRU side, counted as
    // evictions — the pool comes up resident within the new budget
    let small = 16usize;
    let shed = loaded.entries.len() as u64 - small as u64;
    let ok = ShardedStreamScorer::from_ensemble(
        ens,
        opts.shards(2).cache(small),
        Some(&loaded),
    ).unwrap();
    let report = ok.finish();
    assert_eq!(report.cached_ids(), small, "must shed down to the new budget");
    assert_eq!(report.evictions(), loaded.evicted + shed, "shed entries count as evictions");
}

/// Live re-shard mid-stream (the `RESHARD` verb's engine): 2 → 4 → 1
/// across one continuous stream drops zero updates and keeps the merged
/// score log bit-identical to an uninterrupted single-shard run —
/// absorb on, eviction churn on, reshard points off epoch boundaries.
#[test]
fn live_reshard_mid_stream_drops_nothing_and_stays_bit_identical() {
    let model = fitted(0xE1A5);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let updates = synth_updates(400, 3500, 0xC0FFEE);
    let cache = 64usize;
    let opts = ServeOptions { record: true, absorb: true, ..Default::default() };

    let mut reference =
        ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(1).cache(cache),
        None,
    ).unwrap();
    for u in &updates {
        reference.submit(u.clone());
    }
    let reference = reference.finish();
    assert!(reference.evictions() > 0, "harness requires the eviction regime");
    let want = reference.merged_scores();

    let mut scorer = ShardedStreamScorer::from_ensemble(
        ens,
        opts.shards(2).cache(cache),
        None,
    ).unwrap();
    for u in &updates[..1000] {
        scorer.submit(u.clone());
    }
    scorer.reshard(4).unwrap();
    assert_eq!(scorer.shards(), 4);
    for u in &updates[1000..1500] {
        scorer.submit(u.clone());
    }
    scorer.reshard(4).unwrap(); // same count: a no-op, not a respawn
    assert!(matches!(scorer.reshard(0), Err(SparxError::InvalidParams(_))));
    assert!(matches!(scorer.reshard(5000), Err(SparxError::InvalidParams(_))));
    assert_eq!(scorer.shards(), 4, "rejected reshards must leave the pool serving");
    scorer.reshard(1).unwrap();
    for u in &updates[1500..] {
        scorer.submit(u.clone());
    }
    let report = scorer.finish();
    assert_eq!(report.processed(), updates.len() as u64, "reshards must drop zero updates");
    let got = report.merged_scores();
    assert_eq!(got.len(), want.len(), "archived generations must all surface");
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g, w, "resharded stream diverged at submit #{i}");
    }
}

/// Damaged or mismatched checkpoint files fail typed — never panic,
/// never restore garbage. Layout changes (shards, cache) are *not*
/// mismatches from v4 on; model fingerprint and absorb mode are.
#[test]
fn corrupt_truncated_and_mismatched_checkpoints_fail_typed() {
    let model = fitted(1);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let mut scorer = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        ServeOptions { record: false, absorb: true, ..Default::default() }.shards(2).cache(32),
        None,
    )
    .unwrap();
    for u in synth_updates(50, 400, 9) {
        scorer.submit(u);
    }
    let ckpt = scorer.checkpoint().unwrap();
    drop(scorer.finish());
    let bytes = ckpt.to_artifact().to_bytes();

    // truncation at every eighth prefix — always typed, never a panic
    for cut in (0..bytes.len()).step_by(8) {
        let r = sparx::api::ModelArtifact::from_bytes(&bytes[..cut]);
        assert!(
            matches!(r, Err(SparxError::MissingArtifact(_))),
            "prefix of {cut} bytes must fail typed, got {:?}",
            r.err()
        );
    }
    // bit flips are caught by the file checksum
    for pos in [7usize, bytes.len() / 3, bytes.len() - 2] {
        let mut c = bytes.clone();
        c[pos] ^= 0x20;
        assert!(matches!(
            sparx::api::ModelArtifact::from_bytes(&c),
            Err(SparxError::MissingArtifact(_))
        ));
    }
    // a checkpoint is not a model: the registry points at --resume
    let r = registry::load_bytes(&bytes);
    match r {
        Err(SparxError::InvalidParams(msg)) => {
            assert!(msg.contains("--resume"), "must point at the right flag: {msg}")
        }
        other => panic!("expected InvalidParams, got {other:?}"),
    }
    // a model is not a checkpoint
    let model_bytes = {
        use sparx::api::{Detector as _, DetectorSpec, FittedModel as _};
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 200, d: 8, ..Default::default() }.generate(&ctx).unwrap();
        let spec = DetectorSpec {
            k: Some(4),
            components: Some(3),
            depth: Some(3),
            ..Default::default()
        };
        let m = registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
        m.to_artifact().unwrap().to_bytes()
    };
    let art = sparx::api::ModelArtifact::from_bytes(&model_bytes).unwrap();
    assert!(matches!(
        AbsorbCheckpoint::from_artifact(&art),
        Err(SparxError::InvalidParams(_))
    ));

    // wrong model: resume must reject a fingerprint mismatch
    let other = Arc::new(ServedEnsemble::new(&fitted(2)).unwrap());
    let r = ShardedStreamScorer::from_ensemble(
        other,
        ServeOptions { record: false, absorb: true, ..Default::default() }.shards(2).cache(32),
        Some(&ckpt),
    );
    assert!(matches!(r.err(), Some(SparxError::InvalidParams(_))), "wrong model must fail");
    // wrong absorb mode: the continued stream would silently diverge
    let r = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        ServeOptions { record: false, absorb: false, ..Default::default() }.shards(2).cache(32),
        Some(&ckpt),
    );
    assert!(
        matches!(r.err(), Some(SparxError::InvalidParams(_))),
        "absorb-mode mismatch must be rejected against an absorb-on checkpoint"
    );
    // a *different layout* is not a mismatch: v4 validation is lifted to
    // what genuinely breaks bit-identity, so any shards/cache restores
    for (shards, cache) in [(2usize, 32usize), (3, 32), (2, 16), (5, 64)] {
        let ok = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        ServeOptions { record: false, absorb: true, ..Default::default() }.shards(shards).cache(cache),
        Some(&ckpt),
    )
        .unwrap_or_else(|e| {
            panic!("S={shards} cache={cache} must restore from a S=2/cache=32 checkpoint: {e:?}")
        });
        assert_eq!(ok.submitted(), 400);
        drop(ok.finish());
    }
}

/// Hot reload mid-stream: swaps land between batches, drop no updates,
/// and follow the carry rules (Full / SketchesOnly / typed rejection).
#[test]
fn hot_swap_mid_stream_drops_no_updates_and_follows_carry_rules() {
    let model = fitted(0xA);
    let retrained = fitted(0xB); // same schema, different chains
    let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
    let ld = GisetteGen { n: 350, d: 20, ..Default::default() }.generate(&ctx).unwrap();
    let wider = SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 12, num_chains: 6, depth: 5, ..Default::default() },
    )
    .unwrap();

    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let mut scorer = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        ServeOptions { record: true, absorb: true, ..Default::default() }.shards(3).cache(256),
        None,
    )
    .unwrap();
    let updates = synth_updates(80, 900, 0x5107);
    for u in &updates[..300] {
        scorer.submit(u.clone());
    }
    // same model re-loaded → everything carries
    let same = Arc::new(ServedEnsemble::new(&model).unwrap());
    assert_eq!(scorer.swap_ensemble(same).unwrap(), SwapCarry::Full);
    for u in &updates[300..600] {
        scorer.submit(u.clone());
    }
    // retrained, same schema → sketches carry, delta resets
    let re = Arc::new(ServedEnsemble::new(&retrained).unwrap());
    assert_eq!(scorer.swap_ensemble(re).unwrap(), SwapCarry::SketchesOnly);
    // different schema → typed rejection, stream keeps flowing
    let alien = Arc::new(ServedEnsemble::new(&wider).unwrap());
    let r = scorer.swap_ensemble(alien);
    assert!(matches!(r, Err(SparxError::Unsupported(_))), "{:?}", r.err());
    for u in &updates[600..] {
        scorer.submit(u.clone());
    }
    let report = scorer.finish();
    assert_eq!(report.processed(), 900, "swaps must not drop updates");
    let merged = report.merged_scores();
    assert_eq!(merged.len(), 900, "recording must span every swap");
    // determinism of the swap point: replaying the same submits + swaps
    // yields the bit-identical merged log
    let mut replay = ShardedStreamScorer::from_ensemble(
        Arc::new(ServedEnsemble::new(&model).unwrap()),
        ServeOptions { record: true, absorb: true, ..Default::default() }.shards(3).cache(256),
        None,
    )
    .unwrap();
    for u in &updates[..300] {
        replay.submit(u.clone());
    }
    replay.swap_ensemble(Arc::new(ServedEnsemble::new(&model).unwrap())).unwrap();
    for u in &updates[300..600] {
        replay.submit(u.clone());
    }
    replay.swap_ensemble(Arc::new(ServedEnsemble::new(&retrained).unwrap())).unwrap();
    for u in &updates[600..] {
        replay.submit(u.clone());
    }
    let merged2 = replay.finish().merged_scores();
    assert_eq!(merged, merged2, "swap points must be deterministic in the sub-streams");
    let _ = ens;
}
