//! Durable serving-state harness: checkpoint merge correctness, file
//! round trips, kill→resume bit-identity, typed failure of damaged or
//! mismatched checkpoint files, and hot ensemble swaps mid-stream.
//!
//! The two load-bearing properties:
//!
//! 1. **Merge property** — merging the S per-shard `AbsorbState`
//!    snapshots equals the S=1 absorb state for the same stream (any S,
//!    seeded per-ID-order-preserving shuffles, absorb-every-update, in
//!    the no-eviction regime): same sketch set bit-for-bit, same summed
//!    CMS delta, same counters. Every ID is pinned to one shard and its
//!    sketch evolves identically there, so each absorb inserts the same
//!    bins regardless of S — the per-bucket delta counts must sum
//!    exactly.
//! 2. **Resume property** — checkpoint → new process → `--resume`
//!    continues the stream bit-for-bit: the concatenated score logs of
//!    an interrupted run equal the uninterrupted run's log, order
//!    included.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use sparx::api::{registry, SparxError};
use sparx::cluster::ClusterConfig;
use sparx::data::generators::GisetteGen;
use sparx::data::{StreamGen, UpdateTriple};
use sparx::sparx::{
    AbsorbCheckpoint, AbsorbSnapshot, ServeOptions, ServedEnsemble, ShardedStreamScorer,
    SparxModel, SparxParams, StreamScore, StreamScorer, SwapCarry,
};
use sparx::util::Rng;

fn fitted(seed: u64) -> SparxModel {
    let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
    let ld = GisetteGen { n: 350, d: 20, ..Default::default() }.generate(&ctx).unwrap();
    SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 8, num_chains: 6, depth: 5, seed, ..Default::default() },
    )
    .unwrap()
}

fn synth_updates(ids: u64, count: usize, seed: u64) -> Vec<UpdateTriple> {
    let names: Vec<String> = (0..20).map(|j| format!("f{j}")).collect();
    let mut gen = StreamGen::new(ids, names, seed);
    (0..count).map(|_| gen.next_update()).collect()
}

/// Seeded shuffle of the arrival order *across* IDs that preserves each
/// ID's own update order (streams never reorder a single key).
fn shuffle_interleaving(updates: &[UpdateTriple], seed: u64) -> Vec<UpdateTriple> {
    let mut queues: Vec<VecDeque<UpdateTriple>> = Vec::new();
    let mut slot_of: HashMap<u64, usize> = HashMap::new();
    for u in updates {
        let next = queues.len();
        let slot = *slot_of.entry(u.id()).or_insert(next);
        if slot == next {
            queues.push(VecDeque::new());
        }
        queues[slot].push_back(u.clone());
    }
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(updates.len());
    while !queues.is_empty() {
        let pick = rng.below(queues.len() as u64) as usize;
        let u = queues[pick].pop_front().expect("queues are drained eagerly");
        out.push(u);
        if queues[pick].is_empty() {
            queues.swap_remove(pick);
        }
    }
    out
}

/// Sketch entries as (id, f32-bit) pairs sorted by id — sharding changes
/// only the partitioning and recency order of entries, never their bits.
fn entries_by_id(snap: &AbsorbSnapshot) -> Vec<(u64, Vec<u32>)> {
    let mut v: Vec<(u64, Vec<u32>)> = snap
        .entries
        .iter()
        .map(|(id, sk)| (*id, sk.iter().map(|x| x.to_bits()).collect()))
        .collect();
    v.sort_unstable_by_key(|(id, _)| *id);
    v
}

fn temp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("sparx-ckpt-test-{}-{tag}.sparx", std::process::id()))
        .to_str()
        .expect("utf-8 temp path")
        .to_string()
}

/// Property 1: merged shard snapshots == the S=1 absorb state, for any
/// shard count and arrival interleaving, absorbing every update.
#[test]
fn merging_shard_snapshots_equals_the_single_shard_absorb_state() {
    let model = fitted(0x5AB4);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let updates = synth_updates(300, 5000, 0xAB50);

    // S=1 reference: update then absorb, exactly like the absorb serving
    // mode does per shard
    let mut reference = StreamScorer::from_ensemble(ens.clone(), 4096).unwrap();
    for u in &updates {
        let s = reference.update(u);
        reference.absorb(s.id).expect("just updated, must be cached");
    }
    assert_eq!(reference.evictions(), 0, "harness requires the no-eviction regime");
    let want = reference.snapshot();

    for (shards, shuffle_seed) in [(2usize, 21u64), (3, 22), (5, 23)] {
        let replay = shuffle_interleaving(&updates, shuffle_seed);
        assert_ne!(replay, updates, "the shuffle must actually change the interleaving");
        let mut scorer = ShardedStreamScorer::from_ensemble(
            ens.clone(),
            shards,
            4096,
            ServeOptions { record: false, absorb: true },
            None,
        )
        .unwrap();
        for u in replay {
            scorer.submit(u);
        }
        let ckpt = scorer.checkpoint().unwrap();
        let report = scorer.finish();
        assert_eq!(report.processed(), updates.len() as u64, "S={shards}: lost updates");
        assert_eq!(report.absorbed(), updates.len() as u64, "S={shards}: lost absorbs");
        assert_eq!(ckpt.snapshots.len(), shards);
        let merged = ckpt.merged();
        assert_eq!(merged.processed, want.processed, "S={shards}: processed");
        assert_eq!(merged.evicted, 0, "S={shards}: evictions in the no-eviction regime");
        assert_eq!(merged.absorbed, want.absorbed, "S={shards}: absorbed");
        assert_eq!(
            entries_by_id(&merged),
            entries_by_id(&want),
            "S={shards}: merged sketch set must equal the single-shard cache bit-for-bit"
        );
        assert_eq!(
            merged.delta, want.delta,
            "S={shards}: summed per-shard deltas must equal the S=1 delta exactly"
        );
    }
}

/// Property 2: checkpoint at an arbitrary stream position, tear the
/// scorer down (the "kill"), restore from the **file** into a fresh
/// scorer, continue — the concatenated score logs are bit-identical to
/// an uninterrupted run. Exercised with absorb on and real evictions.
#[test]
fn file_checkpoint_resume_continues_bit_identically() {
    let model = fitted(0x7E57);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let updates = synth_updates(500, 4000, 0xFEED5);
    let shards = 4usize;
    let cache = 64usize; // small: real LRU churn crosses the checkpoint
    let opts = ServeOptions { record: true, absorb: true };

    // uninterrupted reference run
    let mut full = ShardedStreamScorer::from_ensemble(ens.clone(), shards, cache, opts, None)
        .unwrap();
    for u in &updates {
        full.submit(u.clone());
    }
    let full_report = full.finish();
    assert!(full_report.evictions() > 0, "harness requires the eviction regime");
    let want: Vec<StreamScore> = full_report.merged_scores();

    // interrupted run: first half, checkpoint to a file, drop everything
    let cut = updates.len() / 2;
    let mut first = ShardedStreamScorer::from_ensemble(ens.clone(), shards, cache, opts, None)
        .unwrap();
    for u in &updates[..cut] {
        first.submit(u.clone());
    }
    let ckpt = first.checkpoint().unwrap();
    let path = temp_path("resume");
    ckpt.save(&path, vec![("model".into(), "in-memory".into())]).unwrap();
    let part1 = first.finish().merged_scores();

    // "new process": reload the checkpoint file and continue the stream
    let loaded = AbsorbCheckpoint::load(&path).unwrap();
    assert_eq!(loaded, ckpt, "file round trip must be exact");
    let mut second =
        ShardedStreamScorer::from_ensemble(ens, shards, cache, opts, Some(&loaded)).unwrap();
    assert_eq!(second.submitted(), cut as u64, "resume continues the submit sequence");
    for u in &updates[cut..] {
        second.submit(u.clone());
    }
    let second_report = second.finish();
    assert_eq!(second_report.processed(), updates.len() as u64, "lifetime total");
    let part2 = second_report.merged_scores();
    std::fs::remove_file(&path).unwrap();

    assert_eq!(part1.len() + part2.len(), want.len());
    let resumed: Vec<StreamScore> = part1.into_iter().chain(part2).collect();
    for (i, (got, wanted)) in resumed.iter().zip(&want).enumerate() {
        assert_eq!(got, wanted, "resumed stream diverged at submit #{i}");
    }
}

/// Damaged or mismatched checkpoint files fail typed — never panic,
/// never restore garbage.
#[test]
fn corrupt_truncated_and_mismatched_checkpoints_fail_typed() {
    let model = fitted(1);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let mut scorer = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        2,
        32,
        ServeOptions { record: false, absorb: true },
        None,
    )
    .unwrap();
    for u in synth_updates(50, 400, 9) {
        scorer.submit(u);
    }
    let ckpt = scorer.checkpoint().unwrap();
    drop(scorer.finish());
    let bytes = ckpt.to_artifact().to_bytes();

    // truncation at every eighth prefix — always typed, never a panic
    for cut in (0..bytes.len()).step_by(8) {
        let r = sparx::api::ModelArtifact::from_bytes(&bytes[..cut]);
        assert!(
            matches!(r, Err(SparxError::MissingArtifact(_))),
            "prefix of {cut} bytes must fail typed, got {:?}",
            r.err()
        );
    }
    // bit flips are caught by the file checksum
    for pos in [7usize, bytes.len() / 3, bytes.len() - 2] {
        let mut c = bytes.clone();
        c[pos] ^= 0x20;
        assert!(matches!(
            sparx::api::ModelArtifact::from_bytes(&c),
            Err(SparxError::MissingArtifact(_))
        ));
    }
    // a checkpoint is not a model: the registry points at --resume
    let r = registry::load_bytes(&bytes);
    match r {
        Err(SparxError::InvalidParams(msg)) => {
            assert!(msg.contains("--resume"), "must point at the right flag: {msg}")
        }
        other => panic!("expected InvalidParams, got {other:?}"),
    }
    // a model is not a checkpoint
    let model_bytes = {
        use sparx::api::{Detector as _, DetectorSpec, FittedModel as _};
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 200, d: 8, ..Default::default() }.generate(&ctx).unwrap();
        let spec = DetectorSpec {
            k: Some(4),
            components: Some(3),
            depth: Some(3),
            ..Default::default()
        };
        let m = registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
        m.to_artifact().unwrap().to_bytes()
    };
    let art = sparx::api::ModelArtifact::from_bytes(&model_bytes).unwrap();
    assert!(matches!(
        AbsorbCheckpoint::from_artifact(&art),
        Err(SparxError::InvalidParams(_))
    ));

    // wrong model: resume must reject a fingerprint mismatch
    let other = Arc::new(ServedEnsemble::new(&fitted(2)).unwrap());
    let r = ShardedStreamScorer::from_ensemble(
        other,
        2,
        32,
        ServeOptions::default(),
        Some(&ckpt),
    );
    assert!(matches!(r.err(), Some(SparxError::InvalidParams(_))), "wrong model must fail");
    // wrong layout: shard count and cache capacity must match the capture
    for (shards, cache) in [(3usize, 32usize), (2, 16)] {
        let r = ShardedStreamScorer::from_ensemble(
            ens.clone(),
            shards,
            cache,
            ServeOptions::default(),
            Some(&ckpt),
        );
        assert!(
            matches!(r.err(), Some(SparxError::InvalidParams(_))),
            "S={shards} cache={cache} must be rejected against a S=2/cache=32 checkpoint"
        );
    }
    // wrong absorb mode: the continued stream would silently diverge
    let r = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        2,
        32,
        ServeOptions { record: false, absorb: false },
        Some(&ckpt),
    );
    assert!(
        matches!(r.err(), Some(SparxError::InvalidParams(_))),
        "absorb-mode mismatch must be rejected against an absorb-on checkpoint"
    );
    // ...and the matching layout + mode restores fine
    let ok = ShardedStreamScorer::from_ensemble(
        ens,
        2,
        32,
        ServeOptions { record: false, absorb: true },
        Some(&ckpt),
    )
    .unwrap();
    assert_eq!(ok.submitted(), 400);
    drop(ok.finish());
}

/// Hot reload mid-stream: swaps land between batches, drop no updates,
/// and follow the carry rules (Full / SketchesOnly / typed rejection).
#[test]
fn hot_swap_mid_stream_drops_no_updates_and_follows_carry_rules() {
    let model = fitted(0xA);
    let retrained = fitted(0xB); // same schema, different chains
    let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
    let ld = GisetteGen { n: 350, d: 20, ..Default::default() }.generate(&ctx).unwrap();
    let wider = SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 12, num_chains: 6, depth: 5, ..Default::default() },
    )
    .unwrap();

    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let mut scorer = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        3,
        256,
        ServeOptions { record: true, absorb: true },
        None,
    )
    .unwrap();
    let updates = synth_updates(80, 900, 0x5107);
    for u in &updates[..300] {
        scorer.submit(u.clone());
    }
    // same model re-loaded → everything carries
    let same = Arc::new(ServedEnsemble::new(&model).unwrap());
    assert_eq!(scorer.swap_ensemble(same).unwrap(), SwapCarry::Full);
    for u in &updates[300..600] {
        scorer.submit(u.clone());
    }
    // retrained, same schema → sketches carry, delta resets
    let re = Arc::new(ServedEnsemble::new(&retrained).unwrap());
    assert_eq!(scorer.swap_ensemble(re).unwrap(), SwapCarry::SketchesOnly);
    // different schema → typed rejection, stream keeps flowing
    let alien = Arc::new(ServedEnsemble::new(&wider).unwrap());
    let r = scorer.swap_ensemble(alien);
    assert!(matches!(r, Err(SparxError::Unsupported(_))), "{:?}", r.err());
    for u in &updates[600..] {
        scorer.submit(u.clone());
    }
    let report = scorer.finish();
    assert_eq!(report.processed(), 900, "swaps must not drop updates");
    let merged = report.merged_scores();
    assert_eq!(merged.len(), 900, "recording must span every swap");
    // determinism of the swap point: replaying the same submits + swaps
    // yields the bit-identical merged log
    let mut replay = ShardedStreamScorer::from_ensemble(
        Arc::new(ServedEnsemble::new(&model).unwrap()),
        3,
        256,
        ServeOptions { record: true, absorb: true },
        None,
    )
    .unwrap();
    for u in &updates[..300] {
        replay.submit(u.clone());
    }
    replay.swap_ensemble(Arc::new(ServedEnsemble::new(&model).unwrap())).unwrap();
    for u in &updates[300..600] {
        replay.submit(u.clone());
    }
    replay.swap_ensemble(Arc::new(ServedEnsemble::new(&retrained).unwrap())).unwrap();
    for u in &updates[600..] {
        replay.submit(u.clone());
    }
    let merged2 = replay.finish().merged_scores();
    assert_eq!(merged, merged2, "swap points must be deterministic in the sub-streams");
    let _ = ens;
}
