#!/usr/bin/env python3
"""Regenerate the committed decoder corpus.

Each binary here is an *independent* reimplementation of the sparx wire
formats (artifact v3 container, absorb-checkpoint blocks, packed-u32
codec) so the Rust decoders are tested against bytes their own encoders
never produced. `ok_ckpt_v3.bin` mirrors
`sparx::testing::fuzz::sample_checkpoint()` field for field; the replay
test decodes it and compares against that struct, cross-checking both
implementations.

Run from this directory: `python3 gen_corpus.py`
"""
import struct
import zlib


def u8(v):
    return struct.pack("<B", v)


def u16(v):
    return struct.pack("<H", v)


def u32(v):
    return struct.pack("<I", v)


def u64(v):
    return struct.pack("<Q", v)


def f32(v):
    return struct.pack("<f", v)


def varint(v):
    out = b""
    while v >= 0x80:
        out += u8((v & 0x7F) | 0x80)
        v >>= 7
    return out + u8(v)


def pstr(s):
    b = s.encode()
    return u32(len(b)) + b


def f32_slice(vals):
    return u32(len(vals)) + b"".join(f32(v) for v in vals)


def crc(b):
    # artifact CRC-32 is IEEE reflected 0xEDB88320 == zlib.crc32
    return u32(zlib.crc32(b) & 0xFFFFFFFF)


def block(b):
    """v2+ artifact block: u32 length, bytes, u32 CRC-32."""
    return u32(len(b)) + b + crc(b)


def artifact_v3(detector, params, payload):
    body = b"SPRX" + u16(3) + pstr(detector) + block(params) + block(payload) + u32(0)
    return body + crc(body)


def ckpt_params(shards=2):
    return (
        u32(0xDEADBEEF)  # model fingerprint
        + u32(0x5A5A0001)  # schema fingerprint
        + u32(shards)
        + u64(4)  # cache_per_shard
        + u64(17)  # submitted
        + u8(1)  # absorb
        + u64(3)  # k
        + u64(2)  # depth
        + u64(2)  # num_chains
        + u64(4)  # cms_rows
        + u64(128)  # cms_cols
    )


def delta_level(pairs):
    """v3 level: u32 pair count + varint(gap) varint(count) per pair."""
    out = u32(len(pairs))
    prev = 0
    for i, (bucket, count) in enumerate(pairs):
        gap = bucket if i == 0 else bucket - prev
        out += varint(gap) + varint(count)
        prev = bucket
    return out


def snapshot(base):
    return (
        u64(40 + base)  # processed
        + u64(base // 2)  # evicted
        + u64(30 + base)  # absorbed
        + u32(2)  # entries
        + u64(base) + f32_slice([0.5] * 3)
        + u64(base + 2) + f32_slice([-1.25] * 3)
        + u32(4)  # delta levels = num_chains * depth
        + delta_level([(0, 1), (5, 2)])
        + delta_level([])
        + delta_level([(63, base + 1)])
        + delta_level([(2, 2), (3, 1), (100, 7)])
    )


def ckpt_payload():
    return u32(2) + snapshot(0) + snapshot(8)


def packed(vals, declared=None):
    """Packed u32 slice: u32 count + varint token stream (0 = zero run)."""
    out = u32(len(vals) if declared is None else declared)
    i = 0
    while i < len(vals):
        if vals[i] == 0:
            run = 1
            while i + run < len(vals) and vals[i + run] == 0:
                run += 1
            out += varint(0) + varint(run)
            i += run
        else:
            out += varint(vals[i])
            i += 1
    return out


def main():
    files = {
        # valid absorb-state checkpoint, == fuzz::sample_checkpoint()
        "ok_ckpt_v3.bin": artifact_v3("absorb-state", ckpt_params(), ckpt_payload()),
        # header declares shards=0 (CRCs valid) -> typed InvalidParams
        "bad_ckpt_shards0.bin": artifact_v3("absorb-state", ckpt_params(shards=0), ckpt_payload()),
        # 11 continuation bytes -> "varint overflows u64", never a hang
        "bad_codec_varint_overflow.bin": b"\xff" * 11,
        # declares 8 elements, then a zero run of 100 -> overrun error
        "bad_codec_rle_overrun.bin": u32(8) + varint(0) + varint(100),
        # well-formed packed block (mixed zero runs and values)
        "ok_packed_block.bin": packed([3, 0, 0, 0, 7, 0, 1, 300]),
        # header-only prefix: magic + version, everything else missing
        "bad_artifact_header_only.bin": b"SPRX" + u16(3),
    }
    for name, data in files.items():
        with open(name, "wb") as fh:
            fh.write(data)
        print(f"{name}: {len(data)} bytes")

    with open("ok_serve_lines.txt", "w") as fh:
        fh.write("1 3 0.5\n# a comment line\n\n2 0 red->blue\n17 7 -2.25\n")
    with open("bad_serve_lines.txt", "w") as fh:
        fh.write("not numbers at all\n1 2\n1 x notanum\nnan 3 0.5\n1 3 zero->\n1 3 inf\n")
    print("ok_serve_lines.txt / bad_serve_lines.txt written")


if __name__ == "__main__":
    main()
