#!/usr/bin/env python3
"""Regenerate the committed decoder corpus.

Each binary here is an *independent* reimplementation of the sparx wire
formats (artifact container v3/v6, absorb-checkpoint blocks including
the v5 decay/window/query tail, packed-u32 codec) so the Rust decoders
are tested against bytes their own encoders never produced.
`ok_ckpt_v4.bin` (named for the global-directory checkpoint layout it
carries) mirrors `sparx::testing::fuzz::sample_checkpoint()` field for
field in a current (v6) container; the replay test decodes it and
compares against that struct, cross-checking both implementations, and
asserts bit-identity with the Rust encoder's output. `ok_ckpt_v3.bin`
is a *legacy* per-shard checkpoint: the replay test pins its converted
(global) form, keeping the v2/v3 upgrade path honest.

Run from this directory: `python3 gen_corpus.py`
"""
import struct
import zlib


def u8(v):
    return struct.pack("<B", v)


def u16(v):
    return struct.pack("<H", v)


def u32(v):
    return struct.pack("<I", v)


def u64(v):
    return struct.pack("<Q", v)


def f32(v):
    return struct.pack("<f", v)


def varint(v):
    out = b""
    while v >= 0x80:
        out += u8((v & 0x7F) | 0x80)
        v >>= 7
    return out + u8(v)


def pstr(s):
    b = s.encode()
    return u32(len(b)) + b


def f32_slice(vals):
    return u32(len(vals)) + b"".join(f32(v) for v in vals)


def crc(b):
    # artifact CRC-32 is IEEE reflected 0xEDB88320 == zlib.crc32
    return u32(zlib.crc32(b) & 0xFFFFFFFF)


def block(b):
    """v2+ artifact block: u32 length, bytes, u32 CRC-32."""
    return u32(len(b)) + b + crc(b)


def artifact(version, detector, params, payload):
    """v2+ container: per-block CRCs, zero extension blocks, file CRC."""
    body = b"SPRX" + u16(version) + pstr(detector) + block(params) + block(payload) + u32(0)
    return body + crc(body)


def ckpt_params(shards=2):
    """Legacy (v<=3) header: per-shard cache budget, no counters."""
    return (
        u32(0xDEADBEEF)  # model fingerprint
        + u32(0x5A5A0001)  # schema fingerprint
        + u32(shards)
        + u64(4)  # cache_per_shard
        + u64(17)  # submitted
        + u8(1)  # absorb
        + u64(3)  # k
        + u64(2)  # depth
        + u64(2)  # num_chains
        + u64(4)  # cms_rows
        + u64(128)  # cms_cols
    )


def ckpt_params_v4():
    """v4 header (global cache budget + pool-wide counters) with the v5
    params tail (the capture-time decay schedule)."""
    return (
        u32(0xDEADBEEF)  # model fingerprint
        + u32(0x5A5A0001)  # schema fingerprint
        + u32(2)  # shards (informational from v4 on)
        + u64(4)  # cache_total (GLOBAL directory budget)
        + u64(17)  # submitted
        + u8(1)  # absorb
        + u64(3)  # k
        + u64(2)  # depth
        + u64(2)  # num_chains
        + u64(4)  # cms_rows
        + u64(128)  # cms_cols
        + u64(48)  # processed
        + u64(4)  # evicted
        + u64(38)  # absorbed
        + u64(8)  # half_life (v5 tail)
        + u64(6)  # window (v5 tail)
    )


def delta_level(pairs):
    """v3 level: u32 pair count + varint(gap) varint(count) per pair."""
    out = u32(len(pairs))
    prev = 0
    for i, (bucket, count) in enumerate(pairs):
        gap = bucket if i == 0 else bucket - prev
        out += varint(gap) + varint(count)
        prev = bucket
    return out


def snapshot(base):
    return (
        u64(40 + base)  # processed
        + u64(base // 2)  # evicted
        + u64(30 + base)  # absorbed
        + u32(2)  # entries
        + u64(base) + f32_slice([0.5] * 3)
        + u64(base + 2) + f32_slice([-1.25] * 3)
        + u32(4)  # delta levels = num_chains * depth
        + delta_level([(0, 1), (5, 2)])
        + delta_level([])
        + delta_level([(63, base + 1)])
        + delta_level([(2, 2), (3, 1), (100, 7)])
    )


def ckpt_payload():
    return u32(2) + snapshot(0) + snapshot(8)


def levels(levels_list):
    """v4 overlay: u32 level count, then one delta_level per level."""
    return u32(len(levels_list)) + b"".join(delta_level(lv) for lv in levels_list)


def ckpt_payload_v4():
    """Mirrors fuzz::sample_checkpoint(): seq-tagged global LRU->MRU
    entries, the visible and pending overlays, then the v5 payload tail
    (rotated prev-window overlay + named queries)."""
    min_positive = 2.0 ** -126  # f32::MIN_POSITIVE
    return (
        u32(4)  # entries
        + u64(0) + u64(3) + f32_slice([0.5] * 3)
        + u64(2) + u64(7) + f32_slice([-1.25] * 3)
        + u64(8) + u64(12) + f32_slice([0.5] * 3)
        + u64(10) + u64(16) + f32_slice([min_positive] * 3)
        + levels([[(0, 1), (5, 2)], [], [(63, 9)], [(2, 2), (3, 1), (100, 7)]])  # visible
        + levels([[(1, 1)], [], [], [(7, 3)]])  # pending
        + levels([[(4, 2)], [], [(0, 1), (64, 5)], []])  # prev_visible (v5 tail)
        + u32(1)  # named queries (v5 tail)
        + pstr("decayed.1k")
        + u64(4)  # query half_life
        + u64(2)  # query window
        + u64(5)  # query scored
        + levels([[(1, 2)], [], [], [(9, 1)]])  # query cur
        + levels([[], [(3, 4)], [], []])  # query prev
    )


def packed(vals, declared=None):
    """Packed u32 slice: u32 count + varint token stream (0 = zero run)."""
    out = u32(len(vals) if declared is None else declared)
    i = 0
    while i < len(vals):
        if vals[i] == 0:
            run = 1
            while i + run < len(vals) and vals[i + run] == 0:
                run += 1
            out += varint(0) + varint(run)
            i += run
        else:
            out += varint(vals[i])
            i += 1
    return out


def main():
    files = {
        # valid current-container absorb-state checkpoint,
        # == fuzz::sample_checkpoint() (and bit-identical to the Rust
        # encoder's output for it)
        "ok_ckpt_v4.bin": artifact(6, "absorb-state", ckpt_params_v4(), ckpt_payload_v4()),
        # valid *legacy* per-shard checkpoint: decodes via the v<=3
        # conversion path (replay test pins the converted global form)
        "ok_ckpt_v3.bin": artifact(3, "absorb-state", ckpt_params(), ckpt_payload()),
        # header declares shards=0 (CRCs valid) -> typed InvalidParams
        "bad_ckpt_shards0.bin": artifact(3, "absorb-state", ckpt_params(shards=0), ckpt_payload()),
        # 11 continuation bytes -> "varint overflows u64", never a hang
        "bad_codec_varint_overflow.bin": b"\xff" * 11,
        # declares 8 elements, then a zero run of 100 -> overrun error
        "bad_codec_rle_overrun.bin": u32(8) + varint(0) + varint(100),
        # well-formed packed block (mixed zero runs and values)
        "ok_packed_block.bin": packed([3, 0, 0, 0, 7, 0, 1, 300]),
        # header-only prefix: magic + version, everything else missing
        "bad_artifact_header_only.bin": b"SPRX" + u16(3),
    }
    for name, data in files.items():
        with open(name, "wb") as fh:
            fh.write(data)
        print(f"{name}: {len(data)} bytes")

    with open("ok_serve_lines.txt", "w") as fh:
        fh.write("1 3 0.5\n# a comment line\n\n2 0 red->blue\n17 7 -2.25\n")
    with open("bad_serve_lines.txt", "w") as fh:
        fh.write("not numbers at all\n1 2\n1 x notanum\nnan 3 0.5\n1 3 zero->\n1 3 inf\n")
    # TCP wire grammar (serve --listen): control verbs + data lines
    with open("ok_wire_commands.txt", "w") as fh:
        fh.write(
            "SCORE 17\nSTATS\nMETRICS\nCHECKPOINT\nRESHARD 4\n# comment\n\n"
            "42 f3 0.5\n7 loc NYC->Austin\nQUIT\nSHUTDOWN\n"
        )
    with open("bad_wire_commands.txt", "w") as fh:
        fh.write(
            "SCORE\nSCORE notanid\nSCORE 1 a b\nRESHARD\nRESHARD zero\nRESHARD 0\n"
            "STATS now\nQUIT loudly\nSHUTDOWN -f\nscore 42\n42 f0\n42 f0 NaN\n"
            "QUERY ADD na->me 1 1\n"
        )
    # detector spec-string grammar (--method / registry::create /
    # ensemble members= lists): good lines parse and round-trip through
    # the canonical printer; bad ones are typed InvalidParams
    with open("ok_spec_strings.txt", "w") as fh:
        fh.write(
            "sparx\n"
            "sparx?k=12&chains=8&depth=10&rate=0.5&seed=7\n"
            "xstream?depth=15\n"
            "spif?trees=20&depth=8\n"
            "dbscout?eps=0.25&min-pts=4\n"
            "ensemble?members=sparx:depth=6:seed=3,xstream&distill=true\n"
            "ensemble?members=sparx,xstream,spif,dbscout&schedule=round-robin&share=false\n"
        )
    with open("bad_spec_strings.txt", "w") as fh:
        fh.write(
            "?k=4\n"
            "sparx?\n"
            "sparx?k\n"
            "sparx?=4\n"
            "sparx?k=\n"
            "sparx?k=4&k=5\n"
            "spa rx?k=4\n"
            "sparx?dep th=4\n"
        )
    print("serve-line, wire-command and spec-string corpora written")


if __name__ == "__main__":
    main()
