//! Self-test for `sparx-lint` (ISSUE 7 acceptance): the repo at HEAD is
//! clean under every rule, and a seeded violation of *each* rule makes
//! the binary exit non-zero. Seeded trees are written under the OS temp
//! dir so the repo's own `src/` is never touched.

use std::path::{Path, PathBuf};
use std::process::Command;

/// Run the `sparx_lint` binary against `root`, returning
/// (exit code, stdout).
fn lint_bin(root: &Path, json: bool) -> (i32, String) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sparx_lint"));
    if json {
        cmd.arg("--json");
    }
    cmd.arg("--root").arg(root);
    let out = cmd.output().expect("spawn sparx_lint");
    (out.status.code().unwrap_or(-1), String::from_utf8_lossy(&out.stdout).into_owned())
}

fn repo_src() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

/// Write a one-file source tree under the temp dir and return its root.
fn seeded_tree(name: &str, rel: &str, contents: &str) -> PathBuf {
    let root = std::env::temp_dir()
        .join("sparx-lint-selftest")
        .join(format!("{}-{name}", std::process::id()));
    let file = root.join(rel);
    std::fs::create_dir_all(file.parent().expect("rel has a parent dir")).expect("mkdir tree");
    std::fs::write(&file, contents).expect("write seeded source");
    root
}

#[test]
fn repo_at_head_is_clean_via_lib() {
    let findings = sparx::lint::run_dir(&repo_src()).expect("lint the crate's own src/");
    assert!(
        findings.is_empty(),
        "sparx-lint must be clean on the repo at HEAD, found:\n{findings:#?}"
    );
}

#[test]
fn repo_at_head_is_clean_via_binary() {
    let (code, out) = lint_bin(&repo_src(), false);
    assert_eq!(code, 0, "binary should exit 0 on a clean tree, said:\n{out}");
    assert!(out.contains("clean"), "{out}");
}

/// One seeded violation per rule; the binary must exit 1 and name the
/// rule. This is the proof that every registered rule actually fires.
#[test]
fn each_rule_fires_on_a_seeded_violation() {
    let cases: &[(&str, &str, &str)] = &[
        ("no-panic-paths", "main.rs", "fn f(v: Option<u8>) -> u8 { v.unwrap() }\n"),
        (
            "unsafe-whitelist",
            "sparx/plan.rs",
            "fn f() { unsafe { core::hint::unreachable_unchecked() } }\n",
        ),
        (
            "error-taxonomy",
            "data/loader.rs",
            "pub fn save(p: &str) -> std::io::Result<()> { std::fs::write(p, b\"x\") }\n",
        ),
        (
            "cms-encapsulation",
            "sparx/plan.rs",
            "fn peek(c: &CountMinSketch) -> Vec<u32> { c.counts_u32() }\n",
        ),
    ];
    for (rule, rel, src) in cases {
        let root = seeded_tree(&format!("rule-{rule}"), rel, src);
        let (code, out) = lint_bin(&root, false);
        assert_eq!(code, 1, "seeded `{rule}` violation must exit 1, said:\n{out}");
        assert!(out.contains(&format!("[{rule}]")), "`{rule}` not named in:\n{out}");
    }
}

/// The SAFETY-comment requirement is a second mode of unsafe-whitelist:
/// whitelisted file, bare `unsafe`, no justification.
#[test]
fn unsafe_in_whitelisted_file_still_needs_safety_comment() {
    let bare = "fn f() { unsafe { core::hint::unreachable_unchecked() } }\n";
    let root = seeded_tree("unsafe-nosafety", "sparx/chain.rs", bare);
    let (code, out) = lint_bin(&root, false);
    assert_eq!(code, 1, "{out}");
    assert!(out.contains("SAFETY"), "{out}");

    let ok = "fn f() {\n    // SAFETY: provably unreachable\n    unsafe {\n        \
              core::hint::unreachable_unchecked()\n    }\n}\n";
    let root = seeded_tree("unsafe-safety", "sparx/chain.rs", ok);
    let (code, out) = lint_bin(&root, false);
    assert_eq!(code, 0, "{out}");
}

#[test]
fn escape_comment_suppresses_a_finding() {
    let src = "fn f(v: Option<u8>) -> u8 {\n    // lint:allow(no-panic-paths)\n    v.unwrap()\n}\n";
    let root = seeded_tree("escape", "main.rs", src);
    let (code, out) = lint_bin(&root, false);
    assert_eq!(code, 0, "escaped finding must not fail the lint:\n{out}");
}

#[test]
fn json_output_shape() {
    let root = seeded_tree("json", "main.rs", "fn f(v: Option<u8>) -> u8 { v.unwrap() }\n");
    let (code, out) = lint_bin(&root, true);
    assert_eq!(code, 1);
    assert!(out.starts_with("{\"count\":1,"), "{out}");
    assert!(out.contains("\"rule\":\"no-panic-paths\""), "{out}");
    assert!(out.contains("\"file\":\"main.rs\""), "{out}");
    assert!(out.contains("\"line\":1"), "{out}");

    let clean = seeded_tree("json-clean", "lib.rs", "fn ok() {}\n");
    let (code, out) = lint_bin(&clean, true);
    assert_eq!(code, 0);
    assert_eq!(out.trim(), "{\"count\":0,\"findings\":[]}");
}

#[test]
fn usage_errors_exit_2() {
    let out = Command::new(env!("CARGO_BIN_EXE_sparx_lint"))
        .arg("--no-such-flag")
        .output()
        .expect("spawn sparx_lint");
    assert_eq!(out.status.code(), Some(2));
    let out = Command::new(env!("CARGO_BIN_EXE_sparx_lint"))
        .args(["--root", "/nonexistent/lint/selftest/path"])
        .output()
        .expect("spawn sparx_lint");
    assert_eq!(out.status.code(), Some(2));
}
