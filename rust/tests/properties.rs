//! Property-based tests over randomized inputs (in-tree generator-driven
//! properties — the offline build has no proptest crate, so cases are
//! driven by the library's own deterministic PCG with fixed seeds and
//! wide case counts; failures print the violating seed for replay).

use std::collections::HashMap;

use sparx::cluster::{ClusterConfig, DistVec};
use sparx::hash::SignHasher;
use sparx::metrics::{auprc, auroc};
use sparx::sparx::chain::{Binner, NativeBinner};
use sparx::sparx::{ChainParams, CountMinSketch};
use sparx::util::{LruCache, Rng};

fn ctx(parts: usize, workers: usize) -> sparx::ClusterContext {
    ClusterConfig { num_partitions: parts, num_workers: workers, ..Default::default() }.build()
}

/// reduce_by_key must equal a sequential group-by for arbitrary inputs,
/// partition counts and worker counts.
#[test]
fn prop_reduce_by_key_equals_sequential_groupby() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(2000) as usize;
        let keys = 1 + rng.below(50) as u32;
        let parts = 1 + rng.below(12) as usize;
        let workers = 1 + rng.below(5) as usize;
        let pairs: Vec<(u32, u64)> =
            (0..n).map(|_| (rng.below(keys as u64) as u32, rng.below(100))).collect();
        let mut want: HashMap<u32, u64> = HashMap::new();
        for &(k, v) in &pairs {
            *want.entry(k).or_insert(0) += v;
        }
        let c = ctx(parts, workers);
        let dv = DistVec::from_vec(&c, pairs).unwrap();
        let got = dv.reduce_by_key(&c, |a, b| a + b).unwrap().collect_as_map(&c).unwrap();
        assert_eq!(got, want, "seed {seed} (n={n} keys={keys} parts={parts})");
    }
}

/// map/flat_map/filter/sample must preserve or bound counts and keep
/// deterministic results across worker counts.
#[test]
fn prop_ops_count_invariants() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0xA11CE);
        let n = rng.below(3000) as usize;
        let parts = 1 + rng.below(9) as usize;
        let data: Vec<u32> = (0..n).map(|_| rng.next_u32() % 1000).collect();
        let c = ctx(parts, 4);
        let dv = DistVec::from_vec(&c, data.clone()).unwrap();
        assert_eq!(dv.map(&c, |x| x + 1).unwrap().len(), n);
        let fm = dv.flat_map(&c, |&x| vec![x; (x % 3) as usize]).unwrap();
        let expect: usize = data.iter().map(|&x| (x % 3) as usize).sum();
        assert_eq!(fm.len(), expect, "seed {seed}");
        let filt = dv.filter(&c, |&x| x % 2 == 0).unwrap();
        assert_eq!(filt.len(), data.iter().filter(|&&x| x % 2 == 0).count());
        let rate = rng.f64();
        let s1 = dv.sample(&c, rate, 99).unwrap();
        let s2 = dv.sample(&c, rate, 99).unwrap();
        assert_eq!(
            s1.collect(&c).unwrap(),
            s2.collect(&c).unwrap(),
            "sampling must be deterministic"
        );
        assert!(s1.len() <= n);
    }
}

/// Results must not depend on the number of workers (only on data and
/// partitioning) — the shared-nothing substrate cannot leak scheduling.
#[test]
fn prop_worker_count_invariance() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0x30B);
        let n = 1 + rng.below(1500) as usize;
        let data: Vec<u64> = (0..n).map(|_| rng.below(10_000)).collect();
        let mut outs = Vec::new();
        for workers in [1usize, 3, 8] {
            let c = ctx(6, workers);
            let dv = DistVec::from_vec(&c, data.clone()).unwrap();
            let mapped = dv.map(&c, |x| x * 3 + 1).unwrap();
            outs.push(mapped.collect(&c).unwrap());
        }
        assert_eq!(outs[0], outs[1], "seed {seed}");
        assert_eq!(outs[1], outs[2], "seed {seed}");
    }
}

/// CMS can only over-estimate, never under-estimate; and merging partial
/// sketches equals inserting the union.
#[test]
fn prop_cms_overestimates_and_merges() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0xC35);
        let r = 1 + rng.below(8) as usize;
        let w = 8 + rng.below(256) as usize;
        let mut a = CountMinSketch::new(r, w);
        let mut b = CountMinSketch::new(r, w);
        let mut whole = CountMinSketch::new(r, w);
        let mut truth: HashMap<Vec<i32>, u32> = HashMap::new();
        for i in 0..1500 {
            let bin: Vec<i32> =
                (0..3).map(|_| rng.below(40) as i32 - 20).collect();
            *truth.entry(bin.clone()).or_insert(0) += 1;
            if i % 2 == 0 {
                a.insert(&bin);
            } else {
                b.insert(&bin);
            }
            whole.insert(&bin);
        }
        a.merge(&b);
        for (bin, &count) in &truth {
            assert!(a.query(bin) >= count, "seed {seed}: underestimate");
            assert_eq!(a.query(bin), whole.query(bin), "merge != union insert");
        }
    }
}

/// Binning invariants: equal sketches get equal bins; bins shift by
/// exactly ±1 at level 0 when a point moves by exactly Δ along the first
/// sampled feature; tile binning equals per-point binning.
#[test]
fn prop_binning_invariants() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0xB1A5);
        let k = 1 + rng.below(12) as usize;
        let l = 1 + rng.below(16) as usize;
        let delta: Vec<f32> = (0..k).map(|_| rng.range_f64(0.25, 4.0) as f32).collect();
        let chain = ChainParams::sample(&delta, l, &mut rng);
        let s: Vec<f32> = (0..k).map(|_| (rng.normal() * 3.0) as f32).collect();
        assert_eq!(chain.bins(&s), chain.bins(&s), "determinism");
        // translation by Δ along the first-sampled feature moves the
        // level-0 bin of that feature by exactly 1
        let f0 = chain.fs[0];
        let mut s2 = s.clone();
        s2[f0] += chain.deltamax[f0];
        let b1 = chain.bins(&s);
        let b2 = chain.bins(&s2);
        assert_eq!(b2[f0] - b1[f0], 1, "seed {seed}: level-0 shift along f0");
        // tile == per-point
        let n = 1 + rng.below(40) as usize;
        let flat: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let tiled = NativeBinner.tile_bins(&chain, &flat, n).unwrap();
        for i in 0..n {
            assert_eq!(
                &tiled[i * l * k..(i + 1) * l * k],
                chain.bins(&flat[i * k..(i + 1) * k]).as_slice()
            );
        }
    }
}

/// AUROC is invariant under strictly monotone score transforms and
/// anti-symmetric under negation; AUPRC of constant scores equals
/// prevalence.
#[test]
fn prop_metric_invariants() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0x4E7);
        let n = 20 + rng.below(500) as usize;
        let scores: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.bool(0.3)).collect();
        if labels.iter().all(|&b| b) || labels.iter().all(|&b| !b) {
            continue;
        }
        let a = auroc(&scores, &labels);
        let transformed: Vec<f64> = scores.iter().map(|s| (s * 2.0).exp()).collect();
        assert!((auroc(&transformed, &labels) - a).abs() < 1e-12, "monotone invariance");
        let negated: Vec<f64> = scores.iter().map(|s| -s).collect();
        assert!((auroc(&negated, &labels) - (1.0 - a)).abs() < 1e-9, "negation");
        let prevalence = labels.iter().filter(|&&b| b).count() as f64 / n as f64;
        let flat = vec![1.0; n];
        assert!((auprc(&flat, &labels) - prevalence).abs() < 1e-9, "AP of constant");
    }
}

/// LRU behaves exactly like a reference implementation under random
/// put/get streams.
#[test]
fn prop_lru_matches_reference_model() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed ^ 0x14B);
        let cap = 1 + rng.below(16) as usize;
        let mut lru = LruCache::new(cap);
        // reference: Vec<(key,value)> ordered most-recent-first
        let mut model: Vec<(u64, u64)> = Vec::new();
        for _ in 0..2000 {
            let key = rng.below(24);
            if rng.bool(0.5) {
                let val = rng.below(1000);
                lru.put(key, val);
                if let Some(pos) = model.iter().position(|(k, _)| *k == key) {
                    model.remove(pos);
                }
                model.insert(0, (key, val));
                model.truncate(cap);
            } else {
                let got = lru.get(&key).copied();
                let want = model.iter().position(|(k, _)| *k == key).map(|pos| {
                    let (k, v) = model.remove(pos);
                    model.insert(0, (k, v));
                    v
                });
                assert_eq!(got, want, "seed {seed} key {key}");
            }
            assert_eq!(lru.len(), model.len(), "seed {seed}");
        }
    }
}

/// The sign-hash family is deterministic across threads and matches the
/// advertised {1/6, 1/6, 2/3} distribution for every member.
#[test]
fn prop_sign_hash_family_thread_deterministic() {
    let fam = SignHasher::family(16, 1.0 / 3.0);
    let inputs: Vec<String> = (0..200).map(|i| format!("feature_{i}")).collect();
    let baseline: Vec<Vec<f32>> = fam
        .iter()
        .map(|h| inputs.iter().map(|s| h.hash_str(s)).collect())
        .collect();
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| {
                for (hi, h) in fam.iter().enumerate() {
                    for (si, input) in inputs.iter().enumerate() {
                        assert_eq!(h.hash_str(input), baseline[hi][si]);
                    }
                }
            });
        }
    });
}

/// Projection is linear: sketch(a + b) == sketch(a) + sketch(b) for
/// dense numeric rows (a direct consequence of Eq. 2 that the streaming
/// δ-updates rely on).
#[test]
fn prop_projection_linearity() {
    use sparx::data::Row;
    use sparx::sparx::Projector;
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0x11EA4);
        let d = 1 + rng.below(64) as usize;
        let k = 1 + rng.below(24) as usize;
        let names: Vec<String> = (0..d).map(|j| format!("f{j}")).collect();
        let p = Projector::new(k, 1.0 / 3.0).with_dense_schema(&names);
        let a: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let sum: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        let sa = p.project(&Row::dense(0, a), None).s;
        let sb = p.project(&Row::dense(1, b), None).s;
        let ss = p.project(&Row::dense(2, sum), None).s;
        for j in 0..k {
            assert!(
                (sa[j] + sb[j] - ss[j]).abs() < 1e-3,
                "seed {seed} dim {j}: {} + {} != {}",
                sa[j],
                sb[j],
                ss[j]
            );
        }
    }
}
