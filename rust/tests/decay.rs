//! Time-decayed / sliding-window streaming harness: the decayed score
//! sequence must be a pure function of the **logical clock** (the
//! global submit sequence), so it is bit-identical to `--shards 1` at
//! any shard count, across a mid-epoch kill → `--resume` cut at a
//! *different* shard count, and it must agree with a brute-force
//! sliding-window oracle assembled from checkpoints of an undecayed
//! reference run. Named queries (`QUERY ADD`) ride the same clock and
//! must survive the checkpoint round trip with their blocks intact.

use std::collections::HashMap;
use std::sync::{mpsc, Arc};

use sparx::api::SparxError;
use sparx::cluster::ClusterConfig;
use sparx::data::generators::GisetteGen;
use sparx::data::UpdateTriple;
use sparx::sparx::{
    AbsorbCheckpoint, DecaySpec, ServeOptions, ServedEnsemble, ShardReply, ShardedStreamScorer,
    SparxModel, SparxParams, StreamScore,
};

fn fitted(seed: u64) -> SparxModel {
    let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
    let ld = GisetteGen { n: 300, d: 16, ..Default::default() }.generate(&ctx).unwrap();
    SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 8, num_chains: 6, depth: 5, seed, ..Default::default() },
    )
    .unwrap()
}

/// Churny deterministic stream: ids recycle (mod `ids`) so a small
/// cache budget evicts — and therefore absorbs — constantly.
fn churn(n: usize, ids: u64) -> Vec<UpdateTriple> {
    (0..n)
        .map(|i| UpdateTriple::Num {
            id: (i as u64).wrapping_mul(7).wrapping_add(3) % ids,
            feature: format!("f{}", i % 16),
            delta: ((i % 13) as f64 - 6.0) * 0.25,
        })
        .collect()
}

fn temp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("sparx-decay-test-{}-{tag}.sparx", std::process::id()))
        .to_str()
        .expect("utf-8 temp path")
        .to_string()
}

// ------------------------------------------------- overlay arithmetic
// Brute-force helpers over the checkpoint's sorted-levels encoding
// (`Vec<Vec<(bucket, count)>>`, one inner vec per chain·depth level).

type Levels = Vec<Vec<(u32, u32)>>;

fn to_maps(levels: &Levels) -> Vec<HashMap<u32, u32>> {
    levels.iter().map(|lvl| lvl.iter().copied().collect()).collect()
}

fn to_sorted(maps: &[HashMap<u32, u32>]) -> Levels {
    maps.iter()
        .map(|m| {
            let mut v: Vec<(u32, u32)> = m.iter().map(|(&b, &c)| (b, c)).collect();
            v.sort_unstable_by_key(|&(b, _)| b);
            v
        })
        .collect()
}

/// Published increments between two cuts of an **undecayed** run, whose
/// visible overlay only ever grows: `later − earlier`, per level. An
/// `earlier` with no levels at all stands for the t=0 empty overlay.
fn block_between(later: &Levels, earlier: &Levels) -> Vec<HashMap<u32, u32>> {
    let earlier = to_maps(earlier);
    later
        .iter()
        .enumerate()
        .map(|(i, lvl)| {
            lvl.iter()
                .map(|&(bucket, count)| {
                    let before =
                        earlier.get(i).and_then(|m| m.get(&bucket)).copied().unwrap_or(0);
                    assert!(count >= before, "an undecayed overlay must be monotone");
                    (bucket, count - before)
                })
                .filter(|&(_, c)| c > 0)
                .collect()
        })
        .collect()
}

fn add_into(acc: &mut [HashMap<u32, u32>], inc: &[HashMap<u32, u32>]) {
    for (a, i) in acc.iter_mut().zip(inc) {
        for (&bucket, &count) in i {
            let c = a.entry(bucket).or_insert(0);
            *c = c.saturating_add(count);
        }
    }
}

/// The exact floor-halving the scorer applies: `c >>= 1`, drop zeros.
fn halve(acc: &mut [HashMap<u32, u32>]) {
    for a in acc.iter_mut() {
        a.retain(|_, c| {
            *c >>= 1;
            *c > 0
        });
    }
}

fn any_nonempty(levels: &Levels) -> bool {
    levels.iter().any(|l| !l.is_empty())
}

// ---------------------------------------------------------- the tests

/// The tentpole invariant with decay on: half-life halving and window
/// rotation are driven off the global submit sequence, so the recorded
/// decayed score log — under eviction churn — is bit-identical at any
/// shard count, and so is the checkpoint (modulo the informational
/// `shards` field).
#[test]
fn decayed_window_scores_are_identical_at_every_shard_count() {
    let model = fitted(0xDECA);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let updates = churn(3500, 300);
    let cache = 96usize; // < 300 distinct ids: the eviction regime
    let decay = DecaySpec::new(512, 512); // halve and rotate, coinciding

    let run = |shards: usize, decay: DecaySpec| -> (Vec<StreamScore>, AbsorbCheckpoint) {
        let opts = ServeOptions { record: true, absorb: true, decay, ..Default::default() };
        let mut scorer =
            ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(shards).cache(cache),
        None,
    ).unwrap();
        for u in &updates {
            scorer.submit(u.clone());
        }
        let ckpt = scorer.checkpoint().unwrap();
        let report = scorer.finish();
        assert_eq!(report.processed(), updates.len() as u64, "S={shards}: lost updates");
        assert!(report.evictions() > 0, "S={shards}: harness requires the eviction regime");
        (report.merged_scores(), ckpt)
    };

    let (want_scores, want_ckpt) = run(1, decay);
    assert_eq!(want_ckpt.half_life, 512);
    assert_eq!(want_ckpt.window, 512);
    // the schedule must be *live*: halving/rotating the absorbed overlay
    // has to move scores relative to the accumulate-forever behaviour
    let (undecayed, _) = run(1, DecaySpec::default());
    assert_ne!(want_scores, undecayed, "a 512/512 schedule must change decayed scores");
    for shards in [2usize, 4] {
        let (scores, mut ckpt) = run(shards, decay);
        assert_eq!(scores.len(), want_scores.len());
        for (i, (got, wanted)) in scores.iter().zip(&want_scores).enumerate() {
            assert_eq!(got, wanted, "S={shards}: decayed stream diverged at submit #{i}");
        }
        ckpt.shards = want_ckpt.shards; // the one informational field
        assert_eq!(ckpt, want_ckpt, "S={shards}: decay state leaked the shard layout");
    }
}

/// Satellite: the checkpoint cut lands mid-absorb-epoch (2000 % 256 ≠ 0
/// — unpublished pending increments in flight) *and* mid-window (2000 %
/// 512 ≠ 0), with a rotated `prev` block live. Kill, resume from the
/// file at a different shard count, and the concatenated score log is
/// still bit-identical to the uninterrupted single-shard run.
#[test]
fn mid_epoch_decay_checkpoint_resumes_bit_identically_across_shard_counts() {
    let model = fitted(0x11D0);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let updates = churn(4000, 500);
    let cache = 64usize;
    let opts =
        ServeOptions { record: true, absorb: true, decay: DecaySpec::new(0, 512), ..Default::default() };

    let mut full = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(1).cache(cache),
        None,
    ).unwrap();
    for u in &updates {
        full.submit(u.clone());
    }
    let full_report = full.finish();
    assert!(full_report.evictions() > 0, "harness requires the eviction regime");
    let want = full_report.merged_scores();

    let cut = 2000usize; // 2000 % 256 = 208 and 2000 % 512 = 464: doubly mid-period
    let mut first = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(3).cache(cache),
        None,
    ).unwrap();
    for u in &updates[..cut] {
        first.submit(u.clone());
    }
    let ckpt = first.checkpoint().unwrap();
    let path = temp_path("mid-epoch-resume");
    ckpt.save(&path, ckpt.manifest_for("in-memory")).unwrap();
    let part1 = first.finish().merged_scores();

    let loaded = AbsorbCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(loaded, ckpt, "file round trip must be exact");
    assert_eq!((loaded.half_life, loaded.window), (0, 512), "the schedule must persist");
    assert!(
        loaded.pending.iter().any(|l| !l.is_empty()),
        "a mid-epoch cut must carry unpublished increments"
    );
    assert!(
        any_nonempty(&loaded.prev_visible),
        "a mid-window cut after three rotations must carry a prev block"
    );

    for resume_shards in [5usize, 1] {
        let mut second = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(resume_shards).cache(cache),
        Some(&loaded),
    )
        .unwrap();
        assert_eq!(second.submitted(), cut as u64, "the logical clock resumes mid-period");
        for u in &updates[cut..] {
            second.submit(u.clone());
        }
        let part2 = second.finish().merged_scores();
        assert_eq!(part1.len() + part2.len(), want.len());
        let resumed: Vec<StreamScore> = part1.iter().cloned().chain(part2).collect();
        for (i, (got, wanted)) in resumed.iter().zip(&want).enumerate() {
            assert_eq!(got, wanted, "S=3→S={resume_shards}: diverged at submit #{i}");
        }
    }
}

/// Brute-force oracle. An undecayed reference run's visible overlay is
/// cumulative, so checkpoints cut at the decay boundaries recover each
/// period's published increment by subtraction; folding those blocks
/// through rotate/halve by hand must reproduce the decayed runs'
/// overlays exactly. Boundaries are multiples of the 256-submit absorb
/// epoch, so the publish schedule of all three runs is identical.
#[test]
fn sliding_window_and_half_life_overlays_match_a_brute_force_oracle() {
    let model = fitted(0x04AC);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let updates = churn(2900, 200);
    let cache = 64usize;
    let period = 512usize;
    let boundaries = [512usize, 1024, 1536, 2048, 2560];
    let t_final = updates.len(); // 2900: mid-period, publishes at 2816 live

    // one undecayed pass, checkpointing at every boundary and at the end
    let plain = ServeOptions { record: false, absorb: true, ..Default::default() };
    let mut cumulative: HashMap<usize, Levels> = HashMap::new();
    cumulative.insert(0, Vec::new()); // the t=0 empty overlay
    {
        let mut scorer =
            ShardedStreamScorer::from_ensemble(
        ens.clone(),
        plain.shards(1).cache(cache),
        None,
    ).unwrap();
        let mut cut_points: Vec<usize> = boundaries.to_vec();
        cut_points.push(t_final);
        let mut at = 0usize;
        for &stop in &cut_points {
            for u in &updates[at..stop] {
                scorer.submit(u.clone());
            }
            at = stop;
            cumulative.insert(stop, scorer.checkpoint().unwrap().visible);
        }
        drop(scorer.finish());
    }

    let decayed_cut = |spec: DecaySpec| -> AbsorbCheckpoint {
        let opts = ServeOptions { record: false, absorb: true, decay: spec, ..Default::default() };
        let mut scorer =
            ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(1).cache(cache),
        None,
    ).unwrap();
        for u in &updates {
            scorer.submit(u.clone());
        }
        let ckpt = scorer.checkpoint().unwrap();
        drop(scorer.finish());
        ckpt
    };

    // --- window only: cur = published in (2560, 2900], prev = (2048, 2560]
    let windowed = decayed_cut(DecaySpec::new(0, period as u64));
    let want_cur = to_sorted(&block_between(&cumulative[&t_final], &cumulative[&2560]));
    let want_prev = to_sorted(&block_between(&cumulative[&2560], &cumulative[&2048]));
    assert!(any_nonempty(&want_cur), "oracle harness: the live block must be non-trivial");
    assert!(any_nonempty(&want_prev), "oracle harness: the prev block must be non-trivial");
    assert_eq!(windowed.visible, want_cur, "windowed live block diverged from the oracle");
    assert_eq!(windowed.prev_visible, want_prev, "windowed prev block diverged from the oracle");

    // --- half-life only: fold acc = halve(acc + period increment) at
    // every boundary (publish lands *before* the halve), then add the
    // trailing partial period; no window → the prev block stays empty
    let halved = decayed_cut(DecaySpec::new(period as u64, 0));
    let levels = halved.visible.len();
    let mut acc: Vec<HashMap<u32, u32>> = vec![HashMap::new(); levels];
    let mut prev_t = 0usize;
    for &b in &boundaries {
        add_into(&mut acc, &block_between(&cumulative[&b], &cumulative[&prev_t]));
        halve(&mut acc);
        prev_t = b;
    }
    add_into(&mut acc, &block_between(&cumulative[&t_final], &cumulative[&prev_t]));
    let want_halved = to_sorted(&acc);
    assert!(any_nonempty(&want_halved), "oracle harness: halved mass must survive");
    assert_eq!(halved.visible, want_halved, "half-life overlay diverged from the oracle");
    assert!(!any_nonempty(&halved.prev_visible), "no window → no rotated block");
}

/// Named queries: registration/drop are typed and feeder-side, probes
/// answer deterministically (bit-equal to an uninterrupted reference at
/// the same clock position), and the full query state — spec, blocks,
/// served counter — survives checkpoint → kill → resume at a different
/// shard count.
#[test]
fn named_queries_survive_checkpoint_resume_and_score_identically() {
    let model = fitted(0x9E44);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let updates = churn(3000, 300);
    let cache = 96usize;
    let opts = ServeOptions { record: false, absorb: true, ..Default::default() };

    // probing a query must not perturb the stream, so the reference and
    // the interrupted run may probe at the same clock positions freely
    let probe = |scorer: &mut ShardedStreamScorer, id: u64, name: &str| -> f64 {
        let (tx, rx) = mpsc::channel();
        scorer.score_named(id, name, tx).unwrap();
        match rx.recv().unwrap() {
            ShardReply::QueryNamed { id: got, name: n, score } => {
                assert_eq!((got, n.as_str()), (id, name));
                score.unwrap_or_else(|| panic!("{id} was just updated and must be resident"))
            }
            other => panic!("expected QueryNamed, got {other:?}"),
        }
    };
    let add_all = |scorer: &mut ShardedStreamScorer| {
        scorer.query_add("w-512", 0, 512).unwrap();
        scorer.query_add("hl-512", 512, 0).unwrap();
        scorer.query_add("cum", 0, 0).unwrap();
    };
    let names = ["w-512", "hl-512", "cum"];
    let mid_id = updates[2599].id(); // MRU at the first probe point
    let end_id = updates[2999].id(); // MRU at the second probe point

    // uninterrupted single-shard reference
    let mut reference =
        ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(1).cache(cache),
        None,
    ).unwrap();
    let mut want_mid = Vec::new();
    let mut want_end = Vec::new();
    for (i, u) in updates.iter().enumerate() {
        if i == 1000 {
            add_all(&mut reference);
        }
        if i == 2600 {
            want_mid = names.map(|n| probe(&mut reference, mid_id, n)).to_vec();
        }
        reference.submit(u.clone());
    }
    for n in names {
        want_end.push(probe(&mut reference, end_id, n));
    }
    drop(reference.finish());

    // interrupted run at S=2: register at the same clock position, probe
    // at 2600, checkpoint mid-epoch (2600 % 256 = 40), tear down
    let mut first = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(2).cache(cache),
        None,
    ).unwrap();
    for (i, u) in updates[..2600].iter().enumerate() {
        if i == 1000 {
            add_all(&mut first);

            // the typed error paths, while the queries are live
            assert!(matches!(
                first.query_add("cum", 1, 1),
                Err(SparxError::InvalidParams(_))
            ));
            assert!(matches!(first.query_drop("ghost"), Err(SparxError::InvalidParams(_))));
            assert!(matches!(
                first.query_add("bad name", 0, 0),
                Err(SparxError::InvalidParams(_))
            ));
            first.query_add("doomed", 7, 0).unwrap();
            first.query_drop("doomed").unwrap();
        }
        first.submit(u.clone());
    }
    let got_mid: Vec<f64> = names.map(|n| probe(&mut first, mid_id, n)).to_vec();
    for (n, (got, want)) in names.iter().zip(got_mid.iter().zip(&want_mid)) {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "query {n}: probe at submit 2600 diverged from the reference"
        );
    }
    assert!(matches!(first.score_named(1, "ghost", mpsc::channel().0), Err(_)));
    let ckpt = first.checkpoint().unwrap();
    drop(first.finish());
    assert_eq!(ckpt.queries.len(), 3, "all registered queries persist");
    let q = &ckpt.queries[0];
    assert_eq!((q.name.as_str(), q.half_life, q.window, q.scored), ("w-512", 0, 512, 1));
    assert!(q.cur.iter().any(|l| !l.is_empty()) || q.prev.iter().any(|l| !l.is_empty()));

    // "new process" at S=3: the query layer resumes with blocks intact
    let bytes = ckpt.to_artifact().to_bytes();
    let loaded =
        AbsorbCheckpoint::from_artifact(&sparx::api::ModelArtifact::from_bytes(&bytes).unwrap())
            .unwrap();
    let mut second =
        ShardedStreamScorer::from_ensemble(
        ens.clone(),
        opts.shards(3).cache(cache),
        Some(&loaded),
    ).unwrap();
    let listed = second.query_list();
    assert_eq!(listed.len(), 3);
    for (info, rec) in listed.iter().zip(&loaded.queries) {
        assert_eq!(
            (info.name.as_str(), info.half_life, info.window, info.scored),
            (rec.name.as_str(), rec.half_life, rec.window, rec.scored)
        );
    }
    for u in &updates[2600..] {
        second.submit(u.clone());
    }
    for (n, want) in names.iter().zip(&want_end) {
        let got = probe(&mut second, end_id, n);
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "query {n}: probe after kill→resume diverged from the uninterrupted run"
        );
    }
    drop(second.finish());

    // registering a query without absorb mode is a typed error
    let mut plain = ShardedStreamScorer::from_ensemble(
        ens,
        ServeOptions { record: false, absorb: false, ..Default::default() }.shards(1).cache(cache),
        None,
    )
    .unwrap();
    assert!(matches!(plain.query_add("w", 0, 8), Err(SparxError::InvalidParams(_))));
    drop(plain.finish());
}

/// A resume whose decay schedule differs from the checkpoint's would
/// silently fork the score sequence — every mismatch must fail typed,
/// and the matching schedule must restore.
#[test]
fn decay_schedule_mismatch_on_resume_fails_typed() {
    let model = fitted(0x5CED);
    let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
    let spec = DecaySpec::new(512, 512);
    let mut scorer = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        ServeOptions { record: false, absorb: true, decay: spec, ..Default::default() }.shards(2).cache(32),
        None,
    )
    .unwrap();
    for u in churn(1500, 100) {
        scorer.submit(u);
    }
    let ckpt = scorer.checkpoint().unwrap();
    drop(scorer.finish());

    for wrong in
        [DecaySpec::default(), DecaySpec::new(512, 1024), DecaySpec::new(256, 512)]
    {
        let r = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        ServeOptions { record: false, absorb: true, decay: wrong, ..Default::default() }.shards(2).cache(32),
        Some(&ckpt),
    );
        assert!(
            matches!(r.err(), Some(SparxError::InvalidParams(_))),
            "schedule {wrong:?} against a (512, 512) checkpoint must be rejected"
        );
    }
    // decay without absorb is incoherent regardless of the checkpoint
    let r = ShardedStreamScorer::from_ensemble(
        ens.clone(),
        ServeOptions { record: false, absorb: false, decay: spec, ..Default::default() }.shards(2).cache(32),
        Some(&ckpt),
    );
    assert!(matches!(r.err(), Some(SparxError::InvalidParams(_))));

    // the matching schedule restores and continues the clock mid-period
    let ok = ShardedStreamScorer::from_ensemble(
        ens,
        ServeOptions { record: false, absorb: true, decay: spec, ..Default::default() }.shards(3).cache(32),
        Some(&ckpt),
    )
    .unwrap();
    assert_eq!(ok.submitted(), 1500);
    drop(ok.finish());
}
