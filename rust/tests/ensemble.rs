//! Acceptance harness for heterogeneous ensembles — the SUOD recipe on
//! the Sparx substrate, driven end-to-end through the public spec-string
//! API:
//!
//! 1. **Grammar** — `ensemble?members=...` round-trips through
//!    `registry::create`, with typed `InvalidParams` + edit-distance
//!    suggestions for near-miss keys and member kinds.
//! 2. **Combination** — rank-averaged scores are bit-identical under
//!    member permutation (integer rank accumulator) and across serving
//!    shard counts.
//! 3. **Artifacts** — the `ensemble` kind (format v6) save → load →
//!    re-save is byte-identical, scores included.
//! 4. **Distillation** — provenance (teacher spec, serving marker)
//!    survives save/load, and the distilled serve path resumes
//!    bit-identically from a file checkpoint at a different shard count.
//! 5. **Substrate sharing** — members with equal `(k, density)` hold the
//!    *same* dense-R allocation, and sharing never changes a score bit.
//! 6. **Scheduling** — LPT packing beats round-robin on mixed costs and
//!    never changes scores.

use sparx::api::{registry, Detector as _, DetectorSpec, FittedModel as _, SparxError};
use sparx::cluster::{ClusterConfig, ClusterContext};
use sparx::data::generators::GisetteGen;
use sparx::data::{Dataset, StreamGen, UpdateTriple};
use sparx::ensemble::cost::{assign_balanced, assign_round_robin, makespan};
use sparx::ensemble::{EnsembleParams, FittedEnsemble, Schedule};
use sparx::sparx::{AbsorbCheckpoint, ServeOptions, ShardedStreamScorer, StreamScore};

fn ctx(parts: usize) -> ClusterContext {
    ClusterConfig { num_partitions: parts, ..Default::default() }.build()
}

fn dense_data(ctx: &ClusterContext, n: usize, d: usize) -> Dataset {
    GisetteGen { n, d, ..Default::default() }.generate(ctx).unwrap().dataset
}

fn synth_updates(ids: u64, count: usize, d: usize, seed: u64) -> Vec<UpdateTriple> {
    let names: Vec<String> = (0..d).map(|j| format!("f{j}")).collect();
    let mut gen = StreamGen::new(ids, names, seed);
    (0..count).map(|_| gen.next_update()).collect()
}

fn temp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("sparx-ensemble-test-{}-{tag}.sparx", std::process::id()))
        .to_str()
        .expect("utf-8 temp path")
        .to_string()
}

/// All four member kinds fit under one ensemble; the combined scores
/// are normalised mean ranks, and `member_info` reports every member
/// with its measured costs.
#[test]
fn all_four_member_kinds_fit_under_one_ensemble() {
    let c = ctx(2);
    let data = dense_data(&c, 240, 12);
    let det = registry::create(
        "ensemble?members=sparx:k=8:chains=6:depth=5,xstream:k=8:depth=6,\
         spif:trees=8:depth=6,dbscout:min-pts=4",
    )
    .unwrap();
    let model = det.fit(&c, &data).unwrap();
    let scores = model.score(&c, &data).unwrap();
    assert_eq!(scores.len(), data.len());
    for (id, s) in &scores {
        assert!((0.0..=1.0).contains(s), "id {id}: rank-averaged score out of range: {s}");
    }

    let info = model.member_info();
    let kinds: Vec<&str> = info.iter().map(|m| m.kind.as_str()).collect();
    assert_eq!(kinds, ["sparx", "xstream", "spif", "dbscout"]);
    for m in &info {
        assert!(m.fit_micros > 0, "{}: calibration fit cost must be measured", m.spec);
        assert!(m.score_micros > 0, "{}: calibration score cost must be measured", m.spec);
        assert!(m.distilled_from.is_none(), "no distillation was requested");
    }
    assert!(
        info.iter().filter(|m| m.serving).count() <= 1,
        "at most one member serves evolving streams"
    );
}

/// The spec grammar fails typed, with edit-distance suggestions, at
/// every level: method name, ensemble key, member kind, member key.
#[test]
fn spec_grammar_suggests_fixes_for_near_misses() {
    let e = registry::create("ensembel?members=sparx").unwrap_err();
    assert!(matches!(e, SparxError::UnknownDetector(_)), "got {e:?}");
    assert!(e.to_string().contains("ensemble"), "no suggestion in {e}");

    let e = registry::create("ensemble?member=sparx").unwrap_err();
    assert!(matches!(e, SparxError::InvalidParams(_)), "got {e:?}");
    assert!(e.to_string().contains("members"), "no suggestion in {e}");

    let e = registry::create("ensemble?members=sparks").unwrap_err();
    assert!(e.to_string().contains("sparx"), "no member-kind suggestion in {e}");

    let e = registry::create("ensemble?members=sparx:dept=4").unwrap_err();
    assert!(e.to_string().contains("depth"), "no member-key suggestion in {e}");

    let e = registry::create("ensemble?schedule=fastest").unwrap_err();
    assert!(e.to_string().contains("round-robin"), "no schedule domain in {e}");
}

/// Rank-averaged combination is bit-identical under member permutation:
/// every member's seed is pinned, so the two ensembles hold the same
/// fitted members in a different order — the integer rank accumulator
/// must erase that order entirely.
#[test]
fn scores_are_bit_identical_under_member_permutation() {
    let c = ctx(2);
    let data = dense_data(&c, 200, 10);
    let fwd = "ensemble?members=sparx:seed=7:k=8:chains=6:depth=5,\
               xstream:seed=11:k=6:depth=6,spif:seed=13:trees=8:depth=6";
    let rev = "ensemble?members=spif:seed=13:trees=8:depth=6,\
               xstream:seed=11:k=6:depth=6,sparx:seed=7:k=8:chains=6:depth=5";
    let score = |spec: &str| {
        registry::create(spec).unwrap().fit(&c, &data).unwrap().score(&c, &data).unwrap()
    };
    let a = score(fwd);
    let b = score(rev);
    assert_eq!(a.len(), b.len());
    for ((ia, sa), (ib, sb)) in a.iter().zip(&b) {
        assert_eq!(ia, ib, "id order must match");
        assert_eq!(sa.to_bits(), sb.to_bits(), "id {ia}: member order leaked into the score");
    }
}

/// The ensemble artifact (format v6) round-trips exactly: loaded scores
/// are bit-identical, and re-saving the loaded model reproduces the
/// original bytes — nested member artifacts, measured costs, worker
/// assignment and all.
#[test]
fn ensemble_artifact_round_trips_bit_identically() {
    let c = ctx(2);
    let data = dense_data(&c, 200, 10);
    let det = registry::create(
        "ensemble?members=sparx:seed=3:k=8:chains=6:depth=5,xstream:seed=5:k=6:depth=6&distill=true",
    )
    .unwrap();
    let model = det.fit(&c, &data).unwrap();
    let before = model.score(&c, &data).unwrap();

    let art = model.to_artifact().unwrap();
    assert_eq!(art.payload.len(), model.model_bytes(), "model_bytes contract");
    let bytes = art.to_bytes();
    let loaded = registry::load_bytes(&bytes).unwrap();
    assert_eq!(loaded.name(), "ensemble");

    let after = loaded.score(&c, &data).unwrap();
    assert_eq!(before.len(), after.len());
    for ((ib, sb), (ia, sa)) in before.iter().zip(&after) {
        assert_eq!(ib, ia, "row ids must line up");
        assert_eq!(sb.to_bits(), sa.to_bits(), "score bits changed for id {ib}");
    }

    let resaved = loaded.to_artifact().unwrap().to_bytes();
    assert_eq!(resaved, bytes, "save → load → re-save must be byte-identical");
}

/// Distillation provenance — teacher spec, agreement-bearing student,
/// serving marker — survives save/load, and the distilled serve path
/// checkpoints and resumes bit-identically at a different shard count.
#[test]
fn distilled_provenance_survives_save_load_and_file_resume() {
    let c = ctx(2);
    let data = dense_data(&c, 240, 12);
    let det = registry::create(
        "ensemble?members=xstream:seed=5:k=8:depth=8,sparx:seed=3:k=8:chains=6:depth=5&distill=true",
    )
    .unwrap();
    let model = det.fit(&c, &data).unwrap();

    let info = model.member_info();
    assert_eq!(info.len(), 3, "two members plus the distilled student");
    let student = info.last().unwrap();
    assert_eq!(student.spec, "sparx:distilled");
    assert_eq!(student.kind, "sparx");
    assert!(student.serving, "the student must own the serve path");
    let teacher = student.distilled_from.clone().expect("student must name its teacher");
    assert!(
        info.iter().any(|m| m.spec == teacher),
        "teacher {teacher:?} must be one of the members"
    );
    for m in &info[..info.len() - 1] {
        assert!(!m.serving, "{}: only the student serves", m.spec);
    }

    // provenance is part of the artifact, not the process
    let bytes = model.to_artifact().unwrap().to_bytes();
    let loaded = registry::load_bytes(&bytes).unwrap();
    assert_eq!(loaded.member_info(), info, "member provenance must survive save/load");

    // kill → resume over the distilled serve path: S=3 interrupted,
    // file checkpoint, resumed at S=4 — bit-identical to uninterrupted
    let updates = synth_updates(300, 3000, 12, 0xD157);
    let cache = 64usize; // < 300 distinct IDs: real LRU churn crosses the cut
    let opts = ServeOptions::new().cache(cache).record(true).absorb(true);

    let mut full = loaded.stream_scorer_sharded(opts.shards(1)).unwrap();
    for u in &updates {
        full.submit(u.clone());
    }
    let want: Vec<StreamScore> = full.finish().merged_scores();

    let ens = loaded.served_ensemble().unwrap();
    let cut = updates.len() / 2;
    let mut first =
        ShardedStreamScorer::from_ensemble(ens.clone(), opts.shards(3), None).unwrap();
    for u in &updates[..cut] {
        first.submit(u.clone());
    }
    let ckpt = first.checkpoint().unwrap();
    let path = temp_path("distilled-resume");
    ckpt.save(&path, ckpt.manifest_for("in-memory")).unwrap();
    let part1 = first.finish().merged_scores();

    let restored = AbsorbCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    let mut second =
        ShardedStreamScorer::from_ensemble(ens, opts.shards(4), Some(&restored)).unwrap();
    for u in &updates[cut..] {
        second.submit(u.clone());
    }
    let part2 = second.finish().merged_scores();
    assert_eq!(part1.len() + part2.len(), want.len());
    let resumed: Vec<StreamScore> = part1.into_iter().chain(part2).collect();
    for (i, (got, wanted)) in resumed.iter().zip(&want).enumerate() {
        assert_eq!(got, wanted, "distilled serve path diverged at submit #{i}");
    }
}

/// Sharded serving over an ensemble model is deterministic in the shard
/// count: merged score logs at S=1 and S=4 are bit-identical.
#[test]
fn serving_is_bit_identical_across_shard_counts() {
    let c = ctx(2);
    let data = dense_data(&c, 200, 10);
    let det =
        registry::create("ensemble?members=sparx:seed=3:k=8:chains=6:depth=5,xstream:seed=5:k=6:depth=6")
            .unwrap();
    let model = det.fit(&c, &data).unwrap();
    let updates = synth_updates(150, 2000, 10, 0xACE5);
    let opts = ServeOptions::new().cache(4096).record(true);

    let run = |shards: usize| -> Vec<StreamScore> {
        let mut scorer = model.stream_scorer_sharded(opts.shards(shards)).unwrap();
        for u in &updates {
            scorer.submit(u.clone());
        }
        scorer.finish().merged_scores()
    };
    let want = run(1);
    let got = run(4);
    assert_eq!(got, want, "shard count leaked into the served scores");
}

/// SUOD module 1: members with equal `(k, density)` hold clones of one
/// projector — the dense R matrices are the *same allocation* — and
/// turning sharing off changes allocations but not one score bit.
#[test]
fn shared_projection_reuses_one_allocation_without_changing_scores() {
    let c = ctx(2);
    let data = dense_data(&c, 200, 10);
    let members = "sparx:seed=3:k=10:chains=6:depth=5,xstream:seed=5:k=10:depth=6";

    let fit = |share: bool| -> FittedEnsemble {
        let spec = DetectorSpec {
            members: Some(members.into()),
            share,
            ..Default::default()
        };
        FittedEnsemble::fit(&c, &data, &EnsembleParams::from_spec(&spec).unwrap()).unwrap()
    };

    let shared = fit(true);
    let r0 = shared.member_projector(0).and_then(|p| p.dense_r()).expect("sparx hashes");
    let r1 = shared.member_projector(1).and_then(|p| p.dense_r()).expect("xstream hashes");
    assert_eq!(r0.as_ptr(), r1.as_ptr(), "equal (k, density) members must share one R");

    let solo = fit(false);
    let s0 = solo.member_projector(0).and_then(|p| p.dense_r()).expect("sparx hashes");
    let s1 = solo.member_projector(1).and_then(|p| p.dense_r()).expect("xstream hashes");
    assert_ne!(s0.as_ptr(), s1.as_ptr(), "share=false must build independent matrices");
    assert_eq!(s0, r0, "the sign family is seeded by index: same bits either way");
    assert_eq!(s1, r1, "the sign family is seeded by index: same bits either way");

    let a = shared.score(&c, &data).unwrap();
    let b = solo.score(&c, &data).unwrap();
    for ((ia, sa), (ib, sb)) in a.iter().zip(&b) {
        assert_eq!(ia, ib);
        assert_eq!(sa.to_bits(), sb.to_bits(), "id {ia}: sharing changed a score bit");
    }
}

/// SUOD module 2: LPT packing beats round-robin on a mixed-cost member
/// set, both schedules are recorded in the fitted assignment, and the
/// schedule never changes a score bit.
#[test]
fn cost_balanced_schedule_beats_round_robin_and_never_changes_scores() {
    // the pure scheduling claim, on a cost profile shaped like a real
    // mixed ensemble (one expensive deep member, several cheap ones)
    let costs = [9000u64, 200, 150, 120, 100, 80];
    for workers in [2usize, 3, 4] {
        let lpt = makespan(&costs, &assign_balanced(&costs, workers), workers);
        let rr = makespan(&costs, &assign_round_robin(costs.len(), workers), workers);
        assert!(
            lpt <= rr,
            "W={workers}: LPT makespan {lpt} must not lose to round-robin {rr}"
        );
    }
    let lpt = makespan(&costs, &assign_balanced(&costs, 2), 2);
    let rr = makespan(&costs, &assign_round_robin(costs.len(), 2), 2);
    assert!(lpt < rr, "mixed costs at W=2 must show a strict win ({lpt} vs {rr})");

    // end to end: the schedule moves work, never results
    let c = ctx(2);
    let data = dense_data(&c, 200, 10);
    let members = "sparx:seed=3:k=8:chains=6:depth=5,xstream:seed=5:k=6:depth=6,\
                   spif:seed=7:trees=8:depth=6";
    let fit = |schedule: Schedule| -> FittedEnsemble {
        let spec = DetectorSpec {
            members: Some(members.into()),
            schedule,
            ..Default::default()
        };
        FittedEnsemble::fit(&c, &data, &EnsembleParams::from_spec(&spec).unwrap()).unwrap()
    };
    let balanced = fit(Schedule::Balanced);
    let naive = fit(Schedule::RoundRobin);
    assert_eq!(balanced.schedule(), Schedule::Balanced);
    assert_eq!(naive.schedule(), Schedule::RoundRobin);
    for i in 0..balanced.member_count() {
        assert!(balanced.member_worker(i).is_some(), "member {i} must record its worker");
    }
    let a = balanced.score(&c, &data).unwrap();
    let b = naive.score(&c, &data).unwrap();
    for ((ia, sa), (ib, sb)) in a.iter().zip(&b) {
        assert_eq!(ia, ib);
        assert_eq!(sa.to_bits(), sb.to_bits(), "id {ia}: the schedule changed a score bit");
    }
}
