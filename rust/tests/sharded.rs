//! Determinism + stress harness for the sharded streaming front-end.
//!
//! The core invariant under test: `murmur(ID) % S` pins every ID to one
//! shard, and the **feeder owns one global LRU directory** holding the
//! *total* cache budget, so eviction decisions are made in submit order
//! regardless of how many shards exist. Consequently **per-ID score
//! sequences — and eviction counts, and the resident set — are
//! bit-identical to a single-threaded `StreamScorer` with the same
//! total budget at any shard count, eviction churn included**. The
//! harness replays recorded update sequences through S = 1 and S ∈
//! {2, 4, 7} (including under seeded shuffles of the arrival order
//! *across* IDs — per-ID order preserved, streams never reorder a
//! single key), and asserts score bits, eviction counts and processed
//! totals line up exactly. A release-mode CI job reruns this file so
//! the thread interleavings are actually exercised at speed.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use sparx::api::{registry, Detector as _, DetectorSpec, FittedModel as _, SparxError};
use sparx::cluster::ClusterConfig;
use sparx::data::generators::GisetteGen;
use sparx::data::{StreamGen, UpdateTriple};
use sparx::sparx::{
    shard_of, ServeOptions, ServedEnsemble, ShardedStreamScorer, SparxModel, SparxParams,
    StreamScorer,
};
use sparx::util::Rng;

/// A sharded scorer with score recording on — what the old `recording`
/// constructor did, spelled through [`ServeOptions`].
fn recording(model: &SparxModel, shards: usize, cache: usize) -> ShardedStreamScorer {
    ShardedStreamScorer::from_ensemble(
        Arc::new(ServedEnsemble::new(model).unwrap()),
        ServeOptions::new().shards(shards).cache(cache).record(true),
        None,
    )
    .unwrap()
}

fn fitted(k: usize, chains: usize, depth: usize) -> SparxModel {
    let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
    let ld = GisetteGen { n: 400, d: 24, ..Default::default() }.generate(&ctx).unwrap();
    SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k, num_chains: chains, depth, ..Default::default() },
    )
    .unwrap()
}

fn synth_updates(ids: u64, count: usize, seed: u64) -> Vec<UpdateTriple> {
    let names: Vec<String> = (0..24).map(|j| format!("f{j}")).collect();
    let mut gen = StreamGen::new(ids, names, seed);
    (0..count).map(|_| gen.next_update()).collect()
}

/// Per-ID outlierness bit sequences from a flat score log.
fn per_id_bits(
    scores: impl IntoIterator<Item = sparx::sparx::StreamScore>,
) -> HashMap<u64, Vec<u64>> {
    let mut m: HashMap<u64, Vec<u64>> = HashMap::new();
    for s in scores {
        m.entry(s.id).or_default().push(s.outlierness.to_bits());
    }
    m
}

/// Seeded shuffle of the arrival order *across* IDs that preserves each
/// ID's own update order: split the sequence into per-ID queues, then
/// repeatedly pop the front of a randomly chosen non-empty queue.
fn shuffle_interleaving(updates: &[UpdateTriple], seed: u64) -> Vec<UpdateTriple> {
    let mut queues: Vec<VecDeque<UpdateTriple>> = Vec::new();
    let mut slot_of: HashMap<u64, usize> = HashMap::new();
    for u in updates {
        let next = queues.len();
        let slot = *slot_of.entry(u.id()).or_insert(next);
        if slot == next {
            queues.push(VecDeque::new());
        }
        queues[slot].push_back(u.clone());
    }
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(updates.len());
    while !queues.is_empty() {
        let pick = rng.below(queues.len() as u64) as usize;
        let u = queues[pick].pop_front().expect("queues are drained eagerly");
        out.push(u);
        if queues[pick].is_empty() {
            queues.swap_remove(pick);
        }
    }
    out
}

/// The acceptance criterion: per-ID score sequences from S ∈ {2, 4, 7}
/// shards are bit-identical to the single-threaded scorer, under a
/// different shuffled arrival order per shard count. Run in the
/// no-eviction regime, where the single-threaded sequence is the unique
/// reference for every interleaving.
#[test]
fn sharded_per_id_scores_bit_identical_to_single_threaded() {
    let model = fitted(12, 10, 6);
    let updates = synth_updates(300, 8000, 0xD15C);

    let mut reference = StreamScorer::new(&model, 4096).unwrap();
    let mut ref_log = Vec::new();
    for u in &updates {
        ref_log.push(reference.update(u));
    }
    assert_eq!(reference.evictions(), 0, "harness requires the no-eviction regime");
    let want = per_id_bits(ref_log);

    for (shards, shuffle_seed) in [(2usize, 11u64), (4, 22), (7, 33)] {
        let replay = shuffle_interleaving(&updates, shuffle_seed);
        assert_ne!(replay, updates, "the shuffle must actually change the interleaving");
        let mut scorer = recording(&model, shards, 4096);
        for u in replay {
            scorer.submit(u);
        }
        let report = scorer.finish();
        assert_eq!(report.processed(), updates.len() as u64, "S={shards}: lost updates");
        assert_eq!(report.evictions(), 0, "S={shards}: no-eviction regime violated");
        let got = per_id_bits(report.scores.into_iter().flatten().map(|(_, s)| s));
        assert_eq!(got.len(), want.len(), "S={shards}: distinct-ID count differs");
        for (id, seq) in &want {
            assert_eq!(
                got.get(id),
                Some(seq),
                "S={shards}: per-ID score sequence diverged for id {id}"
            );
        }
    }
}

/// The feeder-directory contract, under heavy eviction churn: the
/// merged score log (values, order, fresh flags), the eviction count
/// and the resident set at S = 4 are bit-identical to a single-threaded
/// scorer holding the same **total** cache budget — eviction decisions
/// are made by the feeder in global submit order, so the shard count
/// cannot perturb them.
#[test]
fn eviction_churn_matches_single_threaded_with_the_same_total_budget() {
    let model = fitted(8, 6, 5);
    let updates = synth_updates(500, 6000, 0xACE);
    let cache_total = 32; // far fewer slots than the 500 live IDs: constant churn

    let mut reference = StreamScorer::new(&model, cache_total).unwrap();
    let ref_log: Vec<_> = updates.iter().map(|u| reference.update(u)).collect();
    assert!(reference.evictions() > 0, "harness requires the eviction regime");

    let mut scorer = recording(&model, 4, cache_total);
    for u in &updates {
        scorer.submit(u.clone());
    }
    let report = scorer.finish();
    assert_eq!(report.processed(), reference.processed(), "processed counts diverged");
    assert_eq!(report.evictions(), reference.evictions(), "eviction counts diverged");
    assert_eq!(report.cached_ids(), reference.cached_ids(), "resident sets diverged");
    let merged = report.merged_scores();
    assert_eq!(merged.len(), ref_log.len(), "merged log length");
    for (i, (got, want)) in merged.iter().zip(&ref_log).enumerate() {
        assert_eq!(got, want, "merged log diverged at submit #{i}");
    }
}

/// One shard degenerates to the single-threaded scorer exactly: the
/// whole score log, not just per-ID projections, is bit-identical.
#[test]
fn one_shard_matches_the_unsharded_scorer_exactly() {
    let model = fitted(8, 6, 5);
    let updates = synth_updates(200, 2000, 7);
    let mut reference = StreamScorer::new(&model, 32).unwrap();
    let ref_log: Vec<_> = updates.iter().map(|u| reference.update(u)).collect();
    let mut sharded = recording(&model, 1, 32);
    for u in updates {
        sharded.submit(u);
    }
    let report = sharded.finish();
    let log: Vec<_> = report.scores[0].iter().map(|(_, sc)| sc.clone()).collect();
    assert_eq!(log, ref_log);
    assert_eq!(report.processed(), reference.processed());
    assert_eq!(report.evictions(), reference.evictions());
    assert_eq!(report.cached_ids(), reference.cached_ids());
}

/// The merge-order bugfix: recorded per-shard logs interleave back into
/// **global submit order** by sequence number, so the merged log of any
/// shard count is bit-identical to the single-threaded scorer's full
/// log — order included — in the no-eviction regime. (The old merge
/// concatenated per-shard logs and lost the submit order.)
#[test]
fn merged_scores_restore_global_submit_order_at_any_shard_count() {
    let model = fitted(10, 8, 5);
    let updates = synth_updates(250, 5000, 0x0DE4);
    let mut reference = StreamScorer::new(&model, 4096).unwrap();
    let ref_log: Vec<_> = updates.iter().map(|u| reference.update(u)).collect();
    assert_eq!(reference.evictions(), 0, "harness requires the no-eviction regime");
    for shards in [1usize, 3, 5] {
        let mut scorer = recording(&model, shards, 4096);
        for u in &updates {
            scorer.submit(u.clone());
        }
        let merged = scorer.finish().merged_scores();
        assert_eq!(merged.len(), ref_log.len(), "S={shards}: merged log length");
        for (i, (got, want)) in merged.iter().zip(&ref_log).enumerate() {
            assert_eq!(got, want, "S={shards}: merged log diverged at submit #{i}");
        }
    }
}

/// Stress: 4 shards × 50k updates against a tiny **total** cache
/// budget, exercising bounded-queue backpressure and feeder-driven LRU
/// churn under real contention (the release-mode CI job runs this at
/// full speed). Asserts termination (no deadlock), no lost updates,
/// and counter consistency: admitted − evicted == resident, per shard.
#[test]
fn stress_4_shards_50k_updates_small_cache_counters_consistent() {
    let model = fitted(8, 5, 4);
    let names: Vec<String> = (0..16).map(|j| format!("f{j}")).collect();
    let mut gen = StreamGen::new(5000, names, 0x57E55);
    let total = 50_000u64;
    let mut scorer = ShardedStreamScorer::new(&model, 4, 16).unwrap();
    for _ in 0..total {
        scorer.submit(gen.next_update());
    }
    let report = scorer.finish();
    assert_eq!(report.processed(), total, "updates were lost under contention");
    assert_eq!(report.shards.len(), 4);
    for (s, c) in report.shards.iter().enumerate() {
        assert!(c.processed > 0, "shard {s} starved — routing is broken");
        assert!(c.cached_ids <= 16, "shard {s} cache over capacity");
        assert_eq!(
            c.admitted - c.evictions,
            c.cached_ids as u64,
            "shard {s}: admitted − evicted must equal resident sketches"
        );
    }
    assert!(report.evictions() > 0, "a tiny cache must evict under churn");
    assert!(report.worst.is_some());
}

/// Murmur routing is deterministic, in range, and roughly balanced.
#[test]
fn shard_routing_is_stable_and_covers_all_shards() {
    for shards in [2usize, 4, 7] {
        let mut hit = vec![0u64; shards];
        for id in 0..10_000u64 {
            let s = shard_of(id, shards);
            assert!(s < shards);
            assert_eq!(s, shard_of(id, shards), "routing must be deterministic");
            hit[s] += 1;
        }
        for (s, &n) in hit.iter().enumerate() {
            assert!(n > 10_000 / shards as u64 / 2, "shard {s} underloaded: {n} hits");
        }
    }
}

/// The api-trait surface: sparx opens the sharded front-end, parameter
/// misuse fails typed, and detectors without a stream front-end reject
/// it with `Unsupported` — same taxonomy as `stream_scorer`.
#[test]
fn api_surface_and_typed_errors() {
    let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
    let ld = GisetteGen { n: 300, d: 16, ..Default::default() }.generate(&ctx).unwrap();
    let spec = DetectorSpec {
        k: Some(8),
        components: Some(4),
        depth: Some(4),
        sample_rate: Some(0.5),
        ..Default::default()
    };
    let model = registry::build("sparx", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
    let serve = |shards: usize, cache: usize| ServeOptions::new().shards(shards).cache(cache);
    let mut scorer = model.stream_scorer_sharded(serve(3, 64)).unwrap();
    scorer.submit(UpdateTriple::Num { id: 1, feature: "f0".into(), delta: 1.0 });
    assert_eq!(scorer.finish().processed(), 1);
    assert!(matches!(
        model.stream_scorer_sharded(serve(0, 64)),
        Err(SparxError::InvalidParams(_))
    ));
    assert!(matches!(
        model.stream_scorer_sharded(serve(2, 0)),
        Err(SparxError::InvalidParams(_))
    ));
    // a reloaded artifact opens the sharded front-end too
    let loaded = registry::load_bytes(&model.to_artifact().unwrap().to_bytes()).unwrap();
    assert!(loaded.stream_scorer_sharded(serve(2, 64)).is_ok());
    let spif = registry::build("spif", &spec).unwrap().fit(&ctx, &ld.dataset).unwrap();
    assert!(matches!(
        spif.stream_scorer_sharded(serve(2, 64)),
        Err(SparxError::Unsupported(_))
    ));
}
