//! Rank-averaged score combination.
//!
//! Heterogeneous members score on incomparable scales (sparx's
//! log₂-count, SPIF's path length, DBSCOUT's 0/1 verdict), so the
//! ensemble combines **ranks**, not raw scores: each member ranks its
//! points ascending (rank n−1 = most outlying), ties get the average
//! rank of their run, and the ensemble score is the mean rank normalised
//! to [0, 1].
//!
//! Determinism is load-bearing here — the acceptance contract says
//! ensemble scores are bit-identical under member *permutation* and at
//! any shard count — so the accumulator works in integers: each point
//! accumulates `2·rank` (tie runs contribute `start + end`, an exact
//! integer) as a `u64` per member. Integer addition is commutative and
//! associative, so summation order (and hence member order) cannot
//! perturb the result; the single final division by `2·m·(n−1)` is the
//! only float operation.

use std::collections::HashMap;

use crate::api::{Result, SparxError};

/// Combine per-member score sets by tie-averaged rank. Every member must
/// score the same id set; the output is `(id, mean rank / (n-1))` sorted
/// by id, in [0, 1] with higher = more outlying.
pub fn rank_average(per_member: &[Vec<(u64, f64)>]) -> Result<Vec<(u64, f64)>> {
    let m = per_member.len();
    let n = per_member.first().map_or(0, |v| v.len());
    if m == 0 || n == 0 {
        return Ok(Vec::new());
    }
    let mut acc: HashMap<u64, u64> = HashMap::with_capacity(n);
    for scores in per_member {
        if scores.len() != n {
            return Err(SparxError::InvalidParams(format!(
                "rank combination needs aligned member outputs: {} vs {} points",
                scores.len(),
                n
            )));
        }
        for (id, rank2) in ranks2(scores) {
            *acc.entry(id).or_insert(0) += rank2;
        }
    }
    if acc.len() != n {
        return Err(SparxError::InvalidParams(
            "ensemble members scored different id sets".into(),
        ));
    }
    let denom = (2 * m * (n - 1)).max(1) as f64;
    let mut out: Vec<(u64, f64)> = acc
        .into_iter()
        .map(|(id, sum)| (id, sum as f64 / denom))
        .collect();
    out.sort_by_key(|(id, _)| *id);
    Ok(out)
}

/// Doubled tie-averaged ranks: points sorted by `(score, id)` via
/// `total_cmp`; a tie run spanning positions `[start, end]` (0-based)
/// contributes the exact integer `start + end` — twice the conventional
/// average rank — so callers can accumulate without float rounding.
pub(crate) fn ranks2(scores: &[(u64, f64)]) -> Vec<(u64, u64)> {
    let mut sorted: Vec<(u64, f64)> = scores.to_vec();
    sorted.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
    let mut out = Vec::with_capacity(sorted.len());
    let mut start = 0usize;
    while start < sorted.len() {
        let mut end = start;
        while let (Some(a), Some(b)) = (sorted.get(start), sorted.get(end + 1)) {
            if a.1.total_cmp(&b.1) == std::cmp::Ordering::Equal {
                end += 1;
            } else {
                break;
            }
        }
        let rank2 = (start + end) as u64;
        for entry in sorted.get(start..=end).into_iter().flatten() {
            out.push((entry.0, rank2));
        }
        start = end + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invariant_under_member_permutation() {
        let a = vec![(0, 0.1), (1, 5.0), (2, -3.0), (3, 2.2)];
        let b = vec![(0, 100.0), (1, 4.0), (2, 4.0), (3, -9.0)];
        let c = vec![(0, 0.0), (1, 0.0), (2, 1.0), (3, 0.5)];
        let fwd = rank_average(&[a.clone(), b.clone(), c.clone()]).unwrap();
        let rev = rank_average(&[c, b, a]).unwrap();
        for ((i1, s1), (i2, s2)) in fwd.iter().zip(&rev) {
            assert_eq!(i1, i2);
            assert_eq!(s1.to_bits(), s2.to_bits(), "id {i1}: {s1} vs {s2}");
        }
    }

    #[test]
    fn ties_share_the_average_rank() {
        // three-way tie at the bottom: ranks {0,1,2} average to 1
        let scores = vec![(7, 1.0), (8, 1.0), (9, 1.0), (10, 2.0)];
        let r = ranks2(&scores);
        let lookup: std::collections::HashMap<u64, u64> = r.into_iter().collect();
        assert_eq!(lookup[&7], 2); // 2·1
        assert_eq!(lookup[&8], 2);
        assert_eq!(lookup[&9], 2);
        assert_eq!(lookup[&10], 6); // 2·3
    }

    #[test]
    fn single_member_normalises_to_unit_interval() {
        let scores = vec![(0, -1.0), (1, 0.0), (2, 99.0)];
        let out = rank_average(&[scores]).unwrap();
        assert_eq!(out, vec![(0, 0.0), (1, 0.5), (2, 1.0)]);
    }

    #[test]
    fn mismatched_id_sets_fail_typed() {
        let a = vec![(0, 1.0), (1, 2.0)];
        let b = vec![(0, 1.0), (2, 2.0)];
        assert!(matches!(
            rank_average(&[a.clone(), b]),
            Err(SparxError::InvalidParams(_))
        ));
        let short = vec![(0, 1.0)];
        assert!(matches!(
            rank_average(&[a, short]),
            Err(SparxError::InvalidParams(_))
        ));
    }

    #[test]
    fn empty_input_is_empty_output() {
        assert!(rank_average(&[]).unwrap().is_empty());
        assert!(rank_average(&[vec![]]).unwrap().is_empty());
    }
}
