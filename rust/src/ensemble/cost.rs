//! SUOD module 2 — cost-aware member scheduling.
//!
//! Ensemble members have wildly different fit costs (a depth-12 sparx vs
//! a 10-tree SPIF differ by orders of magnitude), so round-robin
//! assignment leaves pool workers idle behind the one slow member. The
//! ensemble layer instead *measures* each member on a small calibration
//! slice and packs the full fits with the classic LPT (longest
//! processing time first) greedy: sort members by measured cost
//! descending, always hand the next one to the least-loaded worker. LPT
//! is a 4/3-approximation of the optimal makespan — ample for a handful
//! of members — and, crucially, deterministic: ties break on member
//! index, so the same costs always produce the same assignment.
//!
//! Assignment only decides *where* a member fits, never *what* it
//! computes — scores are bit-identical under either schedule.

/// How ensemble members are packed onto pool workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Measured-cost LPT packing (default).
    Balanced,
    /// Naive `member i → worker i % W` (the A/B baseline).
    RoundRobin,
}

impl Schedule {
    /// Spec-string form (`schedule=balanced` / `schedule=round-robin`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Schedule::Balanced => "balanced",
            Schedule::RoundRobin => "round-robin",
        }
    }

    /// Parse the spec-string form; `None` for unknown values (the caller
    /// owns the typed error and its suggestion).
    pub fn parse(s: &str) -> Option<Schedule> {
        match s {
            "balanced" => Some(Schedule::Balanced),
            "round-robin" => Some(Schedule::RoundRobin),
            _ => None,
        }
    }

    /// Artifact wire tag.
    pub(crate) fn tag(&self) -> u8 {
        match self {
            Schedule::Balanced => 0,
            Schedule::RoundRobin => 1,
        }
    }

    /// Inverse of [`tag`](Self::tag); `None` for unknown tags.
    pub(crate) fn from_tag(tag: u8) -> Option<Schedule> {
        match tag {
            0 => Some(Schedule::Balanced),
            1 => Some(Schedule::RoundRobin),
            _ => None,
        }
    }
}

/// LPT greedy: members in cost-descending order (ties → lower index
/// first), each to the currently least-loaded worker (ties → lowest
/// worker index). Returns `assignment[i] = worker of member i`.
pub fn assign_balanced(costs: &[u64], workers: usize) -> Vec<usize> {
    let workers = workers.max(1);
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| {
        let ca = costs.get(a).copied().unwrap_or(0);
        let cb = costs.get(b).copied().unwrap_or(0);
        cb.cmp(&ca).then(a.cmp(&b))
    });
    let mut load = vec![0u64; workers];
    let mut assignment = vec![0usize; costs.len()];
    for i in order {
        let w = least_loaded(&load);
        if let (Some(slot), Some(l)) = (assignment.get_mut(i), load.get_mut(w)) {
            *slot = w;
            *l = l.saturating_add(costs.get(i).copied().unwrap_or(0));
        }
    }
    assignment
}

/// The naive baseline: `member i → worker i % workers`.
pub fn assign_round_robin(n: usize, workers: usize) -> Vec<usize> {
    let workers = workers.max(1);
    (0..n).map(|i| i % workers).collect()
}

/// Predicted wall-clock of an assignment: the heaviest worker's total
/// cost. What the `ensemble` bench arm compares across schedules.
pub fn makespan(costs: &[u64], assignment: &[usize], workers: usize) -> u64 {
    let mut load = vec![0u64; workers.max(1)];
    for (c, &w) in costs.iter().zip(assignment) {
        if let Some(l) = load.get_mut(w) {
            *l = l.saturating_add(*c);
        }
    }
    load.iter().copied().max().unwrap_or(0)
}

fn least_loaded(load: &[u64]) -> usize {
    let mut best = 0usize;
    let mut best_load = u64::MAX;
    for (w, &l) in load.iter().enumerate() {
        if l < best_load {
            best = w;
            best_load = l;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lpt_beats_round_robin_on_mixed_costs() {
        // one expensive member followed by cheap ones: round-robin piles
        // the expensive one plus every W-th cheap one on worker 0
        let costs = [1000, 10, 10, 10, 10, 10, 10, 10];
        let balanced = assign_balanced(&costs, 2);
        let naive = assign_round_robin(costs.len(), 2);
        let mb = makespan(&costs, &balanced, 2);
        let mn = makespan(&costs, &naive, 2);
        assert!(mb < mn, "LPT {mb} should beat round-robin {mn}");
        assert_eq!(mb, 1000, "heaviest member alone bounds the makespan");
    }

    #[test]
    fn assignment_is_deterministic_with_ties() {
        let costs = [5, 5, 5, 5];
        assert_eq!(assign_balanced(&costs, 2), assign_balanced(&costs, 2));
        // ties break on index: member 0 → worker 0, member 1 → worker 1, …
        assert_eq!(assign_balanced(&costs, 2), vec![0, 1, 0, 1]);
    }

    #[test]
    fn degenerate_shapes_stay_in_bounds() {
        assert!(assign_balanced(&[], 4).is_empty());
        assert_eq!(assign_balanced(&[7, 7], 0), vec![0, 0], "0 workers clamps to 1");
        assert_eq!(assign_round_robin(3, 1), vec![0, 0, 0]);
        assert_eq!(makespan(&[], &[], 3), 0);
    }

    #[test]
    fn makespan_sums_per_worker() {
        let costs = [3, 4, 5];
        let assignment = [0, 0, 1];
        assert_eq!(makespan(&costs, &assignment, 2), 7);
    }
}
