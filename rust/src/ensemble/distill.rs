//! SUOD module 3 — pseudo-supervised distillation.
//!
//! An ensemble whose most expensive member dominates serving cost can
//! substitute a cheap **student** on the serve path: a small sparx model
//! fit on the same data, selected by how faithfully it reproduces the
//! expensive **teacher**'s ranking on the calibration slice (Spearman
//! rank agreement — scales are incomparable, ranks are not). The batch
//! `score` path still rank-averages the real members; only the
//! evolving-stream front-end swaps in the student, with full provenance
//! (teacher spec + measured agreement) carried through artifacts,
//! checkpoints and `STATS`.

use std::collections::HashMap;
use std::time::Instant;

use crate::api::{FittedSparx, Result};
use crate::cluster::ClusterContext;
use crate::data::Dataset;
use crate::sparx::{ScoreMode, SparxModel, SparxParams};

use super::combine;

/// A distilled serve-path substitute with its lineage.
#[derive(Debug)]
pub(crate) struct Distilled {
    /// Canonical spec text of the member the student was trained to mimic.
    pub(crate) teacher: String,
    /// Spearman rank agreement with the teacher on the calibration slice.
    pub(crate) agreement: f64,
    pub(crate) student: FittedSparx,
    pub(crate) fit_micros: u64,
    pub(crate) score_micros: u64,
}

/// Candidate student depths, cheapest first. All candidates use a small
/// fixed budget (K=16, M=16) — the point is a scorer that is cheap at
/// serve time, not another heavyweight member.
const STUDENT_DEPTHS: [usize; 3] = [4, 6, 8];

/// Fit candidate students on the full dataset and keep the one whose
/// calibration-slice ranking agrees best with the teacher's (ties →
/// shallower). `teacher_calib` is the teacher's scores on `calib`.
pub(crate) fn distill(
    ctx: &ClusterContext,
    data: &Dataset,
    calib: &Dataset,
    teacher: &str,
    teacher_calib: &[(u64, f64)],
    seed: u64,
) -> Result<Distilled> {
    let mut best: Option<Distilled> = None;
    for depth in STUDENT_DEPTHS {
        let params = SparxParams {
            k: 16,
            num_chains: 16,
            depth,
            sample_rate: 1.0,
            score_mode: ScoreMode::Log2,
            seed,
            ..Default::default()
        };
        let t0 = Instant::now();
        let model = SparxModel::fit(ctx, data, &params)?;
        let fit_micros = elapsed_micros(t0);
        let t0 = Instant::now();
        let student_calib = model.score_dataset(ctx, calib)?;
        let score_micros = elapsed_micros(t0);
        let agreement = rank_agreement(teacher_calib, &student_calib);
        if best.as_ref().map_or(true, |b| agreement > b.agreement) {
            best = Some(Distilled {
                teacher: teacher.to_string(),
                agreement,
                student: FittedSparx::from_model(model),
                fit_micros,
                score_micros,
            });
        }
    }
    best.ok_or_else(|| {
        crate::api::SparxError::InvalidParams("distillation produced no candidate".into())
    })
}

/// Wall-clock µs since `t0`, clamped to ≥ 1 so a fast member never
/// reports zero cost (the LPT packer treats 0 as "free"). Wall time, not
/// thread CPU time: member fits are internally multi-threaded, so the
/// calling thread's CPU clock would under-measure exactly the expensive
/// members the cost model exists to catch.
pub(crate) fn elapsed_micros(t0: Instant) -> u64 {
    (t0.elapsed().as_micros() as u64).max(1)
}

/// Spearman's ρ: Pearson correlation of tie-averaged ranks, paired by
/// id. Ids missing on either side are skipped; degenerate variance
/// (constant ranking) yields 0.0 rather than NaN.
pub(crate) fn rank_agreement(a: &[(u64, f64)], b: &[(u64, f64)]) -> f64 {
    let ra: HashMap<u64, u64> = combine::ranks2(a).into_iter().collect();
    let rb: HashMap<u64, u64> = combine::ranks2(b).into_iter().collect();
    let mut pairs: Vec<(f64, f64)> = Vec::with_capacity(ra.len().min(rb.len()));
    for (id, &x) in &ra {
        if let Some(&y) = rb.get(id) {
            pairs.push((x as f64, y as f64));
        }
    }
    if pairs.len() < 2 {
        return 0.0;
    }
    let n = pairs.len() as f64;
    let mx = pairs.iter().map(|(x, _)| x).sum::<f64>() / n;
    let my = pairs.iter().map(|(_, y)| y).sum::<f64>() / n;
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in &pairs {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx <= 0.0 || dy <= 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_rankings_agree_perfectly() {
        let a = vec![(0, 1.0), (1, 2.0), (2, 3.0), (3, 4.0)];
        let b = vec![(0, 10.0), (1, 20.0), (2, 30.0), (3, 40.0)];
        assert!((rank_agreement(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reversed_rankings_anti_agree() {
        let a = vec![(0, 1.0), (1, 2.0), (2, 3.0)];
        let b = vec![(0, 3.0), (1, 2.0), (2, 1.0)];
        assert!((rank_agreement(&a, &b) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn constant_rankings_are_zero_not_nan() {
        let a = vec![(0, 1.0), (1, 1.0), (2, 1.0)];
        let b = vec![(0, 5.0), (1, 2.0), (2, 9.0)];
        assert_eq!(rank_agreement(&a, &b), 0.0);
        assert_eq!(rank_agreement(&a, &[]), 0.0);
    }
}
