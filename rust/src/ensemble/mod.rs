//! Heterogeneous detector ensembles — the SUOD recipe on the Sparx
//! substrate.
//!
//! A single detector family has a single blind spot; SUOD's answer
//! (Zhao et al., MLSys 2021) is to run many *heterogeneous* detectors
//! and make the ensemble cheap with three systems modules, all
//! reproduced here on the distributed Sparx runtime:
//!
//! 1. **Shared projection substrate** (`share=true`, default): members
//!    whose schemas agree on `(k, density)` receive clones of **one**
//!    [`Projector`] — the O(D·K) dense sign matrix lives behind an
//!    `Arc`, so N members hold one allocation instead of N. Sharing
//!    never changes scores: the sign-hash family is seeded by index, so
//!    a shared projector is bit-identical to the one each member would
//!    have built alone.
//! 2. **Cost-aware scheduling** ([`cost`]): every member is fit+scored
//!    on a small calibration slice first; the measured costs drive LPT
//!    packing of the full fits onto pool workers
//!    ([`crate::cluster::pool::run_assigned`]). `schedule=round-robin`
//!    keeps the naive packing for A/B comparison — assignment moves
//!    work, never changes results.
//! 3. **Distillation** ([`Ensemble distillation`](self) — `distill=true`):
//!    a cheap sparx student is fit against the *most expensive* member
//!    and substituted on the evolving-stream serve path, with provenance
//!    (teacher spec, rank agreement) carried through artifacts and
//!    `STATS`.
//!
//! Scores combine by tie-averaged **rank** ([`combine`]) — deterministic
//! under member permutation and shard count by construction.
//!
//! ```no_run
//! use sparx::api::{registry, Detector};
//! # fn main() -> sparx::api::Result<()> {
//! let det = registry::create("ensemble?members=sparx:depth=6,xstream,spif&distill=true")?;
//! # Ok(()) }
//! ```

pub mod combine;
pub mod cost;
mod distill;

pub use cost::Schedule;

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::api::artifact::{self, ModelArtifact};
use crate::api::registry::{self, DetectorSpec};
use crate::api::{
    self, Detector, FittedModel, FittedSparx, MethodSpec, Result, SparxError,
};
use crate::baselines::dbscout::FittedDbscout;
use crate::baselines::{Dbscout, DbscoutParams, Spif, SpifParams, XStream, XStreamParams};
use crate::cluster::{pool, ClusterContext, DistVec};
use crate::data::Dataset;
use crate::sparx::{
    MemberInfo, Projector, ServeOptions, ServedEnsemble, ShardedStreamScorer, SparxModel,
    SparxParams, StreamScorer,
};

/// Decode-side cap on the member count (a corrupt artifact must not
/// allocate unbounded nested models).
pub const MAX_MEMBERS: usize = 64;

/// Members when `members=` is not given: the two hash-projection
/// families, which accept dense *and* sparse data.
pub const DEFAULT_MEMBERS: &str = "sparx,xstream";

/// Calibration slice size (rows) for the cost model and distillation
/// agreement.
const CALIB_ROWS: usize = 256;

/// Seed when neither the ensemble nor a member sets one — matches the
/// library-wide default.
const DEFAULT_SEED: u64 = 0x5AB4;

/// The member kinds an ensemble can host (no nesting).
const MEMBER_KINDS: [&str; 4] = ["sparx", "xstream", "spif", "dbscout"];

/// Resolved per-member hyperparameters.
#[derive(Debug, Clone)]
pub enum MemberConfig {
    Sparx(SparxParams),
    XStream(XStreamParams),
    Spif(SpifParams),
    Dbscout {
        params: DbscoutParams,
        /// eps unset → resolved at fit time via the elbow heuristic,
        /// exactly like the standalone [`crate::baselines::DbscoutDetector`].
        auto_eps: bool,
    },
}

impl MemberConfig {
    /// Registry name of the member's method.
    pub fn kind(&self) -> &'static str {
        match self {
            MemberConfig::Sparx(_) => "sparx",
            MemberConfig::XStream(_) => "xstream",
            MemberConfig::Spif(_) => "spif",
            MemberConfig::Dbscout { .. } => "dbscout",
        }
    }

    /// The projection substrate this member would build, if it hashes:
    /// `(k, density)` for sparx/xstream with `k > 0`. Members with equal
    /// keys can share one [`Projector`].
    fn projection_key(&self) -> Option<(usize, u64)> {
        match self {
            MemberConfig::Sparx(p) if p.k > 0 => Some((p.k, p.density.to_bits())),
            MemberConfig::XStream(p) if p.k > 0 => Some((p.k, p.density.to_bits())),
            _ => None,
        }
    }
}

/// One parsed ensemble member: its canonical spec text (what artifacts
/// and `STATS` echo back) plus resolved hyperparameters.
#[derive(Debug, Clone)]
pub struct MemberSpec {
    text: String,
    config: MemberConfig,
}

impl MemberSpec {
    /// Parse one member from its `name(:key=val)*` form.
    pub fn parse(text: &str) -> Result<MemberSpec> {
        Self::from_method_spec(&MethodSpec::parse_member(text)?, None)
    }

    /// Resolve a parsed member spec. `default_seed`, when given, fills
    /// the member's seed if the spec didn't set one — how the ensemble
    /// de-correlates otherwise-identical members.
    pub fn from_method_spec(ms: &MethodSpec, default_seed: Option<u64>) -> Result<MemberSpec> {
        if !MEMBER_KINDS.contains(&ms.name.as_str()) {
            let hint = crate::util::closest_match(&ms.name, &MEMBER_KINDS)
                .map(|s| format!(" — did you mean {s:?}?"))
                .unwrap_or_default();
            return Err(SparxError::InvalidParams(format!(
                "ensemble members must be one of {} (got {:?}){hint}",
                MEMBER_KINDS.join("|"),
                ms.name
            )));
        }
        let mut spec = DetectorSpec::default();
        for (key, value) in &ms.params {
            registry::apply_key(&ms.name, key, value, &mut spec)?;
        }
        if spec.seed.is_none() {
            spec.seed = default_seed;
        }
        let config = resolve_config(&ms.name, &spec)?;
        Ok(MemberSpec { text: ms.print_member(), config })
    }

    /// Canonical `name(:key=val)*` text.
    pub fn text(&self) -> &str {
        &self.text
    }

    pub fn config(&self) -> &MemberConfig {
        &self.config
    }
}

fn resolve_config(kind: &str, spec: &DetectorSpec) -> Result<MemberConfig> {
    match kind {
        "sparx" => {
            let mut p = SparxParams::default();
            if let Some(k) = spec.k {
                p.k = k;
            }
            if let Some(m) = spec.components {
                p.num_chains = m;
            }
            if let Some(l) = spec.depth {
                p.depth = l;
            }
            if let Some(rate) = spec.sample_rate {
                p.sample_rate = rate;
            }
            if let Some(seed) = spec.seed {
                p.seed = seed;
            }
            p.exec_mode = spec.exec_mode;
            p.validate().map_err(SparxError::InvalidParams)?;
            Ok(MemberConfig::Sparx(p))
        }
        "xstream" => {
            let mut p = XStreamParams::default();
            if let Some(k) = spec.k {
                p.k = k;
            }
            if let Some(m) = spec.components {
                p.num_chains = m;
            }
            if let Some(l) = spec.depth {
                p.depth = l;
            }
            if let Some(seed) = spec.seed {
                p.seed = seed;
            }
            p.validate().map_err(SparxError::InvalidParams)?;
            Ok(MemberConfig::XStream(p))
        }
        "spif" => {
            let mut p = SpifParams::default();
            if let Some(t) = spec.components {
                p.num_trees = t;
            }
            if let Some(l) = spec.depth {
                p.max_depth = l;
            }
            if let Some(rate) = spec.sample_rate {
                p.sample_rate = rate;
            }
            if let Some(seed) = spec.seed {
                p.seed = seed;
            }
            p.validate().map_err(SparxError::InvalidParams)?;
            Ok(MemberConfig::Spif(p))
        }
        "dbscout" => {
            let mut p = DbscoutParams::default();
            let auto_eps = spec.eps.is_none();
            if let Some(eps) = spec.eps {
                p.eps = eps;
            }
            if let Some(min_pts) = spec.min_pts {
                p.min_pts = min_pts;
            }
            p.validate().map_err(SparxError::InvalidParams)?;
            Ok(MemberConfig::Dbscout { params: p, auto_eps })
        }
        other => Err(SparxError::InvalidParams(format!(
            "ensemble members must be one of {} (got {other:?})",
            MEMBER_KINDS.join("|")
        ))),
    }
}

/// Ensemble hyperparameters (see the module docs for the three SUOD
/// modules each field toggles).
#[derive(Debug, Clone)]
pub struct EnsembleParams {
    pub members: Vec<MemberSpec>,
    /// Fit a cheap sparx student against the most expensive member and
    /// serve streams through it.
    pub distill: bool,
    /// Share one projector among members with equal `(k, density)`.
    pub share_projection: bool,
    pub schedule: Schedule,
    /// Base seed: member i defaults to `seed + i` unless its spec pins
    /// one; the distilled student reuses it verbatim.
    pub seed: u64,
}

impl Default for EnsembleParams {
    fn default() -> Self {
        EnsembleParams {
            members: Vec::new(),
            distill: false,
            share_projection: true,
            schedule: Schedule::Balanced,
            seed: DEFAULT_SEED,
        }
    }
}

impl EnsembleParams {
    /// Hyperparameter sanity rules, mirrored on the other detectors.
    pub fn validate(&self) -> std::result::Result<(), String> {
        if self.members.is_empty() {
            return Err("ensemble needs at least one member (members=...)".into());
        }
        if self.members.len() > MAX_MEMBERS {
            return Err(format!(
                "ensemble supports at most {MAX_MEMBERS} members: got {}",
                self.members.len()
            ));
        }
        Ok(())
    }

    /// Resolve a [`DetectorSpec`] (the flag/spec-string description) into
    /// ensemble params: parses `members=` (default
    /// [`DEFAULT_MEMBERS`]), seeds unseeded members `base + i`.
    pub fn from_spec(spec: &DetectorSpec) -> Result<EnsembleParams> {
        let seed = spec.seed.unwrap_or(DEFAULT_SEED);
        let text = spec.members.as_deref().unwrap_or(DEFAULT_MEMBERS);
        let mut members = Vec::new();
        for (i, ms) in api::spec::parse_members(text)?.iter().enumerate() {
            members.push(MemberSpec::from_method_spec(
                ms,
                Some(seed.wrapping_add(i as u64)),
            )?);
        }
        let params = EnsembleParams {
            members,
            distill: spec.distill,
            share_projection: spec.share,
            schedule: spec.schedule,
            seed,
        };
        params.validate().map_err(SparxError::InvalidParams)?;
        Ok(params)
    }
}

/// [`Detector`] front for the ensemble — what
/// `registry::create("ensemble?members=...")` builds.
pub struct EnsembleDetector {
    params: EnsembleParams,
}

impl EnsembleDetector {
    pub fn new(params: EnsembleParams) -> Result<EnsembleDetector> {
        params.validate().map_err(SparxError::InvalidParams)?;
        Ok(EnsembleDetector { params })
    }

    pub fn from_spec(spec: &DetectorSpec) -> Result<EnsembleDetector> {
        Ok(EnsembleDetector { params: EnsembleParams::from_spec(spec)? })
    }

    pub fn params(&self) -> &EnsembleParams {
        &self.params
    }
}

impl Detector for EnsembleDetector {
    fn name(&self) -> &'static str {
        "ensemble"
    }

    fn fit(&self, ctx: &ClusterContext, data: &Dataset) -> Result<Box<dyn FittedModel>> {
        Ok(Box::new(FittedEnsemble::fit(ctx, data, &self.params)?))
    }
}

/// What a pool worker hands back: plain fitted state, no backend
/// runtime attached (that wrapping happens on the calling thread).
enum FitOutput {
    Sparx(SparxModel),
    XStream(XStream),
    Spif(Spif),
    Dbscout(FittedDbscout),
}

/// A fitted member behind the [`FittedModel`] contract.
enum MemberModel {
    Sparx(FittedSparx),
    XStream(XStream),
    Spif(Spif),
    Dbscout(FittedDbscout),
}

impl MemberModel {
    fn as_fitted(&self) -> &dyn FittedModel {
        match self {
            MemberModel::Sparx(m) => m,
            MemberModel::XStream(m) => m,
            MemberModel::Spif(m) => m,
            MemberModel::Dbscout(m) => m,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            MemberModel::Sparx(_) => "sparx",
            MemberModel::XStream(_) => "xstream",
            MemberModel::Spif(_) => "spif",
            MemberModel::Dbscout(_) => "dbscout",
        }
    }

    fn projector(&self) -> Option<&Projector> {
        match self {
            MemberModel::Sparx(m) => Some(&m.model().projector),
            MemberModel::XStream(m) => Some(&m.projector),
            _ => None,
        }
    }
}

fn wrap_output(out: FitOutput) -> MemberModel {
    match out {
        FitOutput::Sparx(m) => MemberModel::Sparx(FittedSparx::from_model(m)),
        FitOutput::XStream(m) => MemberModel::XStream(m),
        FitOutput::Spif(m) => MemberModel::Spif(m),
        FitOutput::Dbscout(m) => MemberModel::Dbscout(m),
    }
}

struct FittedMember {
    text: String,
    model: MemberModel,
    fit_micros: u64,
    score_micros: u64,
    worker: usize,
}

/// A fitted heterogeneous ensemble: N members, their measured costs and
/// worker assignment, and (optionally) a distilled serve-path student.
pub struct FittedEnsemble {
    members: Vec<FittedMember>,
    distilled: Option<distill::Distilled>,
    distill_requested: bool,
    share_projection: bool,
    schedule: Schedule,
    seed: u64,
}

impl FittedEnsemble {
    /// Fit every member: shared-projection grouping → calibration-slice
    /// cost measurement → scheduled full fits on the pool → optional
    /// distillation. Assignment moves work across workers but never
    /// changes any member's scores.
    pub fn fit(ctx: &ClusterContext, data: &Dataset, params: &EnsembleParams) -> Result<FittedEnsemble> {
        params.validate().map_err(SparxError::InvalidParams)?;
        let shared = shared_projectors(data, params);
        let calib = calibration_slice(ctx, data)?;

        // SUOD module 2, step 1: measure each member on the slice.
        let mut fit_micros = Vec::with_capacity(params.members.len());
        let mut score_micros = Vec::with_capacity(params.members.len());
        for (i, member) in params.members.iter().enumerate() {
            let proj = shared.get(i).and_then(|p| p.clone());
            let t0 = Instant::now();
            let out = fit_member(ctx, &calib, member.config(), proj)?;
            fit_micros.push(distill::elapsed_micros(t0));
            let probe = wrap_output(out);
            let t0 = Instant::now();
            probe.as_fitted().score(ctx, &calib)?;
            score_micros.push(distill::elapsed_micros(t0));
        }

        // step 2: pack the full fits.
        let workers = ctx.cfg.num_threads.max(1);
        let assignment = match params.schedule {
            Schedule::Balanced => cost::assign_balanced(&fit_micros, workers),
            Schedule::RoundRobin => cost::assign_round_robin(params.members.len(), workers),
        };
        let members_ref = &params.members;
        let shared_ref = &shared;
        let outputs = pool::run_assigned(workers, &assignment, |i| {
            let member = members_ref.get(i).ok_or_else(|| {
                SparxError::InvalidParams(format!("member index {i} out of range"))
            })?;
            let proj = shared_ref.get(i).and_then(|p| p.clone());
            fit_member(ctx, data, member.config(), proj)
        })?;

        let mut members = Vec::with_capacity(outputs.len());
        for (i, out) in outputs.into_iter().enumerate() {
            members.push(FittedMember {
                text: params.members.get(i).map(|m| m.text.clone()).unwrap_or_default(),
                model: wrap_output(out),
                fit_micros: fit_micros.get(i).copied().unwrap_or(1),
                score_micros: score_micros.get(i).copied().unwrap_or(1),
                worker: assignment.get(i).copied().unwrap_or(0),
            });
        }

        // SUOD module 3: distill the most expensive member, if asked.
        let distilled = if params.distill {
            let teacher = members
                .iter()
                .enumerate()
                .max_by_key(|(i, m)| (m.fit_micros.saturating_add(m.score_micros), usize::MAX - i))
                .map(|(i, _)| i)
                .unwrap_or(0);
            let (teacher_text, teacher_calib) = match members.get(teacher) {
                Some(m) => (m.text.clone(), m.model.as_fitted().score(ctx, &calib)?),
                None => {
                    return Err(SparxError::InvalidParams(
                        "distillation needs at least one member".into(),
                    ))
                }
            };
            Some(distill::distill(ctx, data, &calib, &teacher_text, &teacher_calib, params.seed)?)
        } else {
            None
        };

        Ok(FittedEnsemble {
            members,
            distilled,
            distill_requested: params.distill,
            share_projection: params.share_projection,
            schedule: params.schedule,
            seed: params.seed,
        })
    }

    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    pub fn schedule(&self) -> Schedule {
        self.schedule
    }

    /// Member i's projector, if its method hashes (sparx / xstream).
    /// Under shared projection, members of one `(k, density)` group
    /// return projectors whose dense R matrices are the *same
    /// allocation* (`dense_r().as_ptr()` compares equal).
    pub fn member_projector(&self, i: usize) -> Option<&Projector> {
        self.members.get(i).and_then(|m| m.model.projector())
    }

    /// Pool worker member i's full fit ran on.
    pub fn member_worker(&self, i: usize) -> Option<usize> {
        self.members.get(i).map(|m| m.worker)
    }

    /// Distillation provenance: `(teacher spec, rank agreement)`.
    pub fn distilled_info(&self) -> Option<(&str, f64)> {
        self.distilled.as_ref().map(|d| (d.teacher.as_str(), d.agreement))
    }

    /// The sparx model that serves evolving streams: the distilled
    /// student if present, else the first sparx member.
    fn serve_model(&self) -> Result<&SparxModel> {
        if let Some(d) = &self.distilled {
            return Ok(d.student.model());
        }
        for m in &self.members {
            if let MemberModel::Sparx(f) = &m.model {
                return Ok(f.model());
            }
        }
        Err(SparxError::Unsupported(
            "this ensemble has no sparx member and no distilled student, so it cannot \
             serve evolving streams — include a sparx member or fit with distill=true"
                .into(),
        ))
    }

    fn serve_member_index(&self) -> Option<usize> {
        self.members
            .iter()
            .position(|m| matches!(m.model, MemberModel::Sparx(_)))
    }

    fn encode_params(&self) -> Vec<u8> {
        let mut enc = crate::util::codec::Encoder::new();
        let mut flags = 0u8;
        if self.distill_requested {
            flags |= 1;
        }
        if self.share_projection {
            flags |= 2;
        }
        enc.put_u8(flags);
        enc.put_u8(self.schedule.tag());
        enc.put_u64(self.seed);
        enc.put_u32(self.members.len() as u32);
        for m in &self.members {
            enc.put_str(&m.text);
            enc.put_str(m.model.kind());
            enc.put_u64(m.fit_micros);
            enc.put_u64(m.score_micros);
            enc.put_u64(m.worker as u64);
        }
        match &self.distilled {
            Some(d) => {
                enc.put_u8(1);
                enc.put_str(&d.teacher);
                enc.put_f64(d.agreement);
                enc.put_u64(d.fit_micros);
                enc.put_u64(d.score_micros);
            }
            None => enc.put_u8(0),
        }
        enc.into_bytes()
    }

    fn encode_payload(&self) -> Result<Vec<u8>> {
        let mut enc = crate::util::codec::Encoder::new();
        enc.put_u32(self.members.len() as u32);
        for m in &self.members {
            let bytes = m.model.as_fitted().to_artifact()?.to_bytes();
            enc.put_u32(bytes.len() as u32);
            enc.put_bytes(&bytes);
        }
        match &self.distilled {
            Some(d) => {
                enc.put_u8(1);
                let bytes = d.student.to_artifact()?.to_bytes();
                enc.put_u32(bytes.len() as u32);
                enc.put_bytes(&bytes);
            }
            None => enc.put_u8(0),
        }
        Ok(enc.into_bytes())
    }

    /// Rehydrate from an artifact: each member is a complete nested
    /// artifact, decoded by its own detector's deserializer. Nested
    /// ensembles are rejected.
    pub fn from_artifact(art: &ModelArtifact) -> Result<FittedEnsemble> {
        let blk = |e| artifact::block_err("ensemble", e);
        let mut dec = crate::util::codec::Decoder::new(&art.params);
        let flags = dec.u8().map_err(blk)?;
        let schedule_tag = dec.u8().map_err(blk)?;
        let schedule = Schedule::from_tag(schedule_tag).ok_or_else(|| {
            SparxError::InvalidParams(format!("unknown ensemble schedule tag {schedule_tag}"))
        })?;
        let seed = dec.u64().map_err(blk)?;
        let count = dec.u32().map_err(blk)? as usize;
        if count == 0 || count > MAX_MEMBERS {
            return Err(SparxError::InvalidParams(format!(
                "ensemble artifact names {count} members (1..={MAX_MEMBERS} supported)"
            )));
        }
        let mut metas = Vec::with_capacity(count);
        for _ in 0..count {
            let text = dec.str().map_err(blk)?;
            let kind = dec.str().map_err(blk)?;
            let fit_micros = dec.u64().map_err(blk)?;
            let score_micros = dec.u64().map_err(blk)?;
            let worker = dec.u64().map_err(blk)? as usize;
            metas.push((text, kind, fit_micros, score_micros, worker));
        }
        let distilled_meta = match dec.u8().map_err(blk)? {
            0 => None,
            _ => {
                let teacher = dec.str().map_err(blk)?;
                let agreement = dec.f64().map_err(blk)?;
                let fit_micros = dec.u64().map_err(blk)?;
                let score_micros = dec.u64().map_err(blk)?;
                Some((teacher, agreement, fit_micros, score_micros))
            }
        };
        dec.finish().map_err(blk)?;

        let mut dec = crate::util::codec::Decoder::new(&art.payload);
        let pcount = dec.u32().map_err(blk)? as usize;
        if pcount != count {
            return Err(SparxError::InvalidParams(format!(
                "ensemble artifact blocks disagree: {count} members in params, {pcount} in payload"
            )));
        }
        let mut members = Vec::with_capacity(count);
        for (text, kind, fit_micros, score_micros, worker) in metas {
            let len = dec.u32().map_err(blk)? as usize;
            let bytes = dec.take(len).map_err(blk)?;
            let nested = ModelArtifact::from_bytes(bytes)?;
            if nested.detector != kind {
                return Err(SparxError::InvalidParams(format!(
                    "ensemble member {text:?} declares kind {kind:?} but its nested \
                     artifact was written by {:?}",
                    nested.detector
                )));
            }
            members.push(FittedMember {
                text,
                model: decode_member(&nested)?,
                fit_micros,
                score_micros,
                worker,
            });
        }
        let distilled = match (dec.u8().map_err(blk)?, distilled_meta) {
            (0, None) => None,
            (0, Some(_)) => {
                return Err(SparxError::InvalidParams(
                    "ensemble artifact blocks disagree: distilled student in params \
                     but not in payload"
                        .into(),
                ))
            }
            (_, None) => {
                return Err(SparxError::InvalidParams(
                    "ensemble artifact blocks disagree: distilled student in payload \
                     but not in params"
                        .into(),
                ))
            }
            (_, Some((teacher, agreement, fit_micros, score_micros))) => {
                let len = dec.u32().map_err(blk)? as usize;
                let bytes = dec.take(len).map_err(blk)?;
                let nested = ModelArtifact::from_bytes(bytes)?;
                let student = FittedSparx::from_artifact(&nested)?;
                Some(distill::Distilled { teacher, agreement, student, fit_micros, score_micros })
            }
        };
        dec.finish().map_err(blk)?;
        Ok(FittedEnsemble {
            members,
            distilled,
            distill_requested: flags & 1 != 0,
            share_projection: flags & 2 != 0,
            schedule,
            seed,
        })
    }
}

fn decode_member(art: &ModelArtifact) -> Result<MemberModel> {
    match art.detector.as_str() {
        "sparx" => Ok(MemberModel::Sparx(FittedSparx::from_artifact(art)?)),
        "xstream" => Ok(MemberModel::XStream(XStream::from_artifact(art)?)),
        "spif" => Ok(MemberModel::Spif(Spif::from_artifact(art)?)),
        "dbscout" => Ok(MemberModel::Dbscout(FittedDbscout::from_artifact(art)?)),
        other => Err(SparxError::InvalidParams(format!(
            "ensemble members must be one of {} — nested {other:?} artifacts are not \
             supported",
            MEMBER_KINDS.join("|")
        ))),
    }
}

impl FittedModel for FittedEnsemble {
    fn name(&self) -> &'static str {
        "ensemble"
    }

    fn score(&self, ctx: &ClusterContext, data: &Dataset) -> Result<Vec<(u64, f64)>> {
        let mut per_member = Vec::with_capacity(self.members.len());
        for m in &self.members {
            per_member.push(m.model.as_fitted().score(ctx, data)?);
        }
        combine::rank_average(&per_member)
    }

    fn to_artifact(&self) -> Result<ModelArtifact> {
        Ok(ModelArtifact::new("ensemble", self.encode_params(), self.encode_payload()?))
    }

    fn model_bytes(&self) -> usize {
        self.encode_payload().map(|p| p.len()).unwrap_or(0)
    }

    fn stream_scorer(&self, cache_size: usize) -> Result<StreamScorer> {
        StreamScorer::new(self.serve_model()?, cache_size)
    }

    fn stream_scorer_sharded(&self, opts: ServeOptions) -> Result<ShardedStreamScorer> {
        let mut scorer = ShardedStreamScorer::from_ensemble(
            Arc::new(ServedEnsemble::new(self.serve_model()?)?),
            opts,
            None,
        )?;
        scorer.set_member_info(self.member_info());
        Ok(scorer)
    }

    fn served_ensemble(&self) -> Result<Arc<ServedEnsemble>> {
        Ok(Arc::new(ServedEnsemble::new(self.serve_model()?)?))
    }

    fn member_info(&self) -> Vec<MemberInfo> {
        let serving = if self.distilled.is_some() { None } else { self.serve_member_index() };
        let mut out: Vec<MemberInfo> = self
            .members
            .iter()
            .enumerate()
            .map(|(i, m)| MemberInfo {
                spec: m.text.clone(),
                kind: m.model.kind().to_string(),
                fit_micros: m.fit_micros,
                score_micros: m.score_micros,
                worker: m.worker,
                distilled_from: None,
                serving: serving == Some(i),
            })
            .collect();
        if let Some(d) = &self.distilled {
            out.push(MemberInfo {
                spec: "sparx:distilled".into(),
                kind: "sparx".into(),
                fit_micros: d.fit_micros,
                score_micros: d.score_micros,
                worker: 0,
                distilled_from: Some(d.teacher.clone()),
                serving: true,
            });
        }
        out
    }
}

/// Fit one member. `projector`, when given, is the shared-substrate
/// clone (its `Arc`'d R matrix is the same allocation every group
/// member holds); `None` means the member builds its own, which is
/// bit-identical — the sign-hash family is seeded by index.
fn fit_member(
    ctx: &ClusterContext,
    data: &Dataset,
    config: &MemberConfig,
    projector: Option<Projector>,
) -> std::result::Result<FitOutput, SparxError> {
    match config {
        MemberConfig::Sparx(p) => {
            let model = match projector {
                Some(proj) => SparxModel::fit_with_projector(
                    ctx,
                    data,
                    p,
                    &crate::sparx::NativeBinner,
                    proj,
                )?,
                None => SparxModel::fit(ctx, data, p)?,
            };
            Ok(FitOutput::Sparx(model))
        }
        MemberConfig::XStream(p) => {
            let rows = data.rows.collect(ctx)?;
            let model = match projector {
                Some(proj) => XStream::fit_with_projector(&rows, &data.schema.names, p, proj),
                None => XStream::fit(&rows, &data.schema.names, p),
            };
            Ok(FitOutput::XStream(model))
        }
        MemberConfig::Spif(p) => Ok(FitOutput::Spif(Spif::fit(ctx, data, p)?)),
        MemberConfig::Dbscout { params, auto_eps } => {
            api::ensure_dense(data, "DBSCOUT (ensemble member)")?;
            let mut p = params.clone();
            if *auto_eps {
                p.eps = Dbscout::choose_eps(ctx, data, p.min_pts, 400)?;
            }
            Ok(FitOutput::Dbscout(FittedDbscout::from_params(p)?))
        }
    }
}

/// SUOD module 1: one projector per `(k, density)` group of ≥ 2 hashing
/// members; singleton groups build their own (identical) projector on
/// the normal path. Returns `shared[i] = Some(clone)` for grouped
/// members.
fn shared_projectors(data: &Dataset, params: &EnsembleParams) -> Vec<Option<Projector>> {
    let n = params.members.len();
    let mut out: Vec<Option<Projector>> = vec![None; n];
    if !params.share_projection {
        return out;
    }
    let mut groups: HashMap<(usize, u64), Vec<usize>> = HashMap::new();
    for (i, m) in params.members.iter().enumerate() {
        if let Some(key) = m.config().projection_key() {
            groups.entry(key).or_default().push(i);
        }
    }
    for ((k, density_bits), idxs) in groups {
        if idxs.len() < 2 {
            continue;
        }
        let density = f64::from_bits(density_bits);
        let mut proj = Projector::new(k, density);
        if !data.schema.names.is_empty() {
            proj = proj.with_dense_schema(&data.schema.names);
        }
        for i in idxs {
            if let Some(slot) = out.get_mut(i) {
                *slot = Some(proj.clone());
            }
        }
    }
    out
}

/// The first `min(256, n)` rows, re-partitioned as a driver-local
/// dataset: the common yardstick for the cost model and distillation
/// agreement.
fn calibration_slice(ctx: &ClusterContext, data: &Dataset) -> Result<Dataset> {
    let want = data.len().min(CALIB_ROWS);
    let mut rows = Vec::with_capacity(want);
    'parts: for p in 0..data.rows.num_parts() {
        for row in data.rows.part(p) {
            if rows.len() >= want {
                break 'parts;
            }
            rows.push(row.clone());
        }
    }
    Ok(Dataset::new(data.schema.clone(), DistVec::from_vec(ctx, rows)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::data::generators::GisetteGen;

    fn ctx() -> ClusterContext {
        ClusterConfig { num_partitions: 2, ..Default::default() }.build()
    }

    fn small_data(ctx: &ClusterContext) -> Dataset {
        GisetteGen { n: 160, d: 8, ..Default::default() }.generate(ctx).unwrap().dataset
    }

    #[test]
    fn default_members_fit_and_score_everything() {
        let c = ctx();
        let data = small_data(&c);
        let det = EnsembleDetector::from_spec(&DetectorSpec::default()).unwrap();
        let model = det.fit(&c, &data).unwrap();
        let scores = model.score(&c, &data).unwrap();
        assert_eq!(scores.len(), data.len());
        for (_, s) in &scores {
            assert!((0.0..=1.0).contains(s), "rank-averaged score out of range: {s}");
        }
    }

    #[test]
    fn member_specs_resolve_params() {
        let m = MemberSpec::parse("sparx:depth=6:chains=4").unwrap();
        match m.config() {
            MemberConfig::Sparx(p) => {
                assert_eq!(p.depth, 6);
                assert_eq!(p.num_chains, 4);
            }
            other => panic!("wrong config: {other:?}"),
        }
        assert_eq!(m.text(), "sparx:depth=6:chains=4");
        // unknown member kinds get a suggestion
        let e = MemberSpec::parse("sparks").unwrap_err();
        assert!(e.to_string().contains("sparx"), "no hint in {e}");
        // unknown keys too
        let e = MemberSpec::parse("sparx:depht=6").unwrap_err();
        assert!(e.to_string().contains("depth"), "no hint in {e}");
    }

    #[test]
    fn unseeded_members_decorrelate() {
        let spec = DetectorSpec {
            members: Some("sparx,sparx".into()),
            ..Default::default()
        };
        let params = EnsembleParams::from_spec(&spec).unwrap();
        let seeds: Vec<u64> = params
            .members
            .iter()
            .map(|m| match m.config() {
                MemberConfig::Sparx(p) => p.seed,
                _ => unreachable!(),
            })
            .collect();
        assert_ne!(seeds[0], seeds[1], "identical members must draw different seeds");
    }

    #[test]
    fn no_sparx_member_cannot_serve() {
        let c = ctx();
        let data = small_data(&c);
        let spec = DetectorSpec { members: Some("xstream".into()), ..Default::default() };
        let det = EnsembleDetector::from_spec(&spec).unwrap();
        let model = det.fit(&c, &data).unwrap();
        let e = model.stream_scorer(64).unwrap_err();
        assert!(matches!(e, SparxError::Unsupported(_)), "got {e:?}");
    }
}
