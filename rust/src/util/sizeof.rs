//! Byte-size estimation for the cluster simulator's accounting.
//!
//! The paper reports peak executor / driver memory and we reproduce the
//! *relative* behaviour (who blows up, by what factor) by charging every
//! partition, shuffle buffer and broadcast variable with an estimated
//! deep size. Estimates are deliberately simple (payload bytes + small
//! constant per heap object), which is enough to preserve orderings.

/// Estimated deep size in bytes (heap payload + inline size).
pub trait SizeOf {
    fn size_of(&self) -> usize;
}

macro_rules! prim_size {
    ($($t:ty),*) => {
        $(impl SizeOf for $t {
            fn size_of(&self) -> usize { std::mem::size_of::<$t>() }
        })*
    };
}

prim_size!(u8, u16, u32, u64, usize, i8, i16, i32, i64, isize, f32, f64, bool, char);

impl SizeOf for String {
    fn size_of(&self) -> usize {
        std::mem::size_of::<String>() + self.len()
    }
}

impl<T: SizeOf> SizeOf for Vec<T> {
    fn size_of(&self) -> usize {
        std::mem::size_of::<Vec<T>>() + self.iter().map(|x| x.size_of()).sum::<usize>()
    }
}

impl<T: SizeOf> SizeOf for Option<T> {
    fn size_of(&self) -> usize {
        std::mem::size_of::<Option<T>>() + self.as_ref().map_or(0, |x| x.size_of())
    }
}

impl<T: SizeOf> SizeOf for Box<T> {
    fn size_of(&self) -> usize {
        std::mem::size_of::<Box<T>>() + (**self).size_of()
    }
}

impl<A: SizeOf, B: SizeOf> SizeOf for (A, B) {
    fn size_of(&self) -> usize {
        self.0.size_of() + self.1.size_of()
    }
}

impl<A: SizeOf, B: SizeOf, C: SizeOf> SizeOf for (A, B, C) {
    fn size_of(&self) -> usize {
        self.0.size_of() + self.1.size_of() + self.2.size_of()
    }
}

impl<K: SizeOf, V: SizeOf> SizeOf for std::collections::HashMap<K, V> {
    fn size_of(&self) -> usize {
        std::mem::size_of::<Self>()
            + self
                .iter()
                .map(|(k, v)| k.size_of() + v.size_of() + 16) // bucket overhead
                .sum::<usize>()
    }
}

impl<T: SizeOf, const N: usize> SizeOf for [T; N] {
    fn size_of(&self) -> usize {
        self.iter().map(|x| x.size_of()).sum()
    }
}

/// Human-readable bytes (for reports).
pub fn human_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_of_f32() {
        let v = vec![0f32; 100];
        assert_eq!(v.size_of(), std::mem::size_of::<Vec<f32>>() + 400);
    }

    #[test]
    fn nested_vec() {
        let v = vec![vec![0u8; 10]; 3];
        assert!(v.size_of() >= 30);
    }

    #[test]
    fn string_size_counts_bytes() {
        let s = String::from("hello");
        assert_eq!(s.size_of(), std::mem::size_of::<String>() + 5);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(2048), "2.00KB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00MB");
    }
}
