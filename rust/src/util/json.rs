//! Minimal JSON parser + writer (offline environment: serde_json is not
//! in the vendored dependency set). Supports the full JSON grammar minus
//! exotic number forms; used for the artifact manifest, config files and
//! experiment result dumps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => &[],
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse from text.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

/// Compact serialization (`.to_string()` comes with it, as before, via
/// the blanket `ToString` — the previous inherent `to_string` shadowed
/// this idiom).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.i += 1;
                let mut v = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    self.skip_ws();
                    v.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        _ => return Err(format!("bad array at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    self.skip_ws();
                    let v = self.value()?;
                    m.insert(k, v);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("bad object at byte {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("truncated \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run
                    let start = self.i;
                    while self
                        .peek()
                        .map_or(false, |c| c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {text:?}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"artifacts": [{"name": "demo", "kind": "project", "b": 8, "d": 16}]}"#;
        let j = Json::parse(text).unwrap();
        let a = &j.get("artifacts").unwrap().items()[0];
        assert_eq!(a.get("name").unwrap().as_str(), Some("demo"));
        assert_eq!(a.get("b").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("x", Json::Num(1.5)),
            ("s", Json::Str("a\"b\n".into())),
            ("a", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""aAb""#).unwrap();
        assert_eq!(j.as_str(), Some("aAb"));
    }

    #[test]
    fn nested_structures() {
        let text = r#"{"a": {"b": [1, [2, {"c": 3}]]}}"#;
        let j = Json::parse(text).unwrap();
        let inner = j.get("a").unwrap().get("b").unwrap().items();
        assert_eq!(inner[0].as_f64(), Some(1.0));
    }
}
