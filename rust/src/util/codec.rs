//! Minimal little-endian binary codec (offline environment: serde /
//! bincode are not in the vendored dependency set). Used by the model
//! artifact format (`crate::api::artifact`): fixed-width primitives,
//! length-prefixed strings and slices, and an IEEE CRC-32 for whole-file
//! integrity.
//!
//! Encoding conventions, shared by every detector's artifact codec:
//! * all integers little-endian; `usize` travels as `u64`;
//! * strings and element slices are length-prefixed with a `u32`;
//! * floats are stored via `to_le_bytes` (bit-exact round trips — the
//!   artifact tests assert score bit-identity across save/load).

/// Append-only binary writer.
#[derive(Debug, Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Encoder { buf: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Raw bytes, no length prefix (fixed-size fields like the magic).
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    /// `u32` length prefix + UTF-8 bytes.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// `u32` element count + each element as `u32`.
    pub fn put_u32_slice(&mut self, v: &[u32]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_u32(x);
        }
    }

    /// `u32` element count + each element as `u64` (usize payloads).
    pub fn put_usize_slice(&mut self, v: &[usize]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_u64(x as u64);
        }
    }

    /// `u32` element count + each element's LE bits.
    pub fn put_f32_slice(&mut self, v: &[f32]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_f32(x);
        }
    }

    /// LEB128 varint: 7 value bits per byte, high bit = continuation.
    pub fn put_varint(&mut self, mut v: u64) {
        while v >= 0x80 {
            self.buf.push((v as u8 & 0x7F) | 0x80);
            v >>= 7;
        }
        self.buf.push(v as u8);
    }

    /// Compressed `u32` slice: `u32` element count, then a token stream
    /// of `varint(value)` where a zero value is followed by
    /// `varint(run_length)` covering the whole zero run. CMS count
    /// blocks are dominated by small values and zero runs, so this is
    /// the artifact-format-v3 payload codec for sketch counts (see
    /// `crate::api::artifact`). Decode with [`Decoder::u32_vec_packed`].
    pub fn put_u32_slice_packed(&mut self, v: &[u32]) {
        self.put_u32(v.len() as u32);
        let mut rest = v;
        while let Some((&first, tail)) = rest.split_first() {
            if first == 0 {
                let run = 1 + tail.iter().take_while(|&&x| x == 0).count();
                self.put_varint(0);
                self.put_varint(run as u64);
                rest = rest.get(run..).unwrap_or(&[]);
            } else {
                self.put_varint(first as u64);
                rest = tail;
            }
        }
    }
}

/// Bounds-checked binary reader over a byte slice. Every accessor
/// returns `Err` (never panics) on truncated input, so corrupt artifacts
/// surface as typed errors all the way up.
#[derive(Debug)]
pub struct Decoder<'a> {
    b: &'a [u8],
    i: usize,
}

/// Codec-level read errors (mapped to `SparxError` by the artifact layer).
pub type CodecResult<T> = Result<T, String>;

impl<'a> Decoder<'a> {
    pub fn new(b: &'a [u8]) -> Self {
        Decoder { b, i: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    pub fn take(&mut self, n: usize) -> CodecResult<&'a [u8]> {
        match self.b.get(self.i..self.i.saturating_add(n)) {
            Some(s) => {
                self.i += n;
                Ok(s)
            }
            None => Err(format!(
                "truncated: wanted {n} bytes at offset {}, {} left",
                self.i,
                self.remaining()
            )),
        }
    }

    /// Fixed-width read into an array, the panic-free `try_into` for the
    /// scalar accessors below.
    fn take_arr<const N: usize>(&mut self) -> CodecResult<[u8; N]> {
        let mut out = [0u8; N];
        out.copy_from_slice(self.take(N)?);
        Ok(out)
    }

    pub fn u8(&mut self) -> CodecResult<u8> {
        let [b] = self.take_arr::<1>()?;
        Ok(b)
    }

    pub fn u16(&mut self) -> CodecResult<u16> {
        Ok(u16::from_le_bytes(self.take_arr()?))
    }

    pub fn u32(&mut self) -> CodecResult<u32> {
        Ok(u32::from_le_bytes(self.take_arr()?))
    }

    pub fn u64(&mut self) -> CodecResult<u64> {
        Ok(u64::from_le_bytes(self.take_arr()?))
    }

    pub fn usize(&mut self) -> CodecResult<usize> {
        Ok(self.u64()? as usize)
    }

    pub fn f32(&mut self) -> CodecResult<f32> {
        Ok(f32::from_le_bytes(self.take_arr()?))
    }

    pub fn f64(&mut self) -> CodecResult<f64> {
        Ok(f64::from_le_bytes(self.take_arr()?))
    }

    pub fn str(&mut self) -> CodecResult<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| format!("invalid UTF-8 string: {e}"))
    }

    pub fn u32_vec(&mut self) -> CodecResult<Vec<u32>> {
        let n = self.u32()? as usize;
        // bounds-check the whole run up front so a hostile length cannot
        // trigger a huge allocation before the truncation is noticed
        if self.remaining() < n.saturating_mul(4) {
            return Err(format!("truncated u32 slice: {n} elements declared"));
        }
        (0..n).map(|_| self.u32()).collect()
    }

    pub fn usize_vec(&mut self) -> CodecResult<Vec<usize>> {
        let n = self.u32()? as usize;
        if self.remaining() < n.saturating_mul(8) {
            return Err(format!("truncated usize slice: {n} elements declared"));
        }
        (0..n).map(|_| self.usize()).collect()
    }

    pub fn f32_vec(&mut self) -> CodecResult<Vec<f32>> {
        let n = self.u32()? as usize;
        if self.remaining() < n.saturating_mul(4) {
            return Err(format!("truncated f32 slice: {n} elements declared"));
        }
        (0..n).map(|_| self.f32()).collect()
    }

    /// LEB128 varint (≤ 10 bytes; rejects encodings past 64 bits).
    pub fn varint(&mut self) -> CodecResult<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.u8()?;
            if shift > 63 || (shift == 63 && (byte & 0x7F) > 1) {
                return Err("varint overflows u64".into());
            }
            v |= ((byte & 0x7F) as u64) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    /// Decode [`Encoder::put_u32_slice_packed`]. `max_len` caps the
    /// declared element count so a hostile length cannot allocate out of
    /// thin air (callers pass the exact count they expect, e.g. `r·w`).
    pub fn u32_vec_packed(&mut self, max_len: usize) -> CodecResult<Vec<u32>> {
        let n = self.u32()? as usize;
        if n > max_len {
            return Err(format!("packed u32 slice declares {n} elements, cap is {max_len}"));
        }
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let v = self.varint()?;
            if v == 0 {
                let run = self.varint()? as usize;
                if run == 0 || run > n - out.len() {
                    return Err(format!(
                        "zero run of {run} overflows the declared length {n}"
                    ));
                }
                out.resize(out.len() + run, 0);
            } else if v > u32::MAX as u64 {
                return Err(format!("packed value {v} exceeds u32"));
            } else {
                out.push(v as u32);
            }
        }
        Ok(out)
    }

    /// Assert the reader consumed everything (catches layout drift).
    pub fn finish(&self) -> CodecResult<()> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(format!("{} trailing bytes after decode", self.remaining()))
        }
    }
}

/// IEEE CRC-32 (reflected, polynomial 0xEDB88320) — the artifact file
/// trailer. Bitwise implementation: artifact I/O is not a hot path.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &byte in data {
        crc ^= byte as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip() {
        let mut e = Encoder::new();
        e.put_u8(7);
        e.put_u16(0xBEEF);
        e.put_u32(0xDEAD_BEEF);
        e.put_u64(u64::MAX - 1);
        e.put_f32(-0.0);
        e.put_f64(f64::MIN_POSITIVE);
        e.put_str("héllo");
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u16().unwrap(), 0xBEEF);
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX - 1);
        assert_eq!(d.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(d.f64().unwrap(), f64::MIN_POSITIVE);
        assert_eq!(d.str().unwrap(), "héllo");
        assert!(d.finish().is_ok());
    }

    #[test]
    fn slices_round_trip_bit_exact() {
        let f = vec![1.5f32, f32::NAN, -0.0, f32::INFINITY];
        let u = vec![0u32, 1, u32::MAX];
        let s = vec![0usize, 42, usize::MAX >> 1];
        let mut e = Encoder::new();
        e.put_f32_slice(&f);
        e.put_u32_slice(&u);
        e.put_usize_slice(&s);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        let f2 = d.f32_vec().unwrap();
        assert_eq!(f.len(), f2.len());
        for (a, b) in f.iter().zip(&f2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(d.u32_vec().unwrap(), u);
        assert_eq!(d.usize_vec().unwrap(), s);
        assert!(d.finish().is_ok());
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut e = Encoder::new();
        e.put_u64(1);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes[..5]);
        assert!(d.u64().is_err());
        // declared length far beyond the buffer must not allocate/panic
        let mut e = Encoder::new();
        e.put_u32(u32::MAX);
        let bytes = e.into_bytes();
        assert!(Decoder::new(&bytes).f32_vec().is_err());
        assert!(Decoder::new(&bytes).u32_vec().is_err());
        assert!(Decoder::new(&bytes).usize_vec().is_err());
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut e = Encoder::new();
        e.put_u8(1);
        e.put_u8(2);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        d.u8().unwrap();
        assert!(d.finish().is_err());
    }

    #[test]
    fn varint_round_trips_and_known_encodings() {
        let mut e = Encoder::new();
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            e.put_varint(v);
        }
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            assert_eq!(d.varint().unwrap(), v);
        }
        assert!(d.finish().is_ok());
        // canonical encodings: single byte below 128, LEB128 for 300
        let mut e = Encoder::new();
        e.put_varint(300);
        assert_eq!(e.as_slice(), &[0xAC, 0x02]);
        // an 11-byte continuation chain overflows u64 → typed error
        let mut d = Decoder::new(&[0xFF; 11]);
        assert!(d.varint().is_err());
    }

    #[test]
    fn packed_u32_round_trips() {
        let cases: Vec<Vec<u32>> = vec![
            vec![],
            vec![0],
            vec![0; 1000],
            vec![1, 2, 3],
            vec![0, 0, 5, 0, 0, 0, 7, u32::MAX, 0],
            (0..500).map(|i| if i % 7 == 0 { i } else { 0 }).collect(),
        ];
        for v in &cases {
            let mut e = Encoder::new();
            e.put_u32_slice_packed(v);
            let bytes = e.into_bytes();
            let mut d = Decoder::new(&bytes);
            assert_eq!(&d.u32_vec_packed(v.len()).unwrap(), v);
            assert!(d.finish().is_ok());
        }
        // sparse data compresses well below the 4-bytes-per-element raw form
        let sparse = vec![0u32; 10_000];
        let mut e = Encoder::new();
        e.put_u32_slice_packed(&sparse);
        assert!(e.len() < 16, "10k zeros should pack to a few bytes, got {}", e.len());
    }

    #[test]
    fn packed_u32_rejects_hostile_payloads() {
        // declared count above the caller's cap
        let mut e = Encoder::new();
        e.put_u32_slice_packed(&[1, 2, 3]);
        let bytes = e.into_bytes();
        assert!(Decoder::new(&bytes).u32_vec_packed(2).is_err());
        // zero run overflowing the declared length
        let mut e = Encoder::new();
        e.put_u32(2); // declares 2 elements
        e.put_varint(0);
        e.put_varint(100); // ...but a 100-long zero run
        let bytes = e.into_bytes();
        assert!(Decoder::new(&bytes).u32_vec_packed(10).is_err());
        // zero-length zero run is malformed
        let mut e = Encoder::new();
        e.put_u32(1);
        e.put_varint(0);
        e.put_varint(0);
        let bytes = e.into_bytes();
        assert!(Decoder::new(&bytes).u32_vec_packed(10).is_err());
        // value past u32::MAX
        let mut e = Encoder::new();
        e.put_u32(1);
        e.put_varint(u32::MAX as u64 + 1);
        let bytes = e.into_bytes();
        assert!(Decoder::new(&bytes).u32_vec_packed(10).is_err());
        // truncated token stream surfaces as an error, not a panic
        let mut e = Encoder::new();
        e.put_u32_slice_packed(&[9, 9, 9]);
        let bytes = e.into_bytes();
        assert!(Decoder::new(&bytes[..bytes.len() - 1]).u32_vec_packed(3).is_err());
    }

    #[test]
    fn crc32_known_vector() {
        // the classic check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"abc"), crc32(b"abd"));
    }
}
