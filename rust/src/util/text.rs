//! Tiny text helpers for CLI/registry diagnostics (the offline build has
//! no external fuzzy-matching crate).

/// Levenshtein edit distance (iterative two-row DP).
pub fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The candidate closest to `input` under edit distance, if any is close
/// enough to plausibly be a typo (distance ≤ 2, or a strict prefix —
/// `--chain` for `--chains`).
pub fn closest_match<'a>(input: &str, candidates: &[&'a str]) -> Option<&'a str> {
    let mut best: Option<(&str, usize)> = None;
    for &cand in candidates {
        if cand.starts_with(input) || input.starts_with(cand) {
            return Some(cand);
        }
        let d = edit_distance(input, cand);
        if best.map_or(true, |(_, bd)| d < bd) {
            best = Some((cand, d));
        }
    }
    best.filter(|&(_, d)| d <= 2).map(|(c, _)| c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_basics() {
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("chain", "chains"), 1);
        assert_eq!(edit_distance("sparks", "sparx"), 2);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn suggestions() {
        let cands = ["sparx", "xstream", "spif", "dbscout"];
        assert_eq!(closest_match("sparks", &cands), Some("sparx"));
        assert_eq!(closest_match("dbscot", &cands), Some("dbscout"));
        assert_eq!(closest_match("zzzzzz", &cands), None);
        // prefix rule: truncated flags resolve to the full name
        assert_eq!(closest_match("chain", &["chains", "depth"]), Some("chains"));
    }
}
