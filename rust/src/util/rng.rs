//! Deterministic, dependency-free PRNG (PCG-XSH-RR 64/32 + splitmix64
//! seeding). Every stochastic component in the library (generators,
//! subsampling, chain parameters, baselines) draws from an explicit `Rng`
//! so that experiments are reproducible from a single seed.

/// PCG-XSH-RR 64/32 pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1;
        let mut rng = Rng { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(init_inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-partition / per-chain use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > f64::EPSILON {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (floyd's algorithm for k << n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut set = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j as u64 + 1) as usize;
            if set.insert(t) {
                out.push(t);
            } else {
                set.insert(j);
                out.push(j);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "skewed: {counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_indices_k_ge_n() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(5, 10);
        assert_eq!(idx.len(), 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
