//! Small self-contained utilities: deterministic RNG, LRU cache, size
//! estimation for the cluster simulator's memory/shuffle accounting, and
//! the binary codec behind the model artifact format.

pub mod codec;
pub mod json;
pub mod lru;
pub mod rng;
pub mod sizeof;
pub mod text;

pub use json::Json;
pub use lru::LruCache;
pub use rng::Rng;
pub use sizeof::SizeOf;
pub use text::{closest_match, edit_distance};
