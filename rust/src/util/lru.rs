//! Size-bounded LRU cache used by the streaming front-end (§3.5): the
//! deployment node keeps the sketches of the N most recently updated point
//! IDs so that δ-updates are O(K) and scoring O(KrLM) — constant time.

use std::collections::HashMap;
use std::hash::Hash;

/// Doubly-linked-list LRU over a slab, O(1) get/put/evict.
#[derive(Debug)]
pub struct LruCache<K: Eq + Hash + Clone, V> {
    capacity: usize,
    map: HashMap<K, usize>,
    slab: Vec<Entry<K, V>>,
    head: usize, // most recent
    tail: usize, // least recent
    free: Vec<usize>,
}

/// `value` is `None` only for slots parked on the free list: [`remove`]
/// takes the value out eagerly so a detached entry never keeps it alive
/// until slot reuse. Linked (mapped) entries always hold `Some`.
///
/// [`remove`]: LruCache::remove
#[derive(Debug)]
struct Entry<K, V> {
    key: K,
    value: Option<V>,
    prev: usize,
    next: usize,
}

const NIL: usize = usize::MAX;

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    /// `capacity` must be ≥ 1.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "LRU capacity must be >= 1");
        LruCache {
            capacity,
            map: HashMap::with_capacity(capacity),
            slab: Vec::with_capacity(capacity),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn detach(&mut self, idx: usize) {
        let (prev, next) = (self.slab[idx].prev, self.slab[idx].next);
        if prev != NIL {
            self.slab[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slab[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, idx: usize) {
        self.slab[idx].prev = NIL;
        self.slab[idx].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Get and mark as most-recently used.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let idx = *self.map.get(key)?;
        self.detach(idx);
        self.push_front(idx);
        self.slab[idx].value.as_ref()
    }

    /// Mutable access, marks as most-recently used.
    pub fn get_mut(&mut self, key: &K) -> Option<&mut V> {
        let idx = *self.map.get(key)?;
        self.detach(idx);
        self.push_front(idx);
        self.slab[idx].value.as_mut()
    }

    /// Peek without touching recency.
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.map.get(key).and_then(|&i| self.slab[i].value.as_ref())
    }

    /// Insert, evicting the least-recently-used entry if at capacity.
    /// Returns the evicted (key, value) if any.
    pub fn put(&mut self, key: K, value: V) -> Option<(K, V)> {
        if let Some(&idx) = self.map.get(&key) {
            self.slab[idx].value = Some(value);
            self.detach(idx);
            self.push_front(idx);
            return None;
        }
        let mut evicted = None;
        if self.map.len() >= self.capacity {
            let lru = self.tail;
            debug_assert_ne!(lru, NIL);
            self.detach(lru);
            let old_key = self.slab[lru].key.clone();
            self.map.remove(&old_key);
            self.free.push(lru);
            // take the value out by swapping in the new one below
            evicted = Some((old_key, lru));
        }
        let idx = if let Some(i) = self.free.pop() {
            let old = std::mem::replace(
                &mut self.slab[i],
                Entry { key: key.clone(), value: Some(value), prev: NIL, next: NIL },
            );
            if let Some((k, j)) = evicted.take() {
                debug_assert_eq!(i, j);
                self.map.insert(key, i);
                self.push_front(i);
                return old.value.map(|v| (k, v));
            }
            i
        } else {
            self.slab.push(Entry { key: key.clone(), value: Some(value), prev: NIL, next: NIL });
            self.slab.len() - 1
        };
        self.map.insert(key, idx);
        self.push_front(idx);
        None
    }

    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Remove an entry by key, returning its value if it was present.
    /// Used by the sharded serving plane, where eviction decisions are
    /// made by a global directory rather than by this per-shard cache.
    /// The value is taken out of the slab *now* — dropping the returned
    /// `Option` releases its memory immediately, rather than keeping the
    /// evicted sketch resident until the slot happens to be reused.
    pub fn remove(&mut self, key: &K) -> Option<V> {
        let idx = self.map.remove(key)?;
        self.detach(idx);
        self.free.push(idx);
        self.slab[idx].value.take()
    }

    /// Iterate entries from least-recently to most-recently used, without
    /// touching recency. Re-inserting the yielded entries into an empty
    /// cache *in this order* reproduces the recency order exactly — the
    /// property the serving checkpoint's snapshot/restore relies on.
    pub fn iter_lru_to_mru(&self) -> impl Iterator<Item = (&K, &V)> + '_ {
        let mut idx = self.tail;
        std::iter::from_fn(move || {
            if idx == NIL {
                return None;
            }
            let e = &self.slab[idx];
            idx = e.prev;
            Some((&e.key, e.value.as_ref().expect("linked entries hold values")))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_put_get() {
        let mut c = LruCache::new(2);
        c.put("a", 1);
        c.put("b", 2);
        assert_eq!(c.get(&"a"), Some(&1));
        assert_eq!(c.get(&"b"), Some(&2));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn evicts_least_recent() {
        let mut c = LruCache::new(2);
        c.put("a", 1);
        c.put("b", 2);
        c.get(&"a"); // a is now most recent
        let ev = c.put("c", 3);
        assert_eq!(ev, Some(("b", 2)));
        assert!(c.contains(&"a"));
        assert!(!c.contains(&"b"));
        assert!(c.contains(&"c"));
    }

    #[test]
    fn update_existing_does_not_evict() {
        let mut c = LruCache::new(2);
        c.put("a", 1);
        c.put("b", 2);
        assert!(c.put("a", 10).is_none());
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&"a"), Some(&10));
    }

    #[test]
    fn heavy_churn_capacity_respected() {
        let mut c = LruCache::new(16);
        for i in 0..10_000u64 {
            c.put(i % 64, i);
            assert!(c.len() <= 16);
        }
        // the 16 most recent distinct keys (mod 64) must be present
        for i in (10_000 - 16)..10_000u64 {
            assert!(c.contains(&(i % 64)), "missing {}", i % 64);
        }
    }

    /// `iter_lru_to_mru` yields the exact recency order, and re-inserting
    /// in that order rebuilds a cache that evicts identically.
    #[test]
    fn iteration_order_rebuilds_recency() {
        let mut c = LruCache::new(3);
        c.put("a", 1);
        c.put("b", 2);
        c.put("c", 3);
        c.get(&"a"); // order now (LRU→MRU): b, c, a
        let order: Vec<&str> = c.iter_lru_to_mru().map(|(k, _)| *k).collect();
        assert_eq!(order, vec!["b", "c", "a"]);
        // rebuild and check the next eviction matches the original
        let mut rebuilt = LruCache::new(3);
        for (k, v) in c.iter_lru_to_mru() {
            rebuilt.put(*k, *v);
        }
        assert_eq!(c.put("d", 4).map(|(k, _)| k), Some("b"));
        assert_eq!(rebuilt.put("d", 4).map(|(k, _)| k), Some("b"));
        let empty: LruCache<u64, u64> = LruCache::new(2);
        assert_eq!(empty.iter_lru_to_mru().count(), 0);
    }

    #[test]
    fn remove_frees_capacity_and_slot_is_reused() {
        let mut c = LruCache::new(2);
        c.put("a", 1);
        c.put("b", 2);
        assert_eq!(c.remove(&"a"), Some(1), "remove hands the value back");
        assert!(c.remove(&"a").is_none(), "double remove is a no-op");
        assert_eq!(c.len(), 1);
        assert!(!c.contains(&"a"));
        // capacity freed: inserting two more evicts only once
        assert!(c.put("c", 3).is_none(), "freed slot absorbs the insert");
        assert_eq!(c.put("d", 4), Some(("b", 2)));
        let order: Vec<&str> = c.iter_lru_to_mru().map(|(k, _)| *k).collect();
        assert_eq!(order, vec!["c", "d"]);
    }

    #[test]
    fn remove_mid_chain_preserves_links() {
        let mut c = LruCache::new(3);
        c.put(1u64, 1u64);
        c.put(2, 2);
        c.put(3, 3);
        assert_eq!(c.remove(&2), Some(2));
        let order: Vec<u64> = c.iter_lru_to_mru().map(|(k, _)| *k).collect();
        assert_eq!(order, vec![1, 3]);
        assert_eq!(c.remove(&1), Some(1)); // tail
        assert_eq!(c.remove(&3), Some(3)); // head == tail afterwards empty
        assert!(c.is_empty());
        c.put(9, 9);
        assert_eq!(c.get(&9), Some(&9));
    }

    /// Regression: `remove` used to leave the value alive in the slab
    /// until the slot was reused, so an evicted sketch could stay
    /// resident indefinitely in a quiet shard. The value must drop at
    /// remove time, not at the next insertion.
    #[test]
    fn remove_drops_the_value_eagerly() {
        use std::rc::Rc;
        let payload = Rc::new(vec![0u8; 64]);
        let mut c: LruCache<u64, Rc<Vec<u8>>> = LruCache::new(4);
        c.put(1, Rc::clone(&payload));
        assert_eq!(Rc::strong_count(&payload), 2);
        drop(c.remove(&1));
        // no insertion has reused the slot, yet the clone is gone
        assert_eq!(
            Rc::strong_count(&payload),
            1,
            "removed value must be dropped immediately, not parked in the slab"
        );
    }

    #[test]
    fn get_mut_updates_value() {
        let mut c = LruCache::new(2);
        c.put(1, vec![1.0f32]);
        c.get_mut(&1).unwrap().push(2.0);
        assert_eq!(c.peek(&1).unwrap().len(), 2);
    }
}
