//! # Sparx — Distributed Outlier Detection at Scale (KDD '22), reproduced
//!
//! A three-layer reproduction of *Sparx: Distributed Outlier Detection at
//! Scale* (Zhang, Ursekar & Akoglu, KDD 2022):
//!
//! * **L3 (this crate)** — the paper's system contribution: a shared-nothing
//!   cluster runtime ([`cluster`]) with accounted shuffles and per-worker
//!   memory budgets, the two-pass data-parallel Sparx algorithm ([`sparx`]),
//!   the SPIF / DBSCOUT / single-machine-xStream comparators ([`baselines`]),
//!   dataset substrates ([`data`]), evaluation metrics ([`metrics`]) and the
//!   paper's full experiment suite ([`experiments`]).
//! * **L2/L1 (python/, build-time only)** — the per-tile numeric hot path
//!   (sketch projection and half-space-chain binning) written in JAX +
//!   Pallas, AOT-lowered to HLO text and executed from the worker hot path
//!   through the PJRT CPU client ([`runtime`]). Python never runs at
//!   request time.
//!
//! See `DESIGN.md` for the system inventory and the paper→repo experiment
//! index, and `EXPERIMENTS.md` for measured results.
//!
//! ## Quickstart
//!
//! ```no_run
//! use sparx::config::presets;
//! use sparx::data::generators::gisette::GisetteGen;
//! use sparx::sparx::{SparxParams, SparxModel};
//!
//! let cluster = presets::config_mod().build();
//! let data = GisetteGen::default().generate(&cluster).unwrap();
//! let model = SparxModel::fit(&cluster, &data.dataset, &SparxParams::default()).unwrap();
//! let scores = model.score_dataset(&cluster, &data.dataset).unwrap();
//! ```

pub mod baselines;
pub mod cluster;
pub mod config;
pub mod data;
pub mod experiments;
pub mod hash;
pub mod metrics;
pub mod runtime;
pub mod sparx;
pub mod util;

pub use cluster::{ClusterConfig, ClusterContext, ClusterError};
pub use sparx::{SparxModel, SparxParams};

