//! # Sparx — Distributed Outlier Detection at Scale (KDD '22), reproduced
//!
//! A three-layer reproduction of *Sparx: Distributed Outlier Detection at
//! Scale* (Zhang, Ursekar & Akoglu, KDD 2022):
//!
//! * **L3 (this crate)** — the paper's system contribution: a shared-nothing
//!   cluster runtime ([`cluster`]) with accounted shuffles and per-worker
//!   memory budgets, the two-pass data-parallel Sparx algorithm ([`sparx`]),
//!   the SPIF / DBSCOUT / single-machine-xStream comparators ([`baselines`]),
//!   dataset substrates ([`data`]), evaluation metrics ([`metrics`]) and the
//!   paper's full experiment suite ([`experiments`]).
//! * **L2/L1 (python/, build-time only)** — the per-tile numeric hot path
//!   (sketch projection and half-space-chain binning) written in JAX +
//!   Pallas, AOT-lowered to HLO text and executed from the worker hot path
//!   through the PJRT CPU client ([`runtime`]). Python never runs at
//!   request time.
//!
//! See `DESIGN.md` for the system inventory and the paper→repo experiment
//! index, and `EXPERIMENTS.md` for measured results.
//!
//! ## Quickstart: the model lifecycle
//!
//! Every detector — Sparx and the baselines alike — is driven through
//! the unified [`api`] contract, organised around a three-stage
//! lifecycle: **fit** a [`api::Detector`] (typed builder or string
//! registry) into a [`api::FittedModel`]; **save/load** it as a
//! versioned binary artifact ([`api::ModelArtifact`]); **score/serve**
//! batches or §3.5 δ-update streams from the loaded model. All entry
//! points return [`api::Result`] with the crate-wide [`api::SparxError`]
//! taxonomy, and a loaded model scores **bit-identically** to the
//! in-memory one.
//!
//! ```no_run
//! use sparx::api::{registry, Detector, FittedModel, SparxBuilder};
//! use sparx::config::presets;
//! use sparx::data::generators::GisetteGen;
//!
//! fn main() -> sparx::api::Result<()> {
//!     let cluster = presets::config_mod().build();
//!     let data = GisetteGen::default().generate(&cluster)?;
//!     // fit on the cluster
//!     let detector = SparxBuilder::new().chains(50).depth(10).sample_rate(0.1).build()?;
//!     let model = detector.fit(&cluster, &data.dataset)?;
//!     // save: the artifact payload is the whole deployable model
//!     model.to_artifact()?.save("model.sparx")?;
//!     // load (e.g. on a deployment node) and score a batch
//!     let loaded = registry::load("model.sparx")?;
//!     let scores = loaded.score(&cluster, &data.dataset)?; // (id, outlierness)
//!     println!("scored {} points with a {}B model", scores.len(), loaded.model_bytes());
//!     // serve the evolving stream (§3.5) in constant time per update
//!     let mut scorer = loaded.stream_scorer(4096)?;
//!     Ok(())
//! }
//! ```
//!
//! Name-driven construction goes through the registry (what `sparx fit
//! --method …` does; swap the string for `"xstream"`, `"spif"` or
//! `"dbscout"` to run a baseline through the identical codepath):
//!
//! ```no_run
//! use sparx::api::{registry, Detector, DetectorSpec, FittedModel};
//! use sparx::config::presets;
//! use sparx::data::generators::GisetteGen;
//!
//! fn main() -> sparx::api::Result<()> {
//!     let cluster = presets::config_mod().build();
//!     let data = GisetteGen::default().generate(&cluster)?;
//!     let spec = DetectorSpec { components: Some(50), ..Default::default() };
//!     let scores = registry::build("sparx", &spec)?
//!         .fit(&cluster, &data.dataset)?
//!         .score(&cluster, &data.dataset)?;
//!     println!("{} points scored", scores.len());
//!     Ok(())
//! }
//! ```
//!
//! On the command line the same lifecycle is `sparx fit --method sparx
//! --model-out m.sparx`, then `sparx score --model m.sparx`, then
//! `sparx serve --model m.sparx` (⟨ID, F, δ⟩ triples from stdin or
//! `--updates file`). See `rust/examples/model_lifecycle.rs` for the
//! library version end to end.

// Correctness posture (see ARCHITECTURE.md "Correctness tooling"):
// `unsafe` is opt-in per module — only the two whitelisted kernel
// modules (`sparx::chain`, `cluster::pool`) re-enable it — and every
// unsafe operation inside an `unsafe fn` still needs its own block.
// `unreachable_pub` keeps the public surface honest so the artifact /
// serving APIs stay the only entry points. The repo-specific invariants
// the compiler can't see (no-panic decode paths, SAFETY comments, error
// taxonomy, CMS encapsulation) are enforced by `cargo run --bin
// sparx_lint` ([`lint`]).
#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(unreachable_pub)]

pub mod api;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod data;
pub mod ensemble;
pub mod experiments;
pub mod hash;
pub mod lint;
pub mod metrics;
pub mod runtime;
pub mod serve;
pub mod sparx;
pub mod testing;
pub mod util;

pub use api::{
    Backend, Detector, DetectorSpec, FittedModel, ModelArtifact, SparxBuilder, SparxError,
};
pub use cluster::{ClusterConfig, ClusterContext, ClusterError};
pub use sparx::{SparxModel, SparxParams};

