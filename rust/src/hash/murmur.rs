//! Murmur3 x86 32-bit — the workhorse hash. Hand-rolled (no deps) and
//! verified against the reference vectors of the original C++
//! implementation (Austin Appleby, public domain).

#[inline(always)]
fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85EB_CA6B);
    h ^= h >> 13;
    h = h.wrapping_mul(0xC2B2_AE35);
    h ^= h >> 16;
    h
}

/// Murmur3-x86-32 over raw bytes.
pub fn murmur3_bytes(data: &[u8], seed: u32) -> u32 {
    const C1: u32 = 0xCC9E_2D51;
    const C2: u32 = 0x1B87_3593;
    let mut h1 = seed;
    let chunks = data.chunks_exact(4);
    let tail = chunks.remainder();
    for chunk in chunks {
        let mut k1 = u32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
        h1 = h1.rotate_left(13);
        h1 = h1.wrapping_mul(5).wrapping_add(0xE654_6B64);
    }
    let mut k1: u32 = 0;
    if !tail.is_empty() {
        for (i, &b) in tail.iter().enumerate() {
            k1 ^= (b as u32) << (8 * i);
        }
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
    }
    h1 ^= data.len() as u32;
    fmix32(h1)
}

/// Murmur3-x86-32 over a UTF-8 string.
#[inline]
pub fn murmur3_32(s: &str, seed: u32) -> u32 {
    murmur3_bytes(s.as_bytes(), seed)
}

/// Murmur3 over an i32 slice without copying (block-wise LE words).
#[inline]
pub fn murmur3_i32_slice(xs: &[i32], seed: u32) -> u32 {
    const C1: u32 = 0xCC9E_2D51;
    const C2: u32 = 0x1B87_3593;
    let mut h1 = seed;
    for &x in xs {
        let mut k1 = x as u32;
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
        h1 = h1.rotate_left(13);
        h1 = h1.wrapping_mul(5).wrapping_add(0xE654_6B64);
    }
    h1 ^= (xs.len() * 4) as u32;
    fmix32(h1)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference vectors from the canonical MurmurHash3 C++ implementation.
    #[test]
    fn reference_vectors() {
        assert_eq!(murmur3_bytes(b"", 0), 0);
        assert_eq!(murmur3_bytes(b"", 1), 0x514E_28B7);
        assert_eq!(murmur3_bytes(b"", 0xFFFF_FFFF), 0x81F1_6F39);
        assert_eq!(murmur3_bytes(b"\xFF\xFF\xFF\xFF", 0), 0x7629_3B50);
        assert_eq!(murmur3_bytes(b"!Ce\x87", 0), 0xF55B_516B);
        assert_eq!(murmur3_bytes(b"!Ce", 0), 0x7E4A_8634);
        assert_eq!(murmur3_bytes(b"!C", 0), 0xA0F7_B07A);
        assert_eq!(murmur3_bytes(b"!", 0), 0x72661CF4);
        assert_eq!(murmur3_bytes(b"\0\0\0\0", 0), 0x2362_F9DE);
        assert_eq!(murmur3_32("Hello, world!", 1234), 0xFAF6_CDB3);
        assert_eq!(murmur3_32("Hello, world!", 4321), 0xBF50_5788);
    }

    #[test]
    fn i32_slice_matches_bytes() {
        let xs = [1i32, -2, 300000, i32::MIN, i32::MAX];
        let mut bytes = Vec::new();
        for x in xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        for seed in [0u32, 1, 0xDEAD_BEEF] {
            assert_eq!(murmur3_i32_slice(&xs, seed), murmur3_bytes(&bytes, seed));
        }
    }

    #[test]
    fn avalanche_rough_check() {
        // flipping one input bit should flip ~half the output bits on average
        let base = murmur3_bytes(&42u64.to_le_bytes(), 0);
        let mut total = 0u32;
        for bit in 0..64 {
            let v = 42u64 ^ (1 << bit);
            total += (murmur3_bytes(&v.to_le_bytes(), 0) ^ base).count_ones();
        }
        let avg = total as f64 / 64.0;
        assert!((10.0..22.0).contains(&avg), "weak avalanche: {avg}");
    }
}
