//! The sparse sign-hash family of Eq. (2) / Achlioptas projections.
//!
//! Each of the K hash functions h_k maps a string (feature name, or
//! name⊕value for categoricals) to {+1, −1, 0} with probabilities
//! {1/6, 1/6, 2/3} (density 1/3). The *same* seeds are shared by every
//! worker so all points land in one embedding space (Algorithm 1, Line 1),
//! and entries of the implicit random matrix R are recomputed on the fly —
//! "not to cash, but to hash" — which is what lets evolving streams add
//! features without coordination.


/// One sign-hash function h_k; density is the probability of a non-zero.
#[derive(Debug, Clone, Copy)]
pub struct SignHasher {
    seed: u32,
    /// Non-zero probability (paper: 1/3).
    density: f64,
}

impl SignHasher {
    pub fn new(seed: u32, density: f64) -> Self {
        assert!((0.0..=1.0).contains(&density));
        SignHasher { seed, density }
    }

    /// The family {h_1 .. h_K} with seeds 0..K (Algorithm 1, Line 1).
    pub fn family(k: usize, density: f64) -> Vec<SignHasher> {
        (0..k as u32).map(|s| SignHasher::new(s, density)).collect()
    }

    /// h_k(str) ∈ {+1, −1, 0}: uses the top 53 bits of a 64-bit mix of two
    /// murmur passes as a uniform u ∈ [0,1); u < density/2 → +1,
    /// u < density → −1, else 0.
    #[inline]
    pub fn hash_str(&self, s: &str) -> f32 {
        self.hash_bytes(s.as_bytes())
    }

    #[inline]
    pub fn hash_bytes(&self, b: &[u8]) -> f32 {
        let lo =
            super::murmur::murmur3_bytes(b, self.seed.wrapping_mul(2654435761).wrapping_add(1))
                as u64;
        let hi = super::murmur::murmur3_bytes(b, self.seed ^ 0xA5A5_5A5A) as u64;
        let u = (((hi << 32) | lo) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u < self.density / 2.0 {
            1.0
        } else if u < self.density {
            -1.0
        } else {
            0.0
        }
    }

    /// Convenience: h_k over a feature name only (numeric features).
    #[inline]
    pub fn feature(&self, name: &str) -> f32 {
        self.hash_str(name)
    }

    /// h_k over name ⊕ value (categorical features / OHE columns).
    /// Avoids building the concatenated String: hashes a streaming
    /// concatenation through a small stack buffer when possible.
    #[inline]
    pub fn feature_value(&self, name: &str, value: &str) -> f32 {
        let need = name.len() + 1 + value.len();
        let mut stack = [0u8; 96];
        if need <= stack.len() {
            stack[..name.len()].copy_from_slice(name.as_bytes());
            stack[name.len()] = 0x1F; // unit separator avoids "ab"+"c" == "a"+"bc"
            stack[name.len() + 1..need].copy_from_slice(value.as_bytes());
            self.hash_bytes(&stack[..need])
        } else {
            let mut buf = Vec::with_capacity(need);
            buf.extend_from_slice(name.as_bytes());
            buf.push(0x1F);
            buf.extend_from_slice(value.as_bytes());
            self.hash_bytes(&buf)
        }
    }

    pub fn seed(&self) -> u32 {
        self.seed
    }

    /// The non-zero probability this hasher was built with (needed to
    /// reconstruct the family when deserializing a model artifact).
    pub fn density(&self) -> f64 {
        self.density
    }
}

/// Materialise the implicit projection matrix R[D,K] for a *fixed* dense
/// schema (feature names = column identifiers). Used to feed the AOT
/// projection artifact, whose matmul then matches the hash-based Eq. (2)
/// path exactly (tested in `sparx::projector`).
pub fn materialize_r(feature_names: &[String], hashers: &[SignHasher]) -> Vec<f32> {
    let d = feature_names.len();
    let k = hashers.len();
    let mut r = vec![0f32; d * k];
    for (fi, name) in feature_names.iter().enumerate() {
        for (ki, h) in hashers.iter().enumerate() {
            r[fi * k + ki] = h.feature(name);
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let h = SignHasher::new(3, 1.0 / 3.0);
        assert_eq!(h.hash_str("featX"), h.hash_str("featX"));
    }

    #[test]
    fn distribution_matches_density() {
        let h = SignHasher::new(0, 1.0 / 3.0);
        let n = 60_000;
        let mut pos = 0;
        let mut neg = 0;
        let mut zero = 0;
        for i in 0..n {
            match h.hash_str(&format!("f{i}")) as i32 {
                1 => pos += 1,
                -1 => neg += 1,
                0 => zero += 1,
                _ => unreachable!(),
            }
        }
        let nf = n as f64;
        assert!((pos as f64 / nf - 1.0 / 6.0).abs() < 0.01, "pos {pos}");
        assert!((neg as f64 / nf - 1.0 / 6.0).abs() < 0.01, "neg {neg}");
        assert!((zero as f64 / nf - 2.0 / 3.0).abs() < 0.01, "zero {zero}");
    }

    #[test]
    fn family_members_independent() {
        let fam = SignHasher::family(8, 1.0 / 3.0);
        // same input must not produce identical sign across all k
        let signs: Vec<f32> = fam.iter().map(|h| h.hash_str("some-feature")).collect();
        let all_same = signs.windows(2).all(|w| w[0] == w[1]);
        assert!(!all_same, "{signs:?}");
    }

    #[test]
    fn concat_separator_prevents_aliasing() {
        let h = SignHasher::new(1, 1.0);
        // density 1 → every hash is ±1; aliased inputs would often collide
        let mut diff = 0;
        for i in 0..200 {
            let a = h.feature_value(&format!("ab{i}"), "c");
            let b = h.feature_value(&format!("a{i}"), "bc");
            if a != b {
                diff += 1;
            }
        }
        assert!(diff > 50, "aliasing suspected: only {diff}/200 differ");
    }

    #[test]
    fn feature_value_long_strings() {
        let h = SignHasher::new(2, 1.0 / 3.0);
        let long = "x".repeat(300);
        // must not panic, and must be deterministic
        assert_eq!(h.feature_value(&long, &long), h.feature_value(&long, &long));
    }

    #[test]
    fn materialize_matches_hash() {
        let names: Vec<String> = (0..10).map(|i| format!("c{i}")).collect();
        let fam = SignHasher::family(4, 1.0 / 3.0);
        let r = materialize_r(&names, &fam);
        for (fi, name) in names.iter().enumerate() {
            for (ki, h) in fam.iter().enumerate() {
                assert_eq!(r[fi * 4 + ki], h.feature(name));
            }
        }
    }
}
