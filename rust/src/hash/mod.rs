//! Hashing substrate: Murmur3-x86-32, the {+1, −1, 0} sparse sign-hash
//! family of Eq. (2), and the count-min-sketch row hashes.

pub mod murmur;
pub mod sign;

pub use murmur::{murmur3_32, murmur3_bytes};
pub use sign::SignHasher;

/// Hash a K-dimensional integer bin id (paper's \bar z_l ∈ Z^K) to a
/// 64-bit key, order-sensitively, without allocating. Used both for the
/// CMS bucket hashing and for the exact-dictionary reference counter.
#[inline]
pub fn bin_id_hash(bin: &[i32], seed: u32) -> u64 {
    // two murmur passes with decorrelated seeds → 64-bit key, which makes
    // accidental full-key collisions negligible for the exact counter.
    let lo = murmur::murmur3_i32_slice(bin, seed);
    let hi = murmur::murmur3_i32_slice(bin, seed ^ 0x9E37_79B9);
    ((hi as u64) << 32) | lo as u64
}

/// The CMS row hashes use Kirsch–Mitzenmacher double hashing: one murmur
/// pair per bin id, then `bucket_i = (h1 + i·h2) mod w`. Equivalent
/// independence guarantees for count-min at a tenth of the hashing cost —
/// this is the §Perf optimization that removed r-fold rehashing from both
/// the counting and the scoring hot loops (see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BinHash {
    pub h1: u64,
    pub h2: u64,
}

/// Hash a bin id once; rows derive their buckets from this pair.
#[inline]
pub fn bin_hash(bin: &[i32]) -> BinHash {
    let h1 = murmur::murmur3_i32_slice(bin, 0xCAFE_0001) as u64;
    // force h2 odd so consecutive rows never collapse onto one bucket
    let h2 = (murmur::murmur3_i32_slice(bin, 0x5EED_5EED) as u64) | 1;
    BinHash { h1, h2 }
}

/// CMS bucket index for hash-table row `row` of width `w`.
#[inline]
pub fn cms_bucket_from(h: BinHash, row: u32, w: usize) -> usize {
    (h.h1.wrapping_add((row as u64).wrapping_mul(h.h2)) % w as u64) as usize
}

/// One-shot convenience (hashes `bin` then derives the bucket).
#[inline]
pub fn cms_bucket(bin: &[i32], row: u32, w: usize) -> usize {
    cms_bucket_from(bin_hash(bin), row, w)
}

/// Branch-free incremental walk over the `r` row buckets of one bin hash.
///
/// `cms_bucket_from` pays one 64-bit modulo per row; this walk pays two
/// modulos total (`h1 % w`, `h2 % w`) and then advances with an add and a
/// predicated subtract — bit-identical to the per-row formula because
/// `h1`, `h2` < 2^32 (murmur3 outputs) and row counts stay far below the
/// shuffle-key packing limit r < 128, so `h1 + row·h2 < 2^39` never wraps
/// a `u64` and `(b + step) < 2w` needs at most one reduction.
#[derive(Debug, Clone, Copy)]
pub struct BucketWalk {
    bucket: u64,
    step: u64,
    w: u64,
}

impl BucketWalk {
    #[inline]
    pub fn new(h: BinHash, w: usize) -> BucketWalk {
        debug_assert!(w >= 1);
        debug_assert!(h.h1 <= u32::MAX as u64 && h.h2 <= u32::MAX as u64);
        let w64 = w as u64;
        BucketWalk { bucket: h.h1 % w64, step: h.h2 % w64, w: w64 }
    }

    /// Bucket for the current row, then advance to the next row.
    #[inline]
    pub fn next_bucket(&mut self) -> usize {
        let cur = self.bucket;
        let next = self.bucket + self.step;
        self.bucket = next - self.w * u64::from(next >= self.w);
        cur as usize
    }
}

/// All `out.len()` row buckets of `h` at once (the batch entry point the
/// fused executors and `query_many`/`insert_many` build on).
#[inline]
pub fn cms_buckets_into(h: BinHash, w: usize, out: &mut [u32]) {
    let mut walk = BucketWalk::new(h, w);
    for slot in out.iter_mut() {
        *slot = walk.next_bucket() as u32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_id_hash_order_sensitive() {
        let a = bin_id_hash(&[1, 2, 3], 0);
        let b = bin_id_hash(&[3, 2, 1], 0);
        assert_ne!(a, b);
    }

    #[test]
    fn bin_id_hash_seed_sensitive() {
        let a = bin_id_hash(&[1, 2, 3], 0);
        let b = bin_id_hash(&[1, 2, 3], 1);
        assert_ne!(a, b);
    }

    #[test]
    fn cms_bucket_in_range() {
        for row in 0..10 {
            for v in 0..100 {
                let b = cms_bucket(&[v, -v, v * 7], row, 97);
                assert!(b < 97);
            }
        }
    }

    #[test]
    fn bucket_walk_matches_per_row_formula() {
        // the incremental walk is the hot-path replacement for the per-row
        // modulo — it must agree bucket-for-bucket with the oracle
        for w in [1usize, 2, 3, 97, 100, 1024, (1 << 20) - 1] {
            for v in 0..50i32 {
                let h = bin_hash(&[v, v * 31 - 7, -v]);
                let mut walk = BucketWalk::new(h, w);
                for row in 0..127u32 {
                    assert_eq!(
                        walk.next_bucket(),
                        cms_bucket_from(h, row, w),
                        "w={w} v={v} row={row}"
                    );
                }
            }
        }
    }

    #[test]
    fn cms_buckets_into_fills_all_rows() {
        let h = bin_hash(&[5, -9]);
        let mut out = [0u32; 10];
        cms_buckets_into(h, 97, &mut out);
        for (row, &b) in out.iter().enumerate() {
            assert_eq!(b as usize, cms_bucket_from(h, row as u32, 97));
        }
    }

    #[test]
    fn cms_rows_decorrelated() {
        // same bin must not hash to the same bucket in every row
        let mut same = 0;
        for v in 0..200 {
            let b0 = cms_bucket(&[v, v + 1], 0, 100);
            let b1 = cms_bucket(&[v, v + 1], 1, 100);
            if b0 == b1 {
                same += 1;
            }
        }
        assert!(same < 20, "rows correlated: {same}/200 equal");
    }
}
