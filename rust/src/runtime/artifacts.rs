//! Artifact manifest: what `python/compile/aot.py` emitted, with the
//! static operand shapes each HLO module was lowered for.

use std::path::{Path, PathBuf};

use crate::util::Json;

/// One AOT-compiled module.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    /// Variant name ("demo", "gisette", …).
    pub name: String,
    /// Kind: "project" (x[B,D]·R[D,K]→s[B,K]), "chain_bins"
    /// (s[B,K],Δ[K],shift[K],fs[L]→bins[B,L,K]) or "project_bins" (fused).
    pub kind: String,
    /// HLO text file, relative to the manifest.
    pub file: PathBuf,
    pub b: usize,
    pub d: usize,
    pub k: usize,
    pub l: usize,
}

#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub entries: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {path:?}: {e} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| format!("parse {path:?}: {e}"))?;
        let mut entries = Vec::new();
        for a in j.get("artifacts").map(Json::items).unwrap_or(&[]) {
            let field = |k: &str| -> Result<usize, String> {
                a.get(k).and_then(Json::as_usize).ok_or_else(|| format!("manifest missing {k}"))
            };
            entries.push(ArtifactEntry {
                name: a
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or("manifest missing name")?
                    .to_string(),
                kind: a
                    .get("kind")
                    .and_then(Json::as_str)
                    .ok_or("manifest missing kind")?
                    .to_string(),
                file: dir
                    .join(a.get("file").and_then(Json::as_str).ok_or("manifest missing file")?),
                b: field("b")?,
                d: field("d")?,
                k: field("k")?,
                l: field("l")?,
            });
        }
        Ok(ArtifactManifest { entries, dir: dir.to_path_buf() })
    }

    pub fn find(&self, kind: &str, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.kind == kind && e.name == name)
    }

    pub fn variants(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.iter().map(|e| e.name.as_str()).collect();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_when_present() {
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = ArtifactManifest::load(&dir).unwrap();
        assert!(m.find("chain_bins", "demo").is_some());
        let e = m.find("project", "demo").unwrap();
        assert_eq!((e.b, e.d, e.k, e.l), (8, 16, 4, 6));
        assert!(e.file.exists());
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let e = ArtifactManifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(e.contains("make artifacts"));
    }
}
