//! The PJRT execution engine: a service thread owning the CPU client and
//! all compiled executables; callers submit fixed-shape tiles through a
//! channel and block on the reply.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use crate::sparx::chain::{Binner, ChainParams};

use super::artifacts::ArtifactManifest;

enum Request {
    /// x (n×d, row-major) → s (n×k)
    Project { variant: String, x: Vec<f32>, n: usize },
    /// s (n×k) + chain params → bins (n×l×k)
    ChainBins {
        variant: String,
        s: Vec<f32>,
        n: usize,
        delta: Vec<f32>,
        shift: Vec<f32>,
        fs: Vec<i32>,
    },
    /// fused x (n×d) + chain params → bins (n×l×k)
    ProjectBins {
        variant: String,
        x: Vec<f32>,
        n: usize,
        delta: Vec<f32>,
        shift: Vec<f32>,
        fs: Vec<i32>,
    },
    Shutdown,
}

enum Reply {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

struct Job {
    req: Request,
    reply: Sender<Result<Reply, String>>,
}

/// Handle to the engine service thread. Cheap to share (`&PjrtEngine` is
/// Sync); drop shuts the thread down.
pub struct PjrtEngine {
    tx: Mutex<Sender<Job>>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// (kind, variant) → static shapes, mirrored from the manifest for
    /// request validation without a round-trip.
    shapes: HashMap<(String, String), (usize, usize, usize, usize)>,
}

impl PjrtEngine {
    /// Start the engine: loads the manifest, compiles every artifact on
    /// the PJRT CPU client (once), then serves requests.
    pub fn start(manifest: &ArtifactManifest) -> Result<PjrtEngine, String> {
        let (tx, rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let entries = manifest.entries.clone();
        let handle = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                // --- startup: client + compile all artifacts ---
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => c,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("PjRtClient::cpu: {e}")));
                        return;
                    }
                };
                type ExecEntry = (xla::PjRtLoadedExecutable, usize, usize, usize, usize);
                let mut execs: HashMap<(String, String), ExecEntry> = HashMap::new();
                for e in &entries {
                    let proto = match xla::HloModuleProto::from_text_file(
                        e.file.to_str().unwrap_or_default(),
                    ) {
                        Ok(p) => p,
                        Err(err) => {
                            let _ = ready_tx.send(Err(format!("load {:?}: {err}", e.file)));
                            return;
                        }
                    };
                    let comp = xla::XlaComputation::from_proto(&proto);
                    match client.compile(&comp) {
                        Ok(exe) => {
                            execs.insert(
                                (e.kind.clone(), e.name.clone()),
                                (exe, e.b, e.d, e.k, e.l),
                            );
                        }
                        Err(err) => {
                            let _ = ready_tx.send(Err(format!("compile {:?}: {err}", e.file)));
                            return;
                        }
                    }
                }
                let _ = ready_tx.send(Ok(()));
                // --- serve ---
                while let Ok(job) = rx.recv() {
                    match job.req {
                        Request::Shutdown => break,
                        req => {
                            let r = serve(&execs, req);
                            let _ = job.reply.send(r);
                        }
                    }
                }
            })
            .map_err(|e| format!("spawn: {e}"))?;
        ready_rx.recv().map_err(|_| "engine died during startup".to_string())??;
        let shapes = manifest
            .entries
            .iter()
            .map(|e| ((e.kind.clone(), e.name.clone()), (e.b, e.d, e.k, e.l)))
            .collect();
        Ok(PjrtEngine { tx: Mutex::new(tx), handle: Some(handle), shapes })
    }

    /// Start from the default artifacts directory.
    pub fn start_default() -> Result<PjrtEngine, String> {
        let manifest = ArtifactManifest::load(&super::default_artifact_dir())?;
        Self::start(&manifest)
    }

    pub fn shape(&self, kind: &str, variant: &str) -> Option<(usize, usize, usize, usize)> {
        self.shapes.get(&(kind.to_string(), variant.to_string())).copied()
    }

    fn call(&self, req: Request) -> Result<Reply, String> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job { req, reply: reply_tx })
            .map_err(|_| "engine thread gone".to_string())?;
        reply_rx.recv().map_err(|_| "engine thread gone".to_string())?
    }

    /// Execute the projection artifact over `n` rows of width `d` (any n:
    /// tiles are padded to the compiled batch). R is the materialised
    /// sign matrix (row-major [d][k]).
    pub fn project(&self, variant: &str, x: &[f32], n: usize) -> Result<Vec<f32>, String> {
        match self.call(Request::Project { variant: variant.into(), x: x.to_vec(), n })? {
            Reply::F32(v) => Ok(v),
            _ => Err("bad reply".into()),
        }
    }

    /// Execute the binning artifact over `n` sketches.
    pub fn chain_bins(
        &self,
        variant: &str,
        s: &[f32],
        n: usize,
        chain: &ChainParams,
    ) -> Result<Vec<i32>, String> {
        let fs: Vec<i32> = chain.fs.iter().map(|&f| f as i32).collect();
        match self.call(Request::ChainBins {
            variant: variant.into(),
            s: s.to_vec(),
            n,
            delta: chain.deltamax.clone(),
            shift: chain.shift.clone(),
            fs,
        })? {
            Reply::I32(v) => Ok(v),
            _ => Err("bad reply".into()),
        }
    }

    /// Execute the fused project+bin artifact over `n` rows.
    pub fn project_bins(
        &self,
        variant: &str,
        x: &[f32],
        n: usize,
        chain: &ChainParams,
    ) -> Result<Vec<i32>, String> {
        let fs: Vec<i32> = chain.fs.iter().map(|&f| f as i32).collect();
        match self.call(Request::ProjectBins {
            variant: variant.into(),
            x: x.to_vec(),
            n,
            delta: chain.deltamax.clone(),
            shift: chain.shift.clone(),
            fs,
        })? {
            Reply::I32(v) => Ok(v),
            _ => Err("bad reply".into()),
        }
    }
}

impl Drop for PjrtEngine {
    fn drop(&mut self) {
        let (reply_tx, _reply_rx) = channel();
        let _ = self.tx.lock().unwrap().send(Job { req: Request::Shutdown, reply: reply_tx });
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

type Execs = HashMap<(String, String), (xla::PjRtLoadedExecutable, usize, usize, usize, usize)>;

fn get_exec<'a>(
    execs: &'a Execs,
    kind: &str,
    variant: &str,
) -> Result<&'a (xla::PjRtLoadedExecutable, usize, usize, usize, usize), String> {
    execs
        .get(&(kind.to_string(), variant.to_string()))
        .ok_or_else(|| format!("no artifact {kind}/{variant} (rebuild with `make artifacts`)"))
}

/// Stored per variant the first time it's used: the R operand literal.
/// (The R matrix is part of the *request* in `project`; we rebuild the
/// literal per call — cheap relative to execution at these sizes.)
fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal, String> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| format!("reshape{dims:?}: {e}"))
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal, String> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| format!("reshape{dims:?}: {e}"))
}

fn run1(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal, String> {
    let out = exe.execute::<xla::Literal>(args).map_err(|e| format!("execute: {e}"))?;
    let lit = out[0][0].to_literal_sync().map_err(|e| format!("to_literal: {e}"))?;
    lit.to_tuple1().map_err(|e| format!("tuple1: {e}"))
}

fn serve(execs: &Execs, req: Request) -> Result<Reply, String> {
    match req {
        Request::Project { variant, x, n } => {
            let (exe, b, d, k, _l) = get_exec(execs, "project", &variant)?;
            let (b, d, k) = (*b, *d, *k);
            if x.len() != n * d + (d * k) {
                return Err(format!(
                    "project {variant}: want n*d + d*k = {} floats (x ++ R), got {}",
                    n * d + d * k,
                    x.len()
                ));
            }
            let (xs, r) = x.split_at(n * d);
            let r_lit = lit_f32(r, &[d as i64, k as i64])?;
            let mut out = Vec::with_capacity(n * k);
            let mut tile = vec![0f32; b * d];
            let mut i = 0;
            while i < n {
                let take = (n - i).min(b);
                tile[..take * d].copy_from_slice(&xs[i * d..(i + take) * d]);
                tile[take * d..].fill(0.0);
                let x_lit = lit_f32(&tile, &[b as i64, d as i64])?;
                let res = run1(exe, &[x_lit, r_lit.clone()])?;
                let v = res.to_vec::<f32>().map_err(|e| format!("to_vec: {e}"))?;
                out.extend_from_slice(&v[..take * k]);
                i += take;
            }
            Ok(Reply::F32(out))
        }
        Request::ChainBins { variant, s, n, delta, shift, fs } => {
            let (exe, b, _d, k, l) = get_exec(execs, "chain_bins", &variant)?;
            let (b, k, l) = (*b, *k, *l);
            let want_l = fs.len();
            if delta.len() != k || want_l > l || s.len() != n * k {
                return Err(format!(
                    "chain_bins {variant}: shape mismatch \
                     (k={k} l={l} vs delta={} fs={} s={}/n={n})",
                    delta.len(),
                    fs.len(),
                    s.len()
                ));
            }
            // the artifact is compiled for a fixed L; shallower chains pad
            // the feature schedule (extra levels only refine — sliced off)
            let mut fs_pad = fs.clone();
            fs_pad.resize(l, *fs.last().unwrap_or(&0));
            let d_lit = lit_f32(&delta, &[k as i64])?;
            let sh_lit = lit_f32(&shift, &[k as i64])?;
            let fs_lit = lit_i32(&fs_pad, &[l as i64])?;
            let mut out = Vec::with_capacity(n * want_l * k);
            let mut tile = vec![0f32; b * k];
            let mut i = 0;
            while i < n {
                let take = (n - i).min(b);
                tile[..take * k].copy_from_slice(&s[i * k..(i + take) * k]);
                tile[take * k..].fill(0.0);
                let s_lit = lit_f32(&tile, &[b as i64, k as i64])?;
                let res = run1(exe, &[s_lit, d_lit.clone(), sh_lit.clone(), fs_lit.clone()])?;
                let v = res.to_vec::<i32>().map_err(|e| format!("to_vec: {e}"))?;
                for p in 0..take {
                    out.extend_from_slice(&v[p * l * k..p * l * k + want_l * k]);
                }
                i += take;
            }
            Ok(Reply::I32(out))
        }
        Request::ProjectBins { variant, x, n, delta, shift, fs } => {
            let (exe, b, d, k, l) = get_exec(execs, "project_bins", &variant)?;
            let (b, d, k, l) = (*b, *d, *k, *l);
            if x.len() != n * d + d * k || delta.len() != k || fs.len() != l {
                return Err(format!("project_bins {variant}: shape mismatch"));
            }
            let (xs, r) = x.split_at(n * d);
            let r_lit = lit_f32(r, &[d as i64, k as i64])?;
            let d_lit = lit_f32(&delta, &[k as i64])?;
            let sh_lit = lit_f32(&shift, &[k as i64])?;
            let fs_lit = lit_i32(&fs, &[l as i64])?;
            let mut out = Vec::with_capacity(n * l * k);
            let mut tile = vec![0f32; b * d];
            let mut i = 0;
            while i < n {
                let take = (n - i).min(b);
                tile[..take * d].copy_from_slice(&xs[i * d..(i + take) * d]);
                tile[take * d..].fill(0.0);
                let x_lit = lit_f32(&tile, &[b as i64, d as i64])?;
                let res = run1(
                    exe,
                    &[x_lit, r_lit.clone(), d_lit.clone(), sh_lit.clone(), fs_lit.clone()],
                )?;
                let v = res.to_vec::<i32>().map_err(|e| format!("to_vec: {e}"))?;
                out.extend_from_slice(&v[..take * l * k]);
                i += take;
            }
            Ok(Reply::I32(out))
        }
        Request::Shutdown => unreachable!("handled by caller"),
    }
}

/// [`Binner`] backed by the AOT `chain_bins` artifact — drop-in for the
/// native backend in `SparxModel::fit_with` / `score_sketches_with`.
pub struct PjrtBinner<'e> {
    pub engine: &'e PjrtEngine,
    pub variant: String,
}

/// Multi-chain tiling (`Binner::tile_bins_multi`) uses the trait
/// default: one fixed-shape engine dispatch per chain over the same
/// resident tile, which the caller flattened once per partition. Only
/// the per-chain operand literals (Δ, shift, fs — O(K+L) each) change
/// per dispatch, keeping the PJRT path at parity with the native fused
/// executors.
impl Binner for PjrtBinner<'_> {
    fn tile_bins(
        &self,
        chain: &ChainParams,
        s: &[f32],
        n: usize,
    ) -> crate::cluster::Result<Vec<i32>> {
        self.engine.chain_bins(&self.variant, s, n, chain).map_err(|e| {
            crate::cluster::ClusterError::Invalid(format!(
                "PJRT binning failed ({}): {e}",
                self.variant
            ))
        })
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests run only when `make artifacts` has produced the AOT
    //! bundle (skipped otherwise so `cargo test` works pre-build).
    use super::*;
    use crate::sparx::chain::NativeBinner;
    use crate::util::Rng;

    fn engine() -> Option<PjrtEngine> {
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(PjrtEngine::start(&ArtifactManifest::load(&dir).unwrap()).unwrap())
    }

    fn demo_chain(rng: &mut Rng) -> ChainParams {
        let delta: Vec<f32> = (0..4).map(|_| rng.range_f64(0.5, 2.0) as f32).collect();
        ChainParams::sample(&delta, 6, rng)
    }

    #[test]
    fn project_matches_native_matmul() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(1);
        let (n, d, k) = (13, 16, 4); // n > B=8 forces padding + multi-tile
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let r: Vec<f32> = (0..d * k)
            .map(|_| [(-1.0f32), 0.0, 1.0][rng.below(3) as usize])
            .collect();
        let mut xr = x.clone();
        xr.extend_from_slice(&r);
        let got = e.project("demo", &xr, n).unwrap();
        assert_eq!(got.len(), n * k);
        for i in 0..n {
            for j in 0..k {
                let want: f32 = (0..d).map(|q| x[i * d + q] * r[q * k + j]).sum();
                assert!(
                    (got[i * k + j] - want).abs() < 1e-4,
                    "({i},{j}): {} vs {want}",
                    got[i * k + j]
                );
            }
        }
    }

    #[test]
    fn pjrt_binner_matches_native_binner() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(2);
        let chain = demo_chain(&mut rng);
        let n = 29; // forces 4 tiles with padding on B=8
        let s: Vec<f32> = (0..n * 4).map(|_| (rng.normal() * 2.0) as f32).collect();
        let native = NativeBinner.tile_bins(&chain, &s, n).unwrap();
        let pjrt =
            PjrtBinner { engine: &e, variant: "demo".into() }.tile_bins(&chain, &s, n).unwrap();
        assert_eq!(native.len(), pjrt.len());
        let diff = native.iter().zip(&pjrt).filter(|(a, b)| a != b).count();
        // identical semantics; float-order may flip a floor at an exact
        // boundary in rare cases
        assert!(
            diff as f64 / native.len() as f64 <= 1e-3,
            "PJRT and native binning diverge: {diff}/{} differ",
            native.len()
        );
    }

    #[test]
    fn fused_matches_two_stage() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(3);
        let (n, d, k) = (10, 16, 4);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let r: Vec<f32> = (0..d * k)
            .map(|_| [(-1.0f32), 0.0, 1.0][rng.below(3) as usize])
            .collect();
        let chain = demo_chain(&mut rng);
        let mut xr = x.clone();
        xr.extend_from_slice(&r);
        let s = e.project("demo", &xr, n).unwrap();
        let two = e.chain_bins("demo", &s, n, &chain).unwrap();
        let one = e.project_bins("demo", &xr, n, &chain).unwrap();
        let diff = two.iter().zip(&one).filter(|(a, b)| a != b).count();
        assert!(diff as f64 / two.len() as f64 <= 1e-3, "{diff}/{} differ", two.len());
    }

    #[test]
    fn engine_serves_concurrent_callers() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(4);
        let chain = demo_chain(&mut rng);
        let s: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let want = e.chain_bins("demo", &s, 8, &chain).unwrap();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..5 {
                        let got = e.chain_bins("demo", &s, 8, &chain).unwrap();
                        assert_eq!(got, want);
                    }
                });
            }
        });
    }

    #[test]
    fn unknown_variant_errors() {
        let Some(e) = engine() else { return };
        let err = e.project("nope", &[0.0; 4], 1).unwrap_err();
        assert!(err.contains("no artifact"));
    }
}
