//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — see /opt/xla-example/README.md for why not serialized
//! protos) and executes them from the worker hot path.
//!
//! Python runs once at build time (`make artifacts`); this module makes
//! the compiled L2/L1 compute callable from Rust with zero Python at
//! request time.
//!
//! The PJRT client and compiled executables live on a dedicated service
//! thread ([`engine::PjrtEngine`]); workers submit fixed-shape tiles over
//! a channel. That models the real deployment (one accelerator shared by
//! executor threads) and sidesteps the C++ handle thread-affinity.

pub mod artifacts;

/// The real engine needs the vendored `xla` crate (PJRT C API bindings),
/// which the offline build does not carry; without `--features pjrt` a
/// stub with the same public surface is compiled whose `start*`
/// constructors return an error — every caller already handles the
/// artifacts-missing path, so the native backend remains fully usable.
/// Enabling `pjrt` additionally requires uncommenting the vendored
/// `xla` dependency in Cargo.toml (the feature alone does not build).
#[cfg(feature = "pjrt")]
#[path = "engine.rs"]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub use artifacts::{ArtifactEntry, ArtifactManifest};
pub use engine::{PjrtBinner, PjrtEngine};

/// Default artifacts directory (relative to the repo root).
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var_os("SPARX_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
