//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — see /opt/xla-example/README.md for why not serialized
//! protos) and executes them from the worker hot path.
//!
//! Python runs once at build time (`make artifacts`); this module makes
//! the compiled L2/L1 compute callable from Rust with zero Python at
//! request time.
//!
//! The PJRT client and compiled executables live on a dedicated service
//! thread ([`engine::PjrtEngine`]); workers submit fixed-shape tiles over
//! a channel. That models the real deployment (one accelerator shared by
//! executor threads) and sidesteps the C++ handle thread-affinity.

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactEntry, ArtifactManifest};
pub use engine::{PjrtBinner, PjrtEngine};

/// Default artifacts directory (relative to the repo root).
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var_os("SPARX_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
