//! Stub PJRT engine, compiled when the `pjrt` feature is off (the
//! offline build has no `xla` crate). Same public surface as the real
//! [`engine`](super::engine) module; the constructors return an error so
//! all callers fall back to / skip onto the native path.

use crate::sparx::chain::{Binner, ChainParams};

use super::artifacts::ArtifactManifest;

const STUB_MSG: &str =
    "PJRT engine unavailable: built without the `pjrt` feature (vendored `xla` crate required)";

/// Stub handle — cannot be constructed (both `start*` always error), so
/// the instance methods are unreachable but keep the call sites compiling.
pub struct PjrtEngine {
    _priv: (),
}

impl PjrtEngine {
    pub fn start(_manifest: &ArtifactManifest) -> Result<PjrtEngine, String> {
        Err(STUB_MSG.into())
    }

    pub fn start_default() -> Result<PjrtEngine, String> {
        Err(STUB_MSG.into())
    }

    pub fn shape(&self, _kind: &str, _variant: &str) -> Option<(usize, usize, usize, usize)> {
        None
    }

    pub fn project(&self, _variant: &str, _x: &[f32], _n: usize) -> Result<Vec<f32>, String> {
        Err(STUB_MSG.into())
    }

    pub fn chain_bins(
        &self,
        _variant: &str,
        _s: &[f32],
        _n: usize,
        _chain: &ChainParams,
    ) -> Result<Vec<i32>, String> {
        Err(STUB_MSG.into())
    }

    pub fn project_bins(
        &self,
        _variant: &str,
        _x: &[f32],
        _n: usize,
        _chain: &ChainParams,
    ) -> Result<Vec<i32>, String> {
        Err(STUB_MSG.into())
    }
}

/// Stub [`Binner`] — mirrors the real `PjrtBinner` so backend-selection
/// code type-checks without the feature.
pub struct PjrtBinner<'e> {
    pub engine: &'e PjrtEngine,
    pub variant: String,
}

impl Binner for PjrtBinner<'_> {
    fn tile_bins(
        &self,
        _chain: &ChainParams,
        _s: &[f32],
        _n: usize,
    ) -> crate::cluster::Result<Vec<i32>> {
        unreachable!("stub PjrtEngine cannot be constructed")
    }
}
