//! Partitioned datasets with schemas, plus the labeled variant used by
//! the evaluation harness (ground truth never enters the pipeline; it
//! lives driver-side for metric computation only).

use crate::cluster::{ClusterContext, DistVec, Result};
use crate::util::SizeOf;

use super::row::{Features, Row};

/// Column schema for dense/sparse encodings. Feature *names* are what the
/// Eq. (2) hash family consumes; for positional encodings the name of
/// column `j` is `"f{j}"` (memoised here so the hot path never formats).
#[derive(Debug, Clone)]
pub struct Schema {
    pub names: Vec<String>,
}

impl Schema {
    pub fn positional(d: usize) -> Self {
        Schema { names: (0..d).map(|j| format!("f{j}")).collect() }
    }

    pub fn named(names: Vec<String>) -> Self {
        Schema { names }
    }

    pub fn dim(&self) -> usize {
        self.names.len()
    }
}

impl SizeOf for Schema {
    fn size_of(&self) -> usize {
        self.names.size_of()
    }
}

/// A distributed point cloud: schema + partitioned rows.
pub struct Dataset {
    pub schema: Schema,
    pub rows: DistVec<Row>,
    /// Cached at construction: every row of every partition is densely
    /// encoded. The dense-only baselines' input guard
    /// (`api::ensure_dense`) reads this flag instead of probing rows, so
    /// a mixed partition cannot slip through on a lucky first row.
    all_dense: bool,
}

impl Dataset {
    pub fn new(schema: Schema, rows: DistVec<Row>) -> Self {
        let all_dense = (0..rows.num_parts()).all(|p| {
            rows.part(p).iter().all(|r| matches!(r.features, Features::Dense(_)))
        });
        Dataset { schema, rows, all_dense }
    }

    /// Whether every row (across all partitions) is densely encoded.
    pub fn is_all_dense(&self) -> bool {
        self.all_dense
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.schema.dim()
    }

    /// Project the dataset onto a subset of (dense) columns — used by the
    /// Table 2 dimensionality sweep and the DBSCOUT d=2/7 reductions.
    pub fn select_columns(&self, ctx: &ClusterContext, cols: &[usize]) -> Result<Dataset> {
        let cols = cols.to_vec();
        let rows = self.rows.map(ctx, |r| {
            let dense = r.features.as_dense();
            Row::dense(r.id, cols.iter().map(|&c| dense[c]).collect())
        })?;
        let schema =
            Schema::named(cols.iter().map(|&c| self.schema.names[c].clone()).collect());
        Ok(Dataset::new(schema, rows))
    }
}

/// Dataset + driver-side ground truth, keyed by row id.
pub struct LabeledDataset {
    pub dataset: Dataset,
    /// `labels[id] == true` ⇔ point `id` is an outlier.
    pub labels: Vec<bool>,
}

impl LabeledDataset {
    pub fn outlier_count(&self) -> usize {
        self.labels.iter().filter(|&&b| b).count()
    }

    pub fn outlier_rate(&self) -> f64 {
        self.outlier_count() as f64 / self.labels.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    #[test]
    fn positional_schema_names() {
        let s = Schema::positional(3);
        assert_eq!(s.names, vec!["f0", "f1", "f2"]);
        assert_eq!(s.dim(), 3);
    }

    #[test]
    fn density_flag_tracks_every_row_of_every_partition() {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let rows = DistVec::from_parts(
            &ctx,
            vec![
                vec![Row::dense(0, vec![1.0])],
                // dense first row, mixed straggler behind it
                vec![
                    Row::dense(1, vec![2.0]),
                    Row::mixed(2, vec![("a".into(), super::super::row::Value::Num(1.0))]),
                ],
            ],
        )
        .unwrap();
        assert!(!Dataset::new(Schema::positional(1), rows).is_all_dense());

        let ctx2 = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let rows = DistVec::from_vec(
            &ctx2,
            vec![Row::dense(0, vec![1.0]), Row::dense(1, vec![2.0])],
        )
        .unwrap();
        assert!(Dataset::new(Schema::positional(1), rows).is_all_dense());
    }

    #[test]
    fn select_columns() {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let rows = DistVec::from_vec(
            &ctx,
            vec![Row::dense(0, vec![1., 2., 3.]), Row::dense(1, vec![4., 5., 6.])],
        )
        .unwrap();
        let ds = Dataset::new(Schema::positional(3), rows);
        let sub = ds.select_columns(&ctx, &[2, 0]).unwrap();
        assert_eq!(sub.dim(), 2);
        let collected = sub.rows.collect(&ctx).unwrap();
        assert_eq!(collected[0].features.as_dense(), &[3., 1.]);
        assert_eq!(sub.schema.names, vec!["f2", "f0"]);
    }
}
