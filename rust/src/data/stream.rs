//! Evolving-stream update triples ⟨ID, F, δ⟩ (§2, Problem 2).
//!
//! * numeric feature: δ ∈ ℝ is a value *increment*;
//! * categorical feature: δ = old_val → new_val is a value substitution
//!   (old_val = None for a newly-arising feature).

use crate::util::{Rng, SizeOf};

/// One update triple over the evolving stream.
#[derive(Debug, Clone, PartialEq)]
pub enum UpdateTriple {
    /// ⟨ID, F, δ⟩ for real-valued F.
    Num { id: u64, feature: String, delta: f64 },
    /// ⟨ID, F, old:new⟩ for categorical F (old = None if newly arising).
    Cat { id: u64, feature: String, old: Option<String>, new: String },
}

impl UpdateTriple {
    pub fn id(&self) -> u64 {
        match self {
            UpdateTriple::Num { id, .. } | UpdateTriple::Cat { id, .. } => *id,
        }
    }

    pub fn feature(&self) -> &str {
        match self {
            UpdateTriple::Num { feature, .. } | UpdateTriple::Cat { feature, .. } => feature,
        }
    }

    /// Render the triple in the serve-input line grammar
    /// ([`parse_update_line`] is the exact inverse — round trips are
    /// bit-identical, f64 `Display` being shortest-round-trip). What
    /// `sparx generate --stream` writes.
    ///
    /// The line grammar cannot represent every `UpdateTriple`: a name
    /// with whitespace would re-tokenize into extra fields, `->` inside
    /// a categorical **old** value would move the old/new split (the
    /// parser splits at the *first* arrow, so a `new` value containing
    /// `->` is fine), and a non-finite δ would be rejected by the parser
    /// outright. Rather than emit a line that parses back as something
    /// else (or not at all), rendering such a triple fails typed
    /// (`SparxError::InvalidParams`). The synthetic generators never
    /// produce unrepresentable names, so their streams always render.
    pub fn to_line(&self) -> crate::api::Result<String> {
        let bad = |what: String| crate::api::SparxError::InvalidParams(format!(
            "update triple for ID {} is not representable in the line grammar: {what}",
            self.id()
        ));
        let check_token = |role: &str, tok: &str, reject_arrow: bool| {
            if tok.is_empty() {
                return Err(bad(format!("empty {role}")));
            }
            if tok.chars().any(char::is_whitespace) {
                return Err(bad(format!("{role} {tok:?} contains whitespace")));
            }
            if reject_arrow && tok.contains("->") {
                return Err(bad(format!("{role} {tok:?} contains `->`")));
            }
            Ok(())
        };
        match self {
            UpdateTriple::Num { id, feature, delta } => {
                check_token("feature", feature, false)?;
                if !delta.is_finite() {
                    return Err(bad(format!("non-finite δ {delta}")));
                }
                Ok(format!("{id} {feature} {delta}"))
            }
            UpdateTriple::Cat { id, feature, old, new } => {
                check_token("feature", feature, false)?;
                if let Some(old) = old {
                    check_token("old value", old, true)?;
                }
                // the parser splits old->new at the FIRST arrow, so an
                // arrow inside `new` still re-parses to this triple
                check_token("new value", new, false)?;
                Ok(format!("{id} {feature} {}->{new}", old.as_deref().unwrap_or("")))
            }
        }
    }
}

impl SizeOf for UpdateTriple {
    fn size_of(&self) -> usize {
        match self {
            UpdateTriple::Num { feature, .. } => 8 + feature.len() + 8,
            UpdateTriple::Cat { feature, old, new, .. } => {
                8 + feature.len() + old.as_ref().map_or(0, String::len) + new.len()
            }
        }
    }
}

/// Parse one ⟨ID, F, δ⟩ serve-input line: `ID FEATURE δ` for a numeric
/// increment, `ID FEATURE old->new` for a categorical substitution
/// (empty `old` for a newly arising value). Blank lines and `#` comments
/// yield `Ok(None)`; anything else malformed is a typed
/// `SparxError::InvalidParams` naming the line number (exit code 2 at
/// the CLI). This is the whole grammar `sparx serve --updates` accepts.
pub fn parse_update_line(lineno: usize, line: &str) -> crate::api::Result<Option<UpdateTriple>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let bad = |what: &str| {
        crate::api::SparxError::InvalidParams(format!(
            "update line {lineno}: {what} (expected `ID FEATURE δ` or `ID FEATURE old->new`)"
        ))
    };
    let mut tok = line.split_whitespace();
    let (Some(id_tok), Some(feature), Some(delta_tok), None) =
        (tok.next(), tok.next(), tok.next(), tok.next())
    else {
        return Err(bad("expected exactly three whitespace-separated fields"));
    };
    let id: u64 = id_tok.parse().map_err(|_| bad(&format!("bad ID {id_tok:?}")))?;
    if let Ok(delta) = delta_tok.parse::<f64>() {
        // a NaN/∞ increment would poison the ID's sketch permanently
        // (every component goes non-finite until eviction) — reject it
        // like any other malformed token instead of scoring garbage
        if !delta.is_finite() {
            return Err(bad(&format!("non-finite δ {delta_tok:?}")));
        }
        return Ok(Some(UpdateTriple::Num { id, feature: feature.into(), delta }));
    }
    if let Some((old, new)) = delta_tok.split_once("->") {
        if new.is_empty() {
            return Err(bad("categorical update needs a non-empty new value"));
        }
        return Ok(Some(UpdateTriple::Cat {
            id,
            feature: feature.into(),
            old: (!old.is_empty()).then(|| old.to_string()),
            new: new.into(),
        }));
    }
    Err(bad(&format!("third field {delta_tok:?} is neither a number nor old->new")))
}

/// Synthetic evolving stream for the §3.5 deployment demo: mostly numeric
/// increments on known features, occasional categorical moves, and a
/// trickle of *brand-new* features (the paper's motivating case — e.g. a
/// new attack indicator starts being tracked).
pub struct StreamGen {
    pub num_ids: u64,
    pub base_features: Vec<String>,
    pub new_feature_rate: f64,
    pub categorical_rate: f64,
    rng: Rng,
    next_new_feature: u64,
    /// current categorical assignment per (id, feature) — needed to emit
    /// consistent old:new substitutions
    cats: std::collections::HashMap<(u64, String), String>,
}

const CITIES: [&str; 6] = ["NYC", "Austin", "SF", "Chicago", "Boston", "Seattle"];

impl StreamGen {
    pub fn new(num_ids: u64, base_features: Vec<String>, seed: u64) -> Self {
        StreamGen {
            num_ids,
            base_features,
            new_feature_rate: 0.01,
            categorical_rate: 0.1,
            rng: Rng::new(seed),
            next_new_feature: 0,
            cats: std::collections::HashMap::new(),
        }
    }

    /// Draw the next update triple.
    pub fn next_update(&mut self) -> UpdateTriple {
        let id = self.rng.below(self.num_ids);
        if self.rng.bool(self.categorical_rate) {
            let feature = "loc".to_string();
            let new = CITIES[self.rng.below(CITIES.len() as u64) as usize].to_string();
            let old = self.cats.insert((id, feature.clone()), new.clone());
            UpdateTriple::Cat { id, feature, old, new }
        } else if self.rng.bool(self.new_feature_rate) {
            // newly-arising numeric feature
            self.next_new_feature += 1;
            UpdateTriple::Num {
                id,
                feature: format!("new_indicator_{}", self.next_new_feature),
                delta: self.rng.normal(),
            }
        } else {
            let f = &self.base_features
                [self.rng.below(self.base_features.len() as u64) as usize];
            UpdateTriple::Num { id, feature: f.clone(), delta: self.rng.normal() }
        }
    }
}

impl Iterator for StreamGen {
    type Item = UpdateTriple;
    fn next(&mut self) -> Option<UpdateTriple> {
        Some(self.next_update())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_all_kinds() {
        let mut g = StreamGen::new(100, vec!["a".into(), "b".into()], 1);
        g.new_feature_rate = 0.2;
        let updates: Vec<UpdateTriple> = (&mut g).take(500).collect();
        let nums = updates.iter().filter(|u| matches!(u, UpdateTriple::Num { .. })).count();
        let cats = updates.iter().filter(|u| matches!(u, UpdateTriple::Cat { .. })).count();
        assert!(nums > 100);
        assert!(cats > 10);
        let new_feats = updates
            .iter()
            .filter(|u| u.feature().starts_with("new_indicator"))
            .count();
        assert!(new_feats > 0, "no evolving features generated");
    }

    #[test]
    fn categorical_substitutions_consistent() {
        let mut g = StreamGen::new(3, vec!["a".into()], 2);
        g.categorical_rate = 1.0;
        let mut current: std::collections::HashMap<u64, String> = Default::default();
        for u in (&mut g).take(200) {
            if let UpdateTriple::Cat { id, old, new, .. } = u {
                assert_eq!(current.get(&id).cloned(), old, "old value must match state");
                current.insert(id, new);
            }
        }
    }

    #[test]
    fn ids_in_range() {
        let g = StreamGen::new(10, vec!["a".into()], 3);
        for u in g.take(100) {
            assert!(u.id() < 10);
        }
    }

    #[test]
    fn parse_numeric_and_categorical_lines() {
        assert_eq!(
            parse_update_line(1, "42 bytes_sent 1.5").unwrap(),
            Some(UpdateTriple::Num { id: 42, feature: "bytes_sent".into(), delta: 1.5 })
        );
        assert_eq!(
            parse_update_line(2, "7 loc NYC->Austin").unwrap(),
            Some(UpdateTriple::Cat {
                id: 7,
                feature: "loc".into(),
                old: Some("NYC".into()),
                new: "Austin".into(),
            })
        );
        // empty old = newly arising categorical value
        assert_eq!(
            parse_update_line(3, "7 loc ->NYC").unwrap(),
            Some(UpdateTriple::Cat {
                id: 7,
                feature: "loc".into(),
                old: None,
                new: "NYC".into(),
            })
        );
    }

    /// `to_line` → `parse_update_line` is the identity, bit for bit —
    /// the contract `sparx generate --stream` + `serve --updates` (and
    /// the lifecycle-e2e CI job) rely on.
    #[test]
    fn to_line_parse_round_trips_bit_identically() {
        let mut g = StreamGen::new(500, (0..8).map(|j| format!("f{j}")).collect(), 0xC0DE);
        g.new_feature_rate = 0.1;
        g.categorical_rate = 0.2;
        for i in 0..2000 {
            let u = g.next_update();
            let line = u.to_line().unwrap();
            let back = parse_update_line(i + 1, &line).unwrap().unwrap_or_else(|| {
                panic!("line {line:?} parsed as a comment/blank")
            });
            assert_eq!(u, back, "round trip diverged for {line:?}");
        }
        // hand-picked deltas that stress the float formatting
        for delta in [0.1, -0.0, 1e-12, 123456789.123456, f64::MIN_POSITIVE] {
            let u = UpdateTriple::Num { id: 1, feature: "f0".into(), delta };
            let back = parse_update_line(1, &u.to_line().unwrap()).unwrap().unwrap();
            match back {
                UpdateTriple::Num { delta: d, .. } => {
                    assert_eq!(d.to_bits(), delta.to_bits(), "{delta} mangled");
                }
                other => panic!("{other:?}"),
            }
        }
    }

    /// Regression: `to_line` used to render hostile names verbatim, so a
    /// `Cat` with `->` in `old` (or whitespace anywhere) produced a line
    /// that parsed back as a *different* triple. Unrepresentable triples
    /// now fail typed instead of silently corrupting the stream.
    #[test]
    fn to_line_rejects_unrepresentable_triples_typed() {
        use crate::api::SparxError;
        let cat = |old: Option<&str>, new: &str| UpdateTriple::Cat {
            id: 9,
            feature: "loc".into(),
            old: old.map(String::from),
            new: new.into(),
        };
        let hostile: Vec<UpdateTriple> = vec![
            UpdateTriple::Num { id: 1, feature: "two words".into(), delta: 1.0 },
            UpdateTriple::Num { id: 1, feature: "".into(), delta: 1.0 },
            UpdateTriple::Num { id: 1, feature: "f0".into(), delta: f64::NAN },
            UpdateTriple::Num { id: 1, feature: "f0".into(), delta: f64::INFINITY },
            cat(Some("a->b"), "c"), // arrow in old moves the split
            cat(Some("New York"), "SF"),
            cat(Some("NYC"), "San Francisco"),
            cat(Some(""), "SF"), // would re-parse as old = None
            cat(None, ""),
            UpdateTriple::Cat { id: 9, feature: "lo c".into(), old: None, new: "SF".into() },
        ];
        for u in hostile {
            match u.to_line() {
                Err(SparxError::InvalidParams(msg)) => {
                    assert!(msg.contains("not representable"), "{u:?}: {msg:?}");
                }
                other => panic!("{u:?} must fail typed, got {other:?}"),
            }
        }
        // every representable triple still round-trips bit-identically
        let fine = [
            UpdateTriple::Num { id: 1, feature: "f-0.v2".into(), delta: -3.25 },
            cat(None, "SF"),
            cat(Some("-"), "a-b"), // `-` is fine; only the `->` digraph splits
            // an arrow in `new` is representable: the parser splits at
            // the FIRST arrow, so `NYC->a->b` re-parses to exactly this
            cat(Some("NYC"), "a->b"),
            cat(None, "a->b"),
        ];
        for u in fine {
            let back = parse_update_line(1, &u.to_line().unwrap()).unwrap().unwrap();
            assert_eq!(u, back);
        }
    }

    #[test]
    fn parse_skips_comments_and_blank_lines() {
        assert_eq!(parse_update_line(1, "").unwrap(), None);
        assert_eq!(parse_update_line(2, "   ").unwrap(), None);
        assert_eq!(parse_update_line(3, "# a comment").unwrap(), None);
        assert_eq!(parse_update_line(4, "  # indented comment").unwrap(), None);
    }

    #[test]
    fn parse_rejects_malformed_lines_typed_with_line_number() {
        use crate::api::SparxError;
        for (lineno, line) in [
            (1, "42"),                     // one field
            (2, "42 f0"),                  // two fields
            (3, "42 f0 1.0 extra"),        // four fields
            (4, "notanid f0 1.0"),         // bad ID
            (5, "42 f0 north"),            // neither number nor old->new
            (6, "42 loc NYC->"),           // empty new value
            (7, "-1 f0 1.0"),              // negative ID
            (8, "42 f0 NaN"),              // sketch-poisoning increment
            (9, "42 f0 inf"),              // likewise
        ] {
            let r = parse_update_line(lineno, line);
            match r {
                Err(SparxError::InvalidParams(msg)) => {
                    assert!(
                        msg.contains(&format!("update line {lineno}")),
                        "line {line:?}: message must name the line, got {msg:?}"
                    );
                }
                other => panic!("line {line:?} must fail typed, got {other:?}"),
            }
        }
    }
}
