//! SpamURL-like sparse high-dimensional dataset (§4.1.1 dataset 3).
//!
//! The real SpamURL has 2.4M URLs × 3.2M lexical/host features (sparse,
//! ~33% malicious). The statistical challenge the paper calls out is that
//! "outliers are likely buried in small subspaces of the high
//! dimensionality". We preserve that structure: token (feature)
//! frequencies follow a power law; benign URLs draw tokens from the
//! common head; malicious URLs additionally draw from per-campaign rare
//! token bands (small subspaces) with slightly different length
//! statistics.

use crate::cluster::{ClusterContext, DistVec, Result};
use crate::data::dataset::{Dataset, LabeledDataset, Schema};
use crate::data::row::Row;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct SpamUrlGen {
    pub n: usize,
    /// Total vocabulary (feature space) size.
    pub d: usize,
    /// Mean tokens per URL.
    pub mean_nnz: usize,
    pub outlier_rate: f64,
    /// Number of spam "campaigns" (each = one rare-token subspace).
    pub campaigns: usize,
    /// Tokens per campaign band.
    pub campaign_band: usize,
    pub seed: u64,
}

impl Default for SpamUrlGen {
    fn default() -> Self {
        // Scaled from 2.4M × 3.2M to 40k × 200k (DESIGN.md §Substitutions).
        SpamUrlGen {
            n: 40_000,
            d: 200_000,
            mean_nnz: 150,
            outlier_rate: 0.33,
            campaigns: 24,
            campaign_band: 40,
            seed: 0x59A9,
        }
    }
}

impl SpamUrlGen {
    /// Zipf-ish head sample over [0, head) via inverse-power transform.
    #[inline]
    fn zipf(&self, rng: &mut Rng, head: usize) -> u32 {
        // P(rank r) ∝ 1/(r+1)^0.9, truncated at `head`
        let u = rng.f64();
        let r = ((head as f64).powf(1.0 - 0.9_f64) * u).powf(1.0 / (1.0 - 0.9_f64));
        (r as usize).min(head - 1) as u32
    }

    fn draw_row(
        &self,
        rng: &mut Rng,
        outlier: bool,
        campaign_starts: &[u32],
    ) -> (Vec<u32>, Vec<f32>) {
        let head = self.d / 10; // common head of the vocabulary
        // token count: geometric-ish around the mean; malicious URLs are
        // slightly longer on average (more querystring junk)
        let target = if outlier {
            (self.mean_nnz as f64 * rng.range_f64(0.9, 1.6)) as usize
        } else {
            (self.mean_nnz as f64 * rng.range_f64(0.6, 1.4)) as usize
        }
        .max(4);
        let mut idx = std::collections::BTreeMap::new();
        for _ in 0..target {
            let tok = self.zipf(rng, head);
            *idx.entry(tok).or_insert(0.0f32) += 1.0;
        }
        if outlier {
            // campaign band: 6–14 rare tokens from one campaign's subspace
            let c = rng.below(campaign_starts.len() as u64) as usize;
            let start = campaign_starts[c];
            let k = 6 + rng.below(9) as usize;
            for _ in 0..k {
                let tok = start + rng.below(self.campaign_band as u64) as u32;
                *idx.entry(tok).or_insert(0.0f32) += 1.0;
            }
        }
        let (is, vs): (Vec<u32>, Vec<f32>) = idx.into_iter().unzip();
        (is, vs)
    }

    pub fn generate(&self, ctx: &ClusterContext) -> Result<LabeledDataset> {
        // campaign bands live in the rare tail of the vocabulary
        let mut meta = Rng::new(self.seed ^ 0xCA4A16);
        let tail_start = (self.d / 2) as u32;
        let tail_room = self.d as u32 - tail_start - self.campaign_band as u32;
        let campaign_starts: Vec<u32> = (0..self.campaigns)
            .map(|_| tail_start + meta.below(tail_room as u64) as u32)
            .collect();

        let mut label_rng = Rng::new(self.seed ^ 0x1ABE1);
        let labels: Vec<bool> = (0..self.n).map(|_| label_rng.bool(self.outlier_rate)).collect();

        let p = ctx.cfg.num_partitions;
        let per = self.n / p;
        let extra = self.n % p;
        let mut bounds = Vec::with_capacity(p);
        let mut next = 0usize;
        for i in 0..p {
            let take = per + usize::from(i < extra);
            bounds.push((next, take));
            next += take;
        }
        let parts: Vec<Vec<Row>> = crate::cluster::pool::run_indexed(
            ctx.cfg.num_workers,
            p,
            |pi| {
                let (start, count) = bounds[pi];
                let mut rng = Rng::new(self.seed ^ (pi as u64 + 7).wrapping_mul(0x9E3779B9));
                (0..count)
                    .map(|j| {
                        let id = (start + j) as u64;
                        let (idx, val) =
                            self.draw_row(&mut rng, labels[id as usize], &campaign_starts);
                        Row::sparse(id, idx, val)
                    })
                    .collect()
            },
        );
        let rows = DistVec::from_parts(ctx, parts)?;
        Ok(LabeledDataset {
            dataset: Dataset::new(Schema::positional(self.d), rows),
            labels,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn small() -> SpamUrlGen {
        SpamUrlGen { n: 2000, d: 10_000, mean_nnz: 40, ..Default::default() }
    }

    #[test]
    fn shape_rate_sparsity() {
        let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
        let ld = small().generate(&ctx).unwrap();
        assert_eq!(ld.dataset.len(), 2000);
        assert!((0.25..0.42).contains(&ld.outlier_rate()), "{}", ld.outlier_rate());
        let rows = ld.dataset.rows.collect(&ctx).unwrap();
        let avg_nnz: f64 =
            rows.iter().map(|r| r.features.nnz() as f64).sum::<f64>() / rows.len() as f64;
        assert!(avg_nnz < 100.0, "not sparse: {avg_nnz}");
    }

    #[test]
    fn outliers_touch_rare_tail() {
        let gen = small();
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = gen.generate(&ctx).unwrap();
        let rows = ld.dataset.rows.collect(&ctx).unwrap();
        let tail = (gen.d / 2) as u32;
        let touches_tail = |r: &Row| match &r.features {
            crate::data::row::Features::Sparse { idx, .. } => idx.iter().any(|&i| i >= tail),
            _ => false,
        };
        let out_frac = rows
            .iter()
            .filter(|r| ld.labels[r.id as usize])
            .filter(|r| touches_tail(r))
            .count() as f64
            / ld.outlier_count() as f64;
        let in_frac = rows
            .iter()
            .filter(|r| !ld.labels[r.id as usize])
            .filter(|r| touches_tail(r))
            .count() as f64
            / (rows.len() - ld.outlier_count()) as f64;
        assert!(out_frac > 0.95, "outliers should hit campaign bands: {out_frac}");
        assert!(in_frac < 0.05, "inliers should stay in the head: {in_frac}");
    }

    #[test]
    fn indices_sorted_unique() {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = small().generate(&ctx).unwrap();
        for r in ld.dataset.rows.collect(&ctx).unwrap() {
            if let crate::data::row::Features::Sparse { idx, .. } = &r.features {
                assert!(idx.windows(2).all(|w| w[0] < w[1]));
            }
        }
    }
}
