//! Synthetic stand-ins for the paper's three datasets (see DESIGN.md
//! §Substitutions). Each generator creates data *partition-local* (born
//! distributed, like cloud-resident data) and returns driver-side labels
//! for evaluation only.
//!
//! | paper | generator | regime |
//! |---|---|---|
//! | Gisette (GMM-resampled) | [`gisette::GisetteGen`] | small-n / large-d, 10% outliers |
//! | OSM GPS points | [`osm::OsmGen`] | large-n / 2-d, ~0.04% injected outliers |
//! | SpamURL | [`spamurl::SpamUrlGen`] | large-n / sparse large-d, 33% outliers |

pub mod gisette;
pub mod osm;
pub mod spamurl;

pub use gisette::GisetteGen;
pub use osm::OsmGen;
pub use spamurl::SpamUrlGen;
