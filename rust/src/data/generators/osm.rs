//! OSM-like GPS point cloud (§4.1.1 + Appendix A.1.1).
//!
//! Inliers imitate real GPS traces: random-walk "roads" (curvilinear
//! strips of correlated points) plus dense "city" blobs, over the full
//! (−180,180)×(−90,90) lat/lon space. Outliers are injected with the
//! *paper's own protocol*: grid the space into 0.01°×0.01° cells, find
//! empty cells whose 8-neighbourhood is also empty, and drop uniform
//! points inside randomly chosen such cells.
//!
//! Occupied cells are kept in a `HashSet` (a dense 36,000 × 18,000 grid
//! would be 648M cells); empty-with-empty-neighbourhood cells are found by
//! rejection sampling — the globe is mostly empty so acceptance is high.

use std::collections::HashSet;

use crate::cluster::{ClusterContext, DistVec, Result};
use crate::data::dataset::{Dataset, LabeledDataset, Schema};
use crate::data::row::Row;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct OsmGen {
    /// Number of inlier (trace) points.
    pub n_inliers: usize,
    /// Number of injected outliers (paper: 1M on 2.77B ⇒ 0.036%).
    pub n_outliers: usize,
    /// Number of random-walk roads.
    pub roads: usize,
    /// Number of city blobs.
    pub cities: usize,
    /// Histogram cell size in degrees (paper: 0.01).
    pub cell: f64,
    pub seed: u64,
}

impl Default for OsmGen {
    fn default() -> Self {
        // Scaled from 2.77B/1M to 2M/720 — same 0.036% rate (DESIGN.md).
        OsmGen {
            n_inliers: 2_000_000,
            n_outliers: 720,
            roads: 200,
            cities: 40,
            cell: 0.01,
            seed: 0x05A1,
        }
    }
}

const LON_RANGE: (f64, f64) = (-180.0, 180.0);
const LAT_RANGE: (f64, f64) = (-90.0, 90.0);

#[inline]
fn clampf(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

impl OsmGen {
    fn cell_of(&self, lon: f64, lat: f64) -> (i32, i32) {
        (((lon - LON_RANGE.0) / self.cell) as i32, ((lat - LAT_RANGE.0) / self.cell) as i32)
    }

    /// Generate inlier points for one partition, returning points and
    /// marking occupied cells.
    fn gen_inliers(&self, rng: &mut Rng, count: usize) -> Vec<(f64, f64)> {
        // Roads and cities are global structures; each partition draws its
        // points from the same parametric description (shared seed).
        let mut meta = Rng::new(self.seed ^ 0x0520);
        let roads: Vec<(f64, f64, f64, f64)> = (0..self.roads)
            .map(|_| {
                (
                    meta.range_f64(LON_RANGE.0 * 0.9, LON_RANGE.1 * 0.9),
                    meta.range_f64(LAT_RANGE.0 * 0.8, LAT_RANGE.1 * 0.8),
                    meta.range_f64(0.0, std::f64::consts::TAU), // heading
                    meta.range_f64(0.5, 8.0),                   // length (deg)
                )
            })
            .collect();
        let cities: Vec<(f64, f64, f64)> = (0..self.cities)
            .map(|_| {
                (
                    meta.range_f64(LON_RANGE.0 * 0.9, LON_RANGE.1 * 0.9),
                    meta.range_f64(LAT_RANGE.0 * 0.8, LAT_RANGE.1 * 0.8),
                    meta.range_f64(0.05, 0.8), // radius (deg)
                )
            })
            .collect();

        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            if rng.bool(0.6) {
                // on a road: position along + lateral jitter
                let (x0, y0, th, len) = roads[rng.below(roads.len() as u64) as usize];
                let t = rng.f64() * len;
                let wiggle = (t * 3.0).sin() * 0.05; // curvature
                let lon = x0 + th.cos() * t - th.sin() * wiggle + rng.normal() * 0.004;
                let lat = y0 + th.sin() * t + th.cos() * wiggle + rng.normal() * 0.004;
                out.push((
                    clampf(lon, LON_RANGE.0, LON_RANGE.1 - 1e-9),
                    clampf(lat, LAT_RANGE.0, LAT_RANGE.1 - 1e-9),
                ));
            } else {
                // in a city blob
                let (cx, cy, r) = cities[rng.below(cities.len() as u64) as usize];
                let lon = cx + rng.normal() * r;
                let lat = cy + rng.normal() * r;
                out.push((
                    clampf(lon, LON_RANGE.0, LON_RANGE.1 - 1e-9),
                    clampf(lat, LAT_RANGE.0, LAT_RANGE.1 - 1e-9),
                ));
            }
        }
        out
    }

    /// Paper protocol: random empty cells with fully-empty 8-neighbourhood.
    fn inject_outliers(&self, occupied: &HashSet<(i32, i32)>, rng: &mut Rng) -> Vec<(f64, f64)> {
        let nx = ((LON_RANGE.1 - LON_RANGE.0) / self.cell) as i32;
        let ny = ((LAT_RANGE.1 - LAT_RANGE.0) / self.cell) as i32;
        let mut out = Vec::with_capacity(self.n_outliers);
        let mut attempts = 0usize;
        while out.len() < self.n_outliers {
            attempts += 1;
            assert!(
                attempts < self.n_outliers * 1000 + 10_000,
                "outlier injection not converging — space too dense"
            );
            let cx = rng.below(nx as u64) as i32;
            let cy = rng.below(ny as u64) as i32;
            let mut isolated = true;
            'nb: for dx in -1..=1 {
                for dy in -1..=1 {
                    if occupied.contains(&(cx + dx, cy + dy)) {
                        isolated = false;
                        break 'nb;
                    }
                }
            }
            if !isolated {
                continue;
            }
            let lon = LON_RANGE.0 + (cx as f64 + rng.f64()) * self.cell;
            let lat = LAT_RANGE.0 + (cy as f64 + rng.f64()) * self.cell;
            out.push((lon, lat));
        }
        out
    }

    pub fn generate(&self, ctx: &ClusterContext) -> Result<LabeledDataset> {
        let p = ctx.cfg.num_partitions;
        let per = self.n_inliers / p;
        let extra = self.n_inliers % p;

        // inliers per partition (parallel-deterministic), cells collected
        let part_points: Vec<Vec<(f64, f64)>> = crate::cluster::pool::run_indexed(
            ctx.cfg.num_workers,
            p,
            |pi| {
                let mut rng = Rng::new(self.seed ^ (pi as u64 + 1).wrapping_mul(0x9E3779B9));
                self.gen_inliers(&mut rng, per + usize::from(pi < extra))
            },
        );
        let mut occupied = HashSet::new();
        for pts in &part_points {
            for &(lon, lat) in pts {
                occupied.insert(self.cell_of(lon, lat));
            }
        }
        let mut rng = Rng::new(self.seed ^ 0x0071E5);
        let outliers = self.inject_outliers(&occupied, &mut rng);

        // interleave: outliers appended round-robin across partitions with
        // fresh ids after the inliers
        let mut parts: Vec<Vec<Row>> = Vec::with_capacity(p);
        let mut labels = vec![false; self.n_inliers + self.n_outliers];
        let mut id = 0u64;
        for pts in part_points {
            let mut rows = Vec::with_capacity(pts.len());
            for (lon, lat) in pts {
                rows.push(Row::dense(id, vec![lon as f32, lat as f32]));
                id += 1;
            }
            parts.push(rows);
        }
        for (i, (lon, lat)) in outliers.into_iter().enumerate() {
            labels[id as usize] = true;
            parts[i % p].push(Row::dense(id, vec![lon as f32, lat as f32]));
            id += 1;
        }
        let rows = DistVec::from_parts(ctx, parts)?;
        Ok(LabeledDataset {
            dataset: Dataset::new(Schema::named(vec!["lon".into(), "lat".into()]), rows),
            labels,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn small() -> OsmGen {
        OsmGen { n_inliers: 20_000, n_outliers: 50, roads: 30, cities: 10, ..Default::default() }
    }

    #[test]
    fn shape_and_bounds() {
        let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
        let ld = small().generate(&ctx).unwrap();
        assert_eq!(ld.dataset.len(), 20_050);
        assert_eq!(ld.outlier_count(), 50);
        for r in ld.dataset.rows.collect(&ctx).unwrap() {
            let d = r.features.as_dense();
            assert!((-180.0..=180.0).contains(&(d[0] as f64)));
            assert!((-90.0..=90.0).contains(&(d[1] as f64)));
        }
    }

    #[test]
    fn outliers_are_isolated() {
        let gen = small();
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = gen.generate(&ctx).unwrap();
        let rows = ld.dataset.rows.collect(&ctx).unwrap();
        let occupied: HashSet<(i32, i32)> = rows
            .iter()
            .filter(|r| !ld.labels[r.id as usize])
            .map(|r| {
                let d = r.features.as_dense();
                gen.cell_of(d[0] as f64, d[1] as f64)
            })
            .collect();
        // every outlier's cell must have an empty inlier 8-neighbourhood
        for r in rows.iter().filter(|r| ld.labels[r.id as usize]) {
            let d = r.features.as_dense();
            let (cx, cy) = gen.cell_of(d[0] as f64, d[1] as f64);
            for dx in -1..=1 {
                for dy in -1..=1 {
                    assert!(
                        !occupied.contains(&(cx + dx, cy + dy)),
                        "outlier {} adjacent to inlier cell",
                        r.id
                    );
                }
            }
        }
    }

    #[test]
    fn deterministic() {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let a = small().generate(&ctx).unwrap().labels;
        let b = small().generate(&ctx).unwrap().labels;
        assert_eq!(a, b);
    }
}
