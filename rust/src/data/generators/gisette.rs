//! Gisette-like benchmark following the Steinbuss–Böhm protocol the paper
//! uses (§4.1.1): fit a GMM to inliers, draw inliers from it directly, and
//! draw outliers from the same GMM with the variance of 10% of randomly
//! chosen features inflated ×5 — so 90% of features carry no outlier
//! signal, which is what makes the task hard and what rewards Sparx's
//! subspace-style sparse projections.
//!
//! We synthesise the "fitted GMM" directly: C components with random
//! means, a shared low-rank correlation structure (digits-like feature
//! correlation) and per-feature noise scales.

use crate::cluster::{ClusterContext, DistVec, Result};
use crate::data::dataset::{Dataset, LabeledDataset, Schema};
use crate::data::row::Row;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct GisetteGen {
    pub n: usize,
    pub d: usize,
    /// GMM component count.
    pub components: usize,
    /// Low-rank correlation dimension.
    pub rank: usize,
    pub outlier_rate: f64,
    /// Fraction of features whose variance is inflated for outliers.
    pub informative_frac: f64,
    /// Variance inflation factor (paper: 5).
    pub inflation: f64,
    pub seed: u64,
}

impl Default for GisetteGen {
    fn default() -> Self {
        // Scaled from the paper's 40,000 × 4,971 (DESIGN.md §Substitutions).
        GisetteGen {
            n: 8_000,
            d: 512,
            components: 6,
            rank: 8,
            outlier_rate: 0.10,
            informative_frac: 0.10,
            inflation: 5.0,
            seed: 0x615E77E,
        }
    }
}

/// Driver-side generation plan, shared by all partitions.
struct Plan {
    means: Vec<Vec<f32>>,    // [C][d]
    loadings: Vec<Vec<f32>>, // [rank][d] shared low-rank structure
    sigma: Vec<f32>,         // [d] per-feature noise scale
    inflated: Vec<bool>,     // [d] which features blow up for outliers
}

impl GisetteGen {
    fn plan(&self) -> Plan {
        let mut rng = Rng::new(self.seed);
        let means = (0..self.components)
            // modest component separation: the detection signal is the
            // *within-component* variance inflation, and over-spread means
            // would dominate the projected ranges and coarsen every bin
            .map(|_| (0..self.d).map(|_| (rng.normal() * 0.7) as f32).collect())
            .collect();
        // Correlation loadings are kept modest: Steinbuss–Böhm fit
        // (near-)diagonal GMMs, so the variance-inflation signal must not
        // be drowned by a shared correlated component that random
        // projections would mix into every sketch dimension.
        let loadings = (0..self.rank)
            .map(|_| (0..self.d).map(|_| (rng.normal() * 0.25) as f32).collect())
            .collect();
        let sigma = (0..self.d).map(|_| rng.range_f64(0.5, 1.5) as f32).collect();
        let n_inf = ((self.d as f64 * self.informative_frac).round() as usize).max(1);
        let mut inflated = vec![false; self.d];
        for i in Rng::new(self.seed ^ 0xABCD).sample_indices(self.d, n_inf) {
            inflated[i] = true;
        }
        Plan { means, loadings, sigma, inflated }
    }

    fn draw(&self, plan: &Plan, rng: &mut Rng, outlier: bool) -> Vec<f32> {
        let c = rng.below(self.components as u64) as usize;
        let mean = &plan.means[c];
        let z: Vec<f32> = (0..self.rank).map(|_| rng.normal() as f32).collect();
        let infl = (self.inflation as f32).sqrt();
        (0..self.d)
            .map(|j| {
                let corr: f32 =
                    (0..self.rank).map(|q| plan.loadings[q][j] * z[q]).sum();
                let noise = plan.sigma[j] * rng.normal() as f32;
                // Steinbuss–Böhm: outliers draw from the fitted GMM with the
                // feature's *variance* inflated ×5 — i.e. the whole deviation
                // from the component mean is scaled, not just the noise term.
                let mut dev = corr + noise;
                if outlier && plan.inflated[j] {
                    dev *= infl;
                }
                mean[j] + dev
            })
            .collect()
    }

    /// Generate the labeled dataset, partition-local.
    pub fn generate(&self, ctx: &ClusterContext) -> Result<LabeledDataset> {
        let plan = self.plan();
        let p = ctx.cfg.num_partitions;
        let per = self.n / p;
        let extra = self.n % p;
        // Decide labels up-front (driver-side, evaluation only).
        let mut label_rng = Rng::new(self.seed ^ 0x1ABE1);
        let labels: Vec<bool> = (0..self.n).map(|_| label_rng.bool(self.outlier_rate)).collect();

        let mut parts = Vec::with_capacity(p);
        let mut next_id = 0u64;
        let mut sizes = Vec::with_capacity(p);
        for i in 0..p {
            let take = per + usize::from(i < extra);
            sizes.push((next_id, take));
            next_id += take as u64;
        }
        // parallel-friendly: deterministic per-partition RNG
        for (pi, &(start_id, count)) in sizes.iter().enumerate() {
            let mut rng = Rng::new(self.seed ^ (0xBEEF + pi as u64).wrapping_mul(0x9E37));
            let mut rows = Vec::with_capacity(count);
            for j in 0..count {
                let id = start_id + j as u64;
                rows.push(Row::dense(id, self.draw(&plan, &mut rng, labels[id as usize])));
            }
            parts.push(rows);
        }
        let rows = DistVec::from_parts(ctx, parts)?;
        Ok(LabeledDataset {
            dataset: Dataset::new(Schema::positional(self.d), rows),
            labels,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn small() -> GisetteGen {
        GisetteGen { n: 500, d: 32, ..Default::default() }
    }

    #[test]
    fn shape_and_rate() {
        let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
        let ld = small().generate(&ctx).unwrap();
        assert_eq!(ld.dataset.len(), 500);
        assert_eq!(ld.dataset.dim(), 32);
        assert_eq!(ld.labels.len(), 500);
        let rate = ld.outlier_rate();
        assert!((0.05..0.16).contains(&rate), "rate {rate}");
    }

    #[test]
    fn deterministic() {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let a = small().generate(&ctx).unwrap();
        let b = small().generate(&ctx).unwrap();
        assert_eq!(a.dataset.rows.collect(&ctx).unwrap(), b.dataset.rows.collect(&ctx).unwrap());
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn outliers_have_larger_spread_on_inflated_features() {
        let gen = GisetteGen { n: 4000, d: 64, ..Default::default() };
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = gen.generate(&ctx).unwrap();
        let plan = gen.plan();
        let rows = ld.dataset.rows.collect(&ctx).unwrap();
        let j = plan.inflated.iter().position(|&b| b).unwrap();
        let spread = |outlier: bool| {
            let vals: Vec<f64> = rows
                .iter()
                .filter(|r| ld.labels[r.id as usize] == outlier)
                .map(|r| r.features.as_dense()[j] as f64)
                .collect();
            let m = vals.iter().sum::<f64>() / vals.len() as f64;
            (vals.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / vals.len() as f64).sqrt()
        };
        let s_out = spread(true);
        let s_in = spread(false);
        assert!(s_out > s_in * 1.3, "outlier spread {s_out} vs inlier {s_in}");
    }
}
