//! Data substrate: mixed-type point-cloud rows, schemas, partitioned
//! datasets, loaders, the three paper-dataset generators and the
//! evolving-stream update triples of §2/§3.5.

pub mod dataset;
pub mod generators;
pub mod loader;
pub mod row;
pub mod stream;

pub use dataset::{Dataset, LabeledDataset, Schema};
pub use row::{Features, Row, Value};
pub use stream::parse_update_line;
pub use stream::StreamGen;
pub use stream::UpdateTriple;
