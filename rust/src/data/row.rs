//! Point representation: mixed-type (numeric + categorical) feature
//! vectors in dense, sparse or name-keyed ("mixed") encodings.

use crate::util::SizeOf;

/// A single feature value — Sparx admits mixed-type data (§1 property v).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Num(f64),
    Cat(String),
}

impl SizeOf for Value {
    fn size_of(&self) -> usize {
        match self {
            Value::Num(_) => std::mem::size_of::<Value>(),
            Value::Cat(s) => std::mem::size_of::<Value>() + s.len(),
        }
    }
}

/// Feature-vector encodings.
///
/// * `Dense` — positional f32s over a fixed schema (Gisette/OSM-style).
/// * `Sparse` — (index, value) pairs over a huge fixed schema
///   (SpamURL-style; indices strictly increasing).
/// * `Mixed` — explicit (name, value) pairs incl. categoricals; the
///   evolving-stream encoding where the feature set is open-ended.
#[derive(Debug, Clone, PartialEq)]
pub enum Features {
    Dense(Vec<f32>),
    Sparse { idx: Vec<u32>, val: Vec<f32> },
    Mixed(Vec<(String, Value)>),
}

impl Features {
    /// Number of stored (non-zero / present) entries.
    pub fn nnz(&self) -> usize {
        match self {
            Features::Dense(v) => v.len(),
            Features::Sparse { idx, .. } => idx.len(),
            Features::Mixed(m) => m.len(),
        }
    }

    /// Dense accessor (panics on other encodings — callers know their schema).
    pub fn as_dense(&self) -> &[f32] {
        match self {
            Features::Dense(v) => v,
            _ => panic!("expected dense features"),
        }
    }

    /// L2 norm over numeric content (used by tests/sanity checks).
    pub fn norm(&self) -> f64 {
        match self {
            Features::Dense(v) => v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt(),
            Features::Sparse { val, .. } => {
                val.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
            }
            Features::Mixed(m) => m
                .iter()
                .map(|(_, v)| match v {
                    Value::Num(x) => x * x,
                    Value::Cat(_) => 1.0,
                })
                .sum::<f64>()
                .sqrt(),
        }
    }
}

impl SizeOf for Features {
    fn size_of(&self) -> usize {
        std::mem::size_of::<Features>()
            + match self {
                Features::Dense(v) => v.len() * 4,
                Features::Sparse { idx, val } => idx.len() * 4 + val.len() * 4,
                Features::Mixed(m) => m
                    .iter()
                    .map(|(n, v)| n.len() + std::mem::size_of::<String>() + v.size_of())
                    .sum(),
            }
    }
}

/// One point with a stable identifier (update triples address it by ID).
#[derive(Debug, Clone, PartialEq)]
pub struct Row {
    pub id: u64,
    pub features: Features,
}

impl Row {
    pub fn dense(id: u64, values: Vec<f32>) -> Self {
        Row { id, features: Features::Dense(values) }
    }

    pub fn sparse(id: u64, idx: Vec<u32>, val: Vec<f32>) -> Self {
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "sparse indices must increase");
        debug_assert_eq!(idx.len(), val.len());
        Row { id, features: Features::Sparse { idx, val } }
    }

    pub fn mixed(id: u64, pairs: Vec<(String, Value)>) -> Self {
        Row { id, features: Features::Mixed(pairs) }
    }
}

impl SizeOf for Row {
    fn size_of(&self) -> usize {
        8 + self.features.size_of()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nnz_per_encoding() {
        assert_eq!(Row::dense(0, vec![1.0, 2.0]).features.nnz(), 2);
        assert_eq!(Row::sparse(0, vec![3, 9], vec![1.0, 2.0]).features.nnz(), 2);
        assert_eq!(
            Row::mixed(0, vec![("a".into(), Value::Num(1.0))]).features.nnz(),
            1
        );
    }

    #[test]
    fn sizeof_scales_with_payload() {
        let small = Row::dense(0, vec![0.0; 4]).size_of();
        let big = Row::dense(0, vec![0.0; 400]).size_of();
        assert!(big > small + 1000);
    }

    #[test]
    fn norm_dense_sparse_agree() {
        let d = Row::dense(0, vec![3.0, 0.0, 4.0]);
        let s = Row::sparse(0, vec![0, 2], vec![3.0, 4.0]);
        assert!((d.features.norm() - 5.0).abs() < 1e-9);
        assert!((s.features.norm() - 5.0).abs() < 1e-9);
    }
}
