//! File loaders so users can run Sparx on their own data: dense CSV
//! (numeric, optional label column) and LibSVM/SVMlight sparse format
//! (the distribution format of the real SpamURL dataset).

use std::io::{BufRead, BufReader};
use std::path::Path;

use crate::cluster::{ClusterContext, DistVec, Result};
use crate::util::SizeOf;

use super::dataset::{Dataset, LabeledDataset, Schema};
use super::row::Row;

fn invalid(msg: String) -> crate::cluster::ClusterError {
    crate::cluster::ClusterError::Invalid(msg)
}

/// Load a dense numeric CSV. If `label_col` is given, that column becomes
/// the ground-truth label (non-zero ⇒ outlier) and is removed from the
/// features. First row may be a header (detected by non-numeric cells).
pub fn load_csv(
    ctx: &ClusterContext,
    path: impl AsRef<Path>,
    label_col: Option<usize>,
) -> Result<LabeledDataset> {
    let f = std::fs::File::open(path.as_ref())
        .map_err(|e| invalid(format!("open {:?}: {e}", path.as_ref())))?;
    let mut lines = BufReader::new(f).lines();

    let first = match lines.next() {
        Some(l) => l.map_err(|e| invalid(format!("read: {e}")))?,
        None => return Err(invalid("empty csv".into())),
    };
    let first_cells: Vec<&str> = first.split(',').map(str::trim).collect();
    let has_header = first_cells.iter().any(|c| c.parse::<f64>().is_err());
    let ncols = first_cells.len();
    let names: Vec<String> = if has_header {
        first_cells.iter().map(|s| s.to_string()).collect()
    } else {
        (0..ncols).map(|j| format!("f{j}")).collect()
    };

    let mut rows = Vec::new();
    let mut labels = Vec::new();
    let mut id = 0u64;
    let mut push_row = |cells: Vec<f64>| -> Result<()> {
        let mut feats = Vec::with_capacity(ncols - usize::from(label_col.is_some()));
        let mut label = false;
        for (j, v) in cells.into_iter().enumerate() {
            if Some(j) == label_col {
                label = v != 0.0;
            } else {
                feats.push(v as f32);
            }
        }
        rows.push(Row::dense(id, feats));
        labels.push(label);
        id += 1;
        Ok(())
    };

    if !has_header {
        let cells = first_cells
            .iter()
            .map(|c| c.parse::<f64>().map_err(|e| invalid(format!("parse {c:?}: {e}"))))
            .collect::<Result<Vec<f64>>>()?;
        push_row(cells)?;
    }
    for (lineno, line) in lines.enumerate() {
        let line = line.map_err(|e| invalid(format!("read: {e}")))?;
        if line.trim().is_empty() {
            continue;
        }
        let cells = line
            .split(',')
            .map(|c| {
                c.trim()
                    .parse::<f64>()
                    .map_err(|e| invalid(format!("line {}: parse {c:?}: {e}", lineno + 2)))
            })
            .collect::<Result<Vec<f64>>>()?;
        if cells.len() != ncols {
            return Err(invalid(format!("line {}: {} cols, want {ncols}", lineno + 2, cells.len())));
        }
        push_row(cells)?;
    }

    let schema = Schema::named(
        names
            .into_iter()
            .enumerate()
            .filter(|(j, _)| Some(*j) != label_col)
            .map(|(_, n)| n)
            .collect(),
    );
    let rows = DistVec::from_vec(ctx, rows)?;
    Ok(LabeledDataset { dataset: Dataset::new(schema, rows), labels })
}

/// Load LibSVM format: `label idx:val idx:val ...` with 1-based indices.
/// Labels > 0 are treated as outliers (SpamURL convention: +1 malicious).
pub fn load_libsvm(
    ctx: &ClusterContext,
    path: impl AsRef<Path>,
    dim: Option<usize>,
) -> Result<LabeledDataset> {
    let f = std::fs::File::open(path.as_ref())
        .map_err(|e| invalid(format!("open {:?}: {e}", path.as_ref())))?;
    let mut rows = Vec::new();
    let mut labels = Vec::new();
    let mut max_idx = 0u32;
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line.map_err(|e| invalid(format!("read: {e}")))?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let label: f64 = it
            .next()
            .ok_or_else(|| invalid(format!("line {}: empty", lineno + 1)))?
            .parse()
            .map_err(|e| invalid(format!("line {}: label: {e}", lineno + 1)))?;
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for tok in it {
            let (i, v) = tok
                .split_once(':')
                .ok_or_else(|| invalid(format!("line {}: token {tok:?}", lineno + 1)))?;
            let i: u32 = i
                .parse::<u32>()
                .map_err(|e| invalid(format!("line {}: idx: {e}", lineno + 1)))?
                .checked_sub(1)
                .ok_or_else(|| invalid(format!("line {}: zero index", lineno + 1)))?;
            let v: f32 =
                v.parse().map_err(|e| invalid(format!("line {}: val: {e}", lineno + 1)))?;
            idx.push(i);
            val.push(v);
            max_idx = max_idx.max(i);
        }
        // libsvm lines are usually sorted; enforce it
        let mut order: Vec<usize> = (0..idx.len()).collect();
        order.sort_by_key(|&i| idx[i]);
        let idx: Vec<u32> = order.iter().map(|&i| idx[i]).collect();
        let val: Vec<f32> = order.iter().map(|&i| val[i]).collect();
        rows.push(Row::sparse(rows.len() as u64, idx, val));
        labels.push(label > 0.0);
    }
    let d = dim.unwrap_or(max_idx as usize + 1);
    let rows = DistVec::from_vec(ctx, rows)?;
    Ok(LabeledDataset { dataset: Dataset::new(Schema::positional(d), rows), labels })
}

/// Write scores (id, score, label) to CSV for external analysis.
pub fn write_scores_csv(
    path: impl AsRef<Path>,
    scores: &[(u64, f64)],
    labels: &[bool],
) -> crate::api::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "id,score,label")?;
    for &(id, s) in scores {
        let label = labels.get(id as usize).copied().unwrap_or(false);
        writeln!(f, "{id},{s},{}", u8::from(label))?;
    }
    Ok(())
}

/// Estimated on-disk/in-memory footprint of a dataset (report plumbing).
pub fn dataset_bytes(ds: &Dataset) -> usize {
    (0..ds.rows.num_parts())
        .map(|p| ds.rows.part(p).iter().map(SizeOf::size_of).sum::<usize>())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn ctx() -> ClusterContext {
        ClusterConfig { num_partitions: 2, ..Default::default() }.build()
    }

    #[test]
    fn csv_roundtrip_with_header_and_label() {
        let dir = std::env::temp_dir().join("sparx_test_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        std::fs::write(&p, "a,b,y\n1.0,2.0,0\n3.0,4.0,1\n").unwrap();
        let ld = load_csv(&ctx(), &p, Some(2)).unwrap();
        assert_eq!(ld.dataset.len(), 2);
        assert_eq!(ld.dataset.dim(), 2);
        assert_eq!(ld.labels, vec![false, true]);
        assert_eq!(ld.dataset.schema.names, vec!["a", "b"]);
    }

    #[test]
    fn csv_headerless() {
        let dir = std::env::temp_dir().join("sparx_test_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t2.csv");
        std::fs::write(&p, "1.0,2.0\n3.0,4.0\n").unwrap();
        let ld = load_csv(&ctx(), &p, None).unwrap();
        assert_eq!(ld.dataset.len(), 2);
        assert_eq!(ld.dataset.dim(), 2);
    }

    #[test]
    fn csv_ragged_fails() {
        let dir = std::env::temp_dir().join("sparx_test_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t3.csv");
        std::fs::write(&p, "1.0,2.0\n3.0\n").unwrap();
        assert!(load_csv(&ctx(), &p, None).is_err());
    }

    #[test]
    fn libsvm_parse() {
        let dir = std::env::temp_dir().join("sparx_test_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.svm");
        std::fs::write(&p, "+1 3:1.5 1:2.0\n-1 2:0.5\n").unwrap();
        let ld = load_libsvm(&ctx(), &p, None).unwrap();
        assert_eq!(ld.dataset.len(), 2);
        assert_eq!(ld.labels, vec![true, false]);
        let rows = ld.dataset.rows.collect(&ctx()).unwrap();
        match &rows[0].features {
            crate::data::row::Features::Sparse { idx, val } => {
                assert_eq!(idx, &vec![0, 2]); // sorted, 0-based
                assert_eq!(val, &vec![2.0, 1.5]);
            }
            _ => panic!("expected sparse"),
        }
    }
}
