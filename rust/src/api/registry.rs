//! String → factory registry of detectors: the single lookup behind
//! `sparx detect --method …` and any other name-driven entry point —
//! plus the artifact side of the lifecycle: [`load`] / [`load_bytes`]
//! read a serialized [`ModelArtifact`] header and dispatch to the right
//! detector's deserializer, returning a ready-to-score
//! [`FittedModel`](super::FittedModel).
//!
//! Each factory consumes a [`DetectorSpec`] — the flag-level description
//! of a run — applies its method's defaults for unset fields, validates,
//! and returns the boxed [`Detector`].

use crate::baselines::dbscout::{DbscoutDetector, FittedDbscout};
use crate::baselines::spif::SpifDetector;
use crate::baselines::xstream::XStreamDetector;
use crate::baselines::{DbscoutParams, Spif, SpifParams, XStream, XStreamParams};
use crate::ensemble::Schedule;
use crate::sparx::ExecMode;

use super::artifact::ModelArtifact;
use super::builder::{Backend, FittedSparx, SparxBuilder};
use super::error::{Result, SparxError};
use super::spec::MethodSpec;
use super::{Detector, FittedModel};

/// Flag-level description of a detector run. `None` fields fall back to
/// the method's own defaults, so one spec can configure any detector.
/// Fields a method has no use for are ignored by its factory (a spec is
/// a superset description); the CLI rejects explicitly-passed
/// inapplicable flags *before* building the spec, so users never hit
/// the silent-ignore path.
#[derive(Debug, Clone)]
pub struct DetectorSpec {
    /// Projection size K (sparx / xstream; 0 ⇒ identity).
    pub k: Option<usize>,
    /// Ensemble size: chains (sparx / xstream) or trees (spif).
    pub components: Option<usize>,
    /// Chain length / tree depth.
    pub depth: Option<usize>,
    /// Fit subsampling rate in (0, 1].
    pub sample_rate: Option<f64>,
    /// Base seed for parameter sampling (None ⇒ library default).
    pub seed: Option<u64>,
    /// Sparx execution plan.
    pub exec_mode: ExecMode,
    /// Sparx binning backend.
    pub backend: Backend,
    /// AOT artifact variant for the PJRT backend.
    pub pjrt_variant: Option<String>,
    /// DBSCOUT eps (None ⇒ chosen at fit time via the elbow heuristic).
    pub eps: Option<f64>,
    /// DBSCOUT minPts.
    pub min_pts: Option<usize>,
    /// Ensemble member list, e.g. `"sparx:depth=6,xstream"` (None ⇒
    /// [`crate::ensemble::DEFAULT_MEMBERS`]).
    pub members: Option<String>,
    /// Ensemble: distill a cheap sparx student for the serve path.
    pub distill: bool,
    /// Ensemble: share one projector among `(k, density)`-compatible
    /// members (default on).
    pub share: bool,
    /// Ensemble member-to-worker packing.
    pub schedule: Schedule,
}

impl Default for DetectorSpec {
    fn default() -> Self {
        DetectorSpec {
            k: None,
            components: None,
            depth: None,
            sample_rate: None,
            seed: None,
            exec_mode: ExecMode::Fused,
            backend: Backend::Native,
            pjrt_variant: None,
            eps: None,
            min_pts: None,
            members: None,
            distill: false,
            share: true,
            schedule: Schedule::Balanced,
        }
    }
}

type Factory = fn(&DetectorSpec) -> Result<Box<dyn Detector>>;

/// The registered methods, in CLI listing order.
const REGISTRY: &[(&str, Factory)] = &[
    ("sparx", make_sparx),
    ("xstream", make_xstream),
    ("spif", make_spif),
    ("dbscout", make_dbscout),
    ("ensemble", make_ensemble),
];

/// Names of every registered detector.
pub fn detector_names() -> Vec<&'static str> {
    REGISTRY.iter().map(|(name, _)| *name).collect()
}

/// Build a detector by name. Unknown names return
/// [`SparxError::UnknownDetector`] with the valid options (and a
/// suggestion when the name looks like a typo).
pub fn build(name: &str, spec: &DetectorSpec) -> Result<Box<dyn Detector>> {
    match REGISTRY.iter().find(|(n, _)| *n == name) {
        Some((_, factory)) => factory(spec),
        None => {
            let names = detector_names().join("|");
            let hint = crate::util::closest_match(name, &detector_names())
                .map(|s| format!(" — did you mean {s:?}?"))
                .unwrap_or_default();
            Err(SparxError::UnknownDetector(format!("{name:?} (expected {names}){hint}")))
        }
    }
}

/// Build a detector from a full spec string — `name` alone or
/// `name?key=val&key=val` (e.g. `"sparx?depth=12&rate=0.05"`,
/// `"ensemble?members=sparx,xstream&distill=true"`). The parameterized
/// front of the registry: one grammar ([`MethodSpec`]) shared by the
/// CLI's `--method`, member specs inside `members=`, and this call.
pub fn create(spec_text: &str) -> Result<Box<dyn Detector>> {
    let ms = MethodSpec::parse(spec_text)?;
    let mut spec = DetectorSpec::default();
    apply_spec_string(&ms, &mut spec)?;
    build(&ms.name, &spec)
}

/// Overlay a parsed spec string's `key=val` pairs onto a
/// [`DetectorSpec`] (spec-string values win — the CLI calls this
/// *after* applying flags). Unknown method names are left for
/// [`build`]'s typed error; unknown keys fail here with a
/// suggestion.
pub fn apply_spec_string(ms: &MethodSpec, spec: &mut DetectorSpec) -> Result<()> {
    if REGISTRY.iter().all(|(n, _)| *n != ms.name) {
        return Ok(());
    }
    for (key, value) in &ms.params {
        apply_key(&ms.name, key, value, spec)?;
    }
    Ok(())
}

/// The spec-string keys each method understands.
pub(crate) fn known_keys(method: &str) -> &'static [&'static str] {
    match method {
        "sparx" => &["k", "chains", "depth", "rate", "seed", "exec"],
        "xstream" => &["k", "chains", "depth", "seed"],
        "spif" => &["trees", "depth", "rate", "seed"],
        "dbscout" => &["eps", "min-pts"],
        "ensemble" => &["members", "distill", "share", "schedule", "seed"],
        _ => &[],
    }
}

/// Apply one `key=val` pair to a [`DetectorSpec`]. Unknown keys and
/// unparsable values fail typed (`InvalidParams`), with an
/// edit-distance suggestion for near-misses.
pub(crate) fn apply_key(
    method: &str,
    key: &str,
    value: &str,
    spec: &mut DetectorSpec,
) -> Result<()> {
    let keys = known_keys(method);
    if !keys.contains(&key) {
        let hint = crate::util::closest_match(key, keys)
            .map(|s| format!(" — did you mean {s:?}?"))
            .unwrap_or_default();
        return Err(SparxError::InvalidParams(format!(
            "unknown {method} option {key:?} (expected {}){hint}",
            keys.join("|")
        )));
    }
    match key {
        "k" => spec.k = Some(parse_usize(key, value)?),
        "chains" | "trees" => spec.components = Some(parse_usize(key, value)?),
        "depth" => spec.depth = Some(parse_usize(key, value)?),
        "rate" => spec.sample_rate = Some(parse_f64(key, value)?),
        "seed" => spec.seed = Some(parse_u64(key, value)?),
        "exec" => {
            spec.exec_mode = match value {
                "fused" => ExecMode::Fused,
                "per-chain" => ExecMode::PerChain,
                other => {
                    return Err(SparxError::InvalidParams(format!(
                        "exec expects fused|per-chain: got {other:?}"
                    )))
                }
            }
        }
        "eps" => spec.eps = Some(parse_f64(key, value)?),
        "min-pts" => spec.min_pts = Some(parse_usize(key, value)?),
        "members" => spec.members = Some(value.to_string()),
        "distill" => spec.distill = parse_bool(key, value)?,
        "share" => spec.share = parse_bool(key, value)?,
        "schedule" => {
            spec.schedule = Schedule::parse(value).ok_or_else(|| {
                SparxError::InvalidParams(format!(
                    "schedule expects balanced|round-robin: got {value:?}"
                ))
            })?
        }
        other => {
            return Err(SparxError::InvalidParams(format!(
                "unhandled {method} option {other:?}"
            )))
        }
    }
    Ok(())
}

fn parse_usize(key: &str, value: &str) -> Result<usize> {
    value.parse().map_err(|_| {
        SparxError::InvalidParams(format!("{key} expects a non-negative integer: got {value:?}"))
    })
}

fn parse_u64(key: &str, value: &str) -> Result<u64> {
    value.parse().map_err(|_| {
        SparxError::InvalidParams(format!("{key} expects a non-negative integer: got {value:?}"))
    })
}

fn parse_f64(key: &str, value: &str) -> Result<f64> {
    value.parse().map_err(|_| {
        SparxError::InvalidParams(format!("{key} expects a number: got {value:?}"))
    })
}

fn parse_bool(key: &str, value: &str) -> Result<bool> {
    match value {
        "true" => Ok(true),
        "false" => Ok(false),
        other => Err(SparxError::InvalidParams(format!(
            "{key} expects true|false: got {other:?}"
        ))),
    }
}

/// Load a fitted model from an artifact file — the read half of the
/// fit → save/load → score/serve lifecycle. Typed failures: missing /
/// unreadable file → `Io`, corrupt / truncated / wrong-version content →
/// `MissingArtifact`, a well-framed artifact naming an unregistered
/// detector → `UnknownDetector`, blocks that don't decode →
/// `InvalidParams`. Never panics.
pub fn load(path: &str) -> Result<Box<dyn FittedModel>> {
    load_with_backend(path, None)
}

/// [`load`] with an optional Sparx backend override (the CLI's
/// `--backend` flag on `score`/`serve`): scores are backend-identical,
/// so a PJRT-fitted artifact can be served with `Backend::Native` on a
/// node without the compiled AOT modules. Overrides on non-sparx
/// artifacts fail typed (`Unsupported`) — no other detector has a
/// backend to swap.
pub fn load_with_backend(path: &str, backend: Option<Backend>) -> Result<Box<dyn FittedModel>> {
    from_artifact_with_backend(&ModelArtifact::load(path)?, backend)
}

/// [`load`] from in-memory bytes.
pub fn load_bytes(bytes: &[u8]) -> Result<Box<dyn FittedModel>> {
    load_bytes_with_backend(bytes, None)
}

/// [`load_with_backend`] from in-memory bytes.
pub fn load_bytes_with_backend(
    bytes: &[u8],
    backend: Option<Backend>,
) -> Result<Box<dyn FittedModel>> {
    from_artifact_with_backend(&ModelArtifact::from_bytes(bytes)?, backend)
}

/// Dispatch a parsed artifact to its detector's deserializer.
pub fn from_artifact(art: &ModelArtifact) -> Result<Box<dyn FittedModel>> {
    from_artifact_with_backend(art, None)
}

/// [`from_artifact`] with an optional Sparx backend override.
pub fn from_artifact_with_backend(
    art: &ModelArtifact,
    backend: Option<Backend>,
) -> Result<Box<dyn FittedModel>> {
    if backend.is_some() && art.detector != "sparx" {
        return Err(SparxError::Unsupported(format!(
            "--backend override applies to sparx artifacts only (this one was written by {:?})",
            art.detector
        )));
    }
    match art.detector.as_str() {
        "sparx" => Ok(Box::new(FittedSparx::from_artifact_with_backend(art, backend)?)),
        "xstream" => Ok(Box::new(XStream::from_artifact(art)?)),
        "spif" => Ok(Box::new(Spif::from_artifact(art)?)),
        "dbscout" => Ok(Box::new(FittedDbscout::from_artifact(art)?)),
        "ensemble" => Ok(Box::new(crate::ensemble::FittedEnsemble::from_artifact(art)?)),
        // a well-framed artifact that is a serving checkpoint, not a
        // model: point the caller at the right flag instead of the
        // generic unknown-detector message
        crate::sparx::checkpoint::CHECKPOINT_DETECTOR => Err(SparxError::InvalidParams(
            "this file is an absorb-state checkpoint (written by `sparx serve \
             --checkpoint-out`), not a model artifact — pass it to `sparx serve --resume`"
                .into(),
        )),
        other => {
            let names = detector_names().join("|");
            Err(SparxError::UnknownDetector(format!(
                "artifact was written by {other:?}, which this build does not register \
                 (known: {names})"
            )))
        }
    }
}

fn make_sparx(spec: &DetectorSpec) -> Result<Box<dyn Detector>> {
    let mut b = SparxBuilder::new().exec_mode(spec.exec_mode).backend(spec.backend);
    if let Some(k) = spec.k {
        b = b.k(k);
    }
    if let Some(m) = spec.components {
        b = b.chains(m);
    }
    if let Some(l) = spec.depth {
        b = b.depth(l);
    }
    if let Some(rate) = spec.sample_rate {
        b = b.sample_rate(rate);
    }
    if let Some(seed) = spec.seed {
        b = b.seed(seed);
    }
    if let Some(variant) = &spec.pjrt_variant {
        b = b.pjrt_variant(variant);
    }
    Ok(Box::new(b.build()?))
}

fn make_xstream(spec: &DetectorSpec) -> Result<Box<dyn Detector>> {
    let mut p = XStreamParams::default();
    if let Some(k) = spec.k {
        p.k = k;
    }
    if let Some(m) = spec.components {
        p.num_chains = m;
    }
    if let Some(l) = spec.depth {
        p.depth = l;
    }
    if let Some(seed) = spec.seed {
        p.seed = seed;
    }
    Ok(Box::new(XStreamDetector::new(p)?))
}

fn make_spif(spec: &DetectorSpec) -> Result<Box<dyn Detector>> {
    let mut p = SpifParams::default();
    if let Some(t) = spec.components {
        p.num_trees = t;
    }
    if let Some(l) = spec.depth {
        p.max_depth = l;
    }
    if let Some(rate) = spec.sample_rate {
        p.sample_rate = rate;
    }
    if let Some(seed) = spec.seed {
        p.seed = seed;
    }
    Ok(Box::new(SpifDetector::new(p)?))
}

fn make_dbscout(spec: &DetectorSpec) -> Result<Box<dyn Detector>> {
    let mut p = DbscoutParams::default();
    if let Some(eps) = spec.eps {
        p.eps = eps;
    }
    if let Some(min_pts) = spec.min_pts {
        p.min_pts = min_pts;
    }
    Ok(Box::new(DbscoutDetector::new(p, spec.eps.is_none())?))
}

fn make_ensemble(spec: &DetectorSpec) -> Result<Box<dyn Detector>> {
    Ok(Box::new(crate::ensemble::EnsembleDetector::from_spec(spec)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_resolve() {
        for name in detector_names() {
            let det = build(name, &DetectorSpec::default()).unwrap();
            assert_eq!(det.name(), name);
        }
    }

    #[test]
    fn unknown_name_is_a_typed_error_with_hint() {
        let e = build("sparks", &DetectorSpec::default()).unwrap_err();
        match e {
            SparxError::UnknownDetector(msg) => {
                assert!(msg.contains("sparx"), "no suggestion in {msg:?}");
            }
            other => panic!("expected UnknownDetector, got {other:?}"),
        }
    }

    #[test]
    fn spec_strings_create_detectors() {
        // bare names keep working
        assert_eq!(create("sparx").unwrap().name(), "sparx");
        // parameterized form
        assert_eq!(create("sparx?depth=12&rate=0.05").unwrap().name(), "sparx");
        assert_eq!(
            create("ensemble?members=sparx:depth=6,xstream&distill=true").unwrap().name(),
            "ensemble"
        );
    }

    #[test]
    fn unknown_spec_keys_fail_with_suggestion() {
        let e = create("sparx?depht=12").unwrap_err();
        match e {
            SparxError::InvalidParams(msg) => {
                assert!(msg.contains("depth"), "no suggestion in {msg:?}");
            }
            other => panic!("expected InvalidParams, got {other:?}"),
        }
        // keys valid for one method are rejected on another
        assert!(matches!(create("xstream?rate=0.5"), Err(SparxError::InvalidParams(_))));
        // bad values name the key
        let e = create("sparx?depth=banana").unwrap_err();
        assert!(e.to_string().contains("depth"), "bad-value error must name the key: {e}");
        // unknown method names still get the UnknownDetector taxonomy
        assert!(matches!(create("sparks?depth=3"), Err(SparxError::UnknownDetector(_))));
    }

    #[test]
    fn invalid_spec_fields_surface_as_invalid_params() {
        let spec = DetectorSpec { depth: Some(0), ..Default::default() };
        for name in ["sparx", "xstream", "spif"] {
            let r = build(name, &spec);
            assert!(
                matches!(r, Err(SparxError::InvalidParams(_))),
                "{name} depth=0 must be rejected, got {:?}",
                r.err()
            );
        }
        let spec = DetectorSpec { eps: Some(-1.0), ..Default::default() };
        assert!(matches!(build("dbscout", &spec), Err(SparxError::InvalidParams(_))));
    }
}
