//! String → factory registry of detectors: the single lookup behind
//! `sparx detect --method …` and any other name-driven entry point —
//! plus the artifact side of the lifecycle: [`load`] / [`load_bytes`]
//! read a serialized [`ModelArtifact`] header and dispatch to the right
//! detector's deserializer, returning a ready-to-score
//! [`FittedModel`](super::FittedModel).
//!
//! Each factory consumes a [`DetectorSpec`] — the flag-level description
//! of a run — applies its method's defaults for unset fields, validates,
//! and returns the boxed [`Detector`].

use crate::baselines::dbscout::{DbscoutDetector, FittedDbscout};
use crate::baselines::spif::SpifDetector;
use crate::baselines::xstream::XStreamDetector;
use crate::baselines::{DbscoutParams, Spif, SpifParams, XStream, XStreamParams};
use crate::sparx::ExecMode;

use super::artifact::ModelArtifact;
use super::builder::{Backend, FittedSparx, SparxBuilder};
use super::error::{Result, SparxError};
use super::{Detector, FittedModel};

/// Flag-level description of a detector run. `None` fields fall back to
/// the method's own defaults, so one spec can configure any detector.
/// Fields a method has no use for are ignored by its factory (a spec is
/// a superset description); the CLI rejects explicitly-passed
/// inapplicable flags *before* building the spec, so users never hit
/// the silent-ignore path.
#[derive(Debug, Clone)]
pub struct DetectorSpec {
    /// Projection size K (sparx / xstream; 0 ⇒ identity).
    pub k: Option<usize>,
    /// Ensemble size: chains (sparx / xstream) or trees (spif).
    pub components: Option<usize>,
    /// Chain length / tree depth.
    pub depth: Option<usize>,
    /// Fit subsampling rate in (0, 1].
    pub sample_rate: Option<f64>,
    /// Base seed for parameter sampling (None ⇒ library default).
    pub seed: Option<u64>,
    /// Sparx execution plan.
    pub exec_mode: ExecMode,
    /// Sparx binning backend.
    pub backend: Backend,
    /// AOT artifact variant for the PJRT backend.
    pub pjrt_variant: Option<String>,
    /// DBSCOUT eps (None ⇒ chosen at fit time via the elbow heuristic).
    pub eps: Option<f64>,
    /// DBSCOUT minPts.
    pub min_pts: Option<usize>,
}

impl Default for DetectorSpec {
    fn default() -> Self {
        DetectorSpec {
            k: None,
            components: None,
            depth: None,
            sample_rate: None,
            seed: None,
            exec_mode: ExecMode::Fused,
            backend: Backend::Native,
            pjrt_variant: None,
            eps: None,
            min_pts: None,
        }
    }
}

type Factory = fn(&DetectorSpec) -> Result<Box<dyn Detector>>;

/// The registered methods, in CLI listing order.
const REGISTRY: &[(&str, Factory)] = &[
    ("sparx", make_sparx),
    ("xstream", make_xstream),
    ("spif", make_spif),
    ("dbscout", make_dbscout),
];

/// Names of every registered detector.
pub fn detector_names() -> Vec<&'static str> {
    REGISTRY.iter().map(|(name, _)| *name).collect()
}

/// Build a detector by name. Unknown names return
/// [`SparxError::UnknownDetector`] with the valid options (and a
/// suggestion when the name looks like a typo).
pub fn build(name: &str, spec: &DetectorSpec) -> Result<Box<dyn Detector>> {
    match REGISTRY.iter().find(|(n, _)| *n == name) {
        Some((_, factory)) => factory(spec),
        None => {
            let names = detector_names().join("|");
            let hint = crate::util::closest_match(name, &detector_names())
                .map(|s| format!(" — did you mean {s:?}?"))
                .unwrap_or_default();
            Err(SparxError::UnknownDetector(format!("{name:?} (expected {names}){hint}")))
        }
    }
}

/// Load a fitted model from an artifact file — the read half of the
/// fit → save/load → score/serve lifecycle. Typed failures: missing /
/// unreadable file → `Io`, corrupt / truncated / wrong-version content →
/// `MissingArtifact`, a well-framed artifact naming an unregistered
/// detector → `UnknownDetector`, blocks that don't decode →
/// `InvalidParams`. Never panics.
pub fn load(path: &str) -> Result<Box<dyn FittedModel>> {
    load_with_backend(path, None)
}

/// [`load`] with an optional Sparx backend override (the CLI's
/// `--backend` flag on `score`/`serve`): scores are backend-identical,
/// so a PJRT-fitted artifact can be served with `Backend::Native` on a
/// node without the compiled AOT modules. Overrides on non-sparx
/// artifacts fail typed (`Unsupported`) — no other detector has a
/// backend to swap.
pub fn load_with_backend(path: &str, backend: Option<Backend>) -> Result<Box<dyn FittedModel>> {
    from_artifact_with_backend(&ModelArtifact::load(path)?, backend)
}

/// [`load`] from in-memory bytes.
pub fn load_bytes(bytes: &[u8]) -> Result<Box<dyn FittedModel>> {
    load_bytes_with_backend(bytes, None)
}

/// [`load_with_backend`] from in-memory bytes.
pub fn load_bytes_with_backend(
    bytes: &[u8],
    backend: Option<Backend>,
) -> Result<Box<dyn FittedModel>> {
    from_artifact_with_backend(&ModelArtifact::from_bytes(bytes)?, backend)
}

/// Dispatch a parsed artifact to its detector's deserializer.
pub fn from_artifact(art: &ModelArtifact) -> Result<Box<dyn FittedModel>> {
    from_artifact_with_backend(art, None)
}

/// [`from_artifact`] with an optional Sparx backend override.
pub fn from_artifact_with_backend(
    art: &ModelArtifact,
    backend: Option<Backend>,
) -> Result<Box<dyn FittedModel>> {
    if backend.is_some() && art.detector != "sparx" {
        return Err(SparxError::Unsupported(format!(
            "--backend override applies to sparx artifacts only (this one was written by {:?})",
            art.detector
        )));
    }
    match art.detector.as_str() {
        "sparx" => Ok(Box::new(FittedSparx::from_artifact_with_backend(art, backend)?)),
        "xstream" => Ok(Box::new(XStream::from_artifact(art)?)),
        "spif" => Ok(Box::new(Spif::from_artifact(art)?)),
        "dbscout" => Ok(Box::new(FittedDbscout::from_artifact(art)?)),
        // a well-framed artifact that is a serving checkpoint, not a
        // model: point the caller at the right flag instead of the
        // generic unknown-detector message
        crate::sparx::checkpoint::CHECKPOINT_DETECTOR => Err(SparxError::InvalidParams(
            "this file is an absorb-state checkpoint (written by `sparx serve \
             --checkpoint-out`), not a model artifact — pass it to `sparx serve --resume`"
                .into(),
        )),
        other => {
            let names = detector_names().join("|");
            Err(SparxError::UnknownDetector(format!(
                "artifact was written by {other:?}, which this build does not register \
                 (known: {names})"
            )))
        }
    }
}

fn make_sparx(spec: &DetectorSpec) -> Result<Box<dyn Detector>> {
    let mut b = SparxBuilder::new().exec_mode(spec.exec_mode).backend(spec.backend);
    if let Some(k) = spec.k {
        b = b.k(k);
    }
    if let Some(m) = spec.components {
        b = b.chains(m);
    }
    if let Some(l) = spec.depth {
        b = b.depth(l);
    }
    if let Some(rate) = spec.sample_rate {
        b = b.sample_rate(rate);
    }
    if let Some(seed) = spec.seed {
        b = b.seed(seed);
    }
    if let Some(variant) = &spec.pjrt_variant {
        b = b.pjrt_variant(variant);
    }
    Ok(Box::new(b.build()?))
}

fn make_xstream(spec: &DetectorSpec) -> Result<Box<dyn Detector>> {
    let mut p = XStreamParams::default();
    if let Some(k) = spec.k {
        p.k = k;
    }
    if let Some(m) = spec.components {
        p.num_chains = m;
    }
    if let Some(l) = spec.depth {
        p.depth = l;
    }
    if let Some(seed) = spec.seed {
        p.seed = seed;
    }
    Ok(Box::new(XStreamDetector::new(p)?))
}

fn make_spif(spec: &DetectorSpec) -> Result<Box<dyn Detector>> {
    let mut p = SpifParams::default();
    if let Some(t) = spec.components {
        p.num_trees = t;
    }
    if let Some(l) = spec.depth {
        p.max_depth = l;
    }
    if let Some(rate) = spec.sample_rate {
        p.sample_rate = rate;
    }
    if let Some(seed) = spec.seed {
        p.seed = seed;
    }
    Ok(Box::new(SpifDetector::new(p)?))
}

fn make_dbscout(spec: &DetectorSpec) -> Result<Box<dyn Detector>> {
    let mut p = DbscoutParams::default();
    if let Some(eps) = spec.eps {
        p.eps = eps;
    }
    if let Some(min_pts) = spec.min_pts {
        p.min_pts = min_pts;
    }
    Ok(Box::new(DbscoutDetector::new(p, spec.eps.is_none())?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_resolve() {
        for name in detector_names() {
            let det = build(name, &DetectorSpec::default()).unwrap();
            assert_eq!(det.name(), name);
        }
    }

    #[test]
    fn unknown_name_is_a_typed_error_with_hint() {
        let e = build("sparks", &DetectorSpec::default()).unwrap_err();
        match e {
            SparxError::UnknownDetector(msg) => {
                assert!(msg.contains("sparx"), "no suggestion in {msg:?}");
            }
            other => panic!("expected UnknownDetector, got {other:?}"),
        }
    }

    #[test]
    fn invalid_spec_fields_surface_as_invalid_params() {
        let spec = DetectorSpec { depth: Some(0), ..Default::default() };
        for name in ["sparx", "xstream", "spif"] {
            let r = build(name, &spec);
            assert!(
                matches!(r, Err(SparxError::InvalidParams(_))),
                "{name} depth=0 must be rejected, got {:?}",
                r.err()
            );
        }
        let spec = DetectorSpec { eps: Some(-1.0), ..Default::default() };
        assert!(matches!(build("dbscout", &spec), Err(SparxError::InvalidParams(_))));
    }
}
