//! Shared hyperparameter validation rules.
//!
//! Before this module, the depth / cms-shape / rate checks were written
//! out four times — once per params struct (`SparxParams`,
//! `XStreamParams`, `SpifParams`, `DbscoutParams`) — and drifted in
//! wording. Each struct's `validate()` now delegates to the rule
//! functions here, so a rule (and its message) exists exactly once and
//! the registry, the typed builders and `SparxModel::fit_with` all
//! reject degenerate settings identically.
//!
//! Rules return `Result<(), String>` (a human-readable reason): the
//! `api` layer maps failures to [`SparxError::InvalidParams`]
//! (exit code 2) and the cluster layer to `ClusterError::Invalid`, same
//! as before.
//!
//! [`SparxError::InvalidParams`]: super::SparxError::InvalidParams

/// A count-like parameter (chains, trees, depth, min_pts) must be ≥ 1.
/// `label` names the parameter as the user knows it, e.g. `"depth (L)"`.
pub fn at_least_one(v: usize, label: &str) -> Result<(), String> {
    if v == 0 {
        return Err(format!("{label} must be ≥ 1"));
    }
    Ok(())
}

/// A rate-like parameter (sample_rate, density) must lie in (0, 1].
/// NaN fails (the comparison chain is false for NaN).
pub fn unit_interval(v: f64, label: &str) -> Result<(), String> {
    if !(v > 0.0 && v <= 1.0) {
        return Err(format!("{label} must be in (0, 1]: got {v}"));
    }
    Ok(())
}

/// A radius-like parameter (eps) must be positive and finite.
pub fn positive_finite(v: f64, label: &str) -> Result<(), String> {
    if !(v > 0.0 && v.is_finite()) {
        return Err(format!("{label} must be a positive finite number: got {v}"));
    }
    Ok(())
}

/// The CMS shape must be non-degenerate: r ≥ 1 tables of w ≥ 1 buckets.
pub fn cms_shape(rows: usize, cols: usize) -> Result<(), String> {
    if rows == 0 || cols == 0 {
        return Err(format!("CMS shape must be non-degenerate: got r={rows} w={cols}"));
    }
    Ok(())
}

/// The distributed fit additionally packs `(level,row,col)` shuffle keys
/// into one u64, which caps the CMS shape (r < 128, w < 2^20). Only the
/// Sparx fit path shuffles these keys; xStream's local fit does not.
pub fn cms_packable(rows: usize, cols: usize) -> Result<(), String> {
    if rows >= 128 || cols >= (1 << 20) {
        return Err(format!(
            "CMS too large for shuffle key packing (r < 128, w < 2^20): got r={rows} w={cols}"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table-driven sweep over every rule: each row is (rule result,
    /// expected verdict, case label). Message content is asserted per
    /// rule family so the four delegating `validate()` impls keep their
    /// historical wording.
    #[test]
    fn rule_table() {
        let table: Vec<(Result<(), String>, bool, &str)> = vec![
            (at_least_one(1, "depth (L)"), true, "depth 1"),
            (at_least_one(0, "depth (L)"), false, "depth 0"),
            (at_least_one(0, "num_chains (M)"), false, "chains 0"),
            (unit_interval(1.0, "sample_rate"), true, "rate 1"),
            (unit_interval(1e-9, "sample_rate"), true, "rate tiny"),
            (unit_interval(0.0, "sample_rate"), false, "rate 0"),
            (unit_interval(1.5, "density"), false, "density 1.5"),
            (unit_interval(f64::NAN, "density"), false, "density NaN"),
            (positive_finite(0.5, "eps"), true, "eps 0.5"),
            (positive_finite(-1.0, "eps"), false, "eps -1"),
            (positive_finite(f64::INFINITY, "eps"), false, "eps inf"),
            (positive_finite(f64::NAN, "eps"), false, "eps NaN"),
            (cms_shape(10, 100), true, "cms 10x100"),
            (cms_shape(0, 100), false, "cms r=0"),
            (cms_shape(10, 0), false, "cms w=0"),
            (cms_packable(127, (1 << 20) - 1), true, "cms at cap"),
            (cms_packable(128, 100), false, "cms r over cap"),
            (cms_packable(10, 1 << 20), false, "cms w over cap"),
        ];
        for (result, expect_ok, label) in table {
            assert_eq!(result.is_ok(), expect_ok, "{label}: got {result:?}");
        }
        // exact message regressions (the strings tests and users see)
        assert_eq!(at_least_one(0, "depth (L)").unwrap_err(), "depth (L) must be ≥ 1");
        assert_eq!(
            unit_interval(2.0, "sample_rate").unwrap_err(),
            "sample_rate must be in (0, 1]: got 2"
        );
        assert_eq!(
            cms_shape(0, 5).unwrap_err(),
            "CMS shape must be non-degenerate: got r=0 w=5"
        );
        assert_eq!(
            positive_finite(-1.0, "eps").unwrap_err(),
            "eps must be a positive finite number: got -1"
        );
    }
}
