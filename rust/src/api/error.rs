//! Crate-wide error taxonomy for the unified detector API.
//!
//! Every public entry point of [`crate::api`] returns
//! [`Result<T>`](Result) with [`SparxError`]; lower layers keep their own
//! error types (the substrate's [`ClusterError`], `std::io::Error`) and
//! convert on the way out via `From`, so `?` works across layers.

use crate::cluster::ClusterError;

/// The library-level error for the detect / score / experiment paths.
///
/// | variant | meaning | CLI exit code |
/// |---|---|---|
/// | `Cluster` | substrate failure: MEM ERR, TIMEOUT, invalid usage | 1 |
/// | `InvalidParams` | hyperparameter / flag validation failure | 2 |
/// | `UnknownDetector` | registry lookup miss | 2 |
/// | `Unsupported` | capability the selected detector lacks | 2 |
/// | `MissingArtifact` | AOT module / PJRT engine unavailable | 1 |
/// | `Io` | filesystem failure | 1 |
#[derive(Debug, Clone, PartialEq)]
pub enum SparxError {
    /// A failure surfaced by the cluster substrate (the paper's "MEM ERR"
    /// and "TIMEOUT" rows arrive here).
    Cluster(ClusterError),
    /// Hyperparameter validation failed (e.g. `depth=0`, `cms_rows=0`,
    /// `sample_rate > 1`).
    InvalidParams(String),
    /// The detector name is not in [`crate::api::registry`].
    UnknownDetector(String),
    /// The selected detector cannot serve this request (e.g. SPIF on
    /// sparse rows, streaming from a non-hashing projector).
    Unsupported(String),
    /// A required runtime artifact (AOT module, PJRT engine) is missing.
    MissingArtifact(String),
    /// Filesystem I/O failed.
    Io(String),
}

impl SparxError {
    /// Process exit code the CLI maps this error to: `2` for usage /
    /// validation problems (the caller can fix the invocation), `1` for
    /// runtime failures.
    pub fn exit_code(&self) -> i32 {
        match self {
            SparxError::InvalidParams(_)
            | SparxError::UnknownDetector(_)
            | SparxError::Unsupported(_) => 2,
            SparxError::Cluster(_) | SparxError::MissingArtifact(_) | SparxError::Io(_) => 1,
        }
    }

    /// Short status label for experiment tables ("MEM ERR", "TIMEOUT",
    /// otherwise the display form).
    pub fn status_label(&self) -> String {
        match self {
            SparxError::Cluster(
                ClusterError::MemExceeded { .. } | ClusterError::DriverMemExceeded { .. },
            ) => "MEM ERR".into(),
            SparxError::Cluster(ClusterError::DeadlineExceeded { .. }) => "TIMEOUT".into(),
            other => other.to_string(),
        }
    }
}

impl std::fmt::Display for SparxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SparxError::Cluster(e) => write!(f, "{e}"),
            SparxError::InvalidParams(m) => write!(f, "invalid parameters: {m}"),
            SparxError::UnknownDetector(m) => write!(f, "unknown detector: {m}"),
            SparxError::Unsupported(m) => write!(f, "unsupported: {m}"),
            SparxError::MissingArtifact(m) => write!(f, "missing artifact: {m}"),
            SparxError::Io(m) => write!(f, "i/o error: {m}"),
        }
    }
}

impl std::error::Error for SparxError {}

impl From<ClusterError> for SparxError {
    fn from(e: ClusterError) -> Self {
        SparxError::Cluster(e)
    }
}

impl From<std::io::Error> for SparxError {
    fn from(e: std::io::Error) -> Self {
        SparxError::Io(e.to_string())
    }
}

/// Library-level result alias (distinct from the substrate's
/// [`crate::cluster::Result`]).
pub type Result<T> = std::result::Result<T, SparxError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_errors_convert_and_label() {
        let e: SparxError =
            ClusterError::MemExceeded { worker: 1, wanted: 10, budget: 5 }.into();
        assert_eq!(e.status_label(), "MEM ERR");
        assert_eq!(e.exit_code(), 1);
        let t: SparxError =
            ClusterError::DeadlineExceeded { elapsed_secs: 9.0, budget_secs: 1.0 }.into();
        assert_eq!(t.status_label(), "TIMEOUT");
    }

    #[test]
    fn usage_errors_exit_2() {
        assert_eq!(SparxError::InvalidParams("depth".into()).exit_code(), 2);
        assert_eq!(SparxError::UnknownDetector("sparks".into()).exit_code(), 2);
        assert_eq!(SparxError::Unsupported("sparse".into()).exit_code(), 2);
    }

    #[test]
    fn io_errors_convert() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: SparxError = io.into();
        assert!(matches!(e, SparxError::Io(_)));
        assert_eq!(e.exit_code(), 1);
    }
}
