//! Versioned, self-describing model artifacts — the **save/load** stage
//! of the fit → save/load → score/serve lifecycle (§3.5's deployment
//! story: train once on the cluster, ship the O(rwLM) model to a
//! deployment node, score updates in constant time).
//!
//! ## File format (all little-endian)
//!
//! ```text
//! magic            4 bytes   "SPRX"
//! format version   u16       bumped on any layout change
//! detector name    u32-len str   "sparx" | "xstream" | "spif" | "dbscout"
//! param block      u32-len bytes detector hyperparameters (+ backend)
//! payload          u32-len bytes the fitted state — the deployable model
//! checksum         u32       IEEE CRC-32 over everything above
//! ```
//!
//! The *payload* holds exactly the fitted state a deployment node needs
//! (chains + CMS counts + projector seeds for Sparx; the tree pool for
//! SPIF; grid parameters + resolved eps for DBSCOUT), and
//! [`FittedModel::model_bytes`](super::FittedModel::model_bytes) reports
//! its length — the footprint we report is the footprint we ship
//! (regression-tested per detector in `rust/tests/api.rs`).
//!
//! Corrupt, truncated or version-mismatched files surface as typed
//! [`SparxError::MissingArtifact`]; a structurally intact file whose
//! blocks don't decode surfaces as [`SparxError::InvalidParams`]; an
//! intact file naming a detector this build doesn't know is
//! [`SparxError::UnknownDetector`](super::SparxError::UnknownDetector).
//! Nothing on the load path panics.
//!
//! Deserialization lives next to each detector
//! (`FittedSparx::from_artifact`, `XStream::from_artifact`, …) and is
//! dispatched by name through [`super::registry::load`] /
//! [`super::registry::load_bytes`].

use crate::sparx::{ChainParams, CountMinSketch, ExecMode, Projector, ScoreMode, TrainedChain};
use crate::util::codec::{crc32, CodecResult, Decoder, Encoder};

use super::error::{Result, SparxError};

/// File magic: the first four bytes of every model artifact.
pub const MAGIC: [u8; 4] = *b"SPRX";

/// Current artifact format version. Readers reject any other value with
/// a typed error rather than guessing at the layout.
pub const FORMAT_VERSION: u16 = 1;

/// A parsed (or to-be-written) model artifact: the header fields plus
/// the two opaque blocks each detector encodes/decodes for itself.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelArtifact {
    /// Registry name of the detector that produced this model.
    pub detector: String,
    /// Format version the blocks were written under.
    pub version: u16,
    /// Hyperparameter block (also carries the Sparx backend tag).
    pub params: Vec<u8>,
    /// The fitted state — what a deployment node loads.
    pub payload: Vec<u8>,
}

impl ModelArtifact {
    pub fn new(detector: &str, params: Vec<u8>, payload: Vec<u8>) -> Self {
        ModelArtifact { detector: detector.into(), version: FORMAT_VERSION, params, payload }
    }

    /// Serialize with framing + checksum.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut enc = Encoder::new();
        enc.put_bytes(&MAGIC);
        enc.put_u16(self.version);
        enc.put_str(&self.detector);
        enc.put_u32(self.params.len() as u32);
        enc.put_bytes(&self.params);
        enc.put_u32(self.payload.len() as u32);
        enc.put_bytes(&self.payload);
        let sum = crc32(enc.as_slice());
        enc.put_u32(sum);
        enc.into_bytes()
    }

    /// Parse framing + checksum. Typed failures, no panics:
    /// bad magic / truncation / checksum / version → `MissingArtifact`.
    pub fn from_bytes(bytes: &[u8]) -> Result<ModelArtifact> {
        let corrupt = |what: &str| {
            SparxError::MissingArtifact(format!("cannot read model artifact: {what}"))
        };
        // magic + version + name len + two block lens + checksum
        if bytes.len() < MAGIC.len() + 2 + 4 + 4 + 4 + 4 {
            return Err(corrupt("file too short to be a sparx model artifact"));
        }
        if bytes[..MAGIC.len()] != MAGIC {
            return Err(corrupt("bad magic (not a sparx model artifact)"));
        }
        let (body, tail) = bytes.split_at(bytes.len() - 4);
        let stored = u32::from_le_bytes(tail.try_into().expect("4-byte tail"));
        if crc32(body) != stored {
            return Err(corrupt("checksum mismatch (corrupt or truncated artifact)"));
        }
        let mut dec = Decoder::new(body);
        let parse = |e: String| corrupt(&e);
        dec.take(MAGIC.len()).map_err(parse)?;
        let version = dec.u16().map_err(parse)?;
        if version != FORMAT_VERSION {
            return Err(SparxError::MissingArtifact(format!(
                "unsupported artifact format version {version} (this build reads v{FORMAT_VERSION})"
            )));
        }
        let detector = dec.str().map_err(parse)?;
        let params_len = dec.u32().map_err(parse)? as usize;
        let params = dec.take(params_len).map_err(parse)?.to_vec();
        let payload_len = dec.u32().map_err(parse)? as usize;
        let payload = dec.take(payload_len).map_err(parse)?.to_vec();
        dec.finish().map_err(parse)?;
        Ok(ModelArtifact { detector, version, params, payload })
    }

    /// Write the framed artifact to a file.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_bytes())?;
        Ok(())
    }

    /// Read and parse an artifact file.
    pub fn load(path: &str) -> Result<ModelArtifact> {
        Self::from_bytes(&std::fs::read(path)?)
    }
}

/// Map a block-decode failure to the typed error the lifecycle promises:
/// the framing was intact (checksum passed), so a mis-shaped block means
/// the parameters/payload don't describe a valid model.
pub(crate) fn block_err(detector: &str, e: String) -> SparxError {
    SparxError::InvalidParams(format!("{detector} artifact block does not decode: {e}"))
}

// ------------------------------------------------------------------
// shared sub-codecs: enums, chains, projector (used by sparx + xstream)

pub(crate) fn encode_score_mode(enc: &mut Encoder, mode: ScoreMode) {
    enc.put_u8(match mode {
        ScoreMode::Extrapolated => 0,
        ScoreMode::Log2 => 1,
    });
}

pub(crate) fn decode_score_mode(dec: &mut Decoder) -> CodecResult<ScoreMode> {
    match dec.u8()? {
        0 => Ok(ScoreMode::Extrapolated),
        1 => Ok(ScoreMode::Log2),
        other => Err(format!("unknown score mode tag {other}")),
    }
}

pub(crate) fn encode_exec_mode(enc: &mut Encoder, mode: ExecMode) {
    enc.put_u8(match mode {
        ExecMode::Fused => 0,
        ExecMode::PerChain => 1,
    });
}

pub(crate) fn decode_exec_mode(dec: &mut Decoder) -> CodecResult<ExecMode> {
    match dec.u8()? {
        0 => Ok(ExecMode::Fused),
        1 => Ok(ExecMode::PerChain),
        other => Err(format!("unknown exec mode tag {other}")),
    }
}

/// One trained chain: sampled parameters + the per-level CMS blocks.
pub(crate) fn encode_chain(enc: &mut Encoder, chain: &TrainedChain) {
    enc.put_usize_slice(&chain.params.fs);
    enc.put_f32_slice(&chain.params.shift);
    enc.put_f32_slice(&chain.params.deltamax);
    enc.put_u32(chain.cms.len() as u32);
    for cms in &chain.cms {
        enc.put_u32(cms.rows() as u32);
        enc.put_u32(cms.cols() as u32);
        enc.put_u32_slice(cms.counts());
    }
}

pub(crate) fn decode_chain(dec: &mut Decoder) -> CodecResult<TrainedChain> {
    let fs = dec.usize_vec()?;
    let shift = dec.f32_vec()?;
    let deltamax = dec.f32_vec()?;
    let k = deltamax.len();
    if k == 0 {
        return Err("chain has an empty deltamax block".into());
    }
    if shift.len() != k {
        return Err(format!("chain shift len {} != deltamax len {k}", shift.len()));
    }
    if fs.iter().any(|&f| f >= k) {
        return Err("chain split feature out of range".into());
    }
    let params = ChainParams::new(fs, shift, deltamax);
    let levels = dec.u32()? as usize;
    let mut cms = Vec::with_capacity(levels.min(1 << 16));
    for _ in 0..levels {
        let r = dec.u32()? as usize;
        let w = dec.u32()? as usize;
        let counts = dec.u32_vec()?;
        if r == 0 || w == 0 || counts.len() != r * w {
            return Err(format!("CMS block shape mismatch: r={r} w={w} n={}", counts.len()));
        }
        cms.push(CountMinSketch::from_counts(r, w, &counts));
    }
    if cms.len() != params.depth() {
        return Err(format!("chain has {} CMS levels for depth {}", cms.len(), params.depth()));
    }
    Ok(TrainedChain { params, cms })
}

/// Encode the chain-ensemble payload shared by Sparx and xStream:
/// projector + Δmax + chain count + every chain.
pub(crate) fn encode_chain_ensemble(
    enc: &mut Encoder,
    projector: &Projector,
    deltamax: &[f32],
    chains: &[TrainedChain],
) {
    encode_projector(enc, projector);
    enc.put_f32_slice(deltamax);
    enc.put_u32(chains.len() as u32);
    for chain in chains {
        encode_chain(enc, chain);
    }
}

/// Decode **and fully validate** the chain-ensemble payload against the
/// param block's declared shape (`k == 0` ⇒ identity projector). One
/// implementation behind both the Sparx and xStream loaders, so the two
/// can never diverge in what they accept: a checksum-valid artifact
/// whose blocks disagree on k / chain count / depth / Δmax width fails
/// here instead of indexing out of bounds in the binning hot path
/// (which trusts these invariants with `debug_assert`s only).
pub(crate) fn decode_chain_ensemble(
    payload: &[u8],
    k: usize,
    num_chains: usize,
    depth: usize,
) -> CodecResult<(Projector, Vec<f32>, Vec<TrainedChain>)> {
    let mut dec = Decoder::new(payload);
    let projector = decode_projector(&mut dec)?;
    let deltamax = dec.f32_vec()?;
    let m = dec.u32()? as usize;
    if m != num_chains {
        return Err(format!("payload has {m} chains but params declare {num_chains}"));
    }
    let chains = (0..m).map(|_| decode_chain(&mut dec)).collect::<CodecResult<Vec<_>>>()?;
    dec.finish()?;
    let consistent = if k == 0 {
        projector.is_identity()
    } else {
        !projector.is_identity() && projector.k() == k
    };
    if !consistent {
        return Err(format!(
            "params declare k={k} but the payload projector emits {} features",
            projector.out_dim()
        ));
    }
    check_chain_model(projector.out_dim(), depth, &deltamax, &chains)?;
    Ok((projector, deltamax, chains))
}

/// Model-level shape agreement for a decoded ensemble (see
/// [`decode_chain_ensemble`], its only caller).
fn check_chain_model(
    kdim: usize,
    depth: usize,
    deltamax: &[f32],
    chains: &[TrainedChain],
) -> CodecResult<()> {
    if deltamax.len() != kdim {
        return Err(format!(
            "deltamax has {} entries for a {kdim}-wide projector",
            deltamax.len()
        ));
    }
    for (m, chain) in chains.iter().enumerate() {
        if chain.params.k() != kdim {
            return Err(format!(
                "chain {m} is {}-wide but the projector emits {kdim} features",
                chain.params.k()
            ));
        }
        if chain.params.depth() != depth {
            return Err(format!(
                "chain {m} has depth {} but params declare {depth}",
                chain.params.depth()
            ));
        }
    }
    Ok(())
}

// projector wire tags
const PROJ_IDENTITY: u8 = 0;
const PROJ_HASHING: u8 = 1;
const SCHEMA_NONE: u8 = 0;
const SCHEMA_POSITIONAL: u8 = 1;
const SCHEMA_NAMED: u8 = 2;

/// Sanity ceiling on decoded projector/schema widths: CRC-32 is
/// integrity, not authentication, so declared sizes that materialise
/// allocations "from thin air" (hashers, positional names) are capped —
/// 16M columns comfortably covers SpamURL's real 3.2M while a hostile
/// 50-byte file can no longer demand terabytes.
const MAX_DECODED_DIM: usize = 1 << 24;

/// Ceiling on the rematerialised R\[D,K\] entry count (4GB of f32) —
/// same thin-air-allocation concern as [`MAX_DECODED_DIM`], applied to
/// the product of schema width and projection count.
const MAX_DENSE_R_ENTRIES: usize = 1 << 30;

/// The projector is fully described by its seeds (always `0..k`), the
/// hash density and — for dense schemas — the feature names; the O(D·K)
/// sign matrix is *rematerialised* at load time, bit-identically, rather
/// than shipped. Positional schemas (`f0..f{d-1}`) compress to a single
/// dimension count.
pub(crate) fn encode_projector(enc: &mut Encoder, proj: &Projector) {
    if proj.is_identity() {
        enc.put_u8(PROJ_IDENTITY);
        enc.put_usize(proj.out_dim());
        return;
    }
    enc.put_u8(PROJ_HASHING);
    enc.put_usize(proj.k());
    enc.put_f64(proj.density().expect("hashing projector has hashers"));
    match proj.dense_schema() {
        None => enc.put_u8(SCHEMA_NONE),
        Some(names) => {
            let positional =
                names.iter().enumerate().all(|(j, n)| n.len() <= 24 && *n == format!("f{j}"));
            if positional {
                enc.put_u8(SCHEMA_POSITIONAL);
                enc.put_usize(names.len());
            } else {
                enc.put_u8(SCHEMA_NAMED);
                enc.put_u32(names.len() as u32);
                for n in names {
                    enc.put_str(n);
                }
            }
        }
    }
}

pub(crate) fn decode_projector(dec: &mut Decoder) -> CodecResult<Projector> {
    match dec.u8()? {
        PROJ_IDENTITY => Ok(Projector::identity(dec.usize()?)),
        PROJ_HASHING => {
            let k = dec.usize()?;
            let density = dec.f64()?;
            if k == 0 || k > MAX_DECODED_DIM || !(density > 0.0 && density <= 1.0) {
                return Err(format!("invalid projector: k={k} density={density}"));
            }
            let proj = Projector::new(k, density);
            match dec.u8()? {
                SCHEMA_NONE => Ok(proj),
                SCHEMA_POSITIONAL => {
                    let d = dec.usize()?;
                    if d == 0 || d > MAX_DECODED_DIM {
                        return Err(format!("positional schema dimension {d} out of range"));
                    }
                    if d.saturating_mul(k) > MAX_DENSE_R_ENTRIES {
                        return Err(format!("dense sign matrix {d}x{k} exceeds the size cap"));
                    }
                    let names: Vec<String> = (0..d).map(|j| format!("f{j}")).collect();
                    Ok(proj.with_dense_schema(&names))
                }
                SCHEMA_NAMED => {
                    let n = dec.u32()? as usize;
                    let names: Vec<String> =
                        (0..n).map(|_| dec.str()).collect::<CodecResult<_>>()?;
                    if names.len().saturating_mul(k) > MAX_DENSE_R_ENTRIES {
                        return Err(format!(
                            "dense sign matrix {}x{k} exceeds the size cap",
                            names.len()
                        ));
                    }
                    Ok(proj.with_dense_schema(&names))
                }
                other => Err(format!("unknown schema tag {other}")),
            }
        }
        other => Err(format!("unknown projector tag {other}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn framing_round_trips() {
        let art = ModelArtifact::new("sparx", vec![1, 2, 3], vec![9; 100]);
        let bytes = art.to_bytes();
        let back = ModelArtifact::from_bytes(&bytes).unwrap();
        assert_eq!(art, back);
    }

    #[test]
    fn empty_blocks_are_legal() {
        let art = ModelArtifact::new("dbscout", Vec::new(), Vec::new());
        let back = ModelArtifact::from_bytes(&art.to_bytes()).unwrap();
        assert_eq!(back.detector, "dbscout");
        assert!(back.params.is_empty() && back.payload.is_empty());
    }

    #[test]
    fn bad_magic_truncation_and_bitflips_are_typed() {
        let bytes = ModelArtifact::new("sparx", vec![4; 16], vec![7; 64]).to_bytes();
        // bad magic
        let mut junk = bytes.clone();
        junk[0] = b'J';
        assert!(matches!(
            ModelArtifact::from_bytes(&junk),
            Err(SparxError::MissingArtifact(_))
        ));
        // truncated at every prefix length — never a panic
        for cut in 0..bytes.len() {
            assert!(
                matches!(
                    ModelArtifact::from_bytes(&bytes[..cut]),
                    Err(SparxError::MissingArtifact(_))
                ),
                "prefix of {cut} bytes must fail typed"
            );
        }
        // a single flipped bit anywhere must be caught by the checksum
        for pos in [6, 14, 30, bytes.len() - 1] {
            let mut c = bytes.clone();
            c[pos] ^= 0x40;
            assert!(
                matches!(ModelArtifact::from_bytes(&c), Err(SparxError::MissingArtifact(_))),
                "bit flip at {pos} must fail typed"
            );
        }
    }

    #[test]
    fn wrong_version_is_rejected_with_the_version_in_the_message() {
        let mut art = ModelArtifact::new("sparx", Vec::new(), Vec::new());
        art.version = 99;
        match ModelArtifact::from_bytes(&art.to_bytes()) {
            Err(SparxError::MissingArtifact(msg)) => {
                assert!(msg.contains("99"), "message must name the version: {msg}");
            }
            other => panic!("expected MissingArtifact, got {other:?}"),
        }
    }

    #[test]
    fn projector_codec_round_trips() {
        // identity
        let mut enc = Encoder::new();
        encode_projector(&mut enc, &Projector::identity(7));
        let bytes = enc.into_bytes();
        let p = decode_projector(&mut Decoder::new(&bytes)).unwrap();
        assert!(p.is_identity());
        assert_eq!(p.out_dim(), 7);
        // hashing + positional schema: R must rematerialise identically
        let names: Vec<String> = (0..12).map(|j| format!("f{j}")).collect();
        let orig = Projector::new(5, 1.0 / 3.0).with_dense_schema(&names);
        let mut enc = Encoder::new();
        encode_projector(&mut enc, &orig);
        let bytes = enc.into_bytes();
        let back = decode_projector(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(orig.dense_r(), back.dense_r());
        assert_eq!(orig.k(), back.k());
        // named (non-positional) schema
        let names = vec!["lon".to_string(), "lat".to_string()];
        let orig = Projector::new(3, 0.5).with_dense_schema(&names);
        let mut enc = Encoder::new();
        encode_projector(&mut enc, &orig);
        let bytes = enc.into_bytes();
        let back = decode_projector(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(orig.dense_r(), back.dense_r());
        assert_eq!(back.dense_schema(), Some(&names[..]));
    }
}
