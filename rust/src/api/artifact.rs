//! Versioned, self-describing model artifacts — the **save/load** stage
//! of the fit → save/load → score/serve lifecycle (§3.5's deployment
//! story: train once on the cluster, ship the O(rwLM) model to a
//! deployment node, score updates in constant time).
//!
//! ## File format v3 (all little-endian)
//!
//! ```text
//! magic            4 bytes   "SPRX"
//! format version   u16       3 (v1/v2 files remain readable, see below)
//! detector name    u32-len str   "sparx" | "xstream" | "spif" |
//!                                "dbscout" | "absorb-state" (checkpoint)
//! param block      u32-len bytes + u32 CRC-32 of the block
//! payload          u32-len bytes + u32 CRC-32 of the block
//! extension count  u32
//!   per extension: u32-len name str, u32-len bytes, u32 CRC-32
//!                  (unknown names are skipped after CRC verification —
//!                  forward compatibility; "manifest" carries the
//!                  provenance key/value pairs)
//! checksum         u32       IEEE CRC-32 over everything above
//! ```
//!
//! The **per-block CRCs** let a reader verify exactly the block it needs
//! (e.g. a header-only peek) without trusting the rest of a partially
//! read file, and pinpoint *which* block a corruption hit. The
//! **manifest** extension records training provenance (dataset, scale,
//! seed, CLI command) as ordered string pairs — carried verbatim,
//! never interpreted by the loaders.
//!
//! ### Version history
//!
//! * **v3** keeps the v2 framing byte-for-byte but compresses the CMS
//!   count blocks inside chain payloads with the zero-RLE varint codec
//!   ([`Encoder::put_u32_slice_packed`]) — sketch counts are dominated
//!   by zeros and small values, so fitted-model artifacts shrink
//!   several-fold with no change in decoded counts.
//! * **v2** added per-block CRCs and extension blocks (manifest).
//! * **v1** files (`detector | params | payload | file CRC`, no
//!   per-block CRCs, no extensions) are still read; an artifact loaded
//!   from a v1 (or v2) file keeps its original `version` and
//!   re-serializes in that layout, so round trips never silently
//!   rewrite a file's format.
//!
//! The *payload* holds exactly the fitted state a deployment node needs
//! (chains + CMS counts + projector seeds for Sparx; the tree pool for
//! SPIF; grid parameters + resolved eps for DBSCOUT), and
//! [`FittedModel::model_bytes`](super::FittedModel::model_bytes) reports
//! its length — the footprint we report is the footprint we ship
//! (regression-tested per detector in `rust/tests/api.rs`).
//!
//! Corrupt, truncated or version-mismatched files surface as typed
//! [`SparxError::MissingArtifact`]; a structurally intact file whose
//! blocks don't decode surfaces as [`SparxError::InvalidParams`]; an
//! intact file naming a detector this build doesn't know is
//! [`SparxError::UnknownDetector`](super::SparxError::UnknownDetector).
//! Nothing on the load path panics.
//!
//! Deserialization lives next to each detector
//! (`FittedSparx::from_artifact`, `XStream::from_artifact`, …) and is
//! dispatched by name through [`super::registry::load`] /
//! [`super::registry::load_bytes`].

use crate::sparx::{ChainParams, CountMinSketch, ExecMode, Projector, ScoreMode, TrainedChain};
use crate::util::codec::{crc32, CodecResult, Decoder, Encoder};

use super::error::{Result, SparxError};

/// File magic: the first four bytes of every model artifact.
pub const MAGIC: [u8; 4] = *b"SPRX";

/// Current artifact format version. Readers accept v1 through this;
/// any other value is rejected with a typed error rather than guessing
/// at the layout. v4 changes only the absorb-state checkpoint payload
/// (global recency-tagged entries instead of per-shard snapshots — see
/// [`crate::sparx::checkpoint`]); v5 appends the checkpoint's decay
/// state (half-life/window schedule, prev window block, named queries);
/// v6 introduces the `"ensemble"` artifact kind, whose payload nests one
/// complete child artifact per member (see [`crate::ensemble`]).
/// Fitted-model blocks for the single-method detectors are
/// byte-identical to v3.
pub const FORMAT_VERSION: u16 = 6;

/// Name of the provenance extension block.
const MANIFEST_BLOCK: &str = "manifest";

/// Cap on counts decoded from v2 headers (extension blocks, manifest
/// entries) so a hostile file cannot demand huge allocations up front.
const MAX_V2_ITEMS: usize = 1 << 12;

/// A parsed (or to-be-written) model artifact: the header fields plus
/// the two opaque blocks each detector encodes/decodes for itself, and
/// (v2) the provenance manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelArtifact {
    /// Registry name of the detector that produced this model (or
    /// `"absorb-state"` for a serving checkpoint).
    pub detector: String,
    /// Format version the blocks were written under.
    pub version: u16,
    /// Hyperparameter block (also carries the Sparx backend tag).
    pub params: Vec<u8>,
    /// The fitted state — what a deployment node loads.
    pub payload: Vec<u8>,
    /// Provenance manifest: ordered key/value pairs (dataset, scale,
    /// seed, …), carried verbatim and never interpreted by the loaders.
    /// Empty for v1 files and for artifacts that set none.
    pub manifest: Vec<(String, String)>,
}

impl ModelArtifact {
    pub fn new(detector: &str, params: Vec<u8>, payload: Vec<u8>) -> Self {
        ModelArtifact {
            detector: detector.into(),
            version: FORMAT_VERSION,
            params,
            payload,
            manifest: Vec::new(),
        }
    }

    /// Attach provenance manifest entries (v2 artifacts only; a v1
    /// round-tripped artifact has nowhere to carry them).
    pub fn with_manifest(mut self, manifest: Vec<(String, String)>) -> Self {
        self.manifest = manifest;
        self
    }

    /// Serialize with framing + checksums, in the layout `self.version`
    /// names (v1 artifacts re-serialize as v1 — see the module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut enc = Encoder::new();
        enc.put_bytes(&MAGIC);
        enc.put_u16(self.version);
        enc.put_str(&self.detector);
        if self.version == 1 {
            enc.put_u32(self.params.len() as u32);
            enc.put_bytes(&self.params);
            enc.put_u32(self.payload.len() as u32);
            enc.put_bytes(&self.payload);
        } else {
            for block in [&self.params, &self.payload] {
                enc.put_u32(block.len() as u32);
                enc.put_bytes(block);
                enc.put_u32(crc32(block));
            }
            let exts: u32 = u32::from(!self.manifest.is_empty());
            enc.put_u32(exts);
            if !self.manifest.is_empty() {
                let mut m = Encoder::new();
                m.put_u32(self.manifest.len() as u32);
                for (key, value) in &self.manifest {
                    m.put_str(key);
                    m.put_str(value);
                }
                let bytes = m.into_bytes();
                enc.put_str(MANIFEST_BLOCK);
                enc.put_u32(bytes.len() as u32);
                enc.put_bytes(&bytes);
                enc.put_u32(crc32(&bytes));
            }
        }
        let sum = crc32(enc.as_slice());
        enc.put_u32(sum);
        enc.into_bytes()
    }

    /// Parse framing + checksums. Typed failures, no panics:
    /// bad magic / truncation / checksum (whole-file or per-block) /
    /// unknown version → `MissingArtifact`.
    pub fn from_bytes(bytes: &[u8]) -> Result<ModelArtifact> {
        let corrupt = |what: &str| {
            SparxError::MissingArtifact(format!("cannot read model artifact: {what}"))
        };
        // magic + version + name len + two block lens + checksum (v1 floor)
        if bytes.len() < MAGIC.len() + 2 + 4 + 4 + 4 + 4 {
            return Err(corrupt("file too short to be a sparx model artifact"));
        }
        if !bytes.starts_with(&MAGIC) {
            return Err(corrupt("bad magic (not a sparx model artifact)"));
        }
        let parse = |e: String| corrupt(&e);
        let (body, tail) = bytes.split_at(bytes.len() - 4);
        let stored = Decoder::new(tail).u32().map_err(parse)?;
        if crc32(body) != stored {
            return Err(corrupt("checksum mismatch (corrupt or truncated artifact)"));
        }
        let mut dec = Decoder::new(body);
        dec.take(MAGIC.len()).map_err(parse)?;
        let version = dec.u16().map_err(parse)?;
        if !(1..=FORMAT_VERSION).contains(&version) {
            return Err(SparxError::MissingArtifact(format!(
                "unsupported artifact format version {version} (this build reads v1 through \
                 v{FORMAT_VERSION})"
            )));
        }
        let detector = dec.str().map_err(parse)?;
        let mut art = ModelArtifact {
            detector,
            version,
            params: Vec::new(),
            payload: Vec::new(),
            manifest: Vec::new(),
        };
        if version == 1 {
            let params_len = dec.u32().map_err(parse)? as usize;
            art.params = dec.take(params_len).map_err(parse)?.to_vec();
            let payload_len = dec.u32().map_err(parse)? as usize;
            art.payload = dec.take(payload_len).map_err(parse)?.to_vec();
        } else {
            art.params = read_checked_block(&mut dec, "params").map_err(parse)?;
            art.payload = read_checked_block(&mut dec, "payload").map_err(parse)?;
            let exts = dec.u32().map_err(parse)? as usize;
            if exts > MAX_V2_ITEMS {
                return Err(corrupt(&format!("{exts} extension blocks declared")));
            }
            for _ in 0..exts {
                let name = dec.str().map_err(parse)?;
                let block = read_checked_block(&mut dec, &name).map_err(parse)?;
                if name == MANIFEST_BLOCK {
                    art.manifest = decode_manifest(&block).map_err(parse)?;
                }
                // unknown extension names: CRC-verified above, then
                // skipped — newer writers may add blocks we don't know
            }
        }
        dec.finish().map_err(parse)?;
        Ok(art)
    }

    /// Write the framed artifact to a file **atomically** (temp file +
    /// rename in the same directory): readers — including a live
    /// `sparx serve --watch` polling this path — can never observe a
    /// torn, half-written artifact. Returns the framed byte count, so
    /// callers reporting file sizes don't serialize a second time.
    pub fn save(&self, path: &str) -> Result<usize> {
        let bytes = self.to_bytes();
        let total = bytes.len();
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, bytes)?;
        std::fs::rename(&tmp, path)?;
        Ok(total)
    }

    /// Read and parse an artifact file.
    pub fn load(path: &str) -> Result<ModelArtifact> {
        Self::from_bytes(&std::fs::read(path)?)
    }
}

/// Read one v2 block (`u32` length, bytes, `u32` CRC-32) and verify its
/// CRC, naming the block on failure.
fn read_checked_block(dec: &mut Decoder, name: &str) -> CodecResult<Vec<u8>> {
    let len = dec.u32()? as usize;
    let bytes = dec.take(len)?.to_vec();
    let stored = dec.u32()?;
    if crc32(&bytes) != stored {
        return Err(format!("{name} block fails its CRC-32 (corrupt block)"));
    }
    Ok(bytes)
}

/// Decode the manifest extension: `u32` count + (key, value) string
/// pairs.
fn decode_manifest(block: &[u8]) -> CodecResult<Vec<(String, String)>> {
    let mut dec = Decoder::new(block);
    let n = dec.u32()? as usize;
    if n > MAX_V2_ITEMS {
        return Err(format!("{n} manifest entries declared"));
    }
    let mut manifest = Vec::with_capacity(n);
    for _ in 0..n {
        let key = dec.str()?;
        let value = dec.str()?;
        manifest.push((key, value));
    }
    dec.finish()?;
    Ok(manifest)
}

/// Map a block-decode failure to the typed error the lifecycle promises:
/// the framing was intact (checksum passed), so a mis-shaped block means
/// the parameters/payload don't describe a valid model.
pub(crate) fn block_err(detector: &str, e: String) -> SparxError {
    SparxError::InvalidParams(format!("{detector} artifact block does not decode: {e}"))
}

// ------------------------------------------------------------------
// shared sub-codecs: enums, chains, projector (used by sparx + xstream)

pub(crate) fn encode_score_mode(enc: &mut Encoder, mode: ScoreMode) {
    enc.put_u8(match mode {
        ScoreMode::Extrapolated => 0,
        ScoreMode::Log2 => 1,
    });
}

pub(crate) fn decode_score_mode(dec: &mut Decoder) -> CodecResult<ScoreMode> {
    match dec.u8()? {
        0 => Ok(ScoreMode::Extrapolated),
        1 => Ok(ScoreMode::Log2),
        other => Err(format!("unknown score mode tag {other}")),
    }
}

pub(crate) fn encode_exec_mode(enc: &mut Encoder, mode: ExecMode) {
    enc.put_u8(match mode {
        ExecMode::Fused => 0,
        ExecMode::PerChain => 1,
    });
}

pub(crate) fn decode_exec_mode(dec: &mut Decoder) -> CodecResult<ExecMode> {
    match dec.u8()? {
        0 => Ok(ExecMode::Fused),
        1 => Ok(ExecMode::PerChain),
        other => Err(format!("unknown exec mode tag {other}")),
    }
}

/// Sanity ceiling on decoded CMS shapes (v3 path): `r·w` is the
/// allocation a hostile header can demand before any payload bytes are
/// read, so both axes are bounded — 128 rows / 1M columns comfortably
/// cover every configuration the builders accept.
const MAX_CMS_ROWS: usize = 128;
const MAX_CMS_COLS: usize = 1 << 20;

/// One trained chain: sampled parameters + the per-level CMS blocks.
/// From v3 on, the count blocks are zero-RLE varint compressed; v1/v2
/// write them raw so old-format round trips stay byte-identical.
pub(crate) fn encode_chain(enc: &mut Encoder, chain: &TrainedChain, version: u16) {
    enc.put_usize_slice(&chain.params.fs);
    enc.put_f32_slice(&chain.params.shift);
    enc.put_f32_slice(&chain.params.deltamax);
    enc.put_u32(chain.cms.len() as u32);
    for cms in &chain.cms {
        enc.put_u32(cms.rows() as u32);
        enc.put_u32(cms.cols() as u32);
        if version >= 3 {
            enc.put_u32_slice_packed(&cms.counts_u32());
        } else {
            enc.put_u32_slice(&cms.counts_u32());
        }
    }
}

pub(crate) fn decode_chain(dec: &mut Decoder, version: u16) -> CodecResult<TrainedChain> {
    let fs = dec.usize_vec()?;
    let shift = dec.f32_vec()?;
    let deltamax = dec.f32_vec()?;
    let k = deltamax.len();
    if k == 0 {
        return Err("chain has an empty deltamax block".into());
    }
    if shift.len() != k {
        return Err(format!("chain shift len {} != deltamax len {k}", shift.len()));
    }
    if fs.iter().any(|&f| f >= k) {
        return Err("chain split feature out of range".into());
    }
    let params = ChainParams::new(fs, shift, deltamax);
    let levels = dec.u32()? as usize;
    let mut cms = Vec::with_capacity(levels.min(1 << 16));
    for _ in 0..levels {
        let r = dec.u32()? as usize;
        let w = dec.u32()? as usize;
        let counts = if version >= 3 {
            if r == 0 || w == 0 || r > MAX_CMS_ROWS || w > MAX_CMS_COLS {
                return Err(format!("CMS shape r={r} w={w} out of range"));
            }
            dec.u32_vec_packed(r * w)?
        } else {
            dec.u32_vec()?
        };
        if r == 0 || w == 0 || counts.len() != r * w {
            return Err(format!("CMS block shape mismatch: r={r} w={w} n={}", counts.len()));
        }
        cms.push(CountMinSketch::from_counts(r, w, &counts));
    }
    if cms.len() != params.depth() {
        return Err(format!("chain has {} CMS levels for depth {}", cms.len(), params.depth()));
    }
    Ok(TrainedChain { params, cms })
}

/// Encode the chain-ensemble payload shared by Sparx and xStream:
/// projector + Δmax + chain count + every chain.
pub(crate) fn encode_chain_ensemble(
    enc: &mut Encoder,
    projector: &Projector,
    deltamax: &[f32],
    chains: &[TrainedChain],
    version: u16,
) {
    encode_projector(enc, projector);
    enc.put_f32_slice(deltamax);
    enc.put_u32(chains.len() as u32);
    for chain in chains {
        encode_chain(enc, chain, version);
    }
}

/// Decode **and fully validate** the chain-ensemble payload against the
/// param block's declared shape (`k == 0` ⇒ identity projector). One
/// implementation behind both the Sparx and xStream loaders, so the two
/// can never diverge in what they accept: a checksum-valid artifact
/// whose blocks disagree on k / chain count / depth / Δmax width fails
/// here instead of indexing out of bounds in the binning hot path
/// (which trusts these invariants with `debug_assert`s only).
pub(crate) fn decode_chain_ensemble(
    payload: &[u8],
    k: usize,
    num_chains: usize,
    depth: usize,
    version: u16,
) -> CodecResult<(Projector, Vec<f32>, Vec<TrainedChain>)> {
    let mut dec = Decoder::new(payload);
    let projector = decode_projector(&mut dec)?;
    let deltamax = dec.f32_vec()?;
    let m = dec.u32()? as usize;
    if m != num_chains {
        return Err(format!("payload has {m} chains but params declare {num_chains}"));
    }
    let chains =
        (0..m).map(|_| decode_chain(&mut dec, version)).collect::<CodecResult<Vec<_>>>()?;
    dec.finish()?;
    let consistent = if k == 0 {
        projector.is_identity()
    } else {
        !projector.is_identity() && projector.k() == k
    };
    if !consistent {
        return Err(format!(
            "params declare k={k} but the payload projector emits {} features",
            projector.out_dim()
        ));
    }
    check_chain_model(projector.out_dim(), depth, &deltamax, &chains)?;
    Ok((projector, deltamax, chains))
}

/// Model-level shape agreement for a decoded ensemble (see
/// [`decode_chain_ensemble`], its only caller).
fn check_chain_model(
    kdim: usize,
    depth: usize,
    deltamax: &[f32],
    chains: &[TrainedChain],
) -> CodecResult<()> {
    if deltamax.len() != kdim {
        return Err(format!(
            "deltamax has {} entries for a {kdim}-wide projector",
            deltamax.len()
        ));
    }
    for (m, chain) in chains.iter().enumerate() {
        if chain.params.k() != kdim {
            return Err(format!(
                "chain {m} is {}-wide but the projector emits {kdim} features",
                chain.params.k()
            ));
        }
        if chain.params.depth() != depth {
            return Err(format!(
                "chain {m} has depth {} but params declare {depth}",
                chain.params.depth()
            ));
        }
    }
    Ok(())
}

// projector wire tags
const PROJ_IDENTITY: u8 = 0;
const PROJ_HASHING: u8 = 1;
const SCHEMA_NONE: u8 = 0;
const SCHEMA_POSITIONAL: u8 = 1;
const SCHEMA_NAMED: u8 = 2;

/// Sanity ceiling on decoded projector/schema widths: CRC-32 is
/// integrity, not authentication, so declared sizes that materialise
/// allocations "from thin air" (hashers, positional names) are capped —
/// 16M columns comfortably covers SpamURL's real 3.2M while a hostile
/// 50-byte file can no longer demand terabytes.
const MAX_DECODED_DIM: usize = 1 << 24;

/// Ceiling on the rematerialised R\[D,K\] entry count (4GB of f32) —
/// same thin-air-allocation concern as [`MAX_DECODED_DIM`], applied to
/// the product of schema width and projection count.
const MAX_DENSE_R_ENTRIES: usize = 1 << 30;

/// The projector is fully described by its seeds (always `0..k`), the
/// hash density and — for dense schemas — the feature names; the O(D·K)
/// sign matrix is *rematerialised* at load time, bit-identically, rather
/// than shipped. Positional schemas (`f0..f{d-1}`) compress to a single
/// dimension count.
pub(crate) fn encode_projector(enc: &mut Encoder, proj: &Projector) {
    // `density()` is `None` exactly when the projector is the identity,
    // so matching on it covers both arms without a panic path.
    let density = match proj.density() {
        None => {
            enc.put_u8(PROJ_IDENTITY);
            enc.put_usize(proj.out_dim());
            return;
        }
        Some(d) => d,
    };
    enc.put_u8(PROJ_HASHING);
    enc.put_usize(proj.k());
    enc.put_f64(density);
    match proj.dense_schema() {
        None => enc.put_u8(SCHEMA_NONE),
        Some(names) => {
            let positional =
                names.iter().enumerate().all(|(j, n)| n.len() <= 24 && *n == format!("f{j}"));
            if positional {
                enc.put_u8(SCHEMA_POSITIONAL);
                enc.put_usize(names.len());
            } else {
                enc.put_u8(SCHEMA_NAMED);
                enc.put_u32(names.len() as u32);
                for n in names {
                    enc.put_str(n);
                }
            }
        }
    }
}

pub(crate) fn decode_projector(dec: &mut Decoder) -> CodecResult<Projector> {
    match dec.u8()? {
        PROJ_IDENTITY => Ok(Projector::identity(dec.usize()?)),
        PROJ_HASHING => {
            let k = dec.usize()?;
            let density = dec.f64()?;
            if k == 0 || k > MAX_DECODED_DIM || !(density > 0.0 && density <= 1.0) {
                return Err(format!("invalid projector: k={k} density={density}"));
            }
            let proj = Projector::new(k, density);
            match dec.u8()? {
                SCHEMA_NONE => Ok(proj),
                SCHEMA_POSITIONAL => {
                    let d = dec.usize()?;
                    if d == 0 || d > MAX_DECODED_DIM {
                        return Err(format!("positional schema dimension {d} out of range"));
                    }
                    if d.saturating_mul(k) > MAX_DENSE_R_ENTRIES {
                        return Err(format!("dense sign matrix {d}x{k} exceeds the size cap"));
                    }
                    let names: Vec<String> = (0..d).map(|j| format!("f{j}")).collect();
                    Ok(proj.with_dense_schema(&names))
                }
                SCHEMA_NAMED => {
                    let n = dec.u32()? as usize;
                    let names: Vec<String> =
                        (0..n).map(|_| dec.str()).collect::<CodecResult<_>>()?;
                    if names.len().saturating_mul(k) > MAX_DENSE_R_ENTRIES {
                        return Err(format!(
                            "dense sign matrix {}x{k} exceeds the size cap",
                            names.len()
                        ));
                    }
                    Ok(proj.with_dense_schema(&names))
                }
                other => Err(format!("unknown schema tag {other}")),
            }
        }
        other => Err(format!("unknown projector tag {other}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn framing_round_trips() {
        let art = ModelArtifact::new("sparx", vec![1, 2, 3], vec![9; 100]);
        let bytes = art.to_bytes();
        let back = ModelArtifact::from_bytes(&bytes).unwrap();
        assert_eq!(art, back);
    }

    #[test]
    fn empty_blocks_are_legal() {
        let art = ModelArtifact::new("dbscout", Vec::new(), Vec::new());
        let back = ModelArtifact::from_bytes(&art.to_bytes()).unwrap();
        assert_eq!(back.detector, "dbscout");
        assert!(back.params.is_empty() && back.payload.is_empty());
    }

    #[test]
    fn bad_magic_truncation_and_bitflips_are_typed() {
        let bytes = ModelArtifact::new("sparx", vec![4; 16], vec![7; 64]).to_bytes();
        // bad magic
        let mut junk = bytes.clone();
        junk[0] = b'J';
        assert!(matches!(
            ModelArtifact::from_bytes(&junk),
            Err(SparxError::MissingArtifact(_))
        ));
        // truncated at every prefix length — never a panic
        for cut in 0..bytes.len() {
            assert!(
                matches!(
                    ModelArtifact::from_bytes(&bytes[..cut]),
                    Err(SparxError::MissingArtifact(_))
                ),
                "prefix of {cut} bytes must fail typed"
            );
        }
        // a single flipped bit anywhere must be caught by the checksum
        for pos in [6, 14, 30, bytes.len() - 1] {
            let mut c = bytes.clone();
            c[pos] ^= 0x40;
            assert!(
                matches!(ModelArtifact::from_bytes(&c), Err(SparxError::MissingArtifact(_))),
                "bit flip at {pos} must fail typed"
            );
        }
    }

    #[test]
    fn wrong_version_is_rejected_with_the_version_in_the_message() {
        let mut art = ModelArtifact::new("sparx", Vec::new(), Vec::new());
        art.version = 99;
        match ModelArtifact::from_bytes(&art.to_bytes()) {
            Err(SparxError::MissingArtifact(msg)) => {
                assert!(msg.contains("99"), "message must name the version: {msg}");
            }
            other => panic!("expected MissingArtifact, got {other:?}"),
        }
    }

    #[test]
    fn manifest_round_trips_and_absence_is_empty() {
        let art = ModelArtifact::new("sparx", vec![1], vec![2, 3]).with_manifest(vec![
            ("dataset".into(), "gisette".into()),
            ("scale".into(), "0.5".into()),
            ("seed".into(), "7".into()),
        ]);
        let back = ModelArtifact::from_bytes(&art.to_bytes()).unwrap();
        assert_eq!(art, back);
        assert_eq!(back.manifest.len(), 3);
        assert_eq!(back.manifest[0], ("dataset".into(), "gisette".into()));
        // no manifest → empty on read, and struct equality still holds
        let bare = ModelArtifact::new("spif", vec![9], Vec::new());
        let back = ModelArtifact::from_bytes(&bare.to_bytes()).unwrap();
        assert!(back.manifest.is_empty());
        assert_eq!(bare, back);
    }

    /// v1 files (written by the previous release) still load, and a
    /// loaded v1 artifact re-serializes in the v1 layout — round trips
    /// never silently rewrite a file's format version.
    #[test]
    fn v1_artifacts_round_trip_unchanged() {
        let mut v1 = ModelArtifact::new("xstream", vec![5; 10], vec![6; 20]);
        v1.version = 1;
        let bytes = v1.to_bytes();
        let back = ModelArtifact::from_bytes(&bytes).unwrap();
        assert_eq!(back.version, 1);
        assert_eq!(back.params, v1.params);
        assert_eq!(back.payload, v1.payload);
        assert!(back.manifest.is_empty());
        assert_eq!(back.to_bytes(), bytes, "v1 must re-serialize byte-identically");
        // and the current serialization of the same blocks differs but parses
        let cur = ModelArtifact::new("xstream", vec![5; 10], vec![6; 20]);
        assert_ne!(cur.to_bytes(), bytes);
        assert_eq!(ModelArtifact::from_bytes(&cur.to_bytes()).unwrap().version, FORMAT_VERSION);
    }

    /// v2 files (same framing, raw CMS counts) still load and keep their
    /// version, exactly like v1.
    #[test]
    fn v2_artifacts_round_trip_unchanged() {
        let mut v2 = ModelArtifact::new("sparx", vec![5; 10], vec![6; 20])
            .with_manifest(vec![("seed".into(), "7".into())]);
        v2.version = 2;
        let bytes = v2.to_bytes();
        let back = ModelArtifact::from_bytes(&bytes).unwrap();
        assert_eq!(back.version, 2);
        assert_eq!(back.to_bytes(), bytes, "v2 must re-serialize byte-identically");
    }

    fn tiny_chain() -> TrainedChain {
        let params = ChainParams::new(vec![0, 1, 0], vec![0.25, 0.5], vec![1.0, 2.0]);
        let mut cms = Vec::new();
        for lvl in 0..params.depth() {
            let mut s = CountMinSketch::new(3, 64);
            for bin in 0..(lvl + 2) as i32 {
                s.insert(&[bin, bin * 7]);
            }
            cms.push(s);
        }
        TrainedChain { params, cms }
    }

    /// The same chain encodes under v2 (raw) and v3 (packed); both
    /// decode to identical models, and the v3 payload is smaller for
    /// the sparse counts a fitted CMS actually holds.
    #[test]
    fn chain_codec_versions_decode_identically_and_v3_is_smaller() {
        let chain = tiny_chain();
        let mut raw = Encoder::new();
        encode_chain(&mut raw, &chain, 2);
        let raw = raw.into_bytes();
        let mut packed = Encoder::new();
        encode_chain(&mut packed, &chain, 3);
        let packed = packed.into_bytes();
        assert!(
            packed.len() * 2 < raw.len(),
            "packed {} vs raw {} bytes: sparse counts should compress >2x",
            packed.len(),
            raw.len()
        );
        let from_raw = decode_chain(&mut Decoder::new(&raw), 2).unwrap();
        let from_packed = decode_chain(&mut Decoder::new(&packed), 3).unwrap();
        assert_eq!(from_raw.cms, chain.cms);
        assert_eq!(from_packed.cms, chain.cms);
        assert_eq!(from_raw.params.fs, chain.params.fs);
        assert_eq!(from_packed.params.fs, chain.params.fs);
    }

    /// A v3 chain whose CMS header declares an outlandish shape fails
    /// before any allocation, with the shape in the message.
    #[test]
    fn v3_chain_rejects_hostile_cms_shapes() {
        let chain = tiny_chain();
        // hand-built chain header declaring levels=1, r=u32::MAX, w=u32::MAX
        let mut enc = Encoder::new();
        enc.put_usize_slice(&chain.params.fs);
        enc.put_f32_slice(&chain.params.shift);
        enc.put_f32_slice(&chain.params.deltamax);
        enc.put_u32(1);
        enc.put_u32(u32::MAX);
        enc.put_u32(u32::MAX);
        let bytes = enc.into_bytes();
        let err = decode_chain(&mut Decoder::new(&bytes), 3).unwrap_err();
        assert!(err.contains("out of range"), "got: {err}");
    }

    /// The v2 per-block CRCs catch corruption even when the whole-file
    /// checksum is recomputed to match (an attacker or a buggy tool
    /// rewriting the trailer).
    #[test]
    fn per_block_crc_catches_patched_files() {
        let art = ModelArtifact::new("sparx", vec![0xAA; 32], vec![0xBB; 64]);
        let bytes = art.to_bytes();
        // flip one params byte AND fix up the file checksum
        let mut patched = bytes.clone();
        let params_start = MAGIC.len() + 2 + 4 + "sparx".len() + 4;
        patched[params_start] ^= 0x01;
        let body_len = patched.len() - 4;
        let sum = crc32(&patched[..body_len]).to_le_bytes();
        patched[body_len..].copy_from_slice(&sum);
        match ModelArtifact::from_bytes(&patched) {
            Err(SparxError::MissingArtifact(msg)) => {
                assert!(msg.contains("params block"), "must name the damaged block: {msg}");
            }
            other => panic!("patched params must fail typed, got {other:?}"),
        }
    }

    #[test]
    fn projector_codec_round_trips() {
        // identity
        let mut enc = Encoder::new();
        encode_projector(&mut enc, &Projector::identity(7));
        let bytes = enc.into_bytes();
        let p = decode_projector(&mut Decoder::new(&bytes)).unwrap();
        assert!(p.is_identity());
        assert_eq!(p.out_dim(), 7);
        // hashing + positional schema: R must rematerialise identically
        let names: Vec<String> = (0..12).map(|j| format!("f{j}")).collect();
        let orig = Projector::new(5, 1.0 / 3.0).with_dense_schema(&names);
        let mut enc = Encoder::new();
        encode_projector(&mut enc, &orig);
        let bytes = enc.into_bytes();
        let back = decode_projector(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(orig.dense_r(), back.dense_r());
        assert_eq!(orig.k(), back.k());
        // named (non-positional) schema
        let names = vec!["lon".to_string(), "lat".to_string()];
        let orig = Projector::new(3, 0.5).with_dense_schema(&names);
        let mut enc = Encoder::new();
        encode_projector(&mut enc, &orig);
        let bytes = enc.into_bytes();
        let back = decode_projector(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(orig.dense_r(), back.dense_r());
        assert_eq!(back.dense_schema(), Some(&names[..]));
    }
}
