//! Typed construction of the Sparx detector: a fluent [`SparxBuilder`]
//! with a [`Backend`] enum that resolves the binning engine internally —
//! no more engine/binner borrow-juggling at call sites — plus parameter
//! validation up front.

use std::sync::Arc;

use crate::cluster::ClusterContext;
use crate::data::Dataset;
use crate::runtime::{PjrtBinner, PjrtEngine};
use crate::sparx::chain::{Binner, NativeBinner};
use crate::sparx::{
    project_dataset, ExecMode, ScoreMode, ServeOptions, ServedEnsemble, ShardedStreamScorer,
    SparxModel, SparxParams, StreamScorer,
};
use crate::util::codec::{CodecResult, Decoder, Encoder};

use super::artifact::{self, ModelArtifact};
use super::error::{Result, SparxError};
use super::{check_projector_input, Detector, FittedModel};

/// Which binning backend executes the per-tile numeric hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust binning (always available).
    Native,
    /// The AOT Pallas kernels through the PJRT CPU client. Requires the
    /// compiled artifacts (`make artifacts`) and the `pjrt` feature;
    /// otherwise [`SparxBuilder::build`] returns
    /// [`SparxError::MissingArtifact`].
    Pjrt,
}

impl Backend {
    /// CLI/report tag.
    pub fn tag(self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// Resolved backend state owned by the detector (and shared with every
/// model it fits). The engine handle lives behind an `Arc` so fitted
/// models stay usable after the detector is dropped.
#[derive(Clone)]
enum BackendRuntime {
    Native,
    Pjrt { engine: Arc<PjrtEngine>, variant: String },
}

impl BackendRuntime {
    /// Run `f` with the backend's binner. The PJRT binner borrows the
    /// engine, so it is materialised only for the duration of the call —
    /// this is the borrow-juggling the old call sites repeated by hand.
    fn with_binner<T>(&self, f: impl FnOnce(&dyn Binner) -> T) -> T {
        match self {
            BackendRuntime::Native => f(&NativeBinner),
            BackendRuntime::Pjrt { engine, variant } => {
                f(&PjrtBinner { engine: engine.as_ref(), variant: variant.clone() })
            }
        }
    }

    fn tag(&self) -> &'static str {
        match self {
            BackendRuntime::Native => "native",
            BackendRuntime::Pjrt { .. } => "pjrt",
        }
    }
}

/// Fluent, validating constructor for [`SparxDetector`].
///
/// ```no_run
/// use sparx::api::{Backend, SparxBuilder};
/// let det = SparxBuilder::new()
///     .k(50)
///     .chains(100)
///     .depth(15)
///     .sample_rate(0.1)
///     .backend(Backend::Native)
///     .build()
///     .expect("valid params");
/// ```
#[derive(Debug, Clone)]
pub struct SparxBuilder {
    params: SparxParams,
    backend: Backend,
    pjrt_variant: String,
}

impl Default for SparxBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SparxBuilder {
    pub fn new() -> Self {
        SparxBuilder {
            params: SparxParams::default(),
            backend: Backend::Native,
            pjrt_variant: "gisette".into(),
        }
    }

    /// Replace the full parameter block (flags already folded in).
    pub fn params(mut self, params: SparxParams) -> Self {
        self.params = params;
        self
    }

    /// Projection size K (0 ⇒ identity, no projection).
    pub fn k(mut self, k: usize) -> Self {
        self.params.k = k;
        self
    }

    /// Ensemble size M.
    pub fn chains(mut self, m: usize) -> Self {
        self.params.num_chains = m;
        self
    }

    /// Chain length / depth L.
    pub fn depth(mut self, l: usize) -> Self {
        self.params.depth = l;
        self
    }

    /// Fit subsampling rate in (0, 1].
    pub fn sample_rate(mut self, rate: f64) -> Self {
        self.params.sample_rate = rate;
        self
    }

    /// CMS shape (r hash tables × w buckets).
    pub fn cms(mut self, rows: usize, cols: usize) -> Self {
        self.params.cms_rows = rows;
        self.params.cms_cols = cols;
        self
    }

    /// Non-zero density of the sign hashes.
    pub fn density(mut self, density: f64) -> Self {
        self.params.density = density;
        self
    }

    pub fn score_mode(mut self, mode: ScoreMode) -> Self {
        self.params.score_mode = mode;
        self
    }

    /// Execution plan (fused single-pass vs legacy per-chain).
    pub fn exec_mode(mut self, mode: ExecMode) -> Self {
        self.params.exec_mode = mode;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.params.seed = seed;
        self
    }

    /// Binning backend; [`Backend::Pjrt`] starts the engine at `build`.
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// AOT artifact variant for the PJRT backend ("gisette" | "osm" |
    /// "spamurl" — fixed tile shapes are compiled per workload).
    pub fn pjrt_variant(mut self, variant: &str) -> Self {
        self.pjrt_variant = variant.into();
        self
    }

    /// Validate the parameters and resolve the backend.
    pub fn build(self) -> Result<SparxDetector> {
        self.params.validate().map_err(SparxError::InvalidParams)?;
        let backend = match self.backend {
            Backend::Native => BackendRuntime::Native,
            Backend::Pjrt => BackendRuntime::Pjrt {
                engine: Arc::new(
                    PjrtEngine::start_default().map_err(SparxError::MissingArtifact)?,
                ),
                variant: self.pjrt_variant,
            },
        };
        Ok(SparxDetector { params: self.params, backend })
    }
}

/// Sparx behind the unified [`Detector`] contract. Build via
/// [`SparxBuilder`]; scores are bit-identical to the direct
/// [`SparxModel::fit`] + `score_dataset` path (regression-tested).
pub struct SparxDetector {
    params: SparxParams,
    backend: BackendRuntime,
}

impl SparxDetector {
    pub fn params(&self) -> &SparxParams {
        &self.params
    }

    /// Backend tag for reports ("native" | "pjrt").
    pub fn backend_tag(&self) -> &'static str {
        self.backend.tag()
    }
}

impl Detector for SparxDetector {
    fn name(&self) -> &'static str {
        "sparx"
    }

    fn fit(&self, ctx: &ClusterContext, data: &Dataset) -> Result<Box<dyn FittedModel>> {
        // params were validated at build(); fit_with re-checks for direct
        // (non-builder) callers of the model API
        let model = self
            .backend
            .with_binner(|binner| SparxModel::fit_with(ctx, data, &self.params, binner))?;
        Ok(Box::new(FittedSparx { model, backend: self.backend.clone() }))
    }
}

/// A fitted Sparx model plus the backend it was fitted with (scoring
/// reuses the same engine).
pub struct FittedSparx {
    model: SparxModel,
    backend: BackendRuntime,
}

// backend wire tags (artifact param block)
const BACKEND_NATIVE: u8 = 0;
const BACKEND_PJRT: u8 = 1;

fn encode_sparx_params(enc: &mut Encoder, p: &SparxParams) {
    enc.put_usize(p.k);
    enc.put_usize(p.num_chains);
    enc.put_usize(p.depth);
    enc.put_f64(p.sample_rate);
    enc.put_usize(p.cms_rows);
    enc.put_usize(p.cms_cols);
    enc.put_f64(p.density);
    artifact::encode_score_mode(enc, p.score_mode);
    artifact::encode_exec_mode(enc, p.exec_mode);
    enc.put_u64(p.seed);
}

fn decode_sparx_params(dec: &mut Decoder) -> CodecResult<SparxParams> {
    Ok(SparxParams {
        k: dec.usize()?,
        num_chains: dec.usize()?,
        depth: dec.usize()?,
        sample_rate: dec.f64()?,
        cms_rows: dec.usize()?,
        cms_cols: dec.usize()?,
        density: dec.f64()?,
        score_mode: artifact::decode_score_mode(dec)?,
        exec_mode: artifact::decode_exec_mode(dec)?,
        seed: dec.u64()?,
    })
}

impl FittedSparx {
    /// The underlying model, for callers that need the fitted state
    /// (chains, projector, Δmax) beyond the trait surface.
    pub fn model(&self) -> &SparxModel {
        &self.model
    }

    /// Wrap an already-fitted model with the native backend — how the
    /// ensemble layer adopts sparx members (and distilled students) fit
    /// through the raw `SparxModel` API.
    pub(crate) fn from_model(model: SparxModel) -> FittedSparx {
        FittedSparx { model, backend: BackendRuntime::Native }
    }

    /// The fitted state the artifact payload carries: projector seeds +
    /// Δmax + every chain's sampled parameters and CMS blocks. The
    /// O(D·K) dense sign matrix is *not* shipped — it rematerialises
    /// bit-identically from the stored schema at load time.
    fn encode_payload(&self) -> Vec<u8> {
        let mut enc = Encoder::new();
        artifact::encode_chain_ensemble(
            &mut enc,
            &self.model.projector,
            &self.model.deltamax,
            &self.model.chains,
            artifact::FORMAT_VERSION,
        );
        enc.into_bytes()
    }

    /// Rehydrate from an artifact: the param block restores the
    /// hyperparameters and resolves the binning backend through the same
    /// [`Backend`] path `build()` uses (a PJRT-fitted model needs the
    /// compiled artifacts again — [`SparxError::MissingArtifact`]
    /// otherwise); the payload restores projector, Δmax and chains.
    pub fn from_artifact(art: &ModelArtifact) -> Result<FittedSparx> {
        Self::from_artifact_with_backend(art, None)
    }

    /// [`from_artifact`](Self::from_artifact) with an optional backend
    /// override. Scores are backend-identical by construction
    /// (regression-tested), so forcing [`Backend::Native`] on a
    /// PJRT-fitted artifact is safe — it lets a deployment node without
    /// the compiled AOT modules serve any artifact. `None` keeps the
    /// backend the model was fitted with.
    pub fn from_artifact_with_backend(
        art: &ModelArtifact,
        override_backend: Option<Backend>,
    ) -> Result<FittedSparx> {
        let blk = |e| artifact::block_err("sparx", e);
        let mut dec = Decoder::new(&art.params);
        let params = decode_sparx_params(&mut dec).map_err(blk)?;
        params.validate().map_err(SparxError::InvalidParams)?;
        let backend_tag = dec.u8().map_err(blk)?;
        let variant = dec.str().map_err(blk)?;
        dec.finish().map_err(blk)?;
        let stored = match backend_tag {
            BACKEND_NATIVE => Backend::Native,
            BACKEND_PJRT => Backend::Pjrt,
            other => return Err(blk(format!("unknown backend tag {other}"))),
        };
        let backend = match override_backend.unwrap_or(stored) {
            Backend::Native => BackendRuntime::Native,
            Backend::Pjrt => {
                // a native-fitted artifact stores no AOT variant, so the
                // engine has no workload shape to run — guessing one
                // would execute modules compiled for the wrong tile
                // shapes; the safe override direction is pjrt → native
                if variant.is_empty() {
                    return Err(SparxError::Unsupported(
                        "this artifact was fitted natively and stores no PJRT variant; \
                         only the pjrt → native override is shape-safe"
                            .into(),
                    ));
                }
                BackendRuntime::Pjrt {
                    engine: Arc::new(
                        PjrtEngine::start_default().map_err(SparxError::MissingArtifact)?,
                    ),
                    variant,
                }
            }
        };

        let (projector, deltamax, chains) = artifact::decode_chain_ensemble(
            &art.payload,
            params.k,
            params.num_chains,
            params.depth,
            art.version,
        )
        .map_err(blk)?;
        Ok(FittedSparx {
            model: SparxModel { params, projector, deltamax, chains },
            backend,
        })
    }
}

impl FittedModel for FittedSparx {
    fn name(&self) -> &'static str {
        "sparx"
    }

    fn score(&self, ctx: &ClusterContext, data: &Dataset) -> Result<Vec<(u64, f64)>> {
        check_projector_input(&self.model.projector, data)?;
        let proj = project_dataset(ctx, data, &self.model.projector)?;
        let scores = self
            .backend
            .with_binner(|binner| self.model.score_sketches_with(ctx, &proj, binner))?;
        Ok(scores)
    }

    fn to_artifact(&self) -> Result<ModelArtifact> {
        let mut params = Encoder::new();
        encode_sparx_params(&mut params, &self.model.params);
        match &self.backend {
            BackendRuntime::Native => {
                params.put_u8(BACKEND_NATIVE);
                params.put_str("");
            }
            BackendRuntime::Pjrt { variant, .. } => {
                params.put_u8(BACKEND_PJRT);
                params.put_str(variant);
            }
        }
        Ok(ModelArtifact::new("sparx", params.into_bytes(), self.encode_payload()))
    }

    fn model_bytes(&self) -> usize {
        self.encode_payload().len()
    }

    fn stream_scorer(&self, cache_size: usize) -> Result<StreamScorer> {
        StreamScorer::new(&self.model, cache_size)
    }

    fn stream_scorer_sharded(&self, opts: ServeOptions) -> Result<ShardedStreamScorer> {
        ShardedStreamScorer::from_ensemble(
            std::sync::Arc::new(ServedEnsemble::new(&self.model)?),
            opts,
            None,
        )
    }

    fn served_ensemble(&self) -> Result<std::sync::Arc<ServedEnsemble>> {
        Ok(std::sync::Arc::new(ServedEnsemble::new(&self.model)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_rejects_invalid_params() {
        for (what, b) in [
            ("depth=0", SparxBuilder::new().depth(0)),
            ("chains=0", SparxBuilder::new().chains(0)),
            ("cms rows=0", SparxBuilder::new().cms(0, 100)),
            ("cms cols=0", SparxBuilder::new().cms(10, 0)),
            ("rate>1", SparxBuilder::new().sample_rate(1.5)),
            ("rate=0", SparxBuilder::new().sample_rate(0.0)),
            ("density=0", SparxBuilder::new().density(0.0)),
        ] {
            let r = b.build();
            assert!(
                matches!(r, Err(SparxError::InvalidParams(_))),
                "{what} must be rejected, got {:?}",
                r.err()
            );
        }
    }

    #[test]
    fn builder_accepts_defaults() {
        assert!(SparxBuilder::new().build().is_ok());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_reports_missing_artifacts() {
        let r = SparxBuilder::new().backend(Backend::Pjrt).build();
        assert!(matches!(r, Err(SparxError::MissingArtifact(_))), "got {:?}", r.err());
    }
}
