//! The unified detector API: **one fit/score contract for every method**.
//!
//! Before this module, each detector exposed its own pipeline: Sparx
//! needed a three-call dance (`fit_with` → `project_dataset` →
//! `score_sketches_with`), while xStream, SPIF and DBSCOUT each had
//! incompatible fit/score signatures, so the CLI, the stream demo and all
//! experiment harnesses hand-wired their own plumbing. Following the
//! PyOD/SUOD lesson — a single `fit`/`decision_function` spine is what
//! makes an OD toolbox extensible — everything now flows through two
//! traits:
//!
//! * [`Detector`] — an unfitted, configured method: `fit(&ctx, &data)`
//!   returns a boxed [`FittedModel`];
//! * [`FittedModel`] — `score(&ctx, &data)` yields `(id, outlierness)`
//!   pairs (higher = more outlying) for *every* point, `model_bytes()`
//!   reports the deployable footprint, and `stream_scorer()` (optional;
//!   Sparx only) opens the §3.5 evolving-stream front-end.
//!
//! Construction is either **typed** — [`SparxBuilder`] with a
//! [`Backend`] that resolves the binner/engine internally — or
//! **string-driven** through [`registry`] (`"sparx" | "xstream" | "spif"
//! | "dbscout"`), which is what `sparx detect --method …` uses.
//!
//! All entry points return [`Result`] with the crate-wide [`SparxError`]
//! taxonomy (see [`error`]); invalid hyperparameters are rejected with
//! `SparxError::InvalidParams` instead of panicking deep in the pipeline.
//!
//! ```no_run
//! use sparx::api::{Detector, FittedModel, SparxBuilder};
//! use sparx::config::presets;
//! use sparx::data::generators::GisetteGen;
//!
//! fn main() -> sparx::api::Result<()> {
//!     let cluster = presets::config_local().build();
//!     let data = GisetteGen::default().generate(&cluster)?;
//!     let detector = SparxBuilder::new().chains(50).depth(10).sample_rate(0.1).build()?;
//!     let model = detector.fit(&cluster, &data.dataset)?;
//!     let scores = model.score(&cluster, &data.dataset)?;
//!     println!("scored {} points, model {}B", scores.len(), model.model_bytes());
//!     Ok(())
//! }
//! ```

pub mod builder;
pub mod error;
pub mod registry;

pub use builder::{Backend, FittedSparx, SparxBuilder, SparxDetector};
pub use error::{Result, SparxError};
pub use registry::DetectorSpec;

use crate::cluster::ClusterContext;
use crate::data::{Dataset, Features};
use crate::sparx::StreamScorer;

/// A configured-but-unfitted outlier detector. The one contract every
/// method implements; the CLI, the experiment harnesses and the examples
/// all drive detectors exclusively through it.
pub trait Detector {
    /// Registry name of the method ("sparx", "xstream", …).
    fn name(&self) -> &'static str;

    /// Fit on a (distributed) dataset, consuming cluster resources
    /// through `ctx`'s ledger and memory meters.
    fn fit(&self, ctx: &ClusterContext, data: &Dataset) -> Result<Box<dyn FittedModel>>;
}

/// A fitted model: scores datasets, reports its deployable footprint,
/// and (for methods that support §3.5) opens a streaming front-end.
pub trait FittedModel {
    /// Name of the method that produced this model.
    fn name(&self) -> &'static str;

    /// Score every point: `(id, outlierness)`, higher = more outlying.
    /// Methods with binary verdicts (DBSCOUT) emit 1.0 / 0.0.
    fn score(&self, ctx: &ClusterContext, data: &Dataset) -> Result<Vec<(u64, f64)>>;

    /// Driver-resident model footprint in bytes (what scoring broadcasts).
    fn model_bytes(&self) -> usize;

    /// Open the evolving-stream front-end (§3.5) with an LRU sketch cache
    /// of `cache_size` IDs. Default: unsupported.
    fn stream_scorer(&self, cache_size: usize) -> Result<StreamScorer> {
        let _ = cache_size;
        Err(SparxError::Unsupported(format!(
            "{} has no evolving-stream front-end (only sparx does)",
            self.name()
        )))
    }
}

/// Guard shared by the dense-only baselines (SPIF, DBSCOUT): the public
/// SPIF implementation cannot ingest sparse RDDs (§4.2.5) and DBSCOUT's
/// grid needs coordinates, so sparse/mixed data must be projected to a
/// dense representation first — exactly as the paper had to.
/// Checks the first row of *every* partition (O(partitions), no data
/// movement) — generators and loaders build homogeneous partitions, so
/// this catches mixed datasets without a full scan.
pub(crate) fn ensure_dense(data: &Dataset, method: &str) -> Result<()> {
    for p in 0..data.rows.num_parts() {
        if let Some(row) = data.rows.part(p).first() {
            if !matches!(&row.features, Features::Dense(_)) {
                return Err(SparxError::Unsupported(format!(
                    "{method} requires dense rows — project the data first \
                     (e.g. Sparx's Eq. 2 hash projection), as the paper did"
                )));
            }
        }
    }
    Ok(())
}
