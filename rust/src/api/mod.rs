//! The unified detector API: **one fit/score contract for every method**.
//!
//! Before this module, each detector exposed its own pipeline: Sparx
//! needed a three-call dance (`fit_with` → `project_dataset` →
//! `score_sketches_with`), while xStream, SPIF and DBSCOUT each had
//! incompatible fit/score signatures, so the CLI, the stream demo and all
//! experiment harnesses hand-wired their own plumbing. Following the
//! PyOD/SUOD lesson — a single `fit`/`decision_function` spine is what
//! makes an OD toolbox extensible — everything now flows through two
//! traits:
//!
//! * [`Detector`] — an unfitted, configured method: `fit(&ctx, &data)`
//!   returns a boxed [`FittedModel`];
//! * [`FittedModel`] — `score(&ctx, &data)` yields `(id, outlierness)`
//!   pairs (higher = more outlying) for *every* point, `to_artifact()`
//!   serializes the fitted state to a versioned [`ModelArtifact`] (the
//!   **save/load** stage of the lifecycle — see [`artifact`]),
//!   `model_bytes()` reports the shipped payload footprint, and
//!   `stream_scorer()` (optional; Sparx only) opens the §3.5
//!   evolving-stream front-end.
//!
//! Construction is either **typed** — [`SparxBuilder`] with a
//! [`Backend`] that resolves the binner/engine internally — or
//! **string-driven** through [`registry`] (`"sparx" | "xstream" | "spif"
//! | "dbscout"`), which is what `sparx fit --method …` uses; saved
//! models come back through [`registry::load`] / [`registry::load_bytes`],
//! which read the artifact header and dispatch to the right
//! deserializer.
//!
//! All entry points return [`Result`] with the crate-wide [`SparxError`]
//! taxonomy (see [`error`]); invalid hyperparameters are rejected with
//! `SparxError::InvalidParams` instead of panicking deep in the pipeline.
//!
//! ```no_run
//! use sparx::api::{Detector, FittedModel, SparxBuilder};
//! use sparx::config::presets;
//! use sparx::data::generators::GisetteGen;
//!
//! fn main() -> sparx::api::Result<()> {
//!     let cluster = presets::config_local().build();
//!     let data = GisetteGen::default().generate(&cluster)?;
//!     let detector = SparxBuilder::new().chains(50).depth(10).sample_rate(0.1).build()?;
//!     let model = detector.fit(&cluster, &data.dataset)?;
//!     let scores = model.score(&cluster, &data.dataset)?;
//!     println!("scored {} points, model {}B", scores.len(), model.model_bytes());
//!     Ok(())
//! }
//! ```

pub mod artifact;
pub mod builder;
pub mod error;
pub mod registry;
pub mod spec;
pub mod validate;

pub use artifact::ModelArtifact;
pub use builder::{Backend, FittedSparx, SparxBuilder, SparxDetector};
pub use error::{Result, SparxError};
pub use registry::DetectorSpec;
pub use spec::MethodSpec;

use std::sync::Arc;

use crate::cluster::ClusterContext;
use crate::data::Dataset;
use crate::sparx::{
    MemberInfo, Projector, ServeOptions, ServedEnsemble, ShardedStreamScorer, StreamScorer,
};

/// A configured-but-unfitted outlier detector. The one contract every
/// method implements; the CLI, the experiment harnesses and the examples
/// all drive detectors exclusively through it.
pub trait Detector {
    /// Registry name of the method ("sparx", "xstream", …).
    fn name(&self) -> &'static str;

    /// Fit on a (distributed) dataset, consuming cluster resources
    /// through `ctx`'s ledger and memory meters.
    fn fit(&self, ctx: &ClusterContext, data: &Dataset) -> Result<Box<dyn FittedModel>>;
}

/// A fitted model: scores datasets, serializes to a deployable
/// [`ModelArtifact`], reports its shipped footprint, and (for methods
/// that support §3.5) opens a streaming front-end.
pub trait FittedModel {
    /// Name of the method that produced this model.
    fn name(&self) -> &'static str;

    /// Score every point: `(id, outlierness)`, higher = more outlying.
    /// Methods with binary verdicts (DBSCOUT) emit 1.0 / 0.0.
    fn score(&self, ctx: &ClusterContext, data: &Dataset) -> Result<Vec<(u64, f64)>>;

    /// Serialize the fitted state to a versioned artifact — what
    /// `sparx fit --model-out` writes and [`registry::load`] reads back.
    /// Round trips are bit-identical: a loaded model scores exactly like
    /// the in-memory one (regression-tested per detector).
    fn to_artifact(&self) -> Result<ModelArtifact>;

    /// Deployable model footprint in bytes: the length of the artifact
    /// *payload* — the fitted state `save` ships to a deployment node
    /// (O(M·L·r·w) for Sparx, the §3.4 claim). Agrees with
    /// `to_artifact()?.payload.len()` by contract (regression-tested).
    fn model_bytes(&self) -> usize;

    /// Open the evolving-stream front-end (§3.5) with an LRU sketch cache
    /// of `cache_size` IDs. Default: unsupported.
    fn stream_scorer(&self, cache_size: usize) -> Result<StreamScorer> {
        let _ = cache_size;
        Err(SparxError::Unsupported(format!(
            "{} has no evolving-stream front-end (only sparx does)",
            self.name()
        )))
    }

    /// Open the **sharded** concurrent front-end: `opts.shards`
    /// shared-nothing workers (updates route by `murmur(ID) % shards`)
    /// behind one feeder-owned LRU directory holding `opts.cache_total`
    /// IDs **in total**, with recording / absorb / decay behaviour
    /// selected by the remaining [`ServeOptions`] fields. Eviction
    /// decisions are made globally by the feeder, so the shard count is
    /// pure parallelism: per-ID score sequences are bit-identical to a
    /// single-threaded [`stream_scorer`](Self::stream_scorer) with the
    /// same total cache, at *any* shard count — including across a live
    /// re-shard or a checkpoint/resume that changes it.
    /// Default: unsupported.
    fn stream_scorer_sharded(&self, opts: ServeOptions) -> Result<ShardedStreamScorer> {
        let _ = opts;
        Err(SparxError::Unsupported(format!(
            "{} has no evolving-stream front-end (only sparx does)",
            self.name()
        )))
    }

    /// Freeze the **read-only** serving state (chains, trained CMS
    /// counts, projector, bin schema) behind an `Arc`, so any number of
    /// stream scorers — including every shard of a
    /// [`ShardedStreamScorer`] — share one resident copy of the model.
    /// This is also the unit `sparx serve --watch` hot-swaps between
    /// batches. Default: unsupported (only sparx serves streams).
    fn served_ensemble(&self) -> Result<Arc<ServedEnsemble>> {
        Err(SparxError::Unsupported(format!(
            "{} has no evolving-stream front-end (only sparx does)",
            self.name()
        )))
    }

    /// Per-member provenance for composite models: one [`MemberInfo`]
    /// row per ensemble member (spec, kind, measured fit/score cost,
    /// pool worker, distillation lineage, which member serves streams).
    /// Surfaces in `STATS` / `METRICS` on the serving plane.
    /// Default: empty (single-method models have no members).
    fn member_info(&self) -> Vec<MemberInfo> {
        Vec::new()
    }
}

/// Guard shared by the dense-only baselines (SPIF, DBSCOUT): the public
/// SPIF implementation cannot ingest sparse RDDs (§4.2.5) and DBSCOUT's
/// grid needs coordinates, so sparse/mixed data must be projected to a
/// dense representation first — exactly as the paper had to.
/// Checks the density flag [`Dataset`] caches at construction (every row
/// of every partition was inspected exactly once, when the dataset was
/// built), so a mixed partition whose *first* row happens to be dense —
/// the hole the old first-row-per-partition probe fell through — is
/// caught too, at O(1) here.
pub(crate) fn ensure_dense(data: &Dataset, method: &str) -> Result<()> {
    if data.is_all_dense() {
        Ok(())
    } else {
        Err(SparxError::Unsupported(format!(
            "{method} requires dense rows — project the data first \
             (e.g. Sparx's Eq. 2 hash projection), as the paper did"
        )))
    }
}

/// Guard shared by the Sparx / xStream scoring paths: with the fit/score
/// split (and especially save/load), the scored dataset can differ from
/// the fitted one, so mismatches the fit-and-score-in-one flow could
/// never produce must fail typed instead of panicking deep in the
/// projection. Dense rows must match the width the model was fit on
/// (identity passes features straight to the chains; a materialised
/// R[D,K] indexes by position); name-hashing projectors accept any
/// sparse/mixed width — that is Sparx's evolving-feature property.
pub(crate) fn check_projector_input(projector: &Projector, data: &Dataset) -> Result<()> {
    if projector.is_identity() && !data.is_all_dense() {
        return Err(SparxError::Unsupported(
            "this model was fit without projection (k=0) and scores dense rows only".into(),
        ));
    }
    if data.is_all_dense() {
        match projector.expected_dense_dim() {
            Some(d) if data.dim() != d => {
                return Err(SparxError::InvalidParams(format!(
                    "model expects {d}-dimensional dense input, dataset has {} columns",
                    data.dim()
                )));
            }
            // a hashing projector that never materialised a dense schema
            // (fit on sparse/mixed rows with a name-less schema) cannot
            // consume positional dense rows — project() would panic on
            // the missing R matrix
            None if !projector.is_identity() => {
                return Err(SparxError::Unsupported(
                    "this model hashes feature names on the fly and has no dense schema \
                     — encode rows as sparse or mixed to score them"
                        .into(),
                ));
            }
            _ => {}
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterConfig, DistVec};
    use crate::data::{Row, Schema};

    /// Regression for the hardened dense guard: a partition whose first
    /// row is dense but that hides a sparse straggler used to slip past
    /// the old first-row-per-partition probe.
    #[test]
    fn ensure_dense_catches_a_mixed_partition() {
        let ctx = ClusterConfig { num_partitions: 1, ..Default::default() }.build();
        let rows = DistVec::from_parts(
            &ctx,
            vec![vec![
                Row::dense(0, vec![1.0, 2.0]),
                Row::sparse(1, vec![0], vec![1.0]),
                Row::dense(2, vec![3.0, 4.0]),
            ]],
        )
        .unwrap();
        let mixed = Dataset::new(Schema::positional(2), rows);
        assert!(!mixed.is_all_dense());
        assert!(matches!(ensure_dense(&mixed, "SPIF"), Err(SparxError::Unsupported(_))));

        let rows = DistVec::from_parts(
            &ctx,
            vec![vec![Row::dense(0, vec![1.0, 2.0]), Row::dense(1, vec![3.0, 4.0])]],
        )
        .unwrap();
        let dense = Dataset::new(Schema::positional(2), rows);
        assert!(dense.is_all_dense());
        assert!(ensure_dense(&dense, "SPIF").is_ok());
    }
}
