//! The parameterized detector-spec grammar: `name?key=val&key=val`.
//!
//! One shared parser sits behind every name-driven entry point —
//! `registry::create`, `registry::build`, and the CLI's `--method` flag —
//! so `sparx`, `sparx?depth=12&rate=0.05`, and
//! `ensemble?members=sparx,xstream:depth=6` all flow through the same
//! grammar instead of each front-end growing its own ad-hoc splitting.
//!
//! Grammar (no escaping; values may contain `=`, `:`, `,`, `.`):
//!
//! ```text
//! spec    := name [ '?' pair ( '&' pair )* ]
//! pair    := key '=' value
//! member  := name ( ':' pair )*            // inside a `members=` value
//! members := member ( ',' member )*
//! ```
//!
//! Names and keys are `[A-Za-z0-9_-]+`; values are any non-empty text
//! free of the structural separators `?` and `&` (and, inside a member
//! list, `,` and `:`). Duplicate keys are rejected. The grammar layer
//! knows nothing about which keys a method accepts — that check (with
//! edit-distance suggestions) lives in [`super::registry`], which owns
//! the per-method key tables.
//!
//! [`MethodSpec::print`] is the canonical form: parse ∘ print is the
//! identity on parsed specs (property-tested), and a bare name prints
//! with no `?`.

use super::error::{Result, SparxError};

/// A parsed `name?key=val&…` spec: the method (or ensemble-member) name
/// plus its key/value pairs in source order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MethodSpec {
    /// Method name (`sparx`, `xstream`, …) — always non-empty.
    pub name: String,
    /// `key=val` pairs in the order written; keys are unique.
    pub params: Vec<(String, String)>,
}

impl MethodSpec {
    /// Parse a `name?key=val&key=val` spec string. A bare `name` parses
    /// to an empty parameter list; every malformed shape (empty name,
    /// bad characters, missing `=`, empty key/value, duplicate key,
    /// dangling `?` or `&`) is a typed [`SparxError::InvalidParams`].
    pub fn parse(input: &str) -> Result<MethodSpec> {
        let (name, query) = match input.split_once('?') {
            Some((n, q)) => (n, Some(q)),
            None => (input, None),
        };
        check_name(name, input)?;
        let mut params: Vec<(String, String)> = Vec::new();
        if let Some(query) = query {
            if query.is_empty() {
                return Err(SparxError::InvalidParams(format!(
                    "spec {input:?} has a dangling '?' — expected key=val pairs after it"
                )));
            }
            for pair in query.split('&') {
                push_pair(&mut params, pair, input, "&")?;
            }
        }
        Ok(MethodSpec { name: name.to_string(), params })
    }

    /// Parse one member of an `ensemble?members=…` list:
    /// `name(:key=val)*` (e.g. `xstream:depth=6:k=8`).
    pub fn parse_member(input: &str) -> Result<MethodSpec> {
        let (name, rest) = match input.split_once(':') {
            Some((n, r)) => (n, Some(r)),
            None => (input, None),
        };
        check_name(name, input)?;
        let mut params: Vec<(String, String)> = Vec::new();
        if let Some(rest) = rest {
            for pair in rest.split(':') {
                push_pair(&mut params, pair, input, ":")?;
            }
        }
        Ok(MethodSpec { name: name.to_string(), params })
    }

    /// Canonical spec-string form: `name` when there are no parameters,
    /// else `name?key=val&…` in stored order. `parse(print(s)) == s`.
    pub fn print(&self) -> String {
        if self.params.is_empty() {
            return self.name.clone();
        }
        let pairs: Vec<String> =
            self.params.iter().map(|(k, v)| format!("{k}={v}")).collect();
        format!("{}?{}", self.name, pairs.join("&"))
    }

    /// Canonical member form: `name(:key=val)*`.
    /// `parse_member(print_member(s)) == s`.
    pub fn print_member(&self) -> String {
        let mut out = self.name.clone();
        for (k, v) in &self.params {
            out.push(':');
            out.push_str(k);
            out.push('=');
            out.push_str(v);
        }
        out
    }

    /// Look up a parameter value by key.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.params.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// Parse a `members=` value: a comma-separated list of
/// [member](MethodSpec::parse_member) specs. Empty lists and empty
/// members (`a,,b`) are typed errors.
pub fn parse_members(value: &str) -> Result<Vec<MethodSpec>> {
    if value.is_empty() {
        return Err(SparxError::InvalidParams(
            "members list is empty — expected e.g. members=sparx,xstream:depth=6".into(),
        ));
    }
    value.split(',').map(MethodSpec::parse_member).collect()
}

fn valid_word(s: &str) -> bool {
    !s.is_empty() && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

fn check_name(name: &str, input: &str) -> Result<()> {
    if valid_word(name) {
        Ok(())
    } else if name.is_empty() {
        Err(SparxError::InvalidParams(format!(
            "spec {input:?} is missing a method name before the parameters"
        )))
    } else {
        Err(SparxError::InvalidParams(format!(
            "method name {name:?} in spec {input:?} may only contain \
             letters, digits, '_' and '-'"
        )))
    }
}

fn push_pair(
    params: &mut Vec<(String, String)>,
    pair: &str,
    input: &str,
    sep: &str,
) -> Result<()> {
    if pair.is_empty() {
        return Err(SparxError::InvalidParams(format!(
            "spec {input:?} has an empty segment after {sep:?} — expected key=val"
        )));
    }
    let Some((key, value)) = pair.split_once('=') else {
        return Err(SparxError::InvalidParams(format!(
            "parameter {pair:?} in spec {input:?} is missing '=' — expected key=val"
        )));
    };
    if !valid_word(key) {
        return Err(SparxError::InvalidParams(format!(
            "parameter key {key:?} in spec {input:?} must be non-empty and may only \
             contain letters, digits, '_' and '-'"
        )));
    }
    if value.is_empty() {
        return Err(SparxError::InvalidParams(format!(
            "parameter {key:?} in spec {input:?} has an empty value"
        )));
    }
    if params.iter().any(|(k, _)| k == key) {
        return Err(SparxError::InvalidParams(format!(
            "duplicate parameter {key:?} in spec {input:?}"
        )));
    }
    params.push((key.to_string(), value.to_string()));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_names_parse_and_print_unchanged() {
        for name in ["sparx", "xstream", "spif", "dbscout", "ensemble"] {
            let spec = MethodSpec::parse(name).unwrap();
            assert_eq!(spec.name, name);
            assert!(spec.params.is_empty());
            assert_eq!(spec.print(), name);
        }
    }

    #[test]
    fn parameterized_specs_parse_in_order() {
        let spec = MethodSpec::parse("sparx?depth=12&rate=0.05").unwrap();
        assert_eq!(spec.name, "sparx");
        assert_eq!(
            spec.params,
            vec![("depth".into(), "12".into()), ("rate".into(), "0.05".into())]
        );
        assert_eq!(spec.get("depth"), Some("12"));
        assert_eq!(spec.get("rate"), Some("0.05"));
        assert_eq!(spec.get("k"), None);
    }

    #[test]
    fn member_lists_nest_inside_a_value() {
        let spec = MethodSpec::parse("ensemble?members=sparx:depth=6,xstream&distill=true")
            .unwrap();
        let members = parse_members(spec.get("members").unwrap()).unwrap();
        assert_eq!(members.len(), 2);
        assert_eq!(members[0].name, "sparx");
        assert_eq!(members[0].get("depth"), Some("6"));
        assert_eq!(members[1].name, "xstream");
        assert!(members[1].params.is_empty());
        assert_eq!(members[0].print_member(), "sparx:depth=6");
    }

    /// Property: parse ∘ print is the identity on parsed specs, for a
    /// deterministic family of generated specs (names/keys/values drawn
    /// from an LCG so the corpus is stable across runs).
    #[test]
    fn parse_print_round_trip_property() {
        let names = ["sparx", "x-stream", "m_1", "dbscout"];
        let keys = ["k", "depth", "rate", "min-pts", "members", "seed"];
        let values = ["1", "0.05", "sparx:depth=6,xstream", "a=b", "true", "1e-3"];
        let mut state = 0x5EED_u64;
        let mut next = move |m: usize| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as usize) % m
        };
        for _ in 0..200 {
            let mut params = Vec::new();
            let nparams = next(keys.len() + 1);
            for (i, key) in keys.iter().enumerate() {
                if i < nparams {
                    params.push((key.to_string(), values[next(values.len())].to_string()));
                }
            }
            let spec =
                MethodSpec { name: names[next(names.len())].to_string(), params };
            let reparsed = MethodSpec::parse(&spec.print()).unwrap();
            assert_eq!(reparsed, spec, "round trip broke for {:?}", spec.print());
            // member form round-trips too when values stay member-safe
            if spec.params.iter().all(|(_, v)| !v.contains([',', ':'])) {
                let member = MethodSpec::parse_member(&spec.print_member()).unwrap();
                assert_eq!(member, spec);
            }
        }
    }

    #[test]
    fn hostile_specs_fail_typed() {
        for bad in [
            "",
            "?depth=3",
            "sparx?",
            "sparx?depth",
            "sparx?=3",
            "sparx?depth=",
            "sparx?depth=3&depth=4",
            "sparx?depth=3&&rate=0.5",
            "spa rx?depth=3",
            "sparx?de pth=3",
            "sparx??depth=3",
        ] {
            let r = MethodSpec::parse(bad);
            assert!(
                matches!(r, Err(SparxError::InvalidParams(_))),
                "{bad:?} must be InvalidParams, got {r:?}"
            );
        }
        for bad in ["", "a,,b", "sparx:depth", "sparx:=3", ":depth=3"] {
            let r = parse_members(bad);
            assert!(
                matches!(r, Err(SparxError::InvalidParams(_))),
                "members {bad:?} must be InvalidParams, got {r:?}"
            );
        }
    }
}
