//! Table 2: DBSCOUT scales poorly with dimensionality d.
//!
//! Paper: on Gisette with d = 2 → 10 randomly sampled features under
//! config-gen, DBSCOUT's runtime grows from 11s to 3,420s and peak memory
//! from 1.6GB to 350GB; at d = 11 it times out (8h). Expected shape here:
//! superlinear runtime growth in d and a TIMEOUT by d = 11.

use crate::api::{self, SparxError};
use crate::baselines::dbscout::{Dbscout, DbscoutDetector, DbscoutParams};
use crate::cluster::ClusterError;
use crate::config::presets;
use crate::util::Rng;

use super::{run_detector, scale, ExpResult, ExpRow};

pub const DIMS: [usize; 6] = [2, 4, 6, 8, 10, 11];

pub fn run(workload_scale: f64, seed: Option<u64>) -> api::Result<ExpResult> {
    let mut rows = Vec::new();
    let mut times: Vec<Option<f64>> = Vec::new();
    let mut gen = scale::gisette(workload_scale);
    if let Some(s) = seed {
        gen.seed = s;
    }
    for &d in &DIMS {
        let mut ctx = presets::config_gen().build();
        let ld = gen.generate(&ctx)?;
        // d randomly sampled features (paper protocol)
        let cols = Rng::new(0xD1A5 + d as u64).sample_indices(gen.d, d);
        let sub = ld.dataset.select_columns(&ctx, &cols)?;
        let sub_ld = crate::data::LabeledDataset { dataset: sub, labels: ld.labels.clone() };
        let min_pts = (2 * d).max(4);
        let eps = Dbscout::choose_eps(&ctx, &sub_ld.dataset, min_pts, 300)?;
        ctx.reset(); // time the detection, not the data prep
        let det =
            DbscoutDetector::new(DbscoutParams { eps, min_pts, ..Default::default() }, false)?;
        match run_detector(&det, &ctx, &sub_ld) {
            Ok((_aligned, res)) => {
                times.push(Some(res.job_secs));
                rows.push(ExpRow::ok(
                    "DBSCOUT",
                    format!("d={d} eps={eps:.2} minPts={min_pts}"),
                    None,
                    res,
                ));
            }
            Err(
                e @ SparxError::Cluster(
                    ClusterError::DeadlineExceeded { .. }
                    | ClusterError::MemExceeded { .. }
                    | ClusterError::DriverMemExceeded { .. },
                ),
            ) => {
                times.push(None);
                rows.push(ExpRow::failed("DBSCOUT", format!("d={d}"), &e.status_label()));
            }
            Err(e) => return Err(e),
        }
    }
    // shape checks
    let ok_times: Vec<f64> = times.iter().flatten().copied().collect();
    let monotone_tail = ok_times.windows(2).skip(1).all(|w| w[1] >= w[0] * 0.8);
    let explosive =
        ok_times.len() >= 3 && ok_times.last().unwrap() > &(ok_times[1].max(0.005) * 10.0);
    let fails_at_11 = matches!(rows.last(), Some(r) if r.status != "ok");
    Ok(ExpResult {
        id: "table2".into(),
        title: "DBSCOUT runtime/memory vs dimensionality (Gisette-like, config-gen)".into(),
        rows,
        checks: vec![
            ("runtime grows (near-)monotonically in d".into(), monotone_tail),
            ("runtime explodes ≥10× from low-d to d=10".into(), explosive),
            ("d=11 fails the resource budget (paper: 8h TIMEOUT)".into(), fails_at_11),
        ],
    })
}

#[cfg(test)]
mod tests {
    /// Smoke-run at tiny scale (the full run is exercised by the bench).
    #[test]
    fn table2_small_scale_has_all_rows() {
        let r = super::run(0.05, None).unwrap();
        assert_eq!(r.rows.len(), super::DIMS.len());
        assert_eq!(r.checks.len(), 3);
        // the final dimension must fail its resource budget (at tiny test
        // scale the memory model trips before the clock; at full scale —
        // see EXPERIMENTS.md — it's the TIMEOUT of the paper)
        assert_ne!(r.rows.last().unwrap().status, "ok");
    }
}
