//! Experiment harness: one module per table/figure of the paper's §4.
//!
//! Every experiment builds its workload from the generators, runs the
//! method(s) under the scaled cluster presets (Table 5), and returns
//! [`ExpRow`]s that render as a markdown table shaped like the paper's.
//! The CLI (`sparx experiment <id>`) and the bench binaries
//! (`cargo bench`) both call these entry points.
//!
//! | id | paper result | module |
//! |----|--------------|--------|
//! | table2 | DBSCOUT vs dimensionality | [`table2`] |
//! | table3 | Sparx vs SPIF head-to-head (Gisette) | [`table3`] |
//! | table4 | SPIF vs input size n (OSM) | [`table4`] |
//! | fig2 | Gisette accuracy-resources landscape (+Fig 7) | [`fig2`] |
//! | fig3 | OSM landscape, all methods (+T6–T10) | [`fig3`] |
//! | fig4 | SpamURL landscape, all methods (+T11–T14) | [`fig4`] |
//! | fig5 | partitions sweep + speed-up vs xStream | [`fig5`] |
//! | fig6 | linear scaling in n | [`fig6`] |

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod scale;
pub mod table2;
pub mod table3;
pub mod table4;

use crate::api::{self, Detector, FittedModel as _, SparxError};
use crate::cluster::ClusterContext;
use crate::data::LabeledDataset;
use crate::metrics::{RankMetrics, ResourceReport};

/// One row of an experiment's result table.
#[derive(Debug, Clone)]
pub struct ExpRow {
    /// Method name ("Sparx", "SPIF", "DBSCOUT", …).
    pub method: String,
    /// Hyperparameter / workload description for the row.
    pub config: String,
    /// Ranking metrics if the method produced them (DBSCOUT: F1 only).
    pub auroc: Option<f64>,
    pub auprc: Option<f64>,
    pub f1: Option<f64>,
    /// Outcome: "ok", "MEM ERR", "TIMEOUT".
    pub status: String,
    pub resources: Option<ResourceReport>,
}

impl ExpRow {
    pub fn ok(
        method: &str,
        config: String,
        metrics: Option<RankMetrics>,
        resources: ResourceReport,
    ) -> ExpRow {
        ExpRow {
            method: method.into(),
            config,
            auroc: metrics.map(|m| m.auroc),
            auprc: metrics.map(|m| m.auprc),
            f1: metrics.map(|m| m.f1),
            status: "ok".into(),
            resources: Some(resources),
        }
    }

    pub fn failed(method: &str, config: String, status: &str) -> ExpRow {
        ExpRow {
            method: method.into(),
            config,
            auroc: None,
            auprc: None,
            f1: None,
            status: status.into(),
            resources: None,
        }
    }
}

/// A completed experiment: id, headline, and rows.
#[derive(Debug, Clone)]
pub struct ExpResult {
    pub id: String,
    pub title: String,
    pub rows: Vec<ExpRow>,
    /// Shape notes: invariants checked against the paper's qualitative
    /// claims ("who wins"), each with a pass flag.
    pub checks: Vec<(String, bool)>,
}

fn fmt_opt(x: Option<f64>) -> String {
    x.map_or("-".into(), |v| format!("{v:.3}"))
}

impl ExpResult {
    /// Render as a markdown table (EXPERIMENTS.md format).
    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {} — {}\n\n", self.id, self.title);
        s.push_str(
            "| method | config | AUROC | AUPRC | F1 | time(s) | net(s) | peak-exec(MB) \
             | total-mem(MB) | driver(MB) | shuffled(MB) | status |\n",
        );
        s.push_str("|---|---|---|---|---|---|---|---|---|---|---|---|\n");
        for r in &self.rows {
            let (t, net, pw, tot, dm, sh) = r.resources.map_or(
                ("-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into()),
                |res| {
                    (
                        format!("{:.2}", res.job_secs),
                        format!("{:.2}", res.network_secs),
                        format!("{:.1}", res.peak_worker_bytes as f64 / 1048576.0),
                        format!("{:.1}", res.total_peak_bytes as f64 / 1048576.0),
                        format!("{:.1}", res.peak_driver_bytes as f64 / 1048576.0),
                        format!("{:.1}", res.shuffle_bytes as f64 / 1048576.0),
                    )
                },
            );
            s.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |\n",
                r.method,
                r.config,
                fmt_opt(r.auroc),
                fmt_opt(r.auprc),
                fmt_opt(r.f1),
                t,
                net,
                pw,
                tot,
                dm,
                sh,
                r.status
            ));
        }
        if !self.checks.is_empty() {
            s.push_str("\nShape checks vs the paper:\n\n");
            for (what, pass) in &self.checks {
                s.push_str(&format!("- [{}] {}\n", if *pass { "x" } else { " " }, what));
            }
        }
        s
    }
}

/// Helper: ids+scores → dense score vector aligned with labels.
pub fn align_scores(scores: &[(u64, f64)], n: usize) -> Vec<f64> {
    let mut out = vec![f64::NEG_INFINITY; n];
    for &(id, s) in scores {
        out[id as usize] = s;
    }
    out
}

/// The one fit/score pipeline every harness drives (replacing the
/// hand-wired per-method plumbing each experiment used to carry): fit the
/// detector through the unified [`Detector`] contract, score the same
/// dataset, and return label-aligned scores plus the run's resource
/// snapshot.
pub fn run_detector(
    det: &dyn Detector,
    ctx: &ClusterContext,
    ld: &LabeledDataset,
) -> api::Result<(Vec<f64>, ResourceReport)> {
    let model = det.fit(ctx, &ld.dataset)?;
    let scores = model.score(ctx, &ld.dataset)?;
    Ok((align_scores(&scores, ld.labels.len()), ResourceReport::from_ctx(ctx)))
}

/// Binary predictions from aligned scores (DBSCOUT emits 1.0 / 0.0).
pub fn binary_preds(aligned: &[f64]) -> Vec<bool> {
    aligned.iter().map(|&s| s > 0.5).collect()
}

/// All experiment ids, in paper order.
pub const EXPERIMENT_IDS: [&str; 8] =
    ["table2", "table3", "table4", "fig2", "fig3", "fig4", "fig5", "fig6"];

/// Run an experiment by id ("all" runs everything). `seed` overrides the
/// dataset generators' and detectors' base seeds for reproducible runs.
pub fn run(id: &str, scale: f64, seed: Option<u64>) -> api::Result<Vec<ExpResult>> {
    Ok(match id {
        "table2" => vec![table2::run(scale, seed)?],
        "table3" => vec![table3::run(scale, seed)?],
        "table4" => vec![table4::run(scale, seed)?],
        "fig2" => vec![fig2::run(scale, true, seed)?, fig2::run(scale, false, seed)?],
        "fig3" => vec![fig3::run(scale, seed)?],
        "fig4" => vec![fig4::run(scale, seed)?],
        "fig5" => vec![fig5::run(scale, seed)?],
        "fig6" => vec![fig6::run(scale, seed)?],
        "all" => {
            let mut all = Vec::new();
            for e in EXPERIMENT_IDS {
                all.extend(run(e, scale, seed)?);
            }
            all
        }
        other => {
            let ids = EXPERIMENT_IDS.join("|");
            return Err(SparxError::InvalidParams(format!(
                "unknown experiment {other:?} (expected {ids}|all)"
            )));
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_failures_and_metrics() {
        let res = ExpResult {
            id: "tX".into(),
            title: "demo".into(),
            rows: vec![
                ExpRow::ok(
                    "Sparx",
                    "M=10".into(),
                    Some(crate::metrics::RankMetrics { auroc: 0.9, auprc: 0.5, f1: 0.4 }),
                    crate::metrics::ResourceReport {
                        wall_secs: 1.0,
                        network_secs: 0.5,
                        job_secs: 1.5,
                        peak_worker_bytes: 1048576,
                        total_peak_bytes: 2097152,
                        peak_driver_bytes: 1048576,
                        shuffle_bytes: 1048576,
                        shuffle_records: 10,
                        shuffle_rounds: 2,
                    },
                ),
                ExpRow::failed("SPIF", "rate=1".into(), "MEM ERR"),
            ],
            checks: vec![("sparx wins".into(), true)],
        };
        let md = res.to_markdown();
        assert!(md.contains("| Sparx | M=10 | 0.900 | 0.500 | 0.400 | 1.50 |"));
        assert!(md.contains("| SPIF | rate=1 | - | - | - | - | - | - | - | - | - | MEM ERR |"));
        assert!(md.contains("- [x] sparx wins"));
    }

    #[test]
    fn align_scores_places_by_id() {
        let s = align_scores(&[(2, 0.5), (0, 1.5)], 3);
        assert_eq!(s[0], 1.5);
        assert_eq!(s[2], 0.5);
    }

    #[test]
    fn unknown_experiment_is_a_typed_error() {
        let e = run("fig99", 0.05, None).unwrap_err();
        assert!(matches!(e, SparxError::InvalidParams(_)), "got {e:?}");
    }

    #[test]
    fn binary_preds_threshold() {
        assert_eq!(binary_preds(&[0.0, 1.0, 0.4, 0.6]), vec![false, true, false, true]);
    }
}
