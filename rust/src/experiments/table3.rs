//! Table 3: "head-to-head" Sparx vs SPIF on Gisette under five matched
//! hyperparameter configurations.
//!
//! Paper shape: Sparx AUROC 0.80–0.87 vs SPIF 0.76–0.80; doubling
//! ensemble size helps Sparx (not SPIF), raising the sampling rate helps
//! SPIF (not Sparx); Sparx pays ~10–20× more time and ~2–3× more memory.

use crate::api::{self, SparxBuilder};
use crate::baselines::{SpifDetector, SpifParams};
use crate::config::presets;
use crate::metrics::RankMetrics;
use crate::sparx::SparxParams;

use super::{run_detector, scale, ExpResult, ExpRow};

/// (#components, sampling rate, depth) — the paper's five rows.
pub const CONFIGS: [(usize, f64, usize); 5] =
    [(50, 0.01, 10), (100, 0.01, 10), (100, 0.1, 10), (100, 0.1, 20), (100, 1.0, 20)];

pub fn run(workload_scale: f64, seed: Option<u64>) -> api::Result<ExpResult> {
    let mut gen = scale::gisette(workload_scale);
    if let Some(s) = seed {
        gen.seed = s;
    }
    let mut rows = Vec::new();
    let mut sparx_auroc = Vec::new();
    let mut spif_auroc = Vec::new();
    let mut sparx_time = Vec::new();
    let mut spif_time = Vec::new();
    for (i, &(m, rate, depth)) in CONFIGS.iter().enumerate() {
        let cfg = format!("conf {} #comp={m} sampl={rate} depth={depth}", i + 1);
        // Sparx
        {
            let mut ctx = presets::config_gen().build();
            let ld = gen.generate(&ctx)?;
            ctx.reset();
            let mut p = SparxParams {
                k: 50,
                num_chains: m,
                depth,
                sample_rate: rate,
                ..Default::default()
            };
            if let Some(s) = seed {
                p.seed = s;
            }
            let det = SparxBuilder::new().params(p).build()?;
            let (aligned, res) = run_detector(&det, &ctx, &ld)?;
            let met = RankMetrics::compute(&aligned, &ld.labels);
            sparx_auroc.push(met.auroc);
            sparx_time.push(res.job_secs);
            rows.push(ExpRow::ok("Sparx", cfg.clone(), Some(met), res));
        }
        // SPIF
        {
            let mut ctx = presets::config_gen().build();
            let ld = gen.generate(&ctx)?;
            ctx.reset();
            let mut p = SpifParams {
                num_trees: m,
                max_depth: depth,
                sample_rate: rate,
                ..Default::default()
            };
            if let Some(s) = seed {
                p.seed = s;
            }
            let det = SpifDetector::new(p)?;
            let (aligned, res) = run_detector(&det, &ctx, &ld)?;
            let met = RankMetrics::compute(&aligned, &ld.labels);
            spif_auroc.push(met.auroc);
            spif_time.push(res.job_secs);
            rows.push(ExpRow::ok("SPIF", cfg, Some(met), res));
        }
    }
    let sparx_wins = sparx_auroc.iter().zip(&spif_auroc).filter(|(a, b)| a > b).count();
    let doubling_helps_sparx = sparx_auroc[1] >= sparx_auroc[0] - 0.01;
    let sparx_slower = sparx_time.iter().zip(&spif_time).filter(|(a, b)| a > b).count();
    Ok(ExpResult {
        id: "table3".into(),
        title: "Sparx vs SPIF head-to-head on Gisette-like (config-gen)".into(),
        rows,
        checks: vec![
            (
                format!("Sparx beats SPIF on AUROC in ≥4/5 configs (got {sparx_wins}/5)"),
                sparx_wins >= 4,
            ),
            (
                "doubling #components does not hurt Sparx (paper: improves)".into(),
                doubling_helps_sparx,
            ),
            (
                format!(
                    "Sparx pays more time than SPIF (paper 10–20×; slower in {sparx_slower}/5)"
                ),
                sparx_slower >= 4,
            ),
        ],
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn table3_tiny_scale_runs_all_configs() {
        let r = super::run(0.05, None).unwrap();
        assert_eq!(r.rows.len(), 10);
        assert!(r.rows.iter().all(|row| row.status == "ok"));
    }
}
