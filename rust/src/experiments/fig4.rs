//! Fig. 4 (+ appendix tables 11–14): the large-n / very-large-d (sparse)
//! SpamURL-like workload.
//!
//! Paper constraints reproduced: SPIF cannot ingest sparse rows, so its
//! input is the K=100 random projection (as the paper had to do); DBSCOUT
//! cannot go past d≈7, so it runs on d=7 and d=2 projections. Sparx
//! consumes the raw sparse rows directly (hash projection, Eq. 2).
//!
//! Paper shape: Sparx robust across HPs and on par with the baselines;
//! DBSCOUT(d=2) frugal but erratic; DBSCOUT(d=7) slower than SPIF.

use crate::baselines::dbscout::{Dbscout, DbscoutParams};
use crate::baselines::{Spif, SpifParams};
use crate::cluster::ClusterContext;
use crate::config::presets;
use crate::data::{Dataset, LabeledDataset, Row, Schema};
use crate::metrics::{f1_binary, RankMetrics, ResourceReport};
use crate::sparx::{project_dataset, Projector, SparxModel, SparxParams};

use super::{align_scores, scale, ExpResult, ExpRow};

/// Densify a sparse dataset via the shared hash projection (what the
/// paper did to feed SpamURL to SPIF and DBSCOUT).
fn project_to_dense(ctx: &ClusterContext, ld: &LabeledDataset, k: usize) -> Dataset {
    let projector = Projector::new(k, 1.0 / 3.0);
    let proj = project_dataset(ctx, &ld.dataset, &projector).expect("project");
    let rows = proj
        .map(ctx, |sk| Row::dense(sk.id, sk.s.clone()))
        .expect("densify");
    Dataset::new(Schema::positional(k), rows)
}

pub fn run(workload_scale: f64) -> ExpResult {
    let gen = scale::spamurl(workload_scale);
    let mut rows = Vec::new();
    let mut sparx_f1 = Vec::new();
    let mut spif_f1 = Vec::new();

    // --- Sparx on raw sparse rows, K=100 (paper §4.2.5)
    for &(m, l, rate) in &[(50usize, 10usize, 0.01), (50, 10, 0.1), (50, 20, 0.01), (100, 10, 0.01)]
    {
        let mut ctx = presets::config_mod().build();
        let ld = gen.generate(&ctx).expect("generate");
        ctx.reset();
        let p = SparxParams {
            k: 100,
            num_chains: m,
            depth: l,
            sample_rate: rate,
            ..Default::default()
        };
        let cfg = format!("K=100 M={m} L={l} rate={rate}");
        match SparxModel::fit(&ctx, &ld.dataset, &p)
            .and_then(|mo| mo.score_dataset(&ctx, &ld.dataset))
        {
            Ok(scores) => {
                let res = ResourceReport::from_ctx(&ctx);
                let met =
                    RankMetrics::compute(&align_scores(&scores, ld.labels.len()), &ld.labels);
                sparx_f1.push(met.f1);
                rows.push(ExpRow::ok("Sparx", cfg, Some(met), res));
            }
            Err(e) => rows.push(ExpRow::failed("Sparx", cfg, &e.to_string())),
        }
    }

    // --- SPIF on the d=100 dense projection
    for &(t, l, rate) in &[(50usize, 10usize, 0.01), (50, 10, 0.1), (100, 10, 0.01)] {
        let mut ctx = presets::config_mod().build();
        let ld = gen.generate(&ctx).expect("generate");
        let dense = project_to_dense(&ctx, &ld, 100);
        ctx.reset();
        let p = SpifParams { num_trees: t, max_depth: l, sample_rate: rate, ..Default::default() };
        let cfg = format!("d=100 #comp={t} depth={l} sampl={rate}");
        match Spif::fit(&ctx, &dense, &p).and_then(|mo| mo.score_dataset(&ctx, &dense)) {
            Ok(scores) => {
                let res = ResourceReport::from_ctx(&ctx);
                let met =
                    RankMetrics::compute(&align_scores(&scores, ld.labels.len()), &ld.labels);
                spif_f1.push(met.f1);
                rows.push(ExpRow::ok("SPIF", cfg, Some(met), res));
            }
            Err(e) => rows.push(ExpRow::failed("SPIF", cfg, &e.to_string())),
        }
    }

    // --- DBSCOUT on d=7 (its ceiling) and d=2
    for &d in &[7usize, 2] {
        for &mp_mult in &[2usize, 4] {
            let mut ctx = presets::config_mod().build();
            let ld = gen.generate(&ctx).expect("generate");
            let dense = project_to_dense(&ctx, &ld, d);
            let min_pts = mp_mult * d;
            let eps = Dbscout::choose_eps(&ctx, &dense, min_pts, 250).expect("eps");
            ctx.reset();
            let params = DbscoutParams { eps, min_pts, ..Default::default() };
            let cfg = format!("d={d} minPts={min_pts} eps={eps:.2}");
            match Dbscout::run(&ctx, &dense, &params) {
                Ok(v) => {
                    let res = ResourceReport::from_ctx(&ctx);
                    let mut pred = vec![false; ld.labels.len()];
                    for (id, o) in v.pred {
                        pred[id as usize] = o;
                    }
                    rows.push(ExpRow {
                        method: format!("DBSCOUT(d={d})"),
                        config: cfg,
                        auroc: None,
                        auprc: None,
                        f1: Some(f1_binary(&pred, &ld.labels)),
                        status: "ok".into(),
                        resources: Some(res),
                    });
                }
                Err(e) => rows.push(ExpRow::failed(&format!("DBSCOUT(d={d})"), cfg, &e.to_string())),
            }
        }
    }

    let spread = |v: &[f64]| {
        if v.is_empty() {
            return f64::NAN;
        }
        v.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - v.iter().cloned().fold(f64::INFINITY, f64::min)
    };
    let sparx_robust = spread(&sparx_f1) < 0.15;
    let sparx_on_par = !sparx_f1.is_empty()
        && !spif_f1.is_empty()
        && sparx_f1.iter().cloned().fold(0.0, f64::max)
            >= spif_f1.iter().cloned().fold(0.0, f64::max) * 0.75;
    ExpResult {
        id: "fig4".into(),
        title: "SpamURL-like landscape: F1 vs resources (config-mod)".into(),
        rows,
        checks: vec![
            ("Sparx F1 robust across HP settings (paper: stable)".into(), sparx_robust),
            ("Sparx on par with baselines".into(), sparx_on_par),
        ],
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig4_smoke() {
        let r = super::run(0.05);
        assert!(r.rows.iter().any(|x| x.method == "Sparx"));
        assert!(r.rows.iter().any(|x| x.method.starts_with("DBSCOUT")));
    }
}
