//! Fig. 4 (+ appendix tables 11–14): the large-n / very-large-d (sparse)
//! SpamURL-like workload.
//!
//! Paper constraints reproduced: SPIF cannot ingest sparse rows, so its
//! input is the K=100 random projection (as the paper had to do); DBSCOUT
//! cannot go past d≈7, so it runs on d=7 and d=2 projections. Sparx
//! consumes the raw sparse rows directly (hash projection, Eq. 2).
//!
//! Paper shape: Sparx robust across HPs and on par with the baselines;
//! DBSCOUT(d=2) frugal but erratic; DBSCOUT(d=7) slower than SPIF.

use crate::api::{self, SparxBuilder};
use crate::baselines::{DbscoutDetector, DbscoutParams, SpifDetector, SpifParams};
use crate::cluster::ClusterContext;
use crate::config::presets;
use crate::data::{Dataset, LabeledDataset, Row, Schema};
use crate::metrics::{f1_binary, RankMetrics};
use crate::sparx::{project_dataset, Projector, SparxParams};

use super::{binary_preds, run_detector, scale, ExpResult, ExpRow};

/// Densify a sparse dataset via the shared hash projection (what the
/// paper did to feed SpamURL to SPIF and DBSCOUT). Labels ride along so
/// the projected data drops into the same harness.
fn project_to_dense(
    ctx: &ClusterContext,
    ld: &LabeledDataset,
    k: usize,
) -> api::Result<LabeledDataset> {
    let projector = Projector::new(k, 1.0 / 3.0);
    let proj = project_dataset(ctx, &ld.dataset, &projector)?;
    let rows = proj.map(ctx, |sk| Row::dense(sk.id, sk.s.clone()))?;
    Ok(LabeledDataset {
        dataset: Dataset::new(Schema::positional(k), rows),
        labels: ld.labels.clone(),
    })
}

pub fn run(workload_scale: f64, seed: Option<u64>) -> api::Result<ExpResult> {
    let mut gen = scale::spamurl(workload_scale);
    if let Some(s) = seed {
        gen.seed = s;
    }
    let mut rows = Vec::new();
    let mut sparx_f1 = Vec::new();
    let mut spif_f1 = Vec::new();

    // --- Sparx on raw sparse rows, K=100 (paper §4.2.5)
    for &(m, l, rate) in &[(50usize, 10usize, 0.01), (50, 10, 0.1), (50, 20, 0.01), (100, 10, 0.01)]
    {
        let mut ctx = presets::config_mod().build();
        let ld = gen.generate(&ctx)?;
        ctx.reset();
        let mut p = SparxParams {
            k: 100,
            num_chains: m,
            depth: l,
            sample_rate: rate,
            ..Default::default()
        };
        if let Some(s) = seed {
            p.seed = s;
        }
        let det = SparxBuilder::new().params(p).build()?;
        let cfg = format!("K=100 M={m} L={l} rate={rate}");
        match run_detector(&det, &ctx, &ld) {
            Ok((aligned, res)) => {
                let met = RankMetrics::compute(&aligned, &ld.labels);
                sparx_f1.push(met.f1);
                rows.push(ExpRow::ok("Sparx", cfg, Some(met), res));
            }
            Err(e) => rows.push(ExpRow::failed("Sparx", cfg, &e.status_label())),
        }
    }

    // --- SPIF on the d=100 dense projection
    for &(t, l, rate) in &[(50usize, 10usize, 0.01), (50, 10, 0.1), (100, 10, 0.01)] {
        let mut ctx = presets::config_mod().build();
        let ld = gen.generate(&ctx)?;
        let dense = project_to_dense(&ctx, &ld, 100)?;
        ctx.reset();
        let mut p =
            SpifParams { num_trees: t, max_depth: l, sample_rate: rate, ..Default::default() };
        if let Some(s) = seed {
            p.seed = s;
        }
        let det = SpifDetector::new(p)?;
        let cfg = format!("d=100 #comp={t} depth={l} sampl={rate}");
        match run_detector(&det, &ctx, &dense) {
            Ok((aligned, res)) => {
                let met = RankMetrics::compute(&aligned, &dense.labels);
                spif_f1.push(met.f1);
                rows.push(ExpRow::ok("SPIF", cfg, Some(met), res));
            }
            Err(e) => rows.push(ExpRow::failed("SPIF", cfg, &e.status_label())),
        }
    }

    // --- DBSCOUT on d=7 (its ceiling) and d=2; eps via the paper's elbow
    // heuristic, resolved before the reset so the timed run is detection
    // only (the heuristic is HP tuning, not the job)
    for &d in &[7usize, 2] {
        for &mp_mult in &[2usize, 4] {
            let mut ctx = presets::config_mod().build();
            let ld = gen.generate(&ctx)?;
            let dense = project_to_dense(&ctx, &ld, d)?;
            let min_pts = mp_mult * d;
            let eps = crate::baselines::Dbscout::choose_eps(&ctx, &dense.dataset, min_pts, 250)?;
            ctx.reset();
            let det = DbscoutDetector::new(
                DbscoutParams { eps, min_pts, ..Default::default() },
                false,
            )?;
            let cfg = format!("d={d} minPts={min_pts} eps={eps:.2}");
            match run_detector(&det, &ctx, &dense) {
                Ok((aligned, res)) => {
                    rows.push(ExpRow {
                        method: format!("DBSCOUT(d={d})"),
                        config: cfg,
                        auroc: None,
                        auprc: None,
                        f1: Some(f1_binary(&binary_preds(&aligned), &dense.labels)),
                        status: "ok".into(),
                        resources: Some(res),
                    });
                }
                Err(e) => rows.push(ExpRow::failed(
                    &format!("DBSCOUT(d={d})"),
                    cfg,
                    &e.status_label(),
                )),
            }
        }
    }

    let spread = |v: &[f64]| {
        if v.is_empty() {
            return f64::NAN;
        }
        v.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - v.iter().cloned().fold(f64::INFINITY, f64::min)
    };
    let sparx_robust = spread(&sparx_f1) < 0.15;
    let sparx_on_par = !sparx_f1.is_empty()
        && !spif_f1.is_empty()
        && sparx_f1.iter().cloned().fold(0.0, f64::max)
            >= spif_f1.iter().cloned().fold(0.0, f64::max) * 0.75;
    Ok(ExpResult {
        id: "fig4".into(),
        title: "SpamURL-like landscape: F1 vs resources (config-mod)".into(),
        rows,
        checks: vec![
            ("Sparx F1 robust across HP settings (paper: stable)".into(), sparx_robust),
            ("Sparx on par with baselines".into(), sparx_on_par),
        ],
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig4_smoke() {
        let r = super::run(0.05, None).unwrap();
        assert!(r.rows.iter().any(|x| x.method == "Sparx"));
        assert!(r.rows.iter().any(|x| x.method.starts_with("DBSCOUT")));
    }
}
