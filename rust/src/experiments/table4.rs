//! Table 4: SPIF does not scale with input size n.
//!
//! Paper: on OSM (2.77B pts), fitting SPIF on a doubling fraction of the
//! data raises time and memory until ~0.5M points/tree hit MEM ERR, and
//! larger fractions can't even reach the error inside the 8h budget
//! (TIMEOUT). All points are always scored.
//!
//! Scaled setup: the workload is ~7000× smaller than the paper's, so the
//! interconnect bandwidth and the per-executor budget are scaled by the
//! same factor (keeping the ratios that decide who fails where — see
//! DESIGN.md). The simulator reaches the fatal allocation immediately
//! instead of grinding toward it, so the paper's trailing TIMEOUT rows
//! surface as MEM ERR here when the allocation dominates, and as TIMEOUT
//! when accumulated (virtual) network time crosses the deadline first;
//! either way the headline — SPIF cannot fit beyond a small absolute
//! subsample — is reproduced.

use crate::api::{self, SparxError};
use crate::baselines::{SpifDetector, SpifParams};
use crate::cluster::{ClusterConfig, ClusterError};
use crate::metrics::RankMetrics;

use super::{run_detector, scale, ExpResult, ExpRow};

pub const FRACTIONS: [f64; 6] = [0.02, 0.04, 0.08, 0.16, 0.32, 0.64];

/// config-gen with interconnect + executor budget scaled to the workload.
/// Calibrated so that (as in the paper's rows) the small fractions
/// complete with growing cost, then the per-worker materialisations
/// (gathered subsamples + broadcast forest) and the shuffle clock kill
/// the larger ones.
fn scaled_cluster() -> ClusterConfig {
    ClusterConfig {
        num_partitions: 128,
        num_workers: 8,
        num_threads: 8,
        worker_mem_bytes: 160 * 1024 * 1024,
        driver_mem_bytes: 720 * 1024 * 1024,
        network_bytes_per_sec: 2e6, // 2 GB/s ÷ 1000 (workload scale factor)
        network_secs_per_record: 1e-6,
        deadline_secs: Some(450.0),
        seed: 0x5EED,
    }
}

pub fn run(workload_scale: f64, seed: Option<u64>) -> api::Result<ExpResult> {
    let mut gen = scale::osm(workload_scale);
    if let Some(s) = seed {
        gen.seed = s;
    }
    let mut rows = Vec::new();
    let mut ok_times = Vec::new();
    let mut failures = 0;
    for &frac in &FRACTIONS {
        let mut ctx = scaled_cluster().build();
        let ld = gen.generate(&ctx)?;
        let n = ld.dataset.len();
        let pts_per_tree = (n as f64 * frac) as usize;
        ctx.reset();
        let mut p =
            SpifParams { num_trees: 50, max_depth: 25, sample_rate: frac, ..Default::default() };
        if let Some(s) = seed {
            p.seed = s;
        }
        let det = SpifDetector::new(p)?;
        let cfg = format!("frac={frac} #pts/tree≈{pts_per_tree}");
        match run_detector(&det, &ctx, &ld) {
            Ok((aligned, res)) => {
                let met = RankMetrics::compute(&aligned, &ld.labels);
                ok_times.push(res.job_secs);
                rows.push(ExpRow::ok("SPIF", cfg, Some(met), res));
            }
            Err(
                e @ SparxError::Cluster(
                    ClusterError::DeadlineExceeded { .. }
                    | ClusterError::MemExceeded { .. }
                    | ClusterError::DriverMemExceeded { .. },
                ),
            ) => {
                failures += 1;
                rows.push(ExpRow::failed("SPIF", cfg, &e.status_label()));
            }
            Err(e) => return Err(e),
        }
    }
    let time_grows = ok_times.windows(2).all(|w| w[1] >= w[0] * 0.9);
    let fails_eventually = failures >= 2;
    let some_succeed = !ok_times.is_empty();
    Ok(ExpResult {
        id: "table4".into(),
        title: "SPIF vs input size n (OSM-like, scaled config-gen)".into(),
        rows,
        checks: vec![
            ("time grows with the fit fraction".into(), time_grows),
            ("small fractions fit fine (paper rows 1–4)".into(), some_succeed),
            (
                format!("large fractions fail — MEM ERR/TIMEOUT ({failures}/6 failed)"),
                fails_eventually,
            ),
        ],
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn table4_small_scale_structure() {
        // The budget cliffs are calibrated for scale=1.0 (see EXPERIMENTS.md
        // for the full-scale run where the failure rows appear); at smoke
        // scale we assert the sweep structure and the cost growth only.
        let r = super::run(0.1, None).unwrap();
        assert_eq!(r.rows.len(), super::FRACTIONS.len());
        let times: Vec<f64> = r
            .rows
            .iter()
            .filter_map(|row| row.resources.map(|res| res.job_secs))
            .collect();
        assert!(!times.is_empty());
        assert!(
            times.windows(2).all(|w| w[1] >= w[0] * 0.8),
            "cost must grow with the fit fraction: {times:?}"
        );
    }
}
