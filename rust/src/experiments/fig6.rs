//! Fig. 6: Sparx scales linearly in the number of points n.
//!
//! Doubling the OSM-like input size must double job time (within noise) —
//! the empirical confirmation of the §3.4 O(n) analysis.

use crate::config::presets;
use crate::metrics::ResourceReport;
use crate::sparx::{ExecMode, SparxModel, SparxParams};

use super::{scale, ExpResult, ExpRow};

pub const N_MULTIPLIERS: [f64; 4] = [0.25, 0.5, 1.0, 2.0];

pub fn run(workload_scale: f64) -> ExpResult {
    let mut rows = Vec::new();
    let mut ns = Vec::new();
    let mut times = Vec::new();
    for &mult in &N_MULTIPLIERS {
        let gen = scale::osm(workload_scale * mult);
        let mut ctx = presets::config_gen().build();
        let ld = gen.generate(&ctx).expect("generate");
        let n = ld.dataset.len();
        for mode in ExecMode::ALL {
            let tag = mode.tag();
            // same dataset for both plans; reset isolates each run
            ctx.reset();
            let p = SparxParams {
                k: 0,
                num_chains: 10,
                depth: 10,
                sample_rate: 0.01,
                exec_mode: mode,
                ..Default::default()
            };
            let model = SparxModel::fit(&ctx, &ld.dataset, &p).expect("fit");
            let _ = model.score_dataset(&ctx, &ld.dataset).expect("score");
            let res = ResourceReport::from_ctx(&ctx);
            // the linearity check tracks the fused (default) plan; the
            // per-chain rows ride along for the pass-structure A/B
            if mode == ExecMode::Fused {
                ns.push(n as f64);
                times.push(res.job_secs);
            }
            rows.push(ExpRow {
                method: "Sparx".into(),
                config: format!("n={n} exec={tag}"),
                auroc: None,
                auprc: None,
                f1: None,
                status: "ok".into(),
                resources: Some(res),
            });
        }
    }
    // linearity: fit t = a·n + b, check R² and that the largest/smallest
    // time ratio tracks the n ratio
    let ratio_n = ns.last().unwrap() / ns[0];
    let ratio_t = times.last().unwrap() / times[0];
    let near_linear = ratio_t > ratio_n * 0.4 && ratio_t < ratio_n * 2.5;
    ExpResult {
        id: "fig6".into(),
        title: "Sparx runtime vs input size n (OSM-like, config-gen)".into(),
        rows,
        checks: vec![(
            format!("runtime scales ~linearly (n x{ratio_n:.1} → t x{ratio_t:.1})"),
            near_linear,
        )],
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig6_smoke() {
        let r = super::run(0.05);
        // one fused and one per-chain row per input size
        assert_eq!(r.rows.len(), 2 * super::N_MULTIPLIERS.len());
        assert!(r.rows.iter().all(|x| x.status == "ok"));
    }
}
