//! Fig. 6: Sparx scales linearly in the number of points n.
//!
//! Doubling the OSM-like input size must double job time (within noise) —
//! the empirical confirmation of the §3.4 O(n) analysis.

use crate::api::{self, Detector, FittedModel as _, SparxBuilder};
use crate::config::presets;
use crate::metrics::ResourceReport;
use crate::sparx::{ExecMode, SparxParams};

use super::{scale, ExpResult, ExpRow};

pub const N_MULTIPLIERS: [f64; 4] = [0.25, 0.5, 1.0, 2.0];

pub fn run(workload_scale: f64, seed: Option<u64>) -> api::Result<ExpResult> {
    let mut rows = Vec::new();
    let mut ns = Vec::new();
    let mut times = Vec::new();
    for &mult in &N_MULTIPLIERS {
        let mut gen = scale::osm(workload_scale * mult);
        if let Some(s) = seed {
            gen.seed = s;
        }
        let mut ctx = presets::config_gen().build();
        let ld = gen.generate(&ctx)?;
        let n = ld.dataset.len();
        for mode in ExecMode::ALL {
            let tag = mode.tag();
            // same dataset for both plans; reset isolates each run
            ctx.reset();
            let mut p = SparxParams {
                k: 0,
                num_chains: 10,
                depth: 10,
                sample_rate: 0.01,
                exec_mode: mode,
                ..Default::default()
            };
            if let Some(s) = seed {
                p.seed = s;
            }
            let det = SparxBuilder::new().params(p).build()?;
            let model = det.fit(&ctx, &ld.dataset)?;
            let _ = model.score(&ctx, &ld.dataset)?;
            let res = ResourceReport::from_ctx(&ctx);
            // the linearity check tracks the fused (default) plan; the
            // per-chain rows ride along for the pass-structure A/B
            if mode == ExecMode::Fused {
                ns.push(n as f64);
                times.push(res.job_secs);
            }
            rows.push(ExpRow {
                method: "Sparx".into(),
                config: format!("n={n} exec={tag}"),
                auroc: None,
                auprc: None,
                f1: None,
                status: "ok".into(),
                resources: Some(res),
            });
        }
    }
    // linearity: the largest/smallest time ratio must track the n ratio
    let ratio_n = ns.last().unwrap() / ns[0];
    let ratio_t = times.last().unwrap() / times[0];
    let near_linear = ratio_t > ratio_n * 0.4 && ratio_t < ratio_n * 2.5;
    Ok(ExpResult {
        id: "fig6".into(),
        title: "Sparx runtime vs input size n (OSM-like, config-gen)".into(),
        rows,
        checks: vec![(
            format!("runtime scales ~linearly (n x{ratio_n:.1} → t x{ratio_t:.1})"),
            near_linear,
        )],
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig6_smoke() {
        let r = super::run(0.05, None).unwrap();
        // one fused and one per-chain row per input size
        assert_eq!(r.rows.len(), 2 * super::N_MULTIPLIERS.len());
        assert!(r.rows.iter().all(|x| x.status == "ok"));
    }
}
