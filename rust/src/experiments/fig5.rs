//! Fig. 5: data-parallel speed-up. Running time of Sparx on Gisette as
//! the number of DataFrame partitions grows 8 → 256, and speed-up
//! relative to single-machine xStream (paper: 4–20×, with a U-shaped
//! runtime curve — over-partitioning re-introduces coordination cost).
//!
//! Model HPs per the paper's footnote 12: M=10 chains, depth 5, rate 1.

use crate::api::{self, Detector, FittedModel as _, SparxBuilder};
use crate::baselines::{XStream, XStreamDetector, XStreamParams};
use crate::cluster::ClusterConfig;
use crate::metrics::ResourceReport;
use crate::sparx::{ExecMode, SparxParams};

use super::{scale, ExpResult, ExpRow};

pub const PARTITIONS: [usize; 6] = [8, 16, 32, 64, 128, 256];

pub fn run(workload_scale: f64, seed: Option<u64>) -> api::Result<ExpResult> {
    let mut gen = scale::gisette(workload_scale);
    if let Some(s) = seed {
        gen.seed = s;
    }
    let mut sp =
        SparxParams { k: 50, num_chains: 10, depth: 5, sample_rate: 1.0, ..Default::default() };
    if let Some(s) = seed {
        sp.seed = s;
    }

    // single-machine xStream baseline (same HPs, same seeds). The rows
    // are collected *before* the clock starts so the speed-up denominator
    // measures the sequential algorithm, not the driver collect — the
    // adapter path (XStreamDetector, equal bit for bit, tests/api.rs)
    // would pay the collect twice inside the window.
    let base_ctx = ClusterConfig { num_partitions: 1, ..Default::default() }.build();
    let ld = gen.generate(&base_ctx)?;
    let local_rows = ld.dataset.rows.collect(&base_ctx)?;
    let xp = XStreamParams {
        k: sp.k,
        num_chains: sp.num_chains,
        depth: sp.depth,
        cms_rows: sp.cms_rows,
        cms_cols: sp.cms_cols,
        density: sp.density,
        score_mode: sp.score_mode,
        seed: sp.seed,
    };
    let xdet = XStreamDetector::new(xp)?; // validates the params up front
    let t0 = std::time::Instant::now();
    let xs = XStream::fit(&local_rows, &ld.dataset.schema.names, xdet.params());
    let _ = xs.score(&local_rows);
    let xstream_secs = t0.elapsed().as_secs_f64();

    let mut rows = vec![ExpRow {
        method: "xStream (1 machine)".into(),
        config: "M=10 L=5 rate=1".into(),
        auroc: None,
        auprc: None,
        f1: None,
        status: "ok".into(),
        resources: Some(ResourceReport {
            wall_secs: xstream_secs,
            network_secs: 0.0,
            job_secs: xstream_secs,
            peak_worker_bytes: 0,
            total_peak_bytes: 0,
            peak_driver_bytes: 0,
            shuffle_bytes: 0,
            shuffle_records: 0,
            shuffle_rounds: 0,
        }),
    }];

    // both execution plans per partition count: the fused single-pass
    // executors (paper-faithful) and the legacy per-chain rounds, so the
    // table shows the pass-structure win alongside the speed-up curve
    let mut times = Vec::new();
    for &p in &PARTITIONS {
        let mut ctx = ClusterConfig {
            num_partitions: p,
            num_workers: 8,
            num_threads: 8,
            ..Default::default()
        }
        .build();
        let ld = gen.generate(&ctx)?;
        for mode in ExecMode::ALL {
            let tag = mode.tag();
            // same dataset for both plans; reset isolates each run's
            // clocks, ledger and peaks
            ctx.reset();
            let det = SparxBuilder::new()
                .params(SparxParams { exec_mode: mode, ..sp.clone() })
                .build()?;
            let model = det.fit(&ctx, &ld.dataset)?;
            let _ = model.score(&ctx, &ld.dataset)?;
            let res = ResourceReport::from_ctx(&ctx);
            if mode == ExecMode::Fused {
                times.push(res.job_secs);
            }
            let speedup = xstream_secs / res.job_secs;
            rows.push(ExpRow {
                method: "Sparx".into(),
                config: format!("partitions={p} exec={tag} (speed-up {speedup:.1}x)"),
                auroc: None,
                auprc: None,
                f1: None,
                status: "ok".into(),
                resources: Some(res),
            });
        }
    }

    let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let best_speedup = xstream_secs / best;
    let first = times[0];
    let decreasing_then_flat = times.iter().skip(1).take(3).any(|&t| t < first);
    Ok(ExpResult {
        id: "fig5".into(),
        title: "Runtime vs #partitions + speed-up over single-machine xStream".into(),
        rows,
        checks: vec![
            (
                format!("parallel speed-up over xStream (best {best_speedup:.1}x; paper 4–20x)"),
                best_speedup > 1.5,
            ),
            (
                "runtime improves beyond 8 partitions before flattening".into(),
                decreasing_then_flat,
            ),
        ],
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig5_smoke() {
        let r = super::run(0.03, None).unwrap();
        // xStream baseline + one fused and one per-chain row per
        // partition count
        assert_eq!(r.rows.len(), 1 + 2 * super::PARTITIONS.len());
    }
}
