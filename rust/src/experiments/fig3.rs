//! Fig. 3 (+ appendix tables 6–10): all three methods on the large-n /
//! 2-d OSM-like workload — F1 versus time and total memory across HP
//! configurations.
//!
//! Paper shape: SPIF can only fit a ~1e-4 sliver and lands at F1 < 0.2;
//! DBSCOUT is fastest and can reach the best F1 but oscillates wildly
//! with its HPs; Sparx is stable, slower, and uses the least memory.

use crate::api::{self, SparxBuilder};
use crate::baselines::{DbscoutDetector, DbscoutParams, SpifDetector, SpifParams};
use crate::config::presets;
use crate::metrics::{f1_binary, RankMetrics};
use crate::sparx::SparxParams;

use super::{binary_preds, run_detector, scale, ExpResult, ExpRow};

pub fn run(workload_scale: f64, seed: Option<u64>) -> api::Result<ExpResult> {
    let mut gen = scale::osm(workload_scale);
    if let Some(s) = seed {
        gen.seed = s;
    }
    let mut rows = Vec::new();
    let mut sparx_f1 = Vec::new();
    let mut dbscout_f1 = Vec::new();
    let mut spif_f1: Vec<f64> = Vec::new();

    // --- Sparx: raw 2-d (no projection, paper §4.1.5), paper's OSM grid
    for &(m, l) in &[(10usize, 5usize), (10, 10), (20, 10), (10, 20)] {
        let mut ctx = presets::config_gen().build();
        let ld = gen.generate(&ctx)?;
        ctx.reset();
        let mut p = SparxParams {
            k: 0,
            num_chains: m,
            depth: l,
            sample_rate: 0.01,
            ..Default::default()
        };
        if let Some(s) = seed {
            p.seed = s;
        }
        let det = SparxBuilder::new().params(p).build()?;
        let cfg = format!("M={m} L={l} rate=0.01");
        match run_detector(&det, &ctx, &ld) {
            Ok((aligned, res)) => {
                let met = RankMetrics::compute(&aligned, &ld.labels);
                sparx_f1.push(met.f1);
                rows.push(ExpRow::ok("Sparx", cfg, Some(met), res));
            }
            Err(e) => rows.push(ExpRow::failed("Sparx", cfg, &e.status_label())),
        }
    }

    // --- SPIF: tiny fit fractions (it cannot handle more — Table 4)
    for &(t, l, rate) in &[(50usize, 10usize, 1e-4), (50, 20, 5e-4), (100, 10, 1e-4)] {
        let mut ctx = presets::config_gen().build();
        let ld = gen.generate(&ctx)?;
        ctx.reset();
        let mut p =
            SpifParams { num_trees: t, max_depth: l, sample_rate: rate, ..Default::default() };
        if let Some(s) = seed {
            p.seed = s;
        }
        let det = SpifDetector::new(p)?;
        let cfg = format!("#comp={t} depth={l} sampl={rate}");
        match run_detector(&det, &ctx, &ld) {
            Ok((aligned, res)) => {
                let met = RankMetrics::compute(&aligned, &ld.labels);
                spif_f1.push(met.f1);
                rows.push(ExpRow::ok("SPIF", cfg, Some(met), res));
            }
            Err(e) => rows.push(ExpRow::failed("SPIF", cfg, &e.status_label())),
        }
    }

    // --- DBSCOUT: binary output, minPts × eps grid (paper Tables 8–9)
    for &min_pts in &[16usize, 32] {
        for &eps in &[0.02f64, 0.05, 0.1, 0.2] {
            let mut ctx = presets::config_gen().build();
            let ld = gen.generate(&ctx)?;
            ctx.reset();
            let det =
                DbscoutDetector::new(DbscoutParams { eps, min_pts, ..Default::default() }, false)?;
            let cfg = format!("minPts={min_pts} eps={eps}");
            match run_detector(&det, &ctx, &ld) {
                Ok((aligned, res)) => {
                    let f1 = f1_binary(&binary_preds(&aligned), &ld.labels);
                    dbscout_f1.push(f1);
                    rows.push(ExpRow {
                        method: "DBSCOUT".into(),
                        config: cfg,
                        auroc: None,
                        auprc: None,
                        f1: Some(f1),
                        status: "ok".into(),
                        resources: Some(res),
                    });
                }
                Err(e) => rows.push(ExpRow::failed("DBSCOUT", cfg, &e.status_label())),
            }
        }
    }

    let spread = |v: &[f64]| {
        v.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - v.iter().cloned().fold(f64::INFINITY, f64::min)
    };
    let sparx_stable = !sparx_f1.is_empty()
        && !dbscout_f1.is_empty()
        && spread(&sparx_f1) < spread(&dbscout_f1);
    let spif_poor = spif_f1.iter().all(|&f| f < 0.5);
    let dbscout_competitive = dbscout_f1.iter().cloned().fold(0.0, f64::max)
        >= sparx_f1.iter().cloned().fold(0.0, f64::max) * 0.7;
    Ok(ExpResult {
        id: "fig3".into(),
        title: "OSM-like landscape: F1 vs resources, all methods (config-gen)".into(),
        rows,
        checks: vec![
            (
                "Sparx F1 more stable across HPs than DBSCOUT (paper: oscillates)".into(),
                sparx_stable,
            ),
            ("SPIF F1 poor (tiny feasible fit fraction)".into(), spif_poor),
            ("DBSCOUT competitive at this low d".into(), dbscout_competitive),
        ],
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig3_smoke() {
        let r = super::run(0.05, None).unwrap();
        assert!(r.rows.len() >= 10);
        assert!(r.rows.iter().any(|x| x.method == "DBSCOUT"));
    }
}
