//! Fig. 2 (config-gen) and Fig. 7 (config-mod): the accuracy-vs-resources
//! landscape on Gisette — AUROC against running time and against peak
//! driver memory, across the HP grid M∈{50,100}, L∈{10,20},
//! rate∈{0.01,0.1,1} for both Sparx and SPIF.
//!
//! Paper shape: SPIF occupies the fast-but-capped region (AUROC
//! 0.72–0.80, 1–2 min); Sparx reaches higher accuracy (0.80–0.87) at
//! 10–20× the time and 2–3× the memory. DBSCOUT cannot run at this d.

use crate::api::{self, SparxBuilder};
use crate::baselines::{SpifDetector, SpifParams};
use crate::config::presets;
use crate::metrics::RankMetrics;
use crate::sparx::SparxParams;

use super::{run_detector, scale, ExpResult, ExpRow};

pub const M_GRID: [usize; 2] = [50, 100];
pub const L_GRID: [usize; 2] = [10, 20];
pub const RATE_GRID: [f64; 3] = [0.01, 0.1, 1.0];

pub fn run(workload_scale: f64, generous: bool, seed: Option<u64>) -> api::Result<ExpResult> {
    let mut gen = scale::gisette(workload_scale);
    if let Some(s) = seed {
        gen.seed = s;
    }
    let preset = if generous { presets::config_gen } else { presets::config_mod };
    let mut rows = Vec::new();
    let mut sparx_best: f64 = 0.0;
    let mut spif_best: f64 = 0.0;
    let mut sparx_worst: f64 = 1.0;
    for &m in &M_GRID {
        for &l in &L_GRID {
            for &rate in &RATE_GRID {
                let cfg = format!("M={m} L={l} rate={rate}");
                // Sparx
                {
                    let mut ctx = preset().build();
                    let ld = gen.generate(&ctx)?;
                    ctx.reset();
                    let mut p = SparxParams {
                        k: 50,
                        num_chains: m,
                        depth: l,
                        sample_rate: rate,
                        ..Default::default()
                    };
                    if let Some(s) = seed {
                        p.seed = s;
                    }
                    let det = SparxBuilder::new().params(p).build()?;
                    match run_detector(&det, &ctx, &ld) {
                        Ok((aligned, res)) => {
                            let met = RankMetrics::compute(&aligned, &ld.labels);
                            sparx_best = sparx_best.max(met.auroc);
                            sparx_worst = sparx_worst.min(met.auroc);
                            rows.push(ExpRow::ok("Sparx", cfg.clone(), Some(met), res));
                        }
                        Err(e) => {
                            rows.push(ExpRow::failed("Sparx", cfg.clone(), &e.status_label()))
                        }
                    }
                }
                // SPIF
                {
                    let mut ctx = preset().build();
                    let ld = gen.generate(&ctx)?;
                    ctx.reset();
                    let mut p = SpifParams {
                        num_trees: m,
                        max_depth: l,
                        sample_rate: rate,
                        ..Default::default()
                    };
                    if let Some(s) = seed {
                        p.seed = s;
                    }
                    let det = SpifDetector::new(p)?;
                    match run_detector(&det, &ctx, &ld) {
                        Ok((aligned, res)) => {
                            let met = RankMetrics::compute(&aligned, &ld.labels);
                            spif_best = spif_best.max(met.auroc);
                            rows.push(ExpRow::ok("SPIF", cfg, Some(met), res));
                        }
                        Err(e) => rows.push(ExpRow::failed("SPIF", cfg, &e.status_label())),
                    }
                }
            }
        }
    }
    let id = if generous { "fig2" } else { "fig7" };
    let cfg_name = if generous { "config-gen" } else { "config-mod" };
    Ok(ExpResult {
        id: id.into(),
        title: format!("Gisette accuracy-vs-resources landscape ({cfg_name})"),
        rows,
        checks: vec![
            (
                format!(
                    "Sparx peak beats SPIF peak (sparx {sparx_best:.3} vs spif {spif_best:.3})"
                ),
                sparx_best > spif_best,
            ),
            ("DBSCOUT absent by design (cannot run at this d — Table 2)".into(), true),
        ],
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig2_tiny_scale_produces_grid() {
        let r = super::run(0.05, true, None).unwrap();
        assert_eq!(r.rows.len(), 2 * 2 * 3 * 2);
    }

    #[test]
    fn fig2_seed_override_is_deterministic() {
        let a = super::run(0.05, true, Some(77)).unwrap();
        let b = super::run(0.05, true, Some(77)).unwrap();
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.auroc, y.auroc, "{}/{} diverges under a fixed seed", x.method, x.config);
        }
    }
}
