//! Workload scaling shared by all experiments.
//!
//! `scale = 1.0` is the repository's default laptop-scale substitution of
//! the paper's datasets (DESIGN.md §Substitutions). Bench binaries run at
//! a smaller scale so `cargo bench` completes in minutes; the CLI default
//! is 1.0. All counts scale linearly, dimensions stay fixed (they change
//! the *problem*, not just its size).

use crate::data::generators::{GisetteGen, OsmGen, SpamUrlGen};

pub fn gisette(scale: f64) -> GisetteGen {
    GisetteGen {
        n: ((8_000.0 * scale) as usize).max(400),
        d: 512,
        ..Default::default()
    }
}

pub fn osm(scale: f64) -> OsmGen {
    OsmGen {
        n_inliers: ((400_000.0 * scale) as usize).max(20_000),
        n_outliers: ((400.0 * scale) as usize).max(40),
        roads: 120,
        cities: 30,
        ..Default::default()
    }
}

pub fn spamurl(scale: f64) -> SpamUrlGen {
    SpamUrlGen {
        n: ((20_000.0 * scale) as usize).max(1_000),
        d: 100_000,
        mean_nnz: 120,
        ..Default::default()
    }
}

/// Scale read from `SPARX_SCALE` (benches honour it), default `dflt`.
pub fn from_env(dflt: f64) -> f64 {
    std::env::var("SPARX_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(dflt)
}

#[cfg(test)]
mod tests {
    #[test]
    fn scaling_monotone() {
        assert!(super::gisette(2.0).n > super::gisette(1.0).n);
        assert!(super::osm(0.5).n_inliers < super::osm(1.0).n_inliers);
        // floors protect tiny scales from degenerate workloads
        assert!(super::spamurl(0.0001).n >= 1000);
    }
}
