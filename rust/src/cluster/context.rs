//! Cluster configuration and the job context threaded through every
//! distributed operation.

use std::sync::Arc;
use std::time::Instant;

use super::{ClusterError, MemoryMeter, Result, ShuffleLedger};

/// Mirror of the paper's Table 5 system configuration knobs, plus the
/// simulator's failure-semantics knobs (bandwidth, deadline).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of DataFrame partitions.
    pub num_partitions: usize,
    /// Number of executor (worker) threads — `#execs × #exec-cores`.
    pub num_workers: usize,
    /// Driver-side model-parallel thread-pool size (#threads in Table 5).
    pub num_threads: usize,
    /// Per-executor memory budget in bytes (`exec-memory`).
    pub worker_mem_bytes: usize,
    /// Driver memory budget in bytes (`driver-memory`).
    pub driver_mem_bytes: usize,
    /// Modelled network bandwidth for shuffled bytes (bytes/sec); shuffles
    /// convert to virtual time at this rate. `f64::INFINITY` disables.
    pub network_bytes_per_sec: f64,
    /// Per-record network overhead in seconds (serialization + framing);
    /// this is what makes many-small-records shuffles slow, as on Spark.
    pub network_secs_per_record: f64,
    /// Job deadline in (wall + virtual network) seconds; None = unlimited.
    /// The paper's runs had an 8-hour supercomputing budget.
    pub deadline_secs: Option<f64>,
    /// Base seed for all stochastic components.
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            num_partitions: 16,
            num_workers: std::thread::available_parallelism().map_or(4, |p| p.get().min(8)),
            num_threads: 4,
            worker_mem_bytes: usize::MAX,
            driver_mem_bytes: usize::MAX,
            network_bytes_per_sec: 1e9,
            network_secs_per_record: 25e-9,
            deadline_secs: None,
            seed: 0x5EED,
        }
    }
}

impl ClusterConfig {
    pub fn build(self) -> ClusterContext {
        ClusterContext::new(self)
    }
}

/// Shared state of a running "cluster": meters, ledger, clock.
#[derive(Clone)]
pub struct ClusterContext {
    pub cfg: ClusterConfig,
    pub worker_mem: Arc<Vec<MemoryMeter>>,
    pub driver_mem: Arc<MemoryMeter>,
    pub ledger: Arc<ShuffleLedger>,
    /// Per-worker busy nanoseconds. The host may have fewer cores than
    /// `num_workers` (this environment has one), so the *parallel* job
    /// time is modelled from the critical path:
    /// `wall − Σ busy + max_w busy_w` — serial sections run at wall speed,
    /// parallelised partition work collapses to the busiest worker.
    busy: Arc<Vec<std::sync::atomic::AtomicU64>>,
    start: Instant,
}

impl ClusterContext {
    pub fn new(cfg: ClusterConfig) -> Self {
        assert!(cfg.num_partitions >= 1 && cfg.num_workers >= 1);
        let worker_mem = (0..cfg.num_workers)
            .map(|_| MemoryMeter::new(cfg.worker_mem_bytes))
            .collect();
        ClusterContext {
            worker_mem: Arc::new(worker_mem),
            driver_mem: Arc::new(MemoryMeter::new(cfg.driver_mem_bytes)),
            ledger: Arc::new(ShuffleLedger::new()),
            busy: Arc::new(
                (0..cfg.num_workers).map(|_| std::sync::atomic::AtomicU64::new(0)).collect(),
            ),
            start: Instant::now(),
            cfg,
        }
    }

    /// Record `nanos` of compute done by `worker` (partition tasks).
    pub fn record_busy(&self, worker: usize, nanos: u64) {
        self.busy[worker].fetch_add(nanos, std::sync::atomic::Ordering::Relaxed);
    }

    fn busy_stats(&self) -> (f64, f64) {
        let mut total = 0u64;
        let mut max = 0u64;
        for b in self.busy.iter() {
            let v = b.load(std::sync::atomic::Ordering::Relaxed);
            total += v;
            max = max.max(v);
        }
        (total as f64 / 1e9, max as f64 / 1e9)
    }

    /// Worker that owns partition `p`.
    #[inline]
    pub fn owner(&self, p: usize) -> usize {
        p % self.cfg.num_workers
    }

    /// Wall-clock seconds since the context was created / reset.
    pub fn wall_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Modelled (non-wall) seconds: network transfer + any cost-model
    /// virtual compute time.
    pub fn network_secs(&self) -> f64 {
        let (bytes, records, _) = self.ledger.snapshot();
        bytes as f64 / self.cfg.network_bytes_per_sec
            + records as f64 * self.cfg.network_secs_per_record
            + self.ledger.virtual_secs()
    }

    /// The clock experiments report: modelled parallel compute time
    /// (critical path over workers) + virtual network time. Falls back to
    /// plain wall when nothing was recorded as parallel work.
    pub fn job_secs(&self) -> f64 {
        let (total, max) = self.busy_stats();
        let serial = (self.wall_secs() - total).max(0.0);
        serial + max + self.network_secs()
    }

    /// Raw single-host wall clock (everything ran on this machine).
    pub fn host_wall_secs(&self) -> f64 {
        self.wall_secs()
    }

    /// Fail if past the deadline (checked between partition tasks).
    pub fn check_deadline(&self) -> Result<()> {
        if let Some(budget) = self.cfg.deadline_secs {
            let elapsed = self.job_secs();
            if elapsed > budget {
                return Err(ClusterError::DeadlineExceeded {
                    elapsed_secs: elapsed,
                    budget_secs: budget,
                });
            }
        }
        Ok(())
    }

    /// Charge a worker meter, mapping overflow to `MemExceeded`.
    pub fn charge_worker(&self, worker: usize, bytes: usize) -> Result<()> {
        self.worker_mem[worker].charge(bytes).map_err(|wanted| ClusterError::MemExceeded {
            worker,
            wanted,
            budget: self.cfg.worker_mem_bytes,
        })
    }

    /// Charge the driver meter.
    pub fn charge_driver(&self, bytes: usize) -> Result<()> {
        self.driver_mem.charge(bytes).map_err(|wanted| ClusterError::DriverMemExceeded {
            wanted,
            budget: self.cfg.driver_mem_bytes,
        })
    }

    /// Peak memory across workers (the paper's "executor peak").
    pub fn peak_worker_bytes(&self) -> usize {
        self.worker_mem.iter().map(|m| m.peak()).max().unwrap_or(0)
    }

    /// Total peak memory (sum of worker peaks + driver peak), the paper's
    /// "total memory (GB)" columns.
    pub fn total_peak_bytes(&self) -> usize {
        self.worker_mem.iter().map(|m| m.peak()).sum::<usize>() + self.driver_mem.peak()
    }

    /// Reset clocks, ledger and peaks between experiment runs.
    pub fn reset(&mut self) {
        self.ledger.reset();
        for m in self.worker_mem.iter() {
            m.reset_peak();
        }
        for b in self.busy.iter() {
            b.store(0, std::sync::atomic::Ordering::Relaxed);
        }
        self.driver_mem.reset_peak();
        self.start = Instant::now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_round_robin() {
        let ctx = ClusterConfig { num_workers: 4, ..Default::default() }.build();
        assert_eq!(ctx.owner(0), 0);
        assert_eq!(ctx.owner(5), 1);
    }

    #[test]
    fn network_time_model() {
        let ctx = ClusterConfig {
            network_bytes_per_sec: 1000.0,
            network_secs_per_record: 0.001,
            ..Default::default()
        }
        .build();
        ctx.ledger.add(2000, 10);
        assert!((ctx.network_secs() - (2.0 + 0.01)).abs() < 1e-9);
    }

    #[test]
    fn deadline_triggers() {
        let ctx = ClusterConfig {
            deadline_secs: Some(0.5),
            network_bytes_per_sec: 1.0,
            network_secs_per_record: 0.0,
            ..Default::default()
        }
        .build();
        assert!(ctx.check_deadline().is_ok());
        ctx.ledger.add(100, 0); // 100 virtual seconds
        assert!(matches!(
            ctx.check_deadline(),
            Err(ClusterError::DeadlineExceeded { .. })
        ));
    }

    #[test]
    fn charge_worker_maps_error() {
        let ctx = ClusterConfig {
            num_workers: 2,
            worker_mem_bytes: 100,
            ..Default::default()
        }
        .build();
        ctx.charge_worker(0, 90).unwrap();
        let e = ctx.charge_worker(0, 20).unwrap_err();
        assert!(matches!(e, ClusterError::MemExceeded { worker: 0, .. }));
        // other worker unaffected
        ctx.charge_worker(1, 90).unwrap();
    }
}
