//! Per-worker / driver memory accounting with budgets.
//!
//! Charges are estimated deep sizes ([`crate::util::SizeOf`]). The meter
//! tracks a high-water mark (the paper's "peak memory" columns) and fails
//! a charge that would exceed the budget, reproducing executor OOMs.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Thread-safe current/peak memory meter with an optional budget.
#[derive(Debug)]
pub struct MemoryMeter {
    current: AtomicUsize,
    peak: AtomicUsize,
    budget: usize, // usize::MAX = unlimited
}

impl MemoryMeter {
    pub fn new(budget: usize) -> Self {
        MemoryMeter { current: AtomicUsize::new(0), peak: AtomicUsize::new(0), budget }
    }

    pub fn unlimited() -> Self {
        Self::new(usize::MAX)
    }

    /// Charge `bytes`; returns the would-be total on budget overflow.
    /// Saturating: cost models can ask for `usize::MAX` (DBSCOUT's
    /// super-literal buffers at high d), which must trip the budget, not
    /// overflow the arithmetic.
    pub fn charge(&self, bytes: usize) -> Result<(), usize> {
        let prev = self.current.fetch_add(bytes, Ordering::Relaxed);
        let now = prev.saturating_add(bytes);
        if now > self.budget {
            // roll back so later (smaller) stages can still run
            self.current.fetch_sub(bytes, Ordering::Relaxed);
            // peak still records the attempt: the job *needed* this much
            self.peak.fetch_max(now, Ordering::Relaxed);
            return Err(now);
        }
        self.peak.fetch_max(now, Ordering::Relaxed);
        Ok(())
    }

    /// Release a previous charge.
    pub fn release(&self, bytes: usize) {
        let prev = self.current.fetch_sub(bytes, Ordering::Relaxed);
        debug_assert!(prev >= bytes, "release underflow: {prev} - {bytes}");
    }

    pub fn current(&self) -> usize {
        self.current.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Reset peak tracking (between experiment runs).
    pub fn reset_peak(&self) {
        self.peak.store(self.current(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_release_peak() {
        let m = MemoryMeter::new(1000);
        m.charge(400).unwrap();
        m.charge(500).unwrap();
        assert_eq!(m.current(), 900);
        assert_eq!(m.peak(), 900);
        m.release(500);
        assert_eq!(m.current(), 400);
        assert_eq!(m.peak(), 900);
    }

    #[test]
    fn budget_enforced_and_rolled_back() {
        let m = MemoryMeter::new(100);
        m.charge(80).unwrap();
        let e = m.charge(50).unwrap_err();
        assert_eq!(e, 130);
        // rolled back: a smaller charge still fits
        m.charge(20).unwrap();
        assert_eq!(m.current(), 100);
        // peak remembers the failed attempt — that's what the job needed
        assert_eq!(m.peak(), 130);
    }

    #[test]
    fn unlimited_never_fails() {
        let m = MemoryMeter::unlimited();
        m.charge(usize::MAX / 4).unwrap();
    }

    #[test]
    fn concurrent_charges() {
        let m = std::sync::Arc::new(MemoryMeter::unlimited());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.charge(3).unwrap();
                        m.release(1);
                    }
                });
            }
        });
        assert_eq!(m.current(), 8 * 1000 * 2);
    }
}
