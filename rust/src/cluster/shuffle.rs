//! Network accounting: every byte that crosses a partition boundary goes
//! through this ledger. Virtual network time = bytes / bandwidth, which the
//! experiment harness adds to wall time so that shuffle-heavy algorithms
//! (SPIF's per-tree subsample gather) pay the cost the paper observed on a
//! real cluster.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct ShuffleLedger {
    bytes: AtomicU64,
    records: AtomicU64,
    /// Number of shuffle (communication) rounds — the paper's "two-pass"
    /// claim for Sparx is asserted against this counter in tests.
    rounds: AtomicU64,
    /// Extra modelled compute nanoseconds, used by cost models for work
    /// that cannot be executed literally at laptop scale (e.g. DBSCOUT's
    /// exponential cell-neighbourhood enumeration — see
    /// `baselines::dbscout`). Included in the job clock like network time.
    virtual_nanos: AtomicU64,
}

impl ShuffleLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, bytes: usize, records: usize) {
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.records.fetch_add(records as u64, Ordering::Relaxed);
    }

    pub fn add_round(&self) {
        self.rounds.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn records(&self) -> u64 {
        self.records.load(Ordering::Relaxed)
    }

    pub fn rounds(&self) -> u64 {
        self.rounds.load(Ordering::Relaxed)
    }

    /// Add modelled compute time (see `virtual_nanos` docs).
    pub fn add_virtual_secs(&self, secs: f64) {
        self.virtual_nanos.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    }

    pub fn virtual_secs(&self) -> f64 {
        self.virtual_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn reset(&self) {
        self.bytes.store(0, Ordering::Relaxed);
        self.records.store(0, Ordering::Relaxed);
        self.rounds.store(0, Ordering::Relaxed);
        self.virtual_nanos.store(0, Ordering::Relaxed);
    }

    /// Snapshot (bytes, records, rounds).
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (self.bytes(), self.records(), self.rounds())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let l = ShuffleLedger::new();
        l.add(100, 10);
        l.add(50, 5);
        l.add_round();
        assert_eq!(l.bytes(), 150);
        assert_eq!(l.records(), 15);
        assert_eq!(l.rounds(), 1);
        l.reset();
        assert_eq!(l.snapshot(), (0, 0, 0));
    }
}
