//! Shared-nothing cluster substrate.
//!
//! The paper's system claims are about *coordination structure*: number of
//! map/reduce passes, size of intermediates crossing the network, data- vs
//! model-parallelism, and per-executor memory behaviour. This module
//! provides an in-process substrate that preserves exactly those semantics
//! while running on worker threads:
//!
//! * data lives in disjoint [`dist::DistVec`] partitions; an operation sees
//!   only its own partition (no shared-memory shortcuts);
//! * every byte that crosses partition boundaries (shuffles, collects,
//!   broadcasts) is accounted in a [`shuffle::ShuffleLedger`] and converted
//!   to virtual network time at a configurable bandwidth;
//! * every worker and the driver have a [`memory::MemoryMeter`] with a
//!   budget — exceeding it fails the job with `MemExceeded`, which is how
//!   the paper's "MEM ERR" rows (Table 4) reproduce;
//! * jobs carry a deadline — the paper's 8-hour "TIMEOUT" rows reproduce
//!   as `DeadlineExceeded` against the accounted virtual+wall clock.

pub mod context;
pub mod dist;
pub mod memory;
pub mod pool;
pub mod shuffle;

pub use context::{ClusterConfig, ClusterContext};
pub use dist::DistVec;
pub use memory::MemoryMeter;
pub use shuffle::ShuffleLedger;

/// Errors surfaced by the cluster substrate.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterError {
    /// A worker exceeded its executor memory budget (paper: "MEM ERR").
    MemExceeded { worker: usize, wanted: usize, budget: usize },
    /// The driver exceeded its memory budget.
    DriverMemExceeded { wanted: usize, budget: usize },
    /// The job ran past its wall+virtual deadline (paper: "TIMEOUT").
    DeadlineExceeded { elapsed_secs: f64, budget_secs: f64 },
    /// Invalid configuration or usage.
    Invalid(String),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::MemExceeded { worker, wanted, budget } => write!(
                f,
                "MEM ERR: worker {worker} needed {wanted}B over budget {budget}B"
            ),
            ClusterError::DriverMemExceeded { wanted, budget } => {
                write!(f, "MEM ERR: driver needed {wanted}B over budget {budget}B")
            }
            ClusterError::DeadlineExceeded { elapsed_secs, budget_secs } => {
                write!(f, "TIMEOUT after {elapsed_secs:.1}s (budget {budget_secs:.1}s)")
            }
            ClusterError::Invalid(m) => write!(f, "invalid: {m}"),
        }
    }
}

impl std::error::Error for ClusterError {}

pub type Result<T> = std::result::Result<T, ClusterError>;
