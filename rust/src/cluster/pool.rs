//! Bounded scoped parallelism.
//!
//! Two uses in the paper's system: (1) worker-level data parallelism —
//! each logical worker processes its partitions; (2) the driver-side
//! *model-parallel* thread pool that trains/scoresthe M chains
//! concurrently (Algorithm 2, lines 9–11; Algorithm 3, lines 4–6).
//!
//! `run_indexed` executes `n` jobs over at most `threads` OS threads with
//! a shared atomic work queue, preserving result order. Scoped, so jobs
//! may borrow from the caller.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// CPU time consumed by the calling thread, in nanoseconds. Immune to
/// time-slicing: on a host with fewer cores than simulated workers
/// (this environment has one), wall-clock elapsed would count the time a
/// task spent descheduled while sibling workers ran — CPU time does not.
///
/// Calls `clock_gettime(CLOCK_THREAD_CPUTIME_ID)` through the C runtime
/// directly (the offline build has no `libc` crate). Restricted to
/// 64-bit targets where `struct timespec` is two 64-bit `long`s — on
/// 32-bit ABIs the layout differs, so those use the fallback below.
#[cfg(all(
    any(target_os = "linux", target_os = "android", target_os = "macos"),
    target_pointer_width = "64"
))]
pub fn thread_cpu_nanos() -> u64 {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    #[cfg(any(target_os = "linux", target_os = "android"))]
    const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    #[cfg(target_os = "macos")]
    const CLOCK_THREAD_CPUTIME_ID: i32 = 16;
    extern "C" {
        fn clock_gettime(clockid: i32, tp: *mut Timespec) -> i32;
    }
    let mut ts = Timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: plain syscall writing into the local timespec
    unsafe {
        clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as u64 * 1_000_000_000 + ts.tv_nsec as u64
}

/// Fallback for targets without a (64-bit-timespec) thread-CPU clock:
/// wall time since the thread first asked (over-counts under
/// contention, but keeps the busy-clock accounting monotone and
/// well-defined).
#[cfg(not(all(
    any(target_os = "linux", target_os = "android", target_os = "macos"),
    target_pointer_width = "64"
)))]
pub fn thread_cpu_nanos() -> u64 {
    thread_local! {
        static T0: std::time::Instant = std::time::Instant::now();
    }
    T0.with(|t| t.elapsed().as_nanos() as u64)
}

/// Run `n` jobs `f(0..n)` on at most `threads` threads; returns results in
/// index order. Panics in jobs propagate.
pub fn run_indexed<R, F>(threads: usize, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not run"))
        .collect()
}

/// Fallible variant: stops scheduling new jobs after the first error and
/// returns it (jobs already running complete).
pub fn try_run_indexed<R, E, F>(threads: usize, n: usize, f: F) -> Result<Vec<R>, E>
where
    R: Send,
    E: Send,
    F: Fn(usize) -> Result<R, E> + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Ok(Vec::new());
    }
    let next = AtomicUsize::new(0);
    let failed = std::sync::atomic::AtomicBool::new(false);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let error: Mutex<Option<E>> = Mutex::new(None);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                match f(i) {
                    Ok(r) => *results[i].lock().unwrap() = Some(r),
                    Err(e) => {
                        failed.store(true, Ordering::Relaxed);
                        let mut slot = error.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                        break;
                    }
                }
            });
        }
    });
    if let Some(e) = error.into_inner().unwrap() {
        return Err(e);
    }
    Ok(results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not run"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = run_indexed(4, 100, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = run_indexed(1, 5, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_jobs() {
        let out: Vec<usize> = run_indexed(4, 0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn borrows_from_caller() {
        let data = vec![10, 20, 30];
        let out = run_indexed(2, 3, |i| data[i] + 1);
        assert_eq!(out, vec![11, 21, 31]);
    }

    #[test]
    fn try_run_propagates_error() {
        let r: Result<Vec<usize>, String> =
            try_run_indexed(4, 100, |i| if i == 37 { Err("boom".into()) } else { Ok(i) });
        assert_eq!(r.unwrap_err(), "boom");
    }

    #[test]
    fn try_run_ok() {
        let r: Result<Vec<usize>, ()> = try_run_indexed(3, 10, Ok);
        assert_eq!(r.unwrap(), (0..10).collect::<Vec<_>>());
    }
}
