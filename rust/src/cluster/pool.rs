//! Bounded scoped parallelism and long-lived pinned workers.
//!
//! Three uses in the paper's system: (1) worker-level data parallelism —
//! each logical worker processes its partitions; (2) the driver-side
//! *model-parallel* thread pool that trains/scoresthe M chains
//! concurrently (Algorithm 2, lines 9–11; Algorithm 3, lines 4–6);
//! (3) the §3.5 serving front-end's shard workers.
//!
//! `run_indexed` executes `n` jobs over at most `threads` OS threads with
//! a shared atomic work queue, preserving result order. Scoped, so jobs
//! may borrow from the caller.
//!
//! [`PinnedPool`] is the long-lived counterpart for *stateful* workers:
//! each worker owns private state and a bounded ingest queue
//! (`std::sync::mpsc::sync_channel`), items are routed to a specific
//! worker (pinned, never stolen — the shared-nothing property sharded
//! serving depends on), and `join` returns the final states. A full
//! queue blocks the sender (backpressure); items are never dropped
//! while their worker is alive (a panicked worker's items are discarded
//! and the panic re-raised at `join`).

// One of the two modules whitelisted for `unsafe` (crate root denies it):
// the direct `clock_gettime` call below. Every unsafe block needs a
// `// SAFETY:` comment (enforced by `sparx_lint`).
#![allow(unsafe_code)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Mutex;

/// CPU time consumed by the calling thread, in nanoseconds. Immune to
/// time-slicing: on a host with fewer cores than simulated workers
/// (this environment has one), wall-clock elapsed would count the time a
/// task spent descheduled while sibling workers ran — CPU time does not.
///
/// Calls `clock_gettime(CLOCK_THREAD_CPUTIME_ID)` through the C runtime
/// directly (the offline build has no `libc` crate). Restricted to
/// 64-bit targets where `struct timespec` is two 64-bit `long`s — on
/// 32-bit ABIs the layout differs, so those use the fallback below.
#[cfg(all(
    any(target_os = "linux", target_os = "android", target_os = "macos"),
    target_pointer_width = "64"
))]
pub fn thread_cpu_nanos() -> u64 {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    #[cfg(any(target_os = "linux", target_os = "android"))]
    const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    #[cfg(target_os = "macos")]
    const CLOCK_THREAD_CPUTIME_ID: i32 = 16;
    extern "C" {
        fn clock_gettime(clockid: i32, tp: *mut Timespec) -> i32;
    }
    let mut ts = Timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: plain syscall writing into the local timespec
    unsafe {
        clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as u64 * 1_000_000_000 + ts.tv_nsec as u64
}

/// Fallback for targets without a (64-bit-timespec) thread-CPU clock:
/// wall time since the thread first asked (over-counts under
/// contention, but keeps the busy-clock accounting monotone and
/// well-defined).
#[cfg(not(all(
    any(target_os = "linux", target_os = "android", target_os = "macos"),
    target_pointer_width = "64"
)))]
pub fn thread_cpu_nanos() -> u64 {
    thread_local! {
        static T0: std::time::Instant = std::time::Instant::now();
    }
    T0.with(|t| t.elapsed().as_nanos() as u64)
}

/// Run `n` jobs `f(0..n)` on at most `threads` threads; returns results in
/// index order. Panics in jobs propagate.
pub fn run_indexed<R, F>(threads: usize, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not run"))
        .collect()
}

/// Fallible variant: stops scheduling new jobs after the first error and
/// returns it (jobs already running complete).
pub fn try_run_indexed<R, E, F>(threads: usize, n: usize, f: F) -> Result<Vec<R>, E>
where
    R: Send,
    E: Send,
    F: Fn(usize) -> Result<R, E> + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Ok(Vec::new());
    }
    let next = AtomicUsize::new(0);
    let failed = std::sync::atomic::AtomicBool::new(false);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let error: Mutex<Option<E>> = Mutex::new(None);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                match f(i) {
                    Ok(r) => *results[i].lock().unwrap() = Some(r),
                    Err(e) => {
                        failed.store(true, Ordering::Relaxed);
                        let mut slot = error.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                        break;
                    }
                }
            });
        }
    });
    if let Some(e) = error.into_inner().unwrap() {
        return Err(e);
    }
    Ok(results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not run"))
        .collect())
}

/// Run `assignment.len()` jobs under a **caller-chosen** schedule:
/// `assignment[i] = w` pins job `i` to worker `w ∈ 0..workers`, and each
/// worker executes its jobs in index order. Unlike
/// [`try_run_indexed`]'s work-stealing counter, the placement here is
/// deterministic input — this is what the ensemble layer's cost model
/// feeds (longest-processing-time bins vs naive round-robin), so the
/// schedule itself can be asserted and benchmarked. Results come back in
/// job-index order; the first error wins and the remaining jobs on that
/// worker are skipped (other workers complete their queues).
pub fn run_assigned<R, E, F>(workers: usize, assignment: &[usize], f: F) -> Result<Vec<R>, E>
where
    R: Send,
    E: Send,
    F: Fn(usize) -> Result<R, E> + Sync,
{
    let n = assignment.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.max(1);
    debug_assert!(assignment.iter().all(|&w| w < workers), "assignment names a missing worker");
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let error: Mutex<Option<E>> = Mutex::new(None);
    std::thread::scope(|s| {
        for w in 0..workers {
            let results = &results;
            let error = &error;
            let f = &f;
            s.spawn(move || {
                for i in
                    assignment.iter().enumerate().filter(|(_, &a)| a == w).map(|(i, _)| i)
                {
                    match f(i) {
                        Ok(r) => *results[i].lock().unwrap() = Some(r),
                        Err(e) => {
                            let mut slot = error.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(e);
                            }
                            return;
                        }
                    }
                }
            });
        }
    });
    if let Some(e) = error.into_inner().unwrap() {
        return Err(e);
    }
    Ok(results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not run"))
        .collect())
}

// ------------------------------------------------- pinned worker pool

/// Long-lived stateful workers, one OS thread + one bounded ingest
/// queue each. Unlike [`run_indexed`]'s fork-join (spawn, drain a shared
/// work list, join), a `PinnedPool` keeps its workers alive across an
/// unbounded item stream and routes every item to the *caller-chosen*
/// worker, so worker state never migrates between threads — the
/// shared-nothing property the sharded §3.5 front-end is built on.
///
/// Queues are `std::sync::mpsc::sync_channel`s: a full queue blocks the
/// sender (backpressure — no loss while the worker is alive; see
/// [`send`](Self::send) for the panicked-worker exception), and the
/// pool holds each worker's only `SyncSender`, so dropping the senders
/// is the end-of-stream signal — workers drain what was queued, then
/// their `recv` loop ends.
pub struct PinnedPool<T, S> {
    senders: Vec<SyncSender<T>>,
    handles: Vec<std::thread::JoinHandle<S>>,
}

impl<T: Send + 'static, S: Send + 'static> PinnedPool<T, S> {
    /// Spawn one worker per entry of `states`. Each worker loops
    /// `handler(&mut state, item)` over its own queue (capacity
    /// `queue_cap` items) until the queue closes, then yields its
    /// final state back through [`join`](Self::join).
    pub fn spawn<F>(states: Vec<S>, queue_cap: usize, handler: F) -> Self
    where
        F: Fn(&mut S, T) + Send + Clone + 'static,
    {
        let mut senders = Vec::with_capacity(states.len());
        let mut handles = Vec::with_capacity(states.len());
        for mut state in states {
            let (tx, rx) = sync_channel::<T>(queue_cap.max(1));
            let f = handler.clone();
            handles.push(std::thread::spawn(move || {
                while let Ok(item) = rx.recv() {
                    f(&mut state, item);
                }
                state
            }));
            senders.push(tx);
        }
        PinnedPool { senders, handles }
    }

    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Enqueue `item` on worker `w`'s queue; blocks only while that
    /// queue is full (backpressure — items are never dropped). If the
    /// worker died (panicked and dropped its receiver), the item is
    /// discarded instead of blocking forever on a queue nothing drains;
    /// the panic itself surfaces at [`join`](Self::join).
    pub fn send(&self, w: usize, item: T) {
        let _ = self.senders[w].send(item);
    }

    /// Non-blocking [`send`](Self::send): enqueue `item` on worker `w`'s
    /// queue if there is room *right now*, otherwise hand the item back
    /// as `Err` so the caller can surface backpressure (the TCP ingress
    /// turns this into a `BUSY` response instead of stalling every
    /// connection on one hot shard). Mirrors `send`'s panicked-worker
    /// behaviour: a dead worker's item is discarded and reported `Ok`,
    /// with the panic surfacing at [`join`](Self::join).
    pub fn try_send(&self, w: usize, item: T) -> Result<(), T> {
        use std::sync::mpsc::TrySendError;
        match self.senders.get(w) {
            Some(tx) => match tx.try_send(item) {
                Ok(()) => Ok(()),
                Err(TrySendError::Full(item)) => Err(item),
                Err(TrySendError::Disconnected(_)) => Ok(()),
            },
            None => Ok(()),
        }
    }

    /// Close every queue (by dropping the senders), wait for the workers
    /// to drain them, and return the final states in worker order.
    /// Panics in workers propagate.
    pub fn join(mut self) -> Vec<S> {
        self.senders.clear();
        self.handles.drain(..).map(|h| h.join().expect("pinned worker panicked")).collect()
    }
}

/// Dropping the pool without [`join`](PinnedPool::join) (e.g. on an
/// error path) still shuts down cleanly: queues close, workers drain
/// and exit, and their states are discarded.
impl<T, S> Drop for PinnedPool<T, S> {
    fn drop(&mut self) {
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = run_indexed(4, 100, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = run_indexed(1, 5, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_jobs() {
        let out: Vec<usize> = run_indexed(4, 0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn borrows_from_caller() {
        let data = vec![10, 20, 30];
        let out = run_indexed(2, 3, |i| data[i] + 1);
        assert_eq!(out, vec![11, 21, 31]);
    }

    #[test]
    fn try_run_propagates_error() {
        let r: Result<Vec<usize>, String> =
            try_run_indexed(4, 100, |i| if i == 37 { Err("boom".into()) } else { Ok(i) });
        assert_eq!(r.unwrap_err(), "boom");
    }

    #[test]
    fn try_run_ok() {
        let r: Result<Vec<usize>, ()> = try_run_indexed(3, 10, Ok);
        assert_eq!(r.unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn pinned_pool_routes_to_the_chosen_worker_in_order() {
        let states: Vec<Vec<u64>> = vec![Vec::new(), Vec::new(), Vec::new()];
        let pool = PinnedPool::spawn(states, 8, |state: &mut Vec<u64>, item: u64| {
            state.push(item);
        });
        assert_eq!(pool.workers(), 3);
        for i in 0..300u64 {
            pool.send((i % 3) as usize, i);
        }
        let states = pool.join();
        for (w, state) in states.iter().enumerate() {
            let want: Vec<u64> = (0..300).filter(|i| (i % 3) as usize == w).collect();
            assert_eq!(state, &want, "worker {w} saw items out of order or missing");
        }
    }

    #[test]
    fn pinned_pool_drop_without_join_terminates() {
        let pool: PinnedPool<u64, u64> =
            PinnedPool::spawn(vec![0u64, 0], 2, |state, item| *state += item);
        pool.send(0, 1);
        pool.send(1, 2);
        drop(pool); // must close + join, not hang or leak blocked threads
    }

    #[test]
    fn pinned_pool_worker_panic_does_not_hang_the_sender() {
        let pool: PinnedPool<u64, u64> = PinnedPool::spawn(vec![0u64], 1, |_state, item| {
            assert!(item != 3, "boom");
        });
        // the worker dies at item 3; later sends must be discarded, not
        // block forever on a queue nothing drains
        for i in 0..100u64 {
            pool.send(0, i);
        }
        let joined = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.join()));
        assert!(joined.is_err(), "join must propagate the worker panic");
    }

    #[test]
    fn pinned_pool_try_send_reports_would_block_deterministically() {
        use std::sync::{Arc, Barrier};
        // Worker blocks on a barrier while handling item 0, so the queue
        // (cap 1) fills deterministically: item 1 occupies the slot,
        // item 2 must come back as Err.
        let gate = Arc::new(Barrier::new(2));
        let g = gate.clone();
        let pool: PinnedPool<u64, u64> = PinnedPool::spawn(vec![0u64], 1, move |state, item| {
            if item == 0 {
                g.wait();
            }
            *state += item;
        });
        pool.send(0, 0); // worker picks this up and parks on the barrier
        // wait until the worker has dequeued item 0 (the queue frees up)
        loop {
            match pool.try_send(0, 1) {
                Ok(()) => break,
                Err(_) => std::thread::yield_now(),
            }
        }
        let rejected = pool.try_send(0, 2);
        assert_eq!(rejected, Err(2), "full queue must hand the item back");
        gate.wait(); // release the worker
        pool.send(0, 3);
        let states = pool.join();
        assert_eq!(states[0], 4, "rejected item 2 was silently enqueued (0+1+3 expected)");
    }

    #[test]
    fn pinned_pool_backpressure_under_contention() {
        // queue cap 1 with a worker that does real work per item: the
        // sender is forced to block repeatedly; every item still lands
        let pool: PinnedPool<u64, u64> = PinnedPool::spawn(vec![0u64], 1, |state, item| {
            *state = state.wrapping_add(item);
            std::hint::black_box(*state);
        });
        for i in 0..1000u64 {
            pool.send(0, i);
        }
        let states = pool.join();
        assert_eq!(states[0], (0..1000u64).sum::<u64>());
    }
}
