//! `DistVec<T>` — the partitioned, shared-nothing dataset (Spark
//! DataFrame/RDD analogue) plus the MapReduce operator set Sparx needs:
//! `map`, `map_partitions`, `flat_map`, `filter`, `sample`,
//! `reduce_by_key`, `collect`, `collect_as_map`, `broadcast`, aggregates.
//!
//! Semantics enforced by construction:
//! * an operator closure sees one element / one partition — never another
//!   partition (shared-nothing);
//! * partition `p` is owned by worker `p % W`; new partitions are charged
//!   to their owner's [`MemoryMeter`] and released when the `DistVec`
//!   drops;
//! * `reduce_by_key` performs a map-side combine, then hash-partitions
//!   keys across reducers; bytes crossing worker boundaries are added to
//!   the [`ShuffleLedger`] (one ledger round per shuffle);
//! * `collect*` gathers to the driver, charging driver memory + network.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

use super::{pool, ClusterContext, MemoryMeter, Result};
use crate::util::{Rng, SizeOf};

/// A partitioned distributed vector. Created and transformed only through
/// a [`ClusterContext`], which owns the accounting.
pub struct DistVec<T> {
    parts: Vec<Vec<T>>,
    charges: Vec<(usize, usize)>, // (worker, bytes) released on drop
    meters: Option<Arc<Vec<MemoryMeter>>>,
}

impl<T> Drop for DistVec<T> {
    fn drop(&mut self) {
        if let Some(meters) = &self.meters {
            for &(w, b) in &self.charges {
                meters[w].release(b);
            }
        }
    }
}

fn charge_parts<T: SizeOf>(
    ctx: &ClusterContext,
    parts: &[Vec<T>],
) -> Result<Vec<(usize, usize)>> {
    let mut charges = Vec::with_capacity(parts.len());
    for (p, part) in parts.iter().enumerate() {
        let w = ctx.owner(p);
        let bytes = part.size_of();
        ctx.charge_worker(w, bytes)?;
        charges.push((w, bytes));
    }
    Ok(charges)
}

/// Run `f` over all partitions with worker-level parallelism: worker `w`
/// sequentially processes the partitions it owns; workers run in parallel.
fn par_over_parts<T, U, F>(ctx: &ClusterContext, parts: &[Vec<T>], f: F) -> Result<Vec<Vec<U>>>
where
    T: Send + Sync,
    U: Send,
    F: Fn(usize, &[T]) -> Result<Vec<U>> + Sync,
{
    let w = ctx.cfg.num_workers;
    let results = pool::try_run_indexed(w.min(parts.len()).max(1), parts.len(), |p| {
        ctx.check_deadline()?;
        let t0 = pool::thread_cpu_nanos();
        let out = f(p, &parts[p]);
        // partition work belongs to its owner worker's busy clock (the
        // modelled-parallel-time input; see ClusterContext::job_secs).
        // CPU time, not elapsed: the host may have fewer cores than
        // simulated workers.
        ctx.record_busy(ctx.owner(p), pool::thread_cpu_nanos() - t0);
        out
    })?;
    Ok(results)
}

impl<T: Send + Sync> DistVec<T> {
    /// Partition a driver-side vector into `ctx.cfg.num_partitions` chunks.
    pub fn from_vec(ctx: &ClusterContext, data: Vec<T>) -> Result<Self>
    where
        T: SizeOf,
    {
        let p = ctx.cfg.num_partitions;
        let n = data.len();
        let base = n / p;
        let extra = n % p;
        let mut parts: Vec<Vec<T>> = Vec::with_capacity(p);
        let mut it = data.into_iter();
        for i in 0..p {
            let take = base + usize::from(i < extra);
            parts.push(it.by_ref().take(take).collect());
        }
        let charges = charge_parts(ctx, &parts)?;
        Ok(DistVec { parts, charges, meters: Some(ctx.worker_mem.clone()) })
    }

    /// Build partitions directly (generators use this to create data
    /// "in place" on workers without a driver round-trip).
    pub fn from_parts(ctx: &ClusterContext, parts: Vec<Vec<T>>) -> Result<Self>
    where
        T: SizeOf,
    {
        let charges = charge_parts(ctx, &parts)?;
        Ok(DistVec { parts, charges, meters: Some(ctx.worker_mem.clone()) })
    }

    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    pub fn len(&self) -> usize {
        self.parts.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read-only view of a partition (tests / local tooling only).
    pub fn part(&self, p: usize) -> &[T] {
        &self.parts[p]
    }

    /// Element-wise map (Spark `map`).
    pub fn map<U, F>(&self, ctx: &ClusterContext, f: F) -> Result<DistVec<U>>
    where
        U: SizeOf + Send + Sync,
        F: Fn(&T) -> U + Sync,
    {
        let parts = par_over_parts(ctx, &self.parts, |_, part| {
            Ok(part.iter().map(&f).collect())
        })?;
        let charges = charge_parts(ctx, &parts)?;
        Ok(DistVec { parts, charges, meters: Some(ctx.worker_mem.clone()) })
    }

    /// Whole-partition map (Spark `mapPartitionsWithIndex`) — the hot-path
    /// variant the PJRT tile runner uses.
    pub fn map_partitions<U, F>(&self, ctx: &ClusterContext, f: F) -> Result<DistVec<U>>
    where
        U: SizeOf + Send + Sync,
        F: Fn(usize, &[T]) -> Result<Vec<U>> + Sync,
    {
        let parts = par_over_parts(ctx, &self.parts, |p, part| f(p, part))?;
        let charges = charge_parts(ctx, &parts)?;
        Ok(DistVec { parts, charges, meters: Some(ctx.worker_mem.clone()) })
    }

    /// Element-to-many map (Spark `flatMap`).
    pub fn flat_map<U, F, I>(&self, ctx: &ClusterContext, f: F) -> Result<DistVec<U>>
    where
        U: SizeOf + Send + Sync,
        I: IntoIterator<Item = U>,
        F: Fn(&T) -> I + Sync,
    {
        let parts = par_over_parts(ctx, &self.parts, |_, part| {
            Ok(part.iter().flat_map(&f).collect())
        })?;
        let charges = charge_parts(ctx, &parts)?;
        Ok(DistVec { parts, charges, meters: Some(ctx.worker_mem.clone()) })
    }

    /// Keep elements satisfying `pred` (clones survivors).
    pub fn filter<F>(&self, ctx: &ClusterContext, pred: F) -> Result<DistVec<T>>
    where
        T: Clone + SizeOf,
        F: Fn(&T) -> bool + Sync,
    {
        let parts = par_over_parts(ctx, &self.parts, |_, part| {
            Ok(part.iter().filter(|x| pred(x)).cloned().collect())
        })?;
        let charges = charge_parts(ctx, &parts)?;
        Ok(DistVec { parts, charges, meters: Some(ctx.worker_mem.clone()) })
    }

    /// Bernoulli subsample at `rate` (Spark `sample(withReplacement=false)`),
    /// deterministic per (seed, partition).
    pub fn sample(&self, ctx: &ClusterContext, rate: f64, seed: u64) -> Result<DistVec<T>>
    where
        T: Clone + SizeOf,
    {
        if !(0.0..=1.0).contains(&rate) {
            return Err(super::ClusterError::Invalid(format!("sample rate {rate}")));
        }
        let parts = par_over_parts(ctx, &self.parts, |p, part| {
            if rate >= 1.0 {
                return Ok(part.to_vec());
            }
            let mut rng = partition_rng(seed, p);
            Ok(part.iter().filter(|_| rng.bool(rate)).cloned().collect())
        })?;
        let charges = charge_parts(ctx, &parts)?;
        Ok(DistVec { parts, charges, meters: Some(ctx.worker_mem.clone()) })
    }

    /// Tree-aggregate: per-partition fold, then driver-side combine of the
    /// (constant-size) partials — how the distributed min/max of Step 2 is
    /// obtained.
    pub fn aggregate<A, F, G>(&self, ctx: &ClusterContext, init: A, seq: F, comb: G) -> Result<A>
    where
        A: Clone + Send + Sync + SizeOf,
        F: Fn(A, &T) -> A + Sync,
        G: Fn(A, A) -> A + Sync,
    {
        let partials = par_over_parts(ctx, &self.parts, |_, part| {
            let mut acc = init.clone();
            for x in part {
                acc = seq(acc, x);
            }
            Ok(vec![acc])
        })?;
        // partials cross the network to the driver, which must hold them
        // while combining (transient driver allocation, budget-checked)
        let bytes: usize = partials.iter().flat_map(|v| v.iter().map(SizeOf::size_of)).sum();
        ctx.ledger.add(bytes, partials.len());
        ctx.ledger.add_round();
        ctx.charge_driver(bytes)?;
        let mut acc = init;
        for v in partials {
            for a in v {
                acc = comb(acc, a);
            }
        }
        ctx.driver_mem.release(bytes);
        Ok(acc)
    }

    /// Partition-level tree-aggregate: `f` maps each partition to a
    /// constant-size partial in a single partition visit; partials owned
    /// by the same worker are combined **worker-side** (a map-side /
    /// tree combine — those merges never cross the network), and only one
    /// partial per worker ships to the driver, in one shuffle round.
    ///
    /// This is the reduction the fused multi-chain executors use: the
    /// `[M][L][r][w]` count block crosses the network `num_workers` times
    /// total, charged once — versus one `aggregate` round *per chain*
    /// (M rounds, `num_partitions` blocks each) on the per-chain path.
    pub fn tree_aggregate<A, F, G>(&self, ctx: &ClusterContext, init: A, f: F, comb: G) -> Result<A>
    where
        A: Send + Sync + SizeOf,
        F: Fn(usize, &[T]) -> Result<A> + Sync,
        G: Fn(A, A) -> A + Sync,
    {
        // 1) one partition visit → one constant-size partial per partition
        let partials = par_over_parts(ctx, &self.parts, |p, part| Ok(vec![f(p, part)?]))?;
        // partials live on their owner workers until combined+shipped:
        // charge them like any other operator output (budget-checked, so
        // the simulated worker OOM can trip on oversized fused blocks)
        let mut charges: Vec<(usize, usize)> = Vec::with_capacity(partials.len());
        let mut charge_err = None;
        for (p, v) in partials.iter().enumerate() {
            let worker = ctx.owner(p);
            let bytes = v[0].size_of();
            match ctx.charge_worker(worker, bytes) {
                Ok(()) => charges.push((worker, bytes)),
                Err(e) => {
                    charge_err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = charge_err {
            for &(worker, bytes) in &charges {
                ctx.worker_mem[worker].release(bytes);
            }
            return Err(e);
        }
        // 2) worker-side combine: merge each worker's own partials locally
        let mut by_worker: Vec<Option<A>> = (0..ctx.cfg.num_workers).map(|_| None).collect();
        for (p, mut v) in partials.into_iter().enumerate() {
            let a = v.pop().expect("one partial per partition");
            let slot = &mut by_worker[ctx.owner(p)];
            *slot = Some(match slot.take() {
                None => a,
                Some(prev) => comb(prev, a),
            });
        }
        // 3) one round: ≤ num_workers partials cross to the driver
        let worker_partials: Vec<A> = by_worker.into_iter().flatten().collect();
        let bytes: usize = worker_partials.iter().map(SizeOf::size_of).sum();
        ctx.ledger.add(bytes, worker_partials.len());
        ctx.ledger.add_round();
        let driver_charge = ctx.charge_driver(bytes);
        for &(worker, b) in &charges {
            ctx.worker_mem[worker].release(b);
        }
        driver_charge?;
        let mut acc = init;
        for a in worker_partials {
            acc = comb(acc, a);
        }
        ctx.driver_mem.release(bytes);
        Ok(acc)
    }

    /// Gather everything to the driver (Spark `collect`). Charges driver
    /// memory; the returned Vec is in partition order.
    pub fn collect(&self, ctx: &ClusterContext) -> Result<Vec<T>>
    where
        T: Clone + SizeOf,
    {
        let bytes: usize = self.parts.iter().map(SizeOf::size_of).sum();
        ctx.ledger.add(bytes, self.len());
        ctx.ledger.add_round();
        ctx.charge_driver(bytes)?;
        let mut out = Vec::with_capacity(self.len());
        for part in &self.parts {
            out.extend(part.iter().cloned());
        }
        // driver copy is transient for callers; keep it charged only while
        // building, then release (callers own the Vec outside accounting).
        ctx.driver_mem.release(bytes);
        Ok(out)
    }

    /// Zip two identically-partitioned DistVecs element-wise — used to sum
    /// per-chain score vectors without a driver round-trip (Alg. 3 line 6).
    pub fn zip_map<U, V, F>(
        &self,
        ctx: &ClusterContext,
        other: &DistVec<U>,
        f: F,
    ) -> Result<DistVec<V>>
    where
        U: Send + Sync,
        V: SizeOf + Send + Sync,
        F: Fn(&T, &U) -> V + Sync,
    {
        if self.parts.len() != other.parts.len()
            || self
                .parts
                .iter()
                .zip(&other.parts)
                .any(|(a, b)| a.len() != b.len())
        {
            return Err(super::ClusterError::Invalid("zip_map: partitioning mismatch".into()));
        }
        let parts = pool::try_run_indexed(
            ctx.cfg.num_workers.min(self.parts.len()).max(1),
            self.parts.len(),
            |p| {
                ctx.check_deadline()?;
                let t0 = pool::thread_cpu_nanos();
                let out = self.parts[p]
                    .iter()
                    .zip(&other.parts[p])
                    .map(|(a, b)| f(a, b))
                    .collect::<Vec<V>>();
                ctx.record_busy(ctx.owner(p), pool::thread_cpu_nanos() - t0);
                Ok(out)
            },
        )?;
        let charges = charge_parts(ctx, &parts)?;
        Ok(DistVec { parts, charges, meters: Some(ctx.worker_mem.clone()) })
    }
}

/// The per-(seed, partition) RNG stream [`DistVec::sample`] draws from.
/// Shared with the fused fit executor (`sparx::plan`), which replays the
/// same Bernoulli masks inside a single partition visit — both callers
/// must derive identical streams for fused/per-chain model parity.
pub(crate) fn partition_rng(seed: u64, p: usize) -> Rng {
    Rng::new(seed ^ (p as u64).wrapping_mul(0x9E3779B97F4A7C15))
}

fn key_hash<K: Hash>(k: &K) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    k.hash(&mut h);
    h.finish()
}

impl<K, V> DistVec<(K, V)>
where
    K: Hash + Eq + Clone + Send + Sync + SizeOf,
    V: Clone + Send + Sync + SizeOf,
{
    /// Spark `reduceByKey`: map-side combine, hash shuffle, reduce-side
    /// merge. Cross-worker bytes/records are accounted to the ledger.
    pub fn reduce_by_key<F>(&self, ctx: &ClusterContext, combine: F) -> Result<DistVec<(K, V)>>
    where
        F: Fn(V, V) -> V + Sync,
    {
        let p = self.parts.len();
        // 1) map-side combine + bucket by target reducer
        let bucketed: Vec<Vec<HashMap<K, V>>> = par_over_parts(ctx, &self.parts, |_, part| {
            let mut local: HashMap<K, V> = HashMap::new();
            for (k, v) in part {
                match local.remove(k) {
                    Some(prev) => {
                        let merged = combine(prev, v.clone());
                        local.insert(k.clone(), merged);
                    }
                    None => {
                        local.insert(k.clone(), v.clone());
                    }
                }
            }
            let mut buckets: Vec<HashMap<K, V>> = (0..p).map(|_| HashMap::new()).collect();
            for (k, v) in local {
                let tgt = (key_hash(&k) % p as u64) as usize;
                buckets[tgt].insert(k, v);
            }
            Ok(vec![buckets])
        })?
        .into_iter()
        .map(|mut v| v.pop().expect("one bucket set per partition"))
        .collect();

        // 2) shuffle accounting: entries moving to a different worker
        let mut moved_bytes = 0usize;
        let mut moved_records = 0usize;
        for (src, buckets) in bucketed.iter().enumerate() {
            let src_w = ctx.owner(src);
            for (tgt, bucket) in buckets.iter().enumerate() {
                if ctx.owner(tgt) != src_w {
                    moved_bytes += bucket
                        .iter()
                        .map(|(k, v)| k.size_of() + v.size_of())
                        .sum::<usize>();
                    moved_records += bucket.len();
                }
            }
        }
        ctx.ledger.add(moved_bytes, moved_records);
        ctx.ledger.add_round();
        ctx.check_deadline()?;

        // 3) reduce-side merge, one output partition per reducer
        let mut merged: Vec<HashMap<K, V>> = (0..p).map(|_| HashMap::new()).collect();
        for buckets in bucketed {
            for (tgt, bucket) in buckets.into_iter().enumerate() {
                let m = &mut merged[tgt];
                for (k, v) in bucket {
                    match m.remove(&k) {
                        Some(prev) => {
                            let c = combine(prev, v);
                            m.insert(k, c);
                        }
                        None => {
                            m.insert(k, v);
                        }
                    }
                }
            }
        }
        let parts: Vec<Vec<(K, V)>> =
            merged.into_iter().map(|m| m.into_iter().collect()).collect();
        let charges = charge_parts(ctx, &parts)?;
        Ok(DistVec { parts, charges, meters: Some(ctx.worker_mem.clone()) })
    }

    /// Spark `collectAsMap`: gather (K,V) pairs into a driver-side map.
    pub fn collect_as_map(&self, ctx: &ClusterContext) -> Result<HashMap<K, V>> {
        let bytes: usize = self
            .parts
            .iter()
            .flat_map(|p| p.iter().map(|(k, v)| k.size_of() + v.size_of()))
            .sum();
        ctx.ledger.add(bytes, self.len());
        ctx.ledger.add_round();
        ctx.charge_driver(bytes)?;
        let mut out = HashMap::with_capacity(self.len());
        for part in &self.parts {
            for (k, v) in part {
                out.insert(k.clone(), v.clone());
            }
        }
        ctx.driver_mem.release(bytes);
        Ok(out)
    }
}

/// A driver-to-all-workers broadcast variable (Spark `sc.broadcast`).
/// Charged once per worker (sent once, cached), released on drop.
pub struct Broadcast<B> {
    value: Arc<B>,
    bytes: usize,
    meters: Arc<Vec<MemoryMeter>>,
}

impl<B: SizeOf> Broadcast<B> {
    pub fn new(ctx: &ClusterContext, value: B) -> Result<Self> {
        let bytes = value.size_of();
        for w in 0..ctx.cfg.num_workers {
            ctx.charge_worker(w, bytes)?;
        }
        ctx.ledger.add(bytes * ctx.cfg.num_workers, ctx.cfg.num_workers);
        ctx.ledger.add_round();
        Ok(Broadcast { value: Arc::new(value), bytes, meters: ctx.worker_mem.clone() })
    }
}

impl<B> Broadcast<B> {
    pub fn value(&self) -> &B {
        &self.value
    }
}

impl<B> Drop for Broadcast<B> {
    fn drop(&mut self) {
        for m in self.meters.iter() {
            m.release(self.bytes);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn ctx() -> ClusterContext {
        ClusterConfig { num_partitions: 4, num_workers: 2, ..Default::default() }.build()
    }

    #[test]
    fn from_vec_partitions_evenly() {
        let c = ctx();
        let dv = DistVec::from_vec(&c, (0..10u32).collect()).unwrap();
        assert_eq!(dv.num_parts(), 4);
        assert_eq!(dv.len(), 10);
        let sizes: Vec<usize> = (0..4).map(|p| dv.part(p).len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn map_preserves_order_within_partitions() {
        let c = ctx();
        let dv = DistVec::from_vec(&c, (0..100u32).collect()).unwrap();
        let doubled = dv.map(&c, |x| x * 2).unwrap();
        assert_eq!(doubled.collect(&c).unwrap(), (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn flat_map_and_filter() {
        let c = ctx();
        let dv = DistVec::from_vec(&c, vec![1u32, 2, 3]).unwrap();
        let fm = dv.flat_map(&c, |&x| vec![x; x as usize]).unwrap();
        assert_eq!(fm.len(), 6);
        let f = fm.filter(&c, |&x| x > 1).unwrap();
        assert_eq!(f.len(), 5);
    }

    #[test]
    fn sample_rate_roughly_holds() {
        let c = ctx();
        let dv = DistVec::from_vec(&c, (0..10_000u32).collect()).unwrap();
        let s = dv.sample(&c, 0.1, 7).unwrap();
        assert!((800..1200).contains(&s.len()), "{}", s.len());
        // deterministic
        let s2 = dv.sample(&c, 0.1, 7).unwrap();
        assert_eq!(s.collect(&c).unwrap(), s2.collect(&c).unwrap());
    }

    #[test]
    fn reduce_by_key_sums() {
        let c = ctx();
        let pairs: Vec<(u32, u64)> = (0..1000).map(|i| (i % 7, 1u64)).collect();
        let dv = DistVec::from_vec(&c, pairs).unwrap();
        let red = dv.reduce_by_key(&c, |a, b| a + b).unwrap();
        let m = red.collect_as_map(&c).unwrap();
        assert_eq!(m.len(), 7);
        let total: u64 = m.values().sum();
        assert_eq!(total, 1000);
        for (k, v) in m {
            assert_eq!(v, if k < 1000 % 7 { 143 } else { 142 }, "key {k}");
        }
    }

    #[test]
    fn reduce_by_key_counts_shuffle() {
        let c = ctx();
        let pairs: Vec<(u32, u64)> = (0..1000).map(|i| (i, 1u64)).collect();
        let dv = DistVec::from_vec(&c, pairs).unwrap();
        let before = c.ledger.bytes();
        let _ = dv.reduce_by_key(&c, |a, b| a + b).unwrap();
        assert!(c.ledger.bytes() > before, "shuffle not accounted");
        assert!(c.ledger.rounds() >= 1);
    }

    #[test]
    fn aggregate_min_max() {
        let c = ctx();
        let dv = DistVec::from_vec(&c, vec![5.0f64, -2.0, 9.0, 3.5]).unwrap();
        let (lo, hi) = dv
            .aggregate(
                &c,
                (f64::INFINITY, f64::NEG_INFINITY),
                |(lo, hi), &x| (lo.min(x), hi.max(x)),
                |(a, b), (c2, d)| (a.min(c2), b.max(d)),
            )
            .unwrap();
        assert_eq!((lo, hi), (-2.0, 9.0));
    }

    #[test]
    fn tree_aggregate_sums_in_one_round_per_worker_partials() {
        let c = ctx(); // 4 partitions, 2 workers
        let dv = DistVec::from_vec(&c, (0..100u64).collect()).unwrap();
        let (b0, r0, rounds0) = c.ledger.snapshot();
        let mem0: Vec<usize> = c.worker_mem.iter().map(|m| m.current()).collect();
        let sum = dv
            .tree_aggregate(
                &c,
                0u64,
                |_, part| Ok(part.iter().sum::<u64>()),
                |a, b| a + b,
            )
            .unwrap();
        assert_eq!(sum, 4950);
        let (b1, r1, rounds1) = c.ledger.snapshot();
        assert_eq!(rounds1 - rounds0, 1, "exactly one shuffle round");
        assert_eq!(r1 - r0, 2, "one partial per worker, not per partition");
        assert_eq!(b1 - b0, 16, "two u64 partials cross the network");
        let mem1: Vec<usize> = c.worker_mem.iter().map(|m| m.current()).collect();
        assert_eq!(mem0, mem1, "transient partial charges must be released");
        // the partials were charged while alive: each worker's peak covers
        // its two 8-byte partition partials on top of its data
        for (w, m) in c.worker_mem.iter().enumerate() {
            assert!(m.peak() >= mem0[w] + 16, "worker {w} partials not metered");
        }
    }

    #[test]
    fn tree_aggregate_partials_respect_worker_budget() {
        let c = ClusterConfig {
            num_partitions: 4,
            num_workers: 2,
            worker_mem_bytes: 2000,
            ..Default::default()
        }
        .build();
        let dv = DistVec::from_vec(&c, vec![0u8; 100]).unwrap();
        let before: Vec<usize> = c.worker_mem.iter().map(|m| m.current()).collect();
        // each partition emits a partial far over the worker budget
        let r = dv.tree_aggregate(
            &c,
            vec![0u64; 0],
            |_, _| Ok(vec![0u64; 1000]),
            |a, _| a,
        );
        assert!(matches!(r, Err(crate::cluster::ClusterError::MemExceeded { .. })));
        let after: Vec<usize> = c.worker_mem.iter().map(|m| m.current()).collect();
        assert_eq!(before, after, "failed partial charges must roll back");
    }

    #[test]
    fn tree_aggregate_matches_aggregate() {
        let c = ctx();
        let dv = DistVec::from_vec(&c, (1..=37u64).collect()).unwrap();
        let a = dv
            .aggregate(&c, 0u64, |acc, &x| acc.max(x), |a, b| a.max(b))
            .unwrap();
        let t = dv
            .tree_aggregate(
                &c,
                0u64,
                |_, part| Ok(part.iter().copied().max().unwrap_or(0)),
                |a, b| a.max(b),
            )
            .unwrap();
        assert_eq!(a, t);
    }

    #[test]
    fn memory_charged_and_released() {
        let c = ctx();
        let before: usize = c.worker_mem.iter().map(|m| m.current()).sum();
        {
            let dv = DistVec::from_vec(&c, vec![0u64; 1000]).unwrap();
            let during: usize = c.worker_mem.iter().map(|m| m.current()).sum();
            assert!(during >= before + 8000);
            drop(dv);
        }
        let after: usize = c.worker_mem.iter().map(|m| m.current()).sum();
        assert_eq!(after, before);
    }

    #[test]
    fn worker_budget_enforced() {
        let c = ClusterConfig {
            num_partitions: 2,
            num_workers: 2,
            worker_mem_bytes: 1000,
            ..Default::default()
        }
        .build();
        let r = DistVec::from_vec(&c, vec![0u64; 10_000]);
        assert!(matches!(r, Err(crate::cluster::ClusterError::MemExceeded { .. })));
    }

    #[test]
    fn zip_map_adds() {
        let c = ctx();
        let a = DistVec::from_vec(&c, vec![1.0f64; 10]).unwrap();
        let b = DistVec::from_vec(&c, vec![2.0f64; 10]).unwrap();
        let s = a.zip_map(&c, &b, |x, y| x + y).unwrap();
        assert_eq!(s.collect(&c).unwrap(), vec![3.0; 10]);
    }

    #[test]
    fn broadcast_charges_every_worker() {
        let c = ctx();
        let cur0: Vec<usize> = c.worker_mem.iter().map(|m| m.current()).collect();
        let b = Broadcast::new(&c, vec![0u8; 500]).unwrap();
        for (w, m) in c.worker_mem.iter().enumerate() {
            assert!(m.current() >= cur0[w] + 500, "worker {w} not charged");
        }
        drop(b);
        let cur1: Vec<usize> = c.worker_mem.iter().map(|m| m.current()).collect();
        assert_eq!(cur0, cur1);
    }

    #[test]
    fn map_partitions_sees_only_own_partition() {
        let c = ctx();
        let dv = DistVec::from_vec(&c, (0..20u32).collect()).unwrap();
        let sums = dv
            .map_partitions(&c, |_, part| Ok(vec![part.iter().sum::<u32>()]))
            .unwrap();
        assert_eq!(sums.len(), 4);
        assert_eq!(sums.collect(&c).unwrap().iter().sum::<u32>(), (0..20).sum());
    }
}
