//! Repo-invariant linter (see [`sparx::lint`]): scans `src/` for
//! violations of the no-panic / unsafe-whitelist / error-taxonomy /
//! CMS-encapsulation rules and exits non-zero when any are found.
//!
//! ```text
//! cargo run --bin sparx_lint            # human output, exit 1 on findings
//! cargo run --bin sparx_lint -- --json  # machine output (CI step summary)
//! sparx_lint --root path/to/src         # lint another tree (self-tests)
//! ```
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/IO error.

use std::process::ExitCode;

const USAGE: &str = "usage: sparx_lint [--json] [--root <src-dir>]";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut json = false;
    let mut root: Option<String> = None;
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--root" => match it.next() {
                Some(r) => root = Some(r.clone()),
                None => {
                    eprintln!("sparx_lint: --root needs a path\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("{USAGE}\n\nrules:");
                for rule in sparx::lint::rules() {
                    println!("  {:<20} {}", rule.name, rule.description);
                }
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("sparx_lint: unknown argument `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    // default: this crate's own src/ (compiled in, so the binary works
    // from any cwd — CI runs it from the workspace root)
    let root = root.unwrap_or_else(|| concat!(env!("CARGO_MANIFEST_DIR"), "/src").to_string());
    let findings = match sparx::lint::run_dir(std::path::Path::new(&root)) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("sparx_lint: {e}");
            return ExitCode::from(2);
        }
    };
    if json {
        println!("{}", sparx::lint::to_json(&findings));
    } else if findings.is_empty() {
        println!("sparx_lint: clean ({} rules over {root})", sparx::lint::rules().len());
    } else {
        for f in &findings {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
        }
        println!("sparx_lint: {} finding(s)", findings.len());
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
