//! `sparx-lint`: repo-invariant lints the compiler can't express.
//!
//! A zero-dependency source scanner (run as `cargo run --bin
//! sparx_lint`, blocking in CI) enforcing four rules over `src/`:
//!
//! * **no-panic-paths** — the load/serve/decode files must not contain
//!   `unwrap`/`expect`/`panic!`-family macros or slice indexing that can
//!   panic; corrupt input and shard failure surface as typed
//!   [`SparxError`](crate::api::SparxError)s, never a crash.
//! * **unsafe-whitelist** — `unsafe` only in the two kernel modules
//!   (`sparx/chain.rs`, `cluster/pool.rs`), each site preceded by a
//!   `// SAFETY:` comment (or a `# Safety` doc section).
//! * **error-taxonomy** — plain-`pub` functions must not leak
//!   `std::io::Error`/`io::Result` or the internal `CodecResult`; the
//!   crate's fallible surface is `SparxError`.
//! * **cms-encapsulation** — raw `CountMinSketch` counter access
//!   (`counts_u32`) stays inside `sparx/cms.rs` and the artifact codec;
//!   everything else goes through insert/query so the quantized-counter
//!   invariants hold.
//!
//! Rules match on *masked* source (comments, strings and `#[cfg(test)]
//! mod` bodies blanked by [`scanner`]), so test code and literals never
//! trip them. A deliberate exception is escaped inline with
//! `// lint:allow(rule-name)` on the offending line or the line above —
//! each escape is a reviewed invariant, not a suppression dump.
//!
//! Adding a rule: write a `fn(&SourceFile, &mut Vec<Finding>)`, add a
//! `Rule` entry to [`rules`], and seed a violation in
//! `rust/tests/lint.rs` so the self-test proves the rule fires.

mod scanner;

use std::path::Path;

/// Files where panicking constructs are forbidden (the load/serve/decode
/// paths; `main.rs` is the CLI binary root). An entry ending in `/`
/// covers every file under that directory — the TCP serving plane is
/// scoped as a whole, so new `serve/` modules are born under the rule.
const NO_PANIC_PATHS: &[&str] = &[
    "api/artifact.rs",
    "api/registry.rs",
    "api/spec.rs",
    "util/codec.rs",
    "sparx/checkpoint.rs",
    "sparx/decay.rs",
    "sparx/sharded.rs",
    "serve/",
    "ensemble/",
    "main.rs",
];

/// Whether `rel` falls under a path list that may mix exact file paths
/// and `dir/` prefixes.
fn in_scope(paths: &[&str], rel: &str) -> bool {
    paths.iter().any(|p| if p.ends_with('/') { rel.starts_with(p) } else { rel == *p })
}

/// The only modules allowed to contain `unsafe` (the AVX2 binning kernel
/// and the pool's direct `clock_gettime` call).
const UNSAFE_WHITELIST: &[&str] = &["sparx/chain.rs", "cluster/pool.rs"];

/// Files allowed to touch raw CMS counters: the sketch itself and the
/// artifact codec that serializes it.
const CMS_COUNTER_ALLOW: &[&str] = &["sparx/cms.rs", "api/artifact.rs"];

/// Files exempt from the error-taxonomy rule: the codec layer *defines*
/// `CodecResult`, and the error module defines the `From<io::Error>`
/// mapping.
const TAXONOMY_EXEMPT: &[&str] = &["util/codec.rs", "api/error.rs"];

/// Panic-capable tokens matched verbatim on masked source. `.unwrap_or*`
/// and `.expect_err` do not match (different token tails).
const PANIC_TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

/// Keywords that legitimately precede `[` (array literals, `for … in
/// […]`), excluded from the indexing heuristic.
const INDEX_KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut", "pub", "ref",
    "return", "static", "struct", "unsafe", "use", "where", "while", "yield",
];

/// One lint violation: rule, file (relative to `src/`), 1-based line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

/// A registered lint rule.
pub struct Rule {
    pub name: &'static str,
    pub description: &'static str,
    check: fn(&SourceFile, &mut Vec<Finding>),
}

/// The rule registry, in reporting order.
pub fn rules() -> &'static [Rule] {
    &[
        Rule {
            name: "no-panic-paths",
            description: "load/serve/decode paths must not unwrap/expect/panic or index slices",
            check: check_no_panic_paths,
        },
        Rule {
            name: "unsafe-whitelist",
            description: "unsafe only in sparx/chain.rs + cluster/pool.rs, with // SAFETY:",
            check: check_unsafe_whitelist,
        },
        Rule {
            name: "error-taxonomy",
            description: "pub fns return SparxError-based results, no io::Error/CodecResult leaks",
            check: check_error_taxonomy,
        },
        Rule {
            name: "cms-encapsulation",
            description: "raw CMS counter access only in sparx/cms.rs and the artifact codec",
            check: check_cms_encapsulation,
        },
    ]
}

/// One source file prepared for rule matching.
pub struct SourceFile {
    /// Path relative to the scanned root, `/`-separated.
    pub rel: String,
    /// Unmodified source (SAFETY-comment checks and escape comments read
    /// this — comments are invisible on the masked text).
    pub raw: String,
    /// Comments, literals and test-mod bodies blanked; same offsets.
    masked: String,
}

impl SourceFile {
    pub fn new(rel: &str, raw: &str) -> SourceFile {
        let masked = scanner::strip_test_mods(&scanner::mask(raw));
        SourceFile { rel: rel.to_string(), raw: raw.to_string(), masked }
    }

    fn line_of(&self, offset: usize) -> usize {
        self.masked.as_bytes().iter().take(offset).filter(|&&c| c == b'\n').count() + 1
    }

    fn raw_line(&self, line: usize) -> &str {
        self.raw.lines().nth(line.saturating_sub(1)).unwrap_or("")
    }
}

/// Lint one file's source text with every registered rule, honouring
/// `// lint:allow(rule)` escapes. `rel` is the path relative to `src/`.
pub fn check_source(rel: &str, raw: &str) -> Vec<Finding> {
    let sf = SourceFile::new(rel, raw);
    let mut findings = Vec::new();
    for rule in rules() {
        (rule.check)(&sf, &mut findings);
    }
    findings.retain(|f| !escaped(&sf, f));
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

fn escaped(sf: &SourceFile, f: &Finding) -> bool {
    let marker = format!("lint:allow({})", f.rule);
    sf.raw_line(f.line).contains(&marker)
        || (f.line > 1 && sf.raw_line(f.line - 1).contains(&marker))
}

/// Lint every `.rs` file under `root` (normally the crate's `src/`).
/// Deterministic: files are visited in sorted order.
pub fn run_dir(root: &Path) -> Result<Vec<Finding>, String> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in files {
        let raw = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        findings.extend(check_source(&rel, &raw));
    }
    Ok(findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("{}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

// ------------------------------------------------------------- rules

fn check_no_panic_paths(sf: &SourceFile, out: &mut Vec<Finding>) {
    if !in_scope(NO_PANIC_PATHS, &sf.rel) {
        return;
    }
    for token in PANIC_TOKENS {
        for (at, _) in sf.masked.match_indices(token) {
            out.push(Finding {
                rule: "no-panic-paths",
                file: sf.rel.clone(),
                line: sf.line_of(at),
                message: format!("`{token}` on a load/serve/decode path — return a typed error"),
            });
        }
    }
    let b = sf.masked.as_bytes();
    let is_ident = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    for (p, &c) in b.iter().enumerate() {
        if c != b'[' || p == 0 {
            continue;
        }
        let prev = b[p - 1];
        if !(is_ident(prev) || prev == b')' || prev == b']') {
            continue;
        }
        let mut s = p;
        while s > 0 && is_ident(b[s - 1]) {
            s -= 1;
        }
        let word = &sf.masked[s..p];
        if INDEX_KEYWORDS.contains(&word) {
            continue;
        }
        out.push(Finding {
            rule: "no-panic-paths",
            file: sf.rel.clone(),
            line: sf.line_of(p),
            message: format!(
                "slice/array indexing can panic on a load/serve/decode path \
                 (`{}[`) — use .get()/.get_mut()",
                if word.is_empty() { "…" } else { word }
            ),
        });
    }
}

fn check_unsafe_whitelist(sf: &SourceFile, out: &mut Vec<Finding>) {
    let b = sf.masked.as_bytes();
    let is_ident = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    for (at, token) in sf.masked.match_indices("unsafe") {
        // word boundaries: skip `unsafe_code`, `unused_unsafe`, …
        if at > 0 && is_ident(b[at - 1]) {
            continue;
        }
        let end = at + token.len();
        if end < b.len() && is_ident(b[end]) {
            continue;
        }
        let line = sf.line_of(at);
        if !UNSAFE_WHITELIST.contains(&sf.rel.as_str()) {
            out.push(Finding {
                rule: "unsafe-whitelist",
                file: sf.rel.clone(),
                line,
                message: "`unsafe` outside the whitelisted kernel modules \
                          (sparx/chain.rs, cluster/pool.rs)"
                    .to_string(),
            });
            continue;
        }
        if !has_safety_comment(sf, line) {
            out.push(Finding {
                rule: "unsafe-whitelist",
                file: sf.rel.clone(),
                line,
                message: "`unsafe` without a preceding `// SAFETY:` comment \
                          (or `# Safety` doc section)"
                    .to_string(),
            });
        }
    }
}

/// Scan upward from the `unsafe` site over contiguous comment / attribute
/// / blank lines, looking for a SAFETY marker.
fn has_safety_comment(sf: &SourceFile, line: usize) -> bool {
    let mentions_safety = |l: &str| l.contains("SAFETY") || l.contains("Safety");
    if mentions_safety(sf.raw_line(line)) {
        return true;
    }
    let mut cur = line;
    while cur > 1 {
        cur -= 1;
        let t = sf.raw_line(cur).trim_start();
        let is_context = t.is_empty()
            || t.starts_with("//")
            || t.starts_with("#[")
            || t.starts_with("#!")
            || t.starts_with("/*")
            || t.starts_with('*');
        if !is_context {
            return false;
        }
        if mentions_safety(t) {
            return true;
        }
    }
    false
}

fn check_error_taxonomy(sf: &SourceFile, out: &mut Vec<Finding>) {
    if TAXONOMY_EXEMPT.contains(&sf.rel.as_str()) {
        return;
    }
    for (at, _) in sf.masked.match_indices("pub fn ") {
        let sig_end = sf.masked[at..]
            .find(|c| c == '{' || c == ';')
            .map_or(sf.masked.len(), |rel| at + rel);
        let sig = &sf.masked[at..sig_end];
        for leak in ["io::Error", "io::Result", "CodecResult"] {
            if sig.contains(leak) {
                out.push(Finding {
                    rule: "error-taxonomy",
                    file: sf.rel.clone(),
                    line: sf.line_of(at),
                    message: format!(
                        "public fn signature leaks `{leak}` — the crate's fallible surface \
                         is `SparxError` (api::Result)"
                    ),
                });
            }
        }
    }
}

fn check_cms_encapsulation(sf: &SourceFile, out: &mut Vec<Finding>) {
    if CMS_COUNTER_ALLOW.contains(&sf.rel.as_str()) {
        return;
    }
    for (at, _) in sf.masked.match_indices("counts_u32(") {
        out.push(Finding {
            rule: "cms-encapsulation",
            file: sf.rel.clone(),
            line: sf.line_of(at),
            message: "raw CountMinSketch counter access outside sparx/cms.rs — go through \
                      insert/query so the quantized-counter invariants hold"
                .to_string(),
        });
    }
}

// -------------------------------------------------------------- output

/// Serialize findings as JSON (hand-rolled — the crate is
/// dependency-free): `{"count":N,"findings":[{rule,file,line,message}]}`.
pub fn to_json(findings: &[Finding]) -> String {
    let mut s = String::from("{\"count\":");
    s.push_str(&findings.len().to_string());
    s.push_str(",\"findings\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("{\"rule\":\"");
        s.push_str(&json_escape(f.rule));
        s.push_str("\",\"file\":\"");
        s.push_str(&json_escape(&f.file));
        s.push_str("\",\"line\":");
        s.push_str(&f.line.to_string());
        s.push_str(",\"message\":\"");
        s.push_str(&json_escape(&f.message));
        s.push_str("\"}");
    }
    s.push_str("]}");
    s
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_snippet_passes() {
        let src = "pub fn ok(v: &[u8]) -> Option<u8> { v.first().copied() }\n";
        assert!(check_source("api/artifact.rs", src).is_empty());
    }

    #[test]
    fn unwrap_flagged_only_in_scope() {
        let src = "fn f(v: Option<u8>) -> u8 { v.unwrap() }\n";
        assert_eq!(check_source("util/codec.rs", src).len(), 1);
        assert!(check_source("metrics/mod.rs", src).is_empty());
    }

    #[test]
    fn indexing_heuristic() {
        let hit = "fn f(v: &[u8]) -> u8 { v[0] }\n";
        let findings = check_source("sparx/sharded.rs", hit);
        assert_eq!(findings.len(), 1, "{findings:?}");
        // keywords, macros, array types and literals don't trip it
        let clean = "fn g() { let v = vec![0u8; 4]; for _x in [1, 2] {} \
                     let _t: [u8; 2] = [0, 0]; }\n";
        assert!(check_source("sparx/sharded.rs", clean).is_empty());
    }

    #[test]
    fn serve_directory_is_in_panic_scope() {
        let src = "fn f(v: Option<u8>) -> u8 { v.unwrap() }\n";
        assert_eq!(check_source("serve/wire.rs", src).len(), 1);
        assert_eq!(check_source("serve/conn.rs", src).len(), 1);
        // a sibling named like the directory is not swept in
        assert!(check_source("server.rs", src).is_empty());
    }

    #[test]
    fn escape_comment_honoured() {
        let src =
            "fn f(v: Option<u8>) -> u8 {\n    // lint:allow(no-panic-paths)\n    v.unwrap()\n}\n";
        assert!(check_source("main.rs", src).is_empty());
    }

    #[test]
    fn unsafe_rules() {
        let bare = "fn f() { unsafe { std::hint::unreachable_unchecked() } }\n";
        let out = check_source("sparx/plan.rs", bare);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "unsafe-whitelist");
        // whitelisted module still needs the SAFETY comment
        let out = check_source("sparx/chain.rs", bare);
        assert_eq!(out.len(), 1, "{out:?}");
        let commented = "fn f() {\n    // SAFETY: provably unreachable\n    \
                         unsafe { std::hint::unreachable_unchecked() }\n}\n";
        assert!(check_source("sparx/chain.rs", commented).is_empty());
    }

    #[test]
    fn taxonomy_and_cms() {
        let leak = "pub fn save(p: &str) -> std::io::Result<()> \
                    { std::fs::write(p, b\"\").map(|_| ()) }\n";
        let out = check_source("data/loader.rs", leak);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "error-taxonomy");
        let poke = "fn f(c: &CountMinSketch) -> Vec<u32> { c.counts_u32() }\n";
        let out = check_source("sparx/plan.rs", poke);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "cms-encapsulation");
    }

    #[test]
    fn test_mods_are_exempt() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    \
                   fn t() { Some(1).unwrap(); }\n}\n";
        assert!(check_source("util/codec.rs", src).is_empty());
    }

    #[test]
    fn json_shape() {
        let f = vec![Finding {
            rule: "no-panic-paths",
            file: "a.rs".into(),
            line: 3,
            message: "x \"y\"".into(),
        }];
        let j = to_json(&f);
        assert!(j.starts_with("{\"count\":1,"));
        assert!(j.contains("\\\"y\\\""));
        assert!(to_json(&[]).contains("\"count\":0"));
    }
}
