//! Source masking for [`super`]: a small, zero-dependency lexer that
//! blanks out the regions lint rules must never match inside — comments,
//! string/char literals, and `#[cfg(test)] mod` bodies — while
//! preserving byte offsets and line structure exactly (every masked byte
//! becomes a space; newlines survive). Rules then pattern-match on the
//! masked text and report line numbers that are valid for the raw file.

/// Blank comments and string/char literals. The output has the same
/// length and the same newline positions as the input.
pub(super) fn mask(raw: &str) -> String {
    let b = raw.as_bytes();
    let n = b.len();
    let mut out: Vec<u8> = Vec::with_capacity(n);
    let mut i = 0;
    let blank = |out: &mut Vec<u8>, byte: u8| {
        out.push(if byte == b'\n' { b'\n' } else { b' ' });
    };
    while i < n {
        let c = b[i];
        // line comment (also covers /// and //! doc comments)
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            while i < n && b[i] != b'\n' {
                out.push(b' ');
                i += 1;
            }
            continue;
        }
        // block comment (nested, per Rust)
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            out.push(b' ');
            out.push(b' ');
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // raw / byte string: optional `b`, optional `r` + hashes, then `"`
        if (c == b'b' || c == b'r') && !prev_is_ident(&out) {
            let mut j = i;
            if b[j] == b'b' {
                j += 1;
            }
            let mut is_raw = false;
            let mut hashes = 0usize;
            if j < n && b[j] == b'r' {
                is_raw = true;
                j += 1;
                while j < n && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
            }
            if j < n && b[j] == b'"' && (is_raw || j > i) {
                for _ in i..=j {
                    out.push(b' ');
                }
                i = j + 1;
                if is_raw {
                    // ends at `"` followed by the same number of `#`s
                    while i < n {
                        let tail = b.get(i + 1..).unwrap_or(&[]);
                        let closes = b[i] == b'"'
                            && tail.len() >= hashes
                            && tail.iter().take(hashes).all(|&h| h == b'#');
                        if closes {
                            for _ in 0..=hashes {
                                out.push(b' ');
                            }
                            i += 1 + hashes;
                            break;
                        }
                        blank(&mut out, b[i]);
                        i += 1;
                    }
                } else {
                    mask_plain_string(b, &mut i, &mut out);
                }
                continue;
            }
        }
        // plain string
        if c == b'"' {
            out.push(b' ');
            i += 1;
            mask_plain_string(b, &mut i, &mut out);
            continue;
        }
        // char literal vs lifetime: `'x'` / `'\n'` are literals, `'a` in
        // `&'a str` (no closing quote in reach) is a lifetime and is
        // copied through
        if c == b'\'' && i + 1 < n {
            if b[i + 1] == b'\\' {
                out.push(b' ');
                i += 1;
                while i < n && b[i] != b'\'' {
                    if b[i] == b'\\' && i + 1 < n {
                        out.push(b' ');
                        out.push(b' ');
                        i += 2;
                    } else {
                        blank(&mut out, b[i]);
                        i += 1;
                    }
                }
                if i < n {
                    out.push(b' ');
                    i += 1;
                }
                continue;
            }
            if i + 2 < n && b[i + 2] == b'\'' && b[i + 1] != b'\'' {
                out.push(b' ');
                out.push(b' ');
                out.push(b' ');
                i += 3;
                continue;
            }
        }
        out.push(c);
        i += 1;
    }
    // every code byte outside literals is ASCII-copied or blanked, so
    // this cannot fail; fall back to a lossy copy defensively
    String::from_utf8(out).unwrap_or_else(|e| String::from_utf8_lossy(e.as_bytes()).into_owned())
}

/// After the opening `"` has been consumed: blank up to and including
/// the closing quote, honouring backslash escapes.
fn mask_plain_string(b: &[u8], i: &mut usize, out: &mut Vec<u8>) {
    let n = b.len();
    while *i < n {
        if b[*i] == b'\\' && *i + 1 < n {
            out.push(b' ');
            out.push(b' ');
            *i += 2;
        } else if b[*i] == b'"' {
            out.push(b' ');
            *i += 1;
            return;
        } else {
            out.push(if b[*i] == b'\n' { b'\n' } else { b' ' });
            *i += 1;
        }
    }
}

fn prev_is_ident(out: &[u8]) -> bool {
    matches!(out.last(), Some(&c) if c == b'_' || c.is_ascii_alphanumeric())
}

/// Blank the bodies of `#[cfg(test)] mod …` items (on already-masked
/// text, so brace counting cannot be fooled by literals). Test-only code
/// is exempt from the production-path rules.
pub(super) fn strip_test_mods(masked: &str) -> String {
    const ATTR: &str = "#[cfg(test)]";
    let mut out = masked.as_bytes().to_vec();
    let mut from = 0usize;
    while let Some(rel) = masked.get(from..).and_then(|s| s.find(ATTR)) {
        let attr_at = from + rel;
        from = attr_at + ATTR.len();
        // skip whitespace and any further attributes to the next token
        let b = masked.as_bytes();
        let mut j = from;
        loop {
            while j < b.len() && b[j].is_ascii_whitespace() {
                j += 1;
            }
            if j < b.len() && b[j] == b'#' {
                match masked.get(j..).and_then(|s| s.find(']')) {
                    Some(close) => j += close + 1,
                    None => break,
                }
            } else {
                break;
            }
        }
        // only `mod` items get stripped; a cfg(test) on a use/fn is rare
        // and harmless to leave in place
        if !masked.get(j..).is_some_and(|s| s.starts_with("mod")) {
            continue;
        }
        let Some(open_rel) = masked.get(j..).and_then(|s| s.find('{')) else {
            continue;
        };
        let open = j + open_rel;
        let mut depth = 0usize;
        let mut end = None;
        for (p, &c) in b.iter().enumerate().skip(open) {
            if c == b'{' {
                depth += 1;
            } else if c == b'}' {
                depth -= 1;
                if depth == 0 {
                    end = Some(p);
                    break;
                }
            }
        }
        let Some(end) = end else { continue };
        for slot in out.iter_mut().take(end + 1).skip(attr_at) {
            if *slot != b'\n' {
                *slot = b' ';
            }
        }
        from = end + 1;
    }
    String::from_utf8(out).unwrap_or_else(|e| String::from_utf8_lossy(e.as_bytes()).into_owned())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_comments_and_strings() {
        let src = "let x = \"unsafe\"; // unsafe\nlet y = 1;\n";
        let m = mask(src);
        assert_eq!(m.len(), src.len());
        assert!(!m.contains("unsafe"));
        assert!(m.contains("let y = 1;"));
        assert_eq!(m.matches('\n').count(), 2);
    }

    #[test]
    fn masks_raw_and_byte_strings() {
        let src = r####"let r = r#"panic!( in raw"#; let b = b"unwrap()";"####;
        let m = mask(src);
        assert!(!m.contains("panic!("));
        assert!(!m.contains("unwrap"));
        assert!(m.contains("let b ="));
    }

    #[test]
    fn keeps_lifetimes_masks_chars() {
        let src = "fn f<'a>(x: &'a str) -> char { '[' }";
        let m = mask(src);
        assert!(m.contains("'a str"), "lifetime survives: {m}");
        assert!(!m.contains('['), "char literal masked: {m}");
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ let z = 2;";
        let m = mask(src);
        assert!(!m.contains("inner"));
        assert!(m.contains("let z = 2;"));
    }

    #[test]
    fn strips_test_mod_bodies() {
        let src = "fn live() { v[0]; }\n#[cfg(test)]\nmod tests {\n    \
                   fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let m = strip_test_mods(&mask(src));
        assert!(m.contains("v[0]"));
        assert!(!m.contains("unwrap"));
        assert!(m.contains("fn after"));
        assert_eq!(m.matches('\n').count(), src.matches('\n').count());
    }
}
