//! `sparx` — CLI launcher for the Sparx reproduction.
//!
//! Subcommands (hand-rolled parser — the offline build has no clap):
//!
//! ```text
//! sparx detect --dataset gisette|osm|spamurl [--config gen|mod|local]
//!              [--chains M] [--depth L] [--rate R] [--k K] [--scale S]
//!              [--backend native|pjrt] [--exec fused|per-chain]
//!              [--out scores.csv]
//! sparx experiment <table2|table3|table4|fig2|fig3|fig4|fig5|fig6|all>
//!              [--scale S] [--out EXPERIMENTS_RESULTS.md]
//! sparx stream   [--updates N] [--cache N]       # §3.5 evolving-stream demo
//! sparx generate --dataset osm --out points.csv  # dump a synthetic dataset
//! sparx info                                     # artifacts + presets
//! ```

use std::collections::HashMap;

use sparx::config::presets;
use sparx::data::generators::{GisetteGen, OsmGen, SpamUrlGen};
use sparx::data::{LabeledDataset, StreamGen};
use sparx::experiments;
use sparx::metrics::{RankMetrics, ResourceReport};
use sparx::runtime::{ArtifactManifest, PjrtBinner, PjrtEngine};
use sparx::sparx::{ExecMode, NativeBinner, SparxModel, SparxParams, StreamScorer};
use sparx::ClusterContext;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".into());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn flag_f64(flags: &HashMap<String, String>, k: &str, d: f64) -> f64 {
    flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn flag_usize(flags: &HashMap<String, String>, k: &str, d: usize) -> usize {
    flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn make_dataset(name: &str, scale: f64, ctx: &ClusterContext) -> LabeledDataset {
    match name {
        "gisette" => GisetteGen {
            n: (8000.0 * scale) as usize,
            d: 512,
            ..Default::default()
        }
        .generate(ctx)
        .expect("generate"),
        "osm" => OsmGen {
            n_inliers: (400_000.0 * scale) as usize,
            n_outliers: (400.0 * scale).max(20.0) as usize,
            ..Default::default()
        }
        .generate(ctx)
        .expect("generate"),
        "spamurl" => SpamUrlGen {
            n: (20_000.0 * scale) as usize,
            ..Default::default()
        }
        .generate(ctx)
        .expect("generate"),
        other => {
            eprintln!("unknown dataset {other:?} (gisette|osm|spamurl)");
            std::process::exit(2);
        }
    }
}

fn cmd_detect(flags: &HashMap<String, String>) {
    let dataset = flags.get("dataset").cloned().unwrap_or_else(|| "gisette".into());
    let scale = flag_f64(flags, "scale", 0.5);
    let cfg_name = flags.get("config").cloned().unwrap_or_else(|| "local".into());
    let mut ctx = presets::by_name(&cfg_name)
        .unwrap_or_else(|| {
            eprintln!("unknown config {cfg_name:?}");
            std::process::exit(2);
        })
        .build();
    let ld = make_dataset(&dataset, scale, &ctx);
    println!(
        "dataset={dataset} n={} d={} outliers={} ({:.3}%)",
        ld.dataset.len(),
        ld.dataset.dim(),
        ld.outlier_count(),
        100.0 * ld.outlier_rate()
    );
    ctx.reset();
    let default_k = if dataset == "osm" {
        0
    } else if dataset == "spamurl" {
        100
    } else {
        50
    };
    let exec_mode = match flags.get("exec").map(String::as_str) {
        Some("per-chain" | "perchain") => ExecMode::PerChain,
        Some("fused") | None => ExecMode::Fused,
        Some(other) => {
            eprintln!("unknown exec mode {other:?} (fused|per-chain)");
            std::process::exit(2);
        }
    };
    let params = SparxParams {
        k: flag_usize(flags, "k", default_k),
        num_chains: flag_usize(flags, "chains", 50),
        depth: flag_usize(flags, "depth", 10),
        sample_rate: flag_f64(flags, "rate", 0.1),
        exec_mode,
        ..Default::default()
    };
    let backend = flags.get("backend").map(String::as_str).unwrap_or("native");
    let engine;
    let pjrt_binner;
    let binner: &dyn sparx::sparx::Binner = if backend == "pjrt" {
        engine = PjrtEngine::start_default().unwrap_or_else(|e| {
            eprintln!("PJRT engine: {e}");
            std::process::exit(1);
        });
        let variant = match dataset.as_str() {
            "osm" => "osm",
            "spamurl" => "spamurl",
            _ => "gisette",
        };
        pjrt_binner = PjrtBinner { engine: &engine, variant: variant.into() };
        &pjrt_binner
    } else {
        &NativeBinner
    };
    let model = SparxModel::fit_with(&ctx, &ld.dataset, &params, binner).expect("fit");
    let proj =
        sparx::sparx::project_dataset(&ctx, &ld.dataset, &model.projector).expect("project");
    let scores = model.score_sketches_with(&ctx, &proj, binner).expect("score");
    let res = ResourceReport::from_ctx(&ctx);
    let aligned = experiments::align_scores(&scores, ld.labels.len());
    let met = RankMetrics::compute(&aligned, &ld.labels);
    let exec_tag = exec_mode.tag();
    println!(
        "Sparx[{backend},{exec_tag}] M={} L={} rate={} K={}: AUROC={:.3} AUPRC={:.3} F1={:.3}",
        params.num_chains, params.depth, params.sample_rate, params.k, met.auroc, met.auprc, met.f1
    );
    println!("{}", res.summary());
    if let Some(out) = flags.get("out") {
        sparx::data::loader::write_scores_csv(out, &scores, &ld.labels).expect("write");
        println!("scores written to {out}");
    }
}

fn cmd_experiment(pos: &[String], flags: &HashMap<String, String>) {
    let id = pos.first().map(String::as_str).unwrap_or("all");
    let scale = flag_f64(flags, "scale", 1.0);
    let results = experiments::run(id, scale);
    let mut md = String::new();
    for r in &results {
        let table = r.to_markdown();
        println!("{table}");
        md.push_str(&table);
        md.push('\n');
    }
    if let Some(out) = flags.get("out") {
        std::fs::write(out, md).expect("write results");
        println!("results written to {out}");
    }
}

fn cmd_stream(flags: &HashMap<String, String>) {
    let updates = flag_usize(flags, "updates", 10_000);
    let cache = flag_usize(flags, "cache", 1024);
    let ctx = presets::config_local().build();
    let ld = make_dataset("gisette", 0.2, &ctx);
    let params = SparxParams { k: 25, num_chains: 20, depth: 8, ..Default::default() };
    let model = SparxModel::fit(&ctx, &ld.dataset, &params).expect("fit");
    let mut scorer = StreamScorer::new(&model, cache).expect("stream scorer");
    let names = ld.dataset.schema.names.clone();
    let mut gen = StreamGen::new(5000, names, 42);
    let t0 = std::time::Instant::now();
    let mut worst: Option<sparx::sparx::StreamScore> = None;
    for _ in 0..updates {
        let u = gen.next_update();
        let s = scorer.update(&u);
        if worst.as_ref().map_or(true, |w| s.outlierness > w.outlierness) {
            worst = Some(s);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "processed {updates} δ-updates in {dt:.3}s ({:.0}/s), cache={}/{} evictions={}",
        updates as f64 / dt,
        scorer.cached_ids(),
        cache,
        scorer.evictions()
    );
    if let Some(w) = worst {
        println!("most outlying update: id={} outlierness={:.3}", w.id, w.outlierness);
    }
}

fn cmd_generate(flags: &HashMap<String, String>) {
    let dataset = flags.get("dataset").cloned().unwrap_or_else(|| "osm".into());
    let scale = flag_f64(flags, "scale", 0.1);
    let out = flags.get("out").cloned().unwrap_or_else(|| format!("{dataset}.csv"));
    let ctx = presets::config_local().build();
    let ld = make_dataset(&dataset, scale, &ctx);
    let rows = ld.dataset.rows.collect(&ctx).expect("collect");
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(&out).expect("create"));
    let names = ld.dataset.schema.names.join(",");
    writeln!(f, "{names},label").unwrap();
    for r in rows {
        match &r.features {
            sparx::data::Features::Dense(v) => {
                let cells: Vec<String> = v.iter().map(|x| x.to_string()).collect();
                writeln!(f, "{},{}", cells.join(","), u8::from(ld.labels[r.id as usize]))
                    .unwrap();
            }
            _ => {
                eprintln!("generate: only dense datasets can be dumped to csv");
                std::process::exit(2);
            }
        }
    }
    println!("wrote {} rows to {out}", ld.dataset.len());
}

fn cmd_info() {
    println!("sparx — distributed outlier detection (KDD'22 reproduction)");
    println!("\ncluster presets (Table 5, scaled):");
    for name in ["config-mod", "config-gen", "local"] {
        let c = presets::by_name(name).unwrap();
        println!(
            "  {name}: partitions={} workers={} threads={} exec-mem={}MB deadline={:?}s",
            c.num_partitions,
            c.num_workers,
            c.num_threads,
            if c.worker_mem_bytes == usize::MAX { 0 } else { c.worker_mem_bytes / 1048576 },
            c.deadline_secs
        );
    }
    print!("\nAOT artifacts: ");
    match ArtifactManifest::load(&sparx::runtime::default_artifact_dir()) {
        Ok(m) => {
            println!("{} compiled modules", m.entries.len());
            for e in &m.entries {
                println!("  {}/{} b={} d={} k={} l={}", e.kind, e.name, e.b, e.d, e.k, e.l);
            }
            match PjrtEngine::start(&m) {
                Ok(_) => println!("PJRT CPU engine: OK"),
                Err(e) => println!("PJRT CPU engine: FAILED ({e})"),
            }
        }
        Err(e) => println!("not built ({e})"),
    }
    println!("\nDBSCOUT neighbourhood sizes (2⌈√d⌉+1)^d:");
    for d in [2usize, 6, 10, 11] {
        println!(
            "  d={d}: {:.2e} cells",
            sparx::baselines::dbscout::CostModel::neighbourhood_cells(d)
        );
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    match pos.first().map(String::as_str) {
        Some("detect") => cmd_detect(&flags),
        Some("experiment") => cmd_experiment(&pos[1..], &flags),
        Some("stream") => cmd_stream(&flags),
        Some("generate") => cmd_generate(&flags),
        Some("info") => cmd_info(),
        _ => {
            eprintln!("usage: sparx <detect|experiment|stream|generate|info> [flags]");
            eprintln!("see `sparx info` and the module docs in rust/src/main.rs");
            std::process::exit(2);
        }
    }
}
