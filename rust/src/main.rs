//! `sparx` — CLI launcher for the Sparx reproduction.
//!
//! The CLI is organised around the model lifecycle — **fit** a model on
//! the cluster, **save** it as a versioned artifact, **load** it on a
//! deployment node to **score** batches or **serve** an evolving stream
//! (§3.5: train once, ship the O(rwLM) model, score updates in constant
//! time). Every command drives the library through the unified
//! [`sparx::api::Detector`] contract; errors are typed
//! ([`sparx::api::SparxError`]) and map to exit codes: `2` for usage /
//! validation problems, `1` for runtime failures (MEM ERR, TIMEOUT,
//! missing/corrupt artifacts, I/O). Unrecognized flags and misspelled
//! subcommands are rejected with a suggestion instead of being silently
//! ignored.
//!
//! Subcommands (hand-rolled parser — the offline build has no clap):
//!
//! ```text
//! sparx fit      --method sparx|xstream|spif|dbscout|ensemble --model-out m.sparx
//!                [--dataset gisette|osm|spamurl] [--config gen|mod|local]
//!                [--components M] [--chains M] [--depth L] [--rate R] [--k K]
//!                [--eps E] [--min-pts P] [--scale S] [--seed N] [--distill]
//!                [--backend native|pjrt] [--exec fused|per-chain]
//! sparx score    --model m.sparx [--dataset gisette|osm|spamurl]
//!                [--config gen|mod|local] [--scale S] [--seed N]
//!                [--out scores.csv] [--backend native|pjrt]
//! sparx serve    --model m.sparx [--updates FILE|-] [--count N]
//!                [--cache N] [--seed N] [--shards S]
//!                [--backend native|pjrt]
//!                [--checkpoint-out c.sparx [--checkpoint-every N]]
//!                [--resume c.sparx] [--watch] [--absorb]
//!                [--listen ADDR]               # TCP ingress instead of a file
//!                [--score-log FILE|-]          # ⟨ID, F, δ⟩ loop, §3.5
//! sparx detect   --method … [fit flags] [--out scores.csv]   # fit+score in one
//! sparx experiment <table2|table3|table4|fig2|fig3|fig4|fig5|fig6|all>
//!                [--scale S] [--seed N] [--out EXPERIMENTS_RESULTS.md]
//! sparx stream   [--updates N] [--cache N] [--seed N]   # synthetic §3.5 demo
//! sparx generate --dataset osm --out points.csv [--scale S] [--seed N]
//! sparx generate --stream N --out updates.txt [--seed N]  # ⟨ID, F, δ⟩ lines
//! sparx info                                    # artifacts + presets
//! ```
//!
//! `--method` takes a full **detector spec string**, not just a name:
//! `name?key=val&key=val` parameterizes the method inline (one shared
//! grammar with `registry::create` — e.g. `--method
//! "sparx?depth=12&rate=0.05"`, or `--method
//! "ensemble?members=sparx:depth=6,xstream&distill=true"` for a
//! heterogeneous ensemble whose members are `name(:key=val)*` specs).
//! Spec-string values win over the equivalent flags; unknown keys are
//! typed errors with an edit-distance suggestion.
//!
//! `serve` reads one update triple per line (`#` comments and blank
//! lines skipped): `ID FEATURE δ` for numeric increments, and
//! `ID FEATURE old->new` (empty `old` for a newly arising value) for
//! categorical substitutions. With `--shards S > 1` (default: the
//! machine's available parallelism) updates are partitioned by
//! `murmur(ID) % S` across S shard worker threads. `--cache N` is the
//! **total** resident-sketch budget: eviction decisions come from one
//! global recency directory and absorb increments publish on a fixed
//! epoch schedule, so per-ID score sequences are **bit-identical at any
//! shard count** — `--shards` is purely a parallelism knob. `--backend
//! native` on `score`/`serve` overrides the backend a sparx artifact
//! was fitted with (scores are backend-identical, so a PJRT-fitted
//! model can be served without the compiled AOT modules).
//!
//! Serving state is durable, elastic and hot-swappable: all shards
//! score against **one** Arc-shared read-only ensemble;
//! `--checkpoint-out PATH` (periodically with `--checkpoint-every N`,
//! and always at the end of the stream) atomically writes the global
//! absorb state — sketches in global recency order, the visible and
//! pending CMS overlays (`--absorb`), counters — as a format-v4
//! artifact, and `--resume PATH` restores it so a restarted server
//! continues the stream **bit-for-bit**. The checkpoint is
//! layout-independent: resume requires the same model and absorb mode
//! but may pick a **different** `--shards`/`--cache`. `--watch` polls
//! the model file between batches and atomically swaps the ensemble
//! when it changes, carrying absorb state forward when the serving
//! schema matches and rejecting typed when it does not.
//! `--score-log FILE|-` records every score and writes them in global
//! submit order (`id score-bits-hex` per line; with `-` the log owns
//! stdout and human output moves to stderr) — what the lifecycle-e2e
//! CI job diffs across a kill/resume boundary. Recording buffers the
//! whole run's scores in memory and writes at stream end, so it is a
//! bounded-run diagnostic, not a steady-state access log.
//!
//! `--listen ADDR` serves the same grammar over TCP instead of a
//! file/stdin (see `sparx::serve`): concurrent clients submit update
//! lines and control verbs (`SCORE`, `STATS`, `METRICS`, `CHECKPOINT`,
//! `RESHARD N`, `QUIT`, `SHUTDOWN`), scores stream back per
//! connection, a full shard queue answers `BUSY` instead of dropping,
//! and `RESHARD` re-partitions the running pool live without losing a
//! queued update. `listening on <addr>` is printed to stderr (port `0`
//! picks a free port). Incompatible with `--updates`/`--count`/
//! `--seed`/`--watch`/`--checkpoint-every`; `--checkpoint-out` arms
//! the `CHECKPOINT` verb and the final cut at `SHUTDOWN`.

use std::collections::HashMap;
use std::str::FromStr;

use sparx::api::{
    registry, Backend, Detector as _, DetectorSpec, FittedModel, MethodSpec, SparxError,
};
use sparx::config::presets;
use sparx::data::generators::{GisetteGen, OsmGen, SpamUrlGen};
use sparx::data::{parse_update_line, LabeledDataset, StreamGen, UpdateTriple};
use sparx::experiments::{self, align_scores};
use sparx::metrics::{RankMetrics, ResourceReport};
use sparx::runtime::{ArtifactManifest, PjrtEngine};
use sparx::sparx::{
    AbsorbCheckpoint, DecaySpec, ExecMode, ServeOptions, ShardedStreamScorer, StreamScore,
    SwapCarry,
};
use sparx::util::closest_match;
use sparx::ClusterContext;

type CliResult = Result<(), SparxError>;

fn usage_err(msg: String) -> SparxError {
    SparxError::InvalidParams(msg)
}

// ---------------------------------------------------------------- flags

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut it = args.iter().peekable();
    while let Some(arg) = it.next() {
        if let Some(name) = arg.strip_prefix("--") {
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    flags.insert(name.to_string(), (*v).clone());
                    it.next();
                }
                _ => {
                    flags.insert(name.to_string(), "true".into());
                }
            }
        } else {
            pos.push(arg.clone());
        }
    }
    (pos, flags)
}

/// Reject any flag the command does not declare — `--chain 40` must be a
/// hard error pointing at `--chains`, not a silently ignored typo.
fn check_flags(cmd: &str, flags: &HashMap<String, String>, allowed: &[&str]) -> CliResult {
    for key in flags.keys() {
        if !allowed.contains(&key.as_str()) {
            let hint = closest_match(key, allowed)
                .map(|s| format!(" (did you mean --{s}?)"))
                .unwrap_or_default();
            let valid: Vec<String> = allowed.iter().map(|f| format!("--{f}")).collect();
            return Err(usage_err(format!(
                "unrecognized flag --{key} for `sparx {cmd}`{hint}; valid flags: {}",
                valid.join(" ")
            )));
        }
    }
    Ok(())
}

/// Parse `--key value` with a default; a present-but-unparsable value is
/// a hard error (the old CLI silently fell back to the default).
fn flag_or<T: FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
    dflt: T,
) -> Result<T, SparxError> {
    Ok(flag_opt(flags, key)?.unwrap_or(dflt))
}

fn flag_opt<T: FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
) -> Result<Option<T>, SparxError> {
    match flags.get(key) {
        None => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|_| usage_err(format!("--{key}: cannot parse value {v:?}"))),
    }
}

/// Boolean flag: absent → false, bare `--flag` → true (the parser maps a
/// valueless flag to `"true"`). An explicit non-boolean value is a hard
/// error — it usually means the flag swallowed the next argument.
fn flag_bool(flags: &HashMap<String, String>, key: &str) -> Result<bool, SparxError> {
    match flags.get(key).map(String::as_str) {
        None => Ok(false),
        Some("true" | "1") => Ok(true),
        Some("false" | "0") => Ok(false),
        Some(other) => Err(usage_err(format!(
            "--{key} is a boolean flag (got {other:?} — did it swallow the next argument?)"
        ))),
    }
}

// ------------------------------------------------------------- datasets

const DATASETS: [&str; 3] = ["gisette", "osm", "spamurl"];

fn make_dataset(
    name: &str,
    scale: f64,
    seed: Option<u64>,
    ctx: &ClusterContext,
) -> Result<LabeledDataset, SparxError> {
    match name {
        "gisette" => {
            let mut g = GisetteGen { n: (8000.0 * scale) as usize, d: 512, ..Default::default() };
            if let Some(s) = seed {
                g.seed = s;
            }
            Ok(g.generate(ctx)?)
        }
        "osm" => {
            let mut g = OsmGen {
                n_inliers: (400_000.0 * scale) as usize,
                n_outliers: (400.0 * scale).max(20.0) as usize,
                ..Default::default()
            };
            if let Some(s) = seed {
                g.seed = s;
            }
            Ok(g.generate(ctx)?)
        }
        "spamurl" => {
            let mut g = SpamUrlGen { n: (20_000.0 * scale) as usize, ..Default::default() };
            if let Some(s) = seed {
                g.seed = s;
            }
            Ok(g.generate(ctx)?)
        }
        other => {
            let hint = closest_match(other, &DATASETS)
                .map(|s| format!(" (did you mean {s:?}?)"))
                .unwrap_or_default();
            Err(usage_err(format!(
                "unknown dataset {other:?} (expected {}){hint}",
                DATASETS.join("|")
            )))
        }
    }
}

// ------------------------------------------------- detect / fit shared

/// The hyperparameter + data flags shared by `detect` and `fit`; each
/// command appends its one extra flag (`--out` / `--model-out`) at its
/// `check_flags` call instead of repeating this list.
const HYPER_FLAGS: [&str; 15] = [
    "method", "dataset", "config", "components", "chains", "depth", "rate", "k", "eps",
    "min-pts", "scale", "seed", "backend", "exec", "distill",
];

/// Explicitly-passed flags the chosen method would ignore are errors,
/// not silent no-ops (the method-level cousin of `check_flags`).
/// `extra_common` names the command's own non-hyperparameter flags.
fn check_method_flags(
    method: &str,
    flags: &HashMap<String, String>,
    extra_common: &[&str],
) -> CliResult {
    let method_flags: &[&str] = match method {
        "sparx" => &["chains", "components", "depth", "rate", "k", "exec", "backend"],
        "xstream" => &["chains", "components", "depth", "k"],
        "spif" => &["chains", "components", "depth", "rate"],
        "dbscout" => &["eps", "min-pts"],
        // member hyperparameters live inside the `members=` spec string
        // (`sparx:depth=6,…`), not in top-level flags — only the
        // ensemble-level toggles are flags
        "ensemble" => &["distill"],
        // unknown method: skip so the registry's UnknownDetector error
        // (with its typo suggestion) surfaces instead
        _ => &HYPER_FLAGS,
    };
    let common = ["method", "dataset", "config", "scale", "seed"];
    for key in flags.keys() {
        if !common.contains(&key.as_str())
            && !extra_common.contains(&key.as_str())
            && !method_flags.contains(&key.as_str())
        {
            return Err(usage_err(format!(
                "--{key} does not apply to --method {method} (applicable: {})",
                method_flags.iter().map(|f| format!("--{f}")).collect::<Vec<_>>().join(" ")
            )));
        }
    }
    Ok(())
}

/// Fold the hyperparameter flags into a [`DetectorSpec`].
fn build_spec(
    method: &str,
    dataset: &str,
    seed: Option<u64>,
    flags: &HashMap<String, String>,
) -> Result<DetectorSpec, SparxError> {
    // the paper's per-dataset projection defaults: OSM stays raw 2-d,
    // SpamURL hashes to K=100, Gisette to K=50
    let default_k = match dataset {
        "osm" => 0,
        "spamurl" => 100,
        _ => 50,
    };
    let exec_mode = match flags.get("exec").map(String::as_str) {
        Some("per-chain" | "perchain") => ExecMode::PerChain,
        Some("fused") | None => ExecMode::Fused,
        Some(other) => {
            return Err(usage_err(format!("unknown exec mode {other:?} (fused|per-chain)")))
        }
    };
    let backend = parse_backend_flag(flags)?.unwrap_or(Backend::Native);
    if flags.contains_key("components") && flags.contains_key("chains") {
        return Err(usage_err("--components and --chains are aliases; pass only one".into()));
    }
    let components = match flag_opt(flags, "components")? {
        Some(m) => Some(m),
        None => flag_opt(flags, "chains")?,
    };
    // sparx keeps the CLI's historical defaults (K per dataset, rate 0.1
    // vs the library's 1.0); other methods fall back to their own library
    // defaults unless the flag is passed explicitly
    let (k, sample_rate) = if method == "sparx" {
        (Some(flag_or(flags, "k", default_k)?), Some(flag_or(flags, "rate", 0.1)?))
    } else {
        (flag_opt(flags, "k")?, flag_opt(flags, "rate")?)
    };
    Ok(DetectorSpec {
        k,
        components,
        depth: flag_opt(flags, "depth")?,
        sample_rate,
        seed,
        exec_mode,
        backend,
        pjrt_variant: Some(dataset.to_string()),
        eps: flag_opt(flags, "eps")?,
        min_pts: flag_opt(flags, "min-pts")?,
        distill: flag_bool(flags, "distill")?,
        // members / share / schedule have no dedicated flags: they are
        // spec-string-only (`--method "ensemble?members=…&schedule=…"`),
        // overlaid by `registry::apply_spec_string` after this
        ..Default::default()
    })
}

/// Build the cluster context named by `--config` (default `local`).
fn make_ctx(flags: &HashMap<String, String>) -> Result<ClusterContext, SparxError> {
    let cfg_name = flags.get("config").cloned().unwrap_or_else(|| "local".into());
    Ok(presets::by_name(&cfg_name)
        .ok_or_else(|| usage_err(format!("unknown config {cfg_name:?} (gen|mod|local)")))?
        .build())
}

/// Generate the dataset named by the flags and print its shape line.
fn make_flagged_dataset(
    flags: &HashMap<String, String>,
    ctx: &ClusterContext,
) -> Result<(String, LabeledDataset), SparxError> {
    let dataset = flags.get("dataset").cloned().unwrap_or_else(|| "gisette".into());
    let scale = flag_or(flags, "scale", 0.5)?;
    let seed: Option<u64> = flag_opt(flags, "seed")?;
    let ld = make_dataset(&dataset, scale, seed, ctx)?;
    println!(
        "dataset={dataset} n={} d={} outliers={} ({:.3}%)",
        ld.dataset.len(),
        ld.dataset.dim(),
        ld.outlier_count(),
        100.0 * ld.outlier_rate()
    );
    Ok((dataset, ld))
}

// --------------------------------------------------------------- detect

fn cmd_detect(flags: &HashMap<String, String>) -> CliResult {
    let mut allowed = HYPER_FLAGS.to_vec();
    allowed.push("out");
    check_flags("detect", flags, &allowed)?;
    let method = flags.get("method").cloned().unwrap_or_else(|| "sparx".into());
    // `--method` is a full spec string (`name?key=val&…`): flag-level
    // checks run against the parsed name, the spec-string pairs overlay
    // the flag-built spec afterwards (spec-string values win)
    let ms = MethodSpec::parse(&method)?;
    check_method_flags(&ms.name, flags, &["out"])?;
    let seed: Option<u64> = flag_opt(flags, "seed")?;
    let mut ctx = make_ctx(flags)?;
    let (dataset, ld) = make_flagged_dataset(flags, &ctx)?;
    ctx.reset();
    let mut spec = build_spec(&ms.name, &dataset, seed, flags)?;
    registry::apply_spec_string(&ms, &mut spec)?;
    let det = registry::build(&ms.name, &spec)?;
    let model = det.fit(&ctx, &ld.dataset)?;
    let scores = model.score(&ctx, &ld.dataset)?;
    let res = ResourceReport::from_ctx(&ctx);
    let aligned = align_scores(&scores, ld.labels.len());
    let met = RankMetrics::compute(&aligned, &ld.labels);
    println!(
        "{}[{},{}]: AUROC={:.3} AUPRC={:.3} F1={:.3} (model {}B)",
        det.name(),
        spec.backend.tag(),
        spec.exec_mode.tag(),
        met.auroc,
        met.auprc,
        met.f1,
        model.model_bytes()
    );
    println!("{}", res.summary());
    if let Some(out) = flags.get("out") {
        sparx::data::loader::write_scores_csv(out, &scores, &ld.labels)?;
        println!("scores written to {out}");
    }
    Ok(())
}

// ------------------------------------------------------------------ fit

fn cmd_fit(flags: &HashMap<String, String>) -> CliResult {
    let mut allowed = HYPER_FLAGS.to_vec();
    allowed.push("model-out");
    check_flags("fit", flags, &allowed)?;
    let model_out = flags
        .get("model-out")
        .cloned()
        .ok_or_else(|| usage_err("fit requires --model-out <file>".into()))?;
    let method = flags.get("method").cloned().unwrap_or_else(|| "sparx".into());
    let ms = MethodSpec::parse(&method)?;
    check_method_flags(&ms.name, flags, &["model-out"])?;
    let seed: Option<u64> = flag_opt(flags, "seed")?;
    let mut ctx = make_ctx(flags)?;
    let (dataset, ld) = make_flagged_dataset(flags, &ctx)?;
    ctx.reset();
    let mut spec = build_spec(&ms.name, &dataset, seed, flags)?;
    registry::apply_spec_string(&ms, &mut spec)?;
    let det = registry::build(&ms.name, &spec)?;
    let t0 = std::time::Instant::now();
    let model = det.fit(&ctx, &ld.dataset)?;
    let fit_secs = t0.elapsed().as_secs_f64();
    // training provenance travels in the format-v2 manifest block —
    // carried verbatim, never interpreted by the loaders
    let artifact = model.to_artifact()?.with_manifest(vec![
        ("method".into(), det.name().into()),
        ("dataset".into(), dataset.clone()),
        ("scale".into(), flags.get("scale").cloned().unwrap_or_else(|| "0.5".into())),
        ("seed".into(), seed.map_or_else(|| "default".into(), |s| s.to_string())),
        ("config".into(), flags.get("config").cloned().unwrap_or_else(|| "local".into())),
    ]);
    let payload = artifact.payload.len();
    // ModelArtifact::save writes atomically (temp + rename): a live
    // `serve --watch` on this path can never read a torn artifact
    let total = artifact.save(&model_out)?;
    println!(
        "fitted {} in {fit_secs:.2}s — model payload {payload}B \
         ({total}B file with header+checksum)",
        det.name()
    );
    println!("{}", ResourceReport::from_ctx(&ctx).summary());
    println!("model written to {model_out} — score it with `sparx score --model {model_out}`");
    Ok(())
}

// ---------------------------------------------------------------- score

/// Parse the optional `--backend` flag. `fit`/`detect` default it to
/// native (via `build_spec`); on `score`/`serve` it overrides the
/// backend a sparx artifact was fitted with — scores are
/// backend-identical, so forcing `native` on a PJRT-fitted artifact is
/// safe (see `registry::load_with_backend`).
fn parse_backend_flag(flags: &HashMap<String, String>) -> Result<Option<Backend>, SparxError> {
    match flags.get("backend").map(String::as_str) {
        None => Ok(None),
        Some("native") => Ok(Some(Backend::Native)),
        Some("pjrt") => Ok(Some(Backend::Pjrt)),
        Some(other) => Err(usage_err(format!("unknown backend {other:?} (native|pjrt)"))),
    }
}

fn cmd_score(flags: &HashMap<String, String>) -> CliResult {
    check_flags(
        "score",
        flags,
        &["model", "dataset", "config", "scale", "seed", "out", "backend"],
    )?;
    let path = flags
        .get("model")
        .cloned()
        .ok_or_else(|| usage_err("score requires --model <file>".into()))?;
    let backend = parse_backend_flag(flags)?;
    let model = registry::load_with_backend(&path, backend)?;
    println!(
        "loaded {} model from {path} ({}B payload{})",
        model.name(),
        model.model_bytes(),
        if backend.is_some() { ", backend overridden" } else { "" }
    );
    let mut ctx = make_ctx(flags)?;
    let (_, ld) = make_flagged_dataset(flags, &ctx)?;
    ctx.reset();
    let t0 = std::time::Instant::now();
    let scores = model.score(&ctx, &ld.dataset)?;
    let score_secs = t0.elapsed().as_secs_f64();
    let aligned = align_scores(&scores, ld.labels.len());
    let met = RankMetrics::compute(&aligned, &ld.labels);
    println!(
        "{}: AUROC={:.3} AUPRC={:.3} F1={:.3} ({} points in {score_secs:.2}s)",
        model.name(),
        met.auroc,
        met.auprc,
        met.f1,
        scores.len()
    );
    println!("{}", ResourceReport::from_ctx(&ctx).summary());
    if let Some(out) = flags.get("out") {
        sparx::data::loader::write_scores_csv(out, &scores, &ld.labels)?;
        println!("scores written to {out}");
    }
    Ok(())
}

// ---------------------------------------------------------------- serve

/// Drive every update from the configured source — `--updates FILE|-`
/// (parsed by `sparx::data::parse_update_line`) or the synthetic
/// `--count` stream — through `f` (which may fail, e.g. a checkpoint
/// write or a rejected hot reload: the stream stops there).
fn for_each_update(
    flags: &HashMap<String, String>,
    names: Option<&[String]>,
    mut f: impl FnMut(UpdateTriple) -> CliResult,
) -> CliResult {
    if let Some(src) = flags.get("updates") {
        // --count/--seed only shape the synthetic stream; silently
        // ignoring them alongside a real update source would break the
        // CLI's no-ignored-flags rule
        for inapplicable in ["count", "seed"] {
            if flags.contains_key(inapplicable) {
                return Err(usage_err(format!(
                    "--{inapplicable} does not apply when --updates provides the stream"
                )));
            }
        }
        use std::io::BufRead;
        let reader: Box<dyn BufRead> = if src == "-" {
            Box::new(std::io::BufReader::new(std::io::stdin()))
        } else {
            Box::new(std::io::BufReader::new(std::fs::File::open(src)?))
        };
        for (i, line) in reader.lines().enumerate() {
            if let Some(u) = parse_update_line(i + 1, &line?)? {
                f(u)?;
            }
        }
    } else {
        // no update source: synthesize an evolving stream against the
        // model's own feature space (or a generic one)
        let count = flag_or(flags, "count", 10_000usize)?;
        let seed: Option<u64> = flag_opt(flags, "seed")?;
        let names = match names {
            Some(names) => names.to_vec(),
            None => (0..64).map(|j| format!("f{j}")).collect(),
        };
        let mut gen = StreamGen::new(5000, names, seed.unwrap_or(42));
        for _ in 0..count {
            f(gen.next_update())?;
        }
    }
    Ok(())
}

/// (mtime, length) stamp used by `serve --watch` to notice model
/// rewrites between batches.
fn file_stamp(path: &str) -> Option<(std::time::SystemTime, u64)> {
    let meta = std::fs::metadata(path).ok()?;
    Some((meta.modified().ok()?, meta.len()))
}

/// Cut a checkpoint from the live scorer and write it atomically
/// (temp + rename), with provenance in the manifest.
fn write_checkpoint(scorer: &mut ShardedStreamScorer, out: &str, model_path: &str) -> CliResult {
    let ckpt = scorer.checkpoint()?;
    let manifest = ckpt.manifest_for(model_path);
    ckpt.save(out, manifest)?;
    Ok(())
}

/// If the model file's stamp moved, reload it and hot-swap the shared
/// ensemble. A file that does not (yet) read as a valid artifact is a
/// transient condition — a writer without the atomic temp+rename
/// discipline may be mid-flight — so it logs and retries at the next
/// poll instead of killing a live server. Incompatible serving schemas,
/// however, surface typed (exit 2) per the `--watch` contract —
/// absorbed state must never be silently misinterpreted under a
/// mismatched model.
fn check_reload(
    scorer: &mut ShardedStreamScorer,
    path: &str,
    backend: Option<Backend>,
    last: &mut Option<(std::time::SystemTime, u64)>,
) -> CliResult {
    let now = file_stamp(path);
    if now.is_none() || now == *last {
        return Ok(());
    }
    let reloaded = match registry::load_with_backend(path, backend) {
        Ok(model) => model,
        Err(e) => {
            // don't advance the stamp: retry on the next poll (the file
            // may still be being written)
            eprintln!("sparx: --watch: {path} not loadable yet ({e}); retrying next poll");
            return Ok(());
        }
    };
    *last = now;
    let carry = scorer.swap_ensemble(reloaded.served_ensemble()?)?;
    // stderr: an operational notice, and stdout may be a `--score-log -`
    // stream that must stay machine-diffable
    eprintln!(
        "sparx: model reloaded from {path}: {}",
        match carry {
            SwapCarry::Full => "same fitted model — absorbed state carried in full",
            SwapCarry::SketchesOnly =>
                "new chains, same serving schema — sketches carried, absorbed delta reset",
        }
    );
    Ok(())
}

/// Write the merged score log: one `id score-bits-hex` line per update,
/// in global submit order (bit-stable across shard counts and runs).
fn write_score_log(path: &str, scores: &[StreamScore]) -> CliResult {
    use std::io::Write as _;
    let mut out: Box<dyn std::io::Write> = if path == "-" {
        Box::new(std::io::stdout().lock())
    } else {
        Box::new(std::io::BufWriter::new(std::fs::File::create(path)?))
    };
    for s in scores {
        writeln!(out, "{} {:016x}", s.id, s.outlierness.to_bits())?;
    }
    out.flush()?;
    Ok(())
}

/// How many updates pass between `--watch` stat polls of the model file.
const WATCH_POLL_UPDATES: u64 = 1024;

fn cmd_serve(flags: &HashMap<String, String>) -> CliResult {
    check_flags(
        "serve",
        flags,
        &[
            "model",
            "updates",
            "count",
            "cache",
            "seed",
            "shards",
            "backend",
            "checkpoint-out",
            "checkpoint-every",
            "resume",
            "watch",
            "absorb",
            "half-life",
            "window",
            "listen",
            "score-log",
        ],
    )?;
    let path = flags
        .get("model")
        .cloned()
        .ok_or_else(|| usage_err("serve requires --model <file>".into()))?;
    let backend = parse_backend_flag(flags)?;
    let listen = flags.get("listen").cloned();
    if listen.is_some() {
        // the TCP ingress replaces the file/synthetic stream, and its
        // control plane replaces the between-updates polling hooks —
        // silently ignoring any of these would break the CLI's
        // no-ignored-flags rule
        for inapplicable in ["updates", "count", "seed", "watch", "checkpoint-every"] {
            if flags.contains_key(inapplicable) {
                return Err(usage_err(format!(
                    "--{inapplicable} does not apply with --listen (clients drive the \
                     stream; use the CHECKPOINT verb for mid-stream cuts)"
                )));
            }
        }
    }
    let resume = match flags.get("resume") {
        Some(p) => Some(AbsorbCheckpoint::load(p)?),
        None => None,
    };
    // an unflagged --cache adopts the resumed checkpoint's total budget;
    // an explicit flag wins — the v4 checkpoint is layout-independent,
    // so a different budget (like a different shard count) still
    // continues bit-identically
    let cache = match flag_opt(flags, "cache")? {
        Some(c) => c,
        None => resume.as_ref().map(|c| c.cache_total as usize).unwrap_or(4096),
    };
    let default_shards = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let shards = match flag_opt(flags, "shards")? {
        Some(s) => s,
        None => resume.as_ref().map(|c| c.shards as usize).unwrap_or(default_shards),
    };
    if shards == 0 {
        return Err(usage_err("--shards must be ≥ 1".into()));
    }
    // like --shards/--cache, an unflagged --absorb adopts the resumed
    // checkpoint's mode; an explicit mismatch is rejected typed (it
    // would silently diverge the continued stream)
    let absorb = if flags.contains_key("absorb") {
        flag_bool(flags, "absorb")?
    } else {
        resume.as_ref().map(|c| c.absorb).unwrap_or(false)
    };
    // the decay schedule follows the same adoption rule: unflagged
    // --half-life/--window continue the checkpoint's schedule, an
    // explicit mismatch is rejected typed (a schedule change mid-stream
    // would silently diverge the decayed score sequence)
    let half_life = if flags.contains_key("half-life") {
        flag_or(flags, "half-life", 0u64)?
    } else {
        resume.as_ref().map(|c| c.half_life).unwrap_or(0)
    };
    let window = if flags.contains_key("window") {
        flag_or(flags, "window", 0u64)?
    } else {
        resume.as_ref().map(|c| c.window).unwrap_or(0)
    };
    let decay = DecaySpec::new(half_life, window);
    if decay.enabled() && !absorb {
        return Err(usage_err(
            "--half-life/--window decay absorbed counts: add --absorb".into(),
        ));
    }
    let watch = flag_bool(flags, "watch")?;
    let score_log = flags.get("score-log").cloned();
    let ckpt_out = flags.get("checkpoint-out").cloned();
    let ckpt_every: u64 = flag_or(flags, "checkpoint-every", 0u64)?;
    if ckpt_every > 0 && ckpt_out.is_none() {
        return Err(usage_err("--checkpoint-every needs --checkpoint-out <file>".into()));
    }
    let model = registry::load_with_backend(&path, backend)?;
    // `--score-log -` reserves stdout for the machine-diffable score
    // lines; every human-readable serve line then goes to stderr so the
    // log pipes clean
    let log_to_stdout = score_log.as_deref() == Some("-");
    let status = |line: String| {
        if log_to_stdout {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    };
    status(format!(
        "serving {} model from {path} ({}B payload, {shards} shard(s), total LRU budget \
         {cache} ids)",
        model.name(),
        model.model_bytes()
    ));
    let plain = !absorb
        && !watch
        && score_log.is_none()
        && ckpt_out.is_none()
        && resume.is_none()
        && listen.is_none();
    if shards == 1 && plain {
        // single-threaded fast path: no queues, no worker threads
        let mut scorer = model.stream_scorer(cache)?;
        let names = scorer.feature_names().map(|n| n.to_vec());
        let t0 = std::time::Instant::now();
        let mut worst: Option<StreamScore> = None;
        for_each_update(flags, names.as_deref(), |u| {
            let s = scorer.update(&u);
            if s.more_outlying_than(worst.as_ref()) {
                worst = Some(s);
            }
            Ok(())
        })?;
        let dt = t0.elapsed().as_secs_f64();
        let n = scorer.processed();
        println!(
            "processed {n} δ-updates in {dt:.3}s ({:.0} updates/s), cache {}/{cache}, \
             {} evictions",
            n as f64 / dt.max(1e-9),
            scorer.cached_ids(),
            scorer.evictions()
        );
        if let Some(w) = worst {
            println!("most outlying update: id={} outlierness={:.3}", w.id, w.outlierness);
        }
        return Ok(());
    }
    // sharded serving: murmur(ID) % shards routes each update to a
    // pinned worker owning its own LRU + absorbed delta, while all
    // shards score against ONE Arc-shared read-only ensemble — each
    // shard is bit-identical to a single-threaded scorer fed its
    // sub-stream (and to --shards 1 per ID, while no shard evicts and
    // absorb is off)
    let ensemble = model.served_ensemble()?;
    status(format!(
        "resident ensemble: {}B, Arc-shared across {shards} shard(s) (1x, fingerprint \
         {:08x})",
        ensemble.resident_bytes(),
        ensemble.model_fingerprint()
    ));
    let opts = ServeOptions::new()
        .shards(shards)
        .cache(cache)
        .record(score_log.is_some())
        .absorb(absorb)
        .decay(decay);
    let mut scorer = ShardedStreamScorer::from_ensemble(ensemble, opts, resume.as_ref())?;
    // ensemble models expose per-member provenance (spec, measured fit /
    // score cost, worker, distillation lineage) — carried into the
    // scorer so STATS / METRICS report it live
    let members = model.member_info();
    for m in &members {
        let lineage = m
            .distilled_from
            .as_deref()
            .map(|t| format!(", distilled from {t}"))
            .unwrap_or_default();
        let serving = if m.serving { " [serving]" } else { "" };
        status(format!(
            "  member {} ({}): fit {}µs, score {}µs, worker {}{lineage}{serving}",
            m.spec, m.kind, m.fit_micros, m.score_micros, m.worker
        ));
    }
    scorer.set_member_info(members);
    let resumed_offset = resume.as_ref().map(|c| c.submitted).unwrap_or(0);
    if let Some(ckpt) = &resume {
        status(format!(
            "resumed from checkpoint: {} updates already absorbed into the stream state, \
             {} sketches resident (captured at {} shard(s), re-partitioned to {shards})",
            ckpt.submitted,
            ckpt.entries.len(),
            ckpt.shards
        ));
    }
    let t0 = std::time::Instant::now();
    if let Some(addr) = &listen {
        // TCP ingress: hand the scorer to the serving plane; it comes
        // back at SHUTDOWN for the shared finalization below
        let engine = sparx::serve::Engine::new(scorer, path.clone(), ckpt_out.clone());
        let server = sparx::serve::Server::bind(addr, engine)?;
        // stderr, always: `--score-log -` owns stdout, and harnesses
        // parse this line to learn a port-0 assignment
        eprintln!("listening on {}", server.local_addr());
        scorer = server.run()?;
    } else {
        let names = scorer.feature_names().map(|n| n.to_vec());
        let mut watch_stamp = if watch { file_stamp(&path) } else { None };
        let mut since_ckpt = 0u64;
        let mut since_watch = 0u64;
        for_each_update(flags, names.as_deref(), |u| {
            scorer.submit(u);
            if ckpt_every > 0 {
                since_ckpt += 1;
                if since_ckpt >= ckpt_every {
                    since_ckpt = 0;
                    // flag validation rejects --checkpoint-every without
                    // --checkpoint-out, so `out` is always present here
                    if let Some(out) = ckpt_out.as_deref() {
                        write_checkpoint(&mut scorer, out, &path)?;
                    }
                }
            }
            if watch {
                since_watch += 1;
                if since_watch >= WATCH_POLL_UPDATES {
                    since_watch = 0;
                    check_reload(&mut scorer, &path, backend, &mut watch_stamp)?;
                }
            }
            Ok(())
        })?;
    }
    if let Some(out) = &ckpt_out {
        // the final cut: covers every update of this run, so a restart
        // with --resume continues exactly at the end of the stream
        write_checkpoint(&mut scorer, out, &path)?;
        status(format!(
            "checkpoint written to {out} ({} updates covered)",
            scorer.submitted()
        ));
    }
    let report = scorer.finish();
    let dt = t0.elapsed().as_secs_f64();
    let total = report.processed();
    let this_run = total - resumed_offset;
    status(format!(
        "processed {this_run} δ-updates in {dt:.3}s ({:.0} updates/s) across {} \
         shards ({total} total over the stream's lifetime), cache {}/{cache} ids, \
         {} evictions, {} absorbed",
        this_run as f64 / dt.max(1e-9),
        report.shards.len(),
        report.cached_ids(),
        report.evictions(),
        report.absorbed()
    ));
    for (i, s) in report.shards.iter().enumerate() {
        status(format!(
            "  shard {i}: {} updates, {} cached ids, {} evictions",
            s.processed, s.cached_ids, s.evictions
        ));
    }
    if let Some(w) = &report.worst {
        status(format!("most outlying update: id={} outlierness={:.3}", w.id, w.outlierness));
    }
    if let Some(log) = &score_log {
        let merged = report.merged_scores();
        write_score_log(log, &merged)?;
        if log != "-" {
            println!("score log: {} scores written to {log} in submit order", merged.len());
        }
    }
    Ok(())
}

// ----------------------------------------------------------- experiment

fn cmd_experiment(pos: &[String], flags: &HashMap<String, String>) -> CliResult {
    check_flags("experiment", flags, &["scale", "seed", "out"])?;
    if pos.len() > 1 {
        return Err(usage_err(format!(
            "experiment takes one id, got {} positional arguments",
            pos.len()
        )));
    }
    let id = pos.first().map(String::as_str).unwrap_or("all");
    let scale = flag_or(flags, "scale", 1.0)?;
    let seed = flag_opt(flags, "seed")?;
    let results = experiments::run(id, scale, seed)?;
    let mut md = String::new();
    for r in &results {
        let table = r.to_markdown();
        println!("{table}");
        md.push_str(&table);
        md.push('\n');
    }
    if let Some(out) = flags.get("out") {
        std::fs::write(out, md)?;
        println!("results written to {out}");
    }
    Ok(())
}

// --------------------------------------------------------------- stream

fn cmd_stream(flags: &HashMap<String, String>) -> CliResult {
    check_flags("stream", flags, &["updates", "cache", "seed"])?;
    let updates = flag_or(flags, "updates", 10_000usize)?;
    let cache = flag_or(flags, "cache", 1024usize)?;
    let seed: Option<u64> = flag_opt(flags, "seed")?;
    let ctx = presets::config_local().build();
    let ld = make_dataset("gisette", 0.2, seed, &ctx)?;
    let spec = DetectorSpec {
        k: Some(25),
        components: Some(20),
        depth: Some(8),
        seed,
        ..Default::default()
    };
    let det = registry::build("sparx", &spec)?;
    let model = det.fit(&ctx, &ld.dataset)?;
    let mut scorer = model.stream_scorer(cache)?;
    let names = ld.dataset.schema.names.clone();
    let mut gen = StreamGen::new(5000, names, seed.unwrap_or(42));
    let t0 = std::time::Instant::now();
    let mut worst: Option<sparx::sparx::StreamScore> = None;
    for _ in 0..updates {
        let u = gen.next_update();
        let s = scorer.update(&u);
        if s.more_outlying_than(worst.as_ref()) {
            worst = Some(s);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "processed {updates} δ-updates in {dt:.3}s ({:.0}/s), cache={}/{} evictions={}",
        updates as f64 / dt,
        scorer.cached_ids(),
        cache,
        scorer.evictions()
    );
    if let Some(w) = worst {
        println!("most outlying update: id={} outlierness={:.3}", w.id, w.outlierness);
    }
    Ok(())
}

// ------------------------------------------------------------- generate

fn cmd_generate(flags: &HashMap<String, String>) -> CliResult {
    check_flags("generate", flags, &["dataset", "scale", "seed", "out", "stream"])?;
    if let Some(n) = flag_opt::<usize>(flags, "stream")? {
        // ⟨ID, F, δ⟩ update lines instead of a point CSV — the file form
        // `sparx serve --updates` reads (and what the lifecycle-e2e CI
        // job splits around a kill/resume boundary). Same generator
        // defaults as serve's synthetic stream, so the two agree.
        for inapplicable in ["dataset", "scale"] {
            if flags.contains_key(inapplicable) {
                return Err(usage_err(format!(
                    "--{inapplicable} does not apply to --stream (update lines, not points)"
                )));
            }
        }
        let seed: Option<u64> = flag_opt(flags, "seed")?;
        let out = flags.get("out").cloned().unwrap_or_else(|| "updates.txt".into());
        let names: Vec<String> = (0..64).map(|j| format!("f{j}")).collect();
        let mut gen = StreamGen::new(5000, names, seed.unwrap_or(42));
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(&out)?);
        for _ in 0..n {
            // generator names are `f{j}` — always representable, but the
            // grammar check is typed now, so thread the error through
            writeln!(f, "{}", gen.next_update().to_line()?)?;
        }
        f.flush()?;
        println!("wrote {n} update triples to {out}");
        return Ok(());
    }
    let dataset = flags.get("dataset").cloned().unwrap_or_else(|| "osm".into());
    let scale = flag_or(flags, "scale", 0.1)?;
    let seed = flag_opt(flags, "seed")?;
    let out = flags.get("out").cloned().unwrap_or_else(|| format!("{dataset}.csv"));
    let ctx = presets::config_local().build();
    let ld = make_dataset(&dataset, scale, seed, &ctx)?;
    let rows = ld.dataset.rows.collect(&ctx)?;
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(&out)?);
    let names = ld.dataset.schema.names.join(",");
    writeln!(f, "{names},label")?;
    for r in rows {
        match &r.features {
            sparx::data::Features::Dense(v) => {
                let cells: Vec<String> = v.iter().map(|x| x.to_string()).collect();
                let label = ld.labels.get(r.id as usize).copied().unwrap_or(false);
                writeln!(f, "{},{}", cells.join(","), u8::from(label))?;
            }
            _ => {
                return Err(SparxError::Unsupported(
                    "generate: only dense datasets can be dumped to csv".into(),
                ));
            }
        }
    }
    println!("wrote {} rows to {out}", ld.dataset.len());
    Ok(())
}

// ----------------------------------------------------------------- info

fn cmd_info(flags: &HashMap<String, String>) -> CliResult {
    check_flags("info", flags, &[])?;
    println!("sparx — distributed outlier detection (KDD'22 reproduction)");
    println!("\ndetectors (sparx fit|detect --method …):");
    for name in registry::detector_names() {
        println!("  {name}");
    }
    println!("\ncluster presets (Table 5, scaled):");
    for name in ["config-mod", "config-gen", "local"] {
        let Some(c) = presets::by_name(name) else { continue };
        println!(
            "  {name}: partitions={} workers={} threads={} exec-mem={}MB deadline={:?}s",
            c.num_partitions,
            c.num_workers,
            c.num_threads,
            if c.worker_mem_bytes == usize::MAX { 0 } else { c.worker_mem_bytes / 1048576 },
            c.deadline_secs
        );
    }
    print!("\nAOT artifacts: ");
    match ArtifactManifest::load(&sparx::runtime::default_artifact_dir()) {
        Ok(m) => {
            println!("{} compiled modules", m.entries.len());
            for e in &m.entries {
                println!("  {}/{} b={} d={} k={} l={}", e.kind, e.name, e.b, e.d, e.k, e.l);
            }
            match PjrtEngine::start(&m) {
                Ok(_) => println!("PJRT CPU engine: OK"),
                Err(e) => println!("PJRT CPU engine: FAILED ({e})"),
            }
        }
        Err(e) => println!("not built ({e})"),
    }
    println!("\nDBSCOUT neighbourhood sizes (2⌈√d⌉+1)^d:");
    for d in [2usize, 6, 10, 11] {
        println!(
            "  d={d}: {:.2e} cells",
            sparx::baselines::dbscout::CostModel::neighbourhood_cells(d)
        );
    }
    Ok(())
}

// ----------------------------------------------------------------- main

const COMMANDS: [&str; 8] =
    ["fit", "score", "serve", "detect", "experiment", "stream", "generate", "info"];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    // every subcommand except `experiment <id>` is flags-only: stray
    // positionals are rejected, not silently dropped
    let no_positionals = |cmd: &str| -> CliResult {
        if pos.len() > 1 {
            Err(usage_err(format!(
                "{cmd} takes no positional arguments, got {:?}",
                pos.get(1..).unwrap_or(&[])
            )))
        } else {
            Ok(())
        }
    };
    let result: CliResult = match pos.first().map(String::as_str) {
        Some("fit") => no_positionals("fit").and_then(|()| cmd_fit(&flags)),
        Some("score") => no_positionals("score").and_then(|()| cmd_score(&flags)),
        Some("serve") => no_positionals("serve").and_then(|()| cmd_serve(&flags)),
        Some("detect") => no_positionals("detect").and_then(|()| cmd_detect(&flags)),
        Some("experiment") => cmd_experiment(pos.get(1..).unwrap_or(&[]), &flags),
        Some("stream") => no_positionals("stream").and_then(|()| cmd_stream(&flags)),
        Some("generate") => no_positionals("generate").and_then(|()| cmd_generate(&flags)),
        Some("info") => no_positionals("info").and_then(|()| cmd_info(&flags)),
        Some(other) => {
            let hint = closest_match(other, &COMMANDS)
                .map(|s| format!(" (did you mean `sparx {s}`?)"))
                .unwrap_or_default();
            Err(usage_err(format!(
                "unknown subcommand {other:?}{hint}; expected one of: {}",
                COMMANDS.join(", ")
            )))
        }
        None => Err(usage_err(format!(
            "usage: sparx <{}> [flags] — see the module docs in rust/src/main.rs",
            COMMANDS.join("|")
        ))),
    };
    if let Err(e) = result {
        eprintln!("sparx: {e}");
        std::process::exit(e.exit_code());
    }
}
