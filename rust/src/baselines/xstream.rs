//! Single-machine xStream (Manzoor, Lamba & Akoglu, KDD 2018) — the
//! sequential reference Sparx distributes. Used as the denominator of the
//! Fig. 5 speed-up curve and as a numeric cross-check: on identical
//! chain parameters, Sparx and xStream must produce identical counts.
//!
//! Everything runs on one thread over plain `Vec`s: projection (Eq. 2),
//! chain fitting with point-wise CMS inserts, scoring (Eq. 5).

use crate::api::artifact::{self, ModelArtifact};
use crate::api::{self, validate, Detector, FittedModel, SparxError};
use crate::cluster::ClusterContext;
use crate::data::{Dataset, Row};
use crate::sparx::plan::chain_rng;
use crate::sparx::{ChainParams, CountMinSketch, Projector, ScoreMode, SparxModel, TrainedChain};
use crate::util::codec::{Decoder, Encoder};

#[derive(Debug, Clone)]
pub struct XStreamParams {
    pub k: usize,
    pub num_chains: usize,
    pub depth: usize,
    pub cms_rows: usize,
    pub cms_cols: usize,
    pub density: f64,
    pub score_mode: ScoreMode,
    pub seed: u64,
}

impl Default for XStreamParams {
    fn default() -> Self {
        XStreamParams {
            k: 50,
            num_chains: 50,
            depth: 10,
            cms_rows: 10,
            cms_cols: 100,
            density: 1.0 / 3.0,
            score_mode: ScoreMode::Log2,
            seed: 0x5AB4,
        }
    }
}

impl XStreamParams {
    /// Same hyperparameter sanity rules as [`crate::sparx::SparxParams`]
    /// — the two implementations must accept identical settings for the
    /// cross-check tests to be meaningful.
    pub fn validate(&self) -> std::result::Result<(), String> {
        validate::at_least_one(self.num_chains, "num_chains (M)")?;
        validate::at_least_one(self.depth, "depth (L)")?;
        validate::cms_shape(self.cms_rows, self.cms_cols)?;
        validate::unit_interval(self.density, "density")?;
        Ok(())
    }
}

/// A fitted single-machine model.
pub struct XStream {
    pub params: XStreamParams,
    pub projector: Projector,
    pub deltamax: Vec<f32>,
    pub chains: Vec<TrainedChain>,
}

impl XStream {
    /// Fit sequentially on a local slice of rows.
    pub fn fit(rows: &[Row], feature_names: &[String], params: &XStreamParams) -> XStream {
        let projector = if params.k == 0 {
            Projector::identity(feature_names.len())
        } else {
            Projector::new(params.k, params.density).with_dense_schema(feature_names)
        };
        Self::fit_with_projector(rows, feature_names, params, projector)
    }

    /// [`fit`](Self::fit) against a caller-supplied projector — the SUOD
    /// shared-projection path: the ensemble layer hands members with
    /// compatible `(k, density)` schemas clones of one projector (cheap
    /// `Arc` shares of its R matrix). The projector must match
    /// `params.k`; callers own that agreement.
    pub fn fit_with_projector(
        rows: &[Row],
        feature_names: &[String],
        params: &XStreamParams,
        projector: Projector,
    ) -> XStream {
        let sketches: Vec<Vec<f32>> = rows.iter().map(|r| projector.project(r, None).s).collect();
        let kdim = if params.k == 0 { feature_names.len() } else { params.k };
        // deltamax = half range per projected dim
        let mut lo = vec![f32::INFINITY; kdim];
        let mut hi = vec![f32::NEG_INFINITY; kdim];
        for s in &sketches {
            for j in 0..kdim {
                lo[j] = lo[j].min(s[j]);
                hi[j] = hi[j].max(s[j]);
            }
        }
        let deltamax: Vec<f32> = lo
            .iter()
            .zip(&hi)
            .map(|(&l, &h)| {
                let d = (h - l) / 2.0;
                if d.is_finite() && d > 1e-12 {
                    d
                } else {
                    0.5
                }
            })
            .collect();
        // sequential chain fitting (the for-loop the paper contrasts with
        // Sparx's thread pool, §3.2.2)
        let mut chains = Vec::with_capacity(params.num_chains);
        for m in 0..params.num_chains {
            let mut rng = chain_rng(params.seed, m);
            let cp = ChainParams::sample(&deltamax, params.depth, &mut rng);
            let mut cms: Vec<CountMinSketch> = (0..params.depth)
                .map(|_| CountMinSketch::new(params.cms_rows, params.cms_cols))
                .collect();
            let mut scratch = vec![0f32; kdim];
            let mut bins = vec![0i32; params.depth * kdim];
            for s in &sketches {
                cp.bins_into(s, &mut scratch, &mut bins);
                for (lvl, c) in cms.iter_mut().enumerate() {
                    c.insert(&bins[lvl * kdim..(lvl + 1) * kdim]);
                }
            }
            chains.push(TrainedChain { params: cp, cms });
        }
        XStream { params: params.clone(), projector, deltamax, chains }
    }

    /// Score rows sequentially; returns outlierness (higher = more outlying).
    pub fn score(&self, rows: &[Row]) -> Vec<(u64, f64)> {
        let kdim = self.deltamax.len();
        let mut scratch = vec![0f32; kdim];
        let mut bins = vec![0i32; self.params.depth * kdim];
        rows.iter()
            .map(|r| {
                let s = self.projector.project(r, None).s;
                let mut total = 0.0;
                for chain in &self.chains {
                    total += SparxModel::score_sketch_against(
                        chain,
                        self.params.score_mode,
                        &s,
                        &mut scratch,
                        &mut bins,
                    );
                }
                (r.id, -(total / self.chains.len() as f64))
            })
            .collect()
    }

    /// Deployable model footprint: the serialized artifact payload
    /// (projector + Δmax + chains with their CMS counts).
    pub fn model_bytes(&self) -> usize {
        self.encode_payload().len()
    }

    fn encode_params(&self) -> Vec<u8> {
        let p = &self.params;
        let mut enc = Encoder::new();
        enc.put_usize(p.k);
        enc.put_usize(p.num_chains);
        enc.put_usize(p.depth);
        enc.put_usize(p.cms_rows);
        enc.put_usize(p.cms_cols);
        enc.put_f64(p.density);
        artifact::encode_score_mode(&mut enc, p.score_mode);
        enc.put_u64(p.seed);
        enc.into_bytes()
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut enc = Encoder::new();
        artifact::encode_chain_ensemble(
            &mut enc,
            &self.projector,
            &self.deltamax,
            &self.chains,
            artifact::FORMAT_VERSION,
        );
        enc.into_bytes()
    }

    /// Rehydrate a fitted xStream from an artifact's blocks.
    pub fn from_artifact(art: &ModelArtifact) -> api::Result<XStream> {
        let blk = |e| artifact::block_err("xstream", e);
        let mut dec = Decoder::new(&art.params);
        let params = XStreamParams {
            k: dec.usize().map_err(blk)?,
            num_chains: dec.usize().map_err(blk)?,
            depth: dec.usize().map_err(blk)?,
            cms_rows: dec.usize().map_err(blk)?,
            cms_cols: dec.usize().map_err(blk)?,
            density: dec.f64().map_err(blk)?,
            score_mode: artifact::decode_score_mode(&mut dec).map_err(blk)?,
            seed: dec.u64().map_err(blk)?,
        };
        dec.finish().map_err(blk)?;
        params.validate().map_err(SparxError::InvalidParams)?;
        let (projector, deltamax, chains) = artifact::decode_chain_ensemble(
            &art.payload,
            params.k,
            params.num_chains,
            params.depth,
            art.version,
        )
        .map_err(blk)?;
        Ok(XStream { params, projector, deltamax, chains })
    }
}

/// [`Detector`] adapter for the single-machine reference: `fit` collects
/// the dataset to the driver (paying the collect through the ledger and
/// the driver memory meter — this *is* the single-machine story Fig. 5
/// divides by) and runs the sequential implementation.
pub struct XStreamDetector {
    params: XStreamParams,
}

impl XStreamDetector {
    pub fn new(params: XStreamParams) -> api::Result<Self> {
        params.validate().map_err(SparxError::InvalidParams)?;
        Ok(XStreamDetector { params })
    }

    pub fn params(&self) -> &XStreamParams {
        &self.params
    }
}

impl Detector for XStreamDetector {
    fn name(&self) -> &'static str {
        "xstream"
    }

    fn fit(&self, ctx: &ClusterContext, data: &Dataset) -> api::Result<Box<dyn FittedModel>> {
        let rows = data.rows.collect(ctx)?;
        Ok(Box::new(XStream::fit(&rows, &data.schema.names, &self.params)))
    }
}

impl FittedModel for XStream {
    fn name(&self) -> &'static str {
        "xstream"
    }

    fn score(&self, ctx: &ClusterContext, data: &Dataset) -> api::Result<Vec<(u64, f64)>> {
        api::check_projector_input(&self.projector, data)?;
        let rows = data.rows.collect(ctx)?;
        Ok(XStream::score(self, &rows))
    }

    fn to_artifact(&self) -> api::Result<ModelArtifact> {
        Ok(ModelArtifact::new("xstream", self.encode_params(), self.encode_payload()))
    }

    fn model_bytes(&self) -> usize {
        XStream::model_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::data::generators::GisetteGen;
    use crate::sparx::SparxParams;

    #[test]
    fn detects_planted_outliers() {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 1000, d: 32, ..Default::default() }.generate(&ctx).unwrap();
        let rows = ld.dataset.rows.collect(&ctx).unwrap();
        let model = XStream::fit(
            &rows,
            &ld.dataset.schema.names,
            &XStreamParams { k: 16, num_chains: 20, depth: 8, ..Default::default() },
        );
        let scored = model.score(&rows);
        let mut s = vec![0.0; 1000];
        for (id, sc) in scored {
            s[id as usize] = sc;
        }
        let auc = crate::metrics::auroc(&s, &ld.labels);
        assert!(auc > 0.58, "xStream above chance: {auc}");
    }

    #[test]
    fn matches_sparx_scores_exactly_at_full_rate() {
        // same seeds + full sampling ⇒ the distributed and single-machine
        // implementations must agree to the last bit
        let ctx = ClusterConfig { num_partitions: 4, num_workers: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 400, d: 16, ..Default::default() }.generate(&ctx).unwrap();
        let rows = ld.dataset.rows.collect(&ctx).unwrap();

        let sp = SparxParams {
            k: 8,
            num_chains: 6,
            depth: 5,
            sample_rate: 1.0,
            ..Default::default()
        };
        let xp = XStreamParams {
            k: 8,
            num_chains: 6,
            depth: 5,
            cms_rows: sp.cms_rows,
            cms_cols: sp.cms_cols,
            density: sp.density,
            score_mode: sp.score_mode,
            seed: sp.seed,
        };
        let dist = SparxModel::fit(&ctx, &ld.dataset, &sp).unwrap();
        let local = XStream::fit(&rows, &ld.dataset.schema.names, &xp);

        // identical chain parameters...
        for (a, b) in dist.chains.iter().zip(&local.chains) {
            assert_eq!(a.params, b.params);
        }
        // ...identical CMS contents...
        for (a, b) in dist.chains.iter().zip(&local.chains) {
            assert_eq!(a.cms, b.cms, "distributed counting diverged from sequential");
        }
        // ...identical scores
        let mut ds = dist.score_dataset(&ctx, &ld.dataset).unwrap();
        let mut ls = local.score(&rows);
        ds.sort_by_key(|(id, _)| *id);
        ls.sort_by_key(|(id, _)| *id);
        for ((i1, s1), (i2, s2)) in ds.iter().zip(&ls) {
            assert_eq!(i1, i2);
            assert!((s1 - s2).abs() < 1e-12, "id {i1}: {s1} vs {s2}");
        }
    }
}
