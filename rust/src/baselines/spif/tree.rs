//! Isolation tree (Liu, Ting & Zhou 2008): extremely randomized binary
//! partitioning. Anomalies isolate in few splits ⇒ short path length.

use crate::util::codec::{CodecResult, Decoder, Encoder};
use crate::util::{Rng, SizeOf};

/// Flat node-array isolation tree over dense f32 rows.
#[derive(Debug, Clone)]
pub struct ITree {
    nodes: Vec<Node>,
    /// Training subsample size (for the c(n) normalisation).
    pub sample_size: usize,
}

#[derive(Debug, Clone)]
enum Node {
    /// (feature, threshold, left child idx, right child idx)
    Split(u32, f32, u32, u32),
    /// Leaf holding `size` training points at depth `depth`.
    Leaf { size: u32 },
}

impl SizeOf for Node {
    fn size_of(&self) -> usize {
        std::mem::size_of::<Node>()
    }
}

impl SizeOf for ITree {
    fn size_of(&self) -> usize {
        std::mem::size_of::<Self>() + self.nodes.len() * std::mem::size_of::<Node>()
    }
}

/// Average unsuccessful-search path length in a BST of n nodes — the
/// standard iForest normaliser c(n).
pub fn c_factor(n: usize) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let n = n as f64;
    2.0 * ((n - 1.0).ln() + 0.5772156649) - 2.0 * (n - 1.0) / n
}

impl ITree {
    /// Build on a subsample (rows indexed into `data`, each `dim` wide).
    pub fn fit(data: &[Vec<f32>], max_depth: usize, rng: &mut Rng) -> ITree {
        let n = data.len();
        let mut nodes = Vec::new();
        let mut idx: Vec<u32> = (0..n as u32).collect();
        Self::build(data, &mut idx, 0, n, 0, max_depth, rng, &mut nodes);
        ITree { nodes, sample_size: n }
    }

    #[allow(clippy::too_many_arguments)]
    fn build(
        data: &[Vec<f32>],
        idx: &mut [u32],
        lo: usize,
        hi: usize,
        depth: usize,
        max_depth: usize,
        rng: &mut Rng,
        nodes: &mut Vec<Node>,
    ) -> u32 {
        let me = nodes.len() as u32;
        let count = hi - lo;
        if count <= 1 || depth >= max_depth {
            nodes.push(Node::Leaf { size: count as u32 });
            return me;
        }
        let dim = data[idx[lo] as usize].len();
        // pick a feature with spread (up to a few retries, as in iForest impls)
        let mut feat = 0usize;
        let mut fmin = 0f32;
        let mut fmax = 0f32;
        let mut found = false;
        for _ in 0..8 {
            feat = rng.below(dim as u64) as usize;
            fmin = f32::INFINITY;
            fmax = f32::NEG_INFINITY;
            for &i in &idx[lo..hi] {
                let v = data[i as usize][feat];
                fmin = fmin.min(v);
                fmax = fmax.max(v);
            }
            if fmax > fmin {
                found = true;
                break;
            }
        }
        if !found {
            nodes.push(Node::Leaf { size: count as u32 });
            return me;
        }
        let thr = fmin + rng.f32() * (fmax - fmin);
        // partition in place
        let mut mid = lo;
        for i in lo..hi {
            if data[idx[i] as usize][feat] < thr {
                idx.swap(i, mid);
                mid += 1;
            }
        }
        if mid == lo || mid == hi {
            // degenerate split (can happen when thr == fmax)
            nodes.push(Node::Leaf { size: count as u32 });
            return me;
        }
        nodes.push(Node::Split(feat as u32, thr, 0, 0)); // children patched below
        let left = Self::build(data, idx, lo, mid, depth + 1, max_depth, rng, nodes);
        let right = Self::build(data, idx, mid, hi, depth + 1, max_depth, rng, nodes);
        if let Node::Split(_, _, l, r) = &mut nodes[me as usize] {
            *l = left;
            *r = right;
        }
        me
    }

    /// Path length of a query point, with the leaf-size c(n) adjustment.
    pub fn path_length(&self, x: &[f32]) -> f64 {
        let mut node = 0u32;
        let mut depth = 0usize;
        loop {
            match &self.nodes[node as usize] {
                Node::Split(f, thr, l, r) => {
                    node = if x[*f as usize] < *thr { *l } else { *r };
                    depth += 1;
                }
                Node::Leaf { size } => {
                    return depth as f64 + c_factor(*size as usize);
                }
            }
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Serialize the flat node array (model-artifact payload).
    pub fn encode(&self, enc: &mut Encoder) {
        enc.put_usize(self.sample_size);
        enc.put_u32(self.nodes.len() as u32);
        for node in &self.nodes {
            match node {
                Node::Split(f, thr, l, r) => {
                    enc.put_u8(0);
                    enc.put_u32(*f);
                    enc.put_f32(*thr);
                    enc.put_u32(*l);
                    enc.put_u32(*r);
                }
                Node::Leaf { size } => {
                    enc.put_u8(1);
                    enc.put_u32(*size);
                }
            }
        }
    }

    /// Deserialize a tree, validating child indices so a malformed
    /// artifact can never send `path_length` out of bounds — children
    /// must point strictly *forward* (as `fit` builds them), which also
    /// rules out cycles that would hang traversal.
    pub(crate) fn decode(dec: &mut Decoder) -> CodecResult<ITree> {
        let sample_size = dec.usize()?;
        let n = dec.u32()? as usize;
        if n == 0 {
            return Err("tree has no nodes".into());
        }
        let mut nodes = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            nodes.push(match dec.u8()? {
                0 => Node::Split(dec.u32()?, dec.f32()?, dec.u32()?, dec.u32()?),
                1 => Node::Leaf { size: dec.u32()? },
                other => return Err(format!("unknown tree node tag {other}")),
            });
        }
        for (i, node) in nodes.iter().enumerate() {
            if let Node::Split(_, _, l, r) = node {
                let (l, r) = (*l as usize, *r as usize);
                if l >= n || r >= n || l <= i || r <= i {
                    return Err(format!(
                        "tree child indices must point forward: node {i} -> {l}/{r} of {n}"
                    ));
                }
            }
        }
        Ok(ITree { nodes, sample_size })
    }

    /// Largest feature index any split consults (None for a single-leaf
    /// tree). Scoring guards input dimensionality with this.
    pub fn max_feature(&self) -> Option<u32> {
        self.nodes
            .iter()
            .filter_map(|node| match node {
                Node::Split(f, _, _, _) => Some(*f),
                Node::Leaf { .. } => None,
            })
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(rng: &mut Rng, n: usize, d: usize, center: f32) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..d).map(|_| center + rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn c_factor_monotone() {
        assert_eq!(c_factor(1), 0.0);
        assert!(c_factor(10) < c_factor(100));
        // c(256) ≈ 10.2 (well-known iForest constant)
        assert!((c_factor(256) - 10.2).abs() < 0.3, "{}", c_factor(256));
    }

    #[test]
    fn isolates_far_point_quickly() {
        let mut rng = Rng::new(1);
        let mut data = blob(&mut rng, 500, 4, 0.0);
        data.push(vec![50.0; 4]); // far outlier
        let mut inlier_depth = 0.0;
        let mut outlier_depth = 0.0;
        for seed in 0..20 {
            let mut r = Rng::new(seed);
            let t = ITree::fit(&data, 12, &mut r);
            outlier_depth += t.path_length(&vec![50.0; 4]);
            inlier_depth += t.path_length(&data[0]);
        }
        assert!(
            outlier_depth < inlier_depth * 0.7,
            "outlier {outlier_depth} vs inlier {inlier_depth}"
        );
    }

    #[test]
    fn handles_constant_data() {
        let data = vec![vec![1.0, 1.0]; 50];
        let mut rng = Rng::new(2);
        let t = ITree::fit(&data, 8, &mut rng);
        // no split possible → single leaf
        assert_eq!(t.num_nodes(), 1);
        assert!(t.path_length(&[1.0, 1.0]) > 0.0);
    }

    #[test]
    fn depth_limit_respected() {
        let mut rng = Rng::new(3);
        let data = blob(&mut rng, 1000, 2, 0.0);
        let t = ITree::fit(&data, 3, &mut rng);
        // path length ≤ max_depth + c(leaf size)
        let p = t.path_length(&data[0]);
        assert!(p <= 3.0 + c_factor(1000), "{p}");
    }

    #[test]
    fn single_point() {
        let data = vec![vec![0.5]];
        let mut rng = Rng::new(4);
        let t = ITree::fit(&data, 8, &mut rng);
        assert_eq!(t.path_length(&[0.5]), 0.0);
    }

    #[test]
    fn codec_round_trips_path_lengths_exactly() {
        let mut rng = Rng::new(9);
        let data = blob(&mut rng, 300, 3, 0.0);
        let t = ITree::fit(&data, 10, &mut rng);
        let mut enc = Encoder::new();
        t.encode(&mut enc);
        let bytes = enc.into_bytes();
        let back = ITree::decode(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(back.num_nodes(), t.num_nodes());
        assert_eq!(back.sample_size, t.sample_size);
        for p in &data[..10] {
            assert_eq!(t.path_length(p), back.path_length(p));
        }
        // truncated input is an error, not a panic
        assert!(ITree::decode(&mut Decoder::new(&bytes[..bytes.len() / 2])).is_err());
    }

    /// A split whose children point at itself (a cycle) must be rejected
    /// at decode — otherwise `path_length` would hang on a crafted
    /// artifact that passes the file checksum.
    #[test]
    fn decode_rejects_non_forward_children() {
        let mut enc = Encoder::new();
        enc.put_usize(10); // sample_size
        enc.put_u32(1); // node count
        enc.put_u8(0); // Split
        enc.put_u32(0); // feature
        enc.put_f32(0.5); // threshold
        enc.put_u32(0); // left -> itself
        enc.put_u32(0); // right -> itself
        let bytes = enc.into_bytes();
        assert!(ITree::decode(&mut Decoder::new(&bytes)).is_err());
    }
}
