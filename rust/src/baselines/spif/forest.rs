//! SPIF forest: model-parallel fit with the per-tree subsample shuffle,
//! data-parallel scoring with a broadcast forest.

use crate::api::artifact::{self, ModelArtifact};
use crate::api::{self, validate, Detector, FittedModel, SparxError};
use crate::cluster::dist::Broadcast;
use crate::cluster::{pool, ClusterContext, DistVec, Result};
use crate::data::{Dataset, Row};
use crate::util::codec::{CodecResult, Decoder, Encoder};
use crate::util::{Rng, SizeOf};

use super::tree::{c_factor, ITree};

#[derive(Debug, Clone)]
pub struct SpifParams {
    /// Ensemble size (#components in the paper's tables).
    pub num_trees: usize,
    /// Tree depth cap.
    pub max_depth: usize,
    /// Subsample rate per tree (of the *fit* input).
    pub sample_rate: f64,
    pub seed: u64,
}

impl Default for SpifParams {
    fn default() -> Self {
        SpifParams { num_trees: 50, max_depth: 10, sample_rate: 0.01, seed: 0x5F1F }
    }
}

impl SpifParams {
    /// Hyperparameter sanity rules, mirrored on the other detectors.
    pub fn validate(&self) -> std::result::Result<(), String> {
        validate::at_least_one(self.num_trees, "num_trees (#components)")?;
        validate::at_least_one(self.max_depth, "max_depth")?;
        validate::unit_interval(self.sample_rate, "sample_rate")?;
        Ok(())
    }
}

/// A fitted SPIF model.
pub struct Spif {
    pub params: SpifParams,
    pub trees: Vec<ITree>,
}

impl Spif {
    /// Fit the forest. **Not data-parallel**: for each tree, the Bernoulli
    /// subsample is shuffled in full to the tree's designated worker
    /// (bytes + records accounted; worker memory charged for the gathered
    /// sample while the tree builds). Requires dense rows — the public
    /// SPIF implementation cannot handle sparse RDDs (§4.2.5), so sparse
    /// data must be projected first, exactly as the paper had to.
    pub fn fit(ctx: &ClusterContext, data: &Dataset, params: &SpifParams) -> Result<Spif> {
        let trees = pool::try_run_indexed(ctx.cfg.num_threads, params.num_trees, |t| {
            ctx.check_deadline()?;
            let target_worker = t % ctx.cfg.num_workers;
            // map phase: <tree-ID, point> pairs for this tree's subsample
            let sample = data.rows.sample(ctx, params.sample_rate, params.seed ^ (t as u64))?;
            // reduce phase: every sampled point crosses the network to the
            // single worker that builds tree t (the "(!)" in §4.1.2)
            let mut bytes = 0usize;
            let mut records = 0usize;
            let mut gathered: Vec<Vec<f32>> = Vec::with_capacity(sample.len());
            for p in 0..sample.num_parts() {
                let from_worker = ctx.owner(p);
                for row in sample.part(p) {
                    let dense = row.features.as_dense().to_vec();
                    if from_worker != target_worker {
                        bytes += row.size_of();
                        records += 1;
                    }
                    gathered.push(dense);
                }
            }
            ctx.ledger.add(bytes, records);
            ctx.ledger.add_round();
            // the gathered subsample materialises on one worker: this is
            // the allocation that OOMs on large n (Table 4 MEM ERR)
            let gathered_bytes: usize =
                gathered.iter().map(|v| v.len() * 4 + 24).sum::<usize>();
            ctx.charge_worker(target_worker, gathered_bytes)?;
            ctx.check_deadline()?;
            let mut rng = Rng::new(params.seed.wrapping_add(0xF0 + t as u64));
            let tree = ITree::fit(&gathered, params.max_depth, &mut rng);
            ctx.worker_mem[target_worker].release(gathered_bytes);
            Ok(tree)
        })?;
        Ok(Spif { params: params.clone(), trees })
    }

    /// Score every point (data-parallel: broadcast forest, local map).
    /// Returns `(id, outlierness)` with higher = more anomalous — the
    /// standard iForest score s = 2^(−E[h]/c(ψ)).
    pub fn score_dataset(&self, ctx: &ClusterContext, data: &Dataset) -> Result<Vec<(u64, f64)>> {
        let bcast: Broadcast<Vec<ITree>> = Broadcast::new(ctx, self.trees.clone())?;
        let scored: DistVec<(u64, f64)> = data.rows.map_partitions(ctx, |_, part| {
            let trees = bcast.value();
            Ok(part
                .iter()
                .map(|row: &Row| {
                    let x = row.features.as_dense();
                    let mut h = 0.0;
                    for t in trees.iter() {
                        h += t.path_length(x);
                    }
                    let e_h = h / trees.len() as f64;
                    let c = c_factor(trees[0].sample_size.max(2));
                    (row.id, 2f64.powf(-e_h / c))
                })
                .collect())
        })?;
        scored.collect(ctx)
    }

    /// Deployable model footprint: the serialized artifact payload (the
    /// tree pool).
    pub fn model_bytes(&self) -> usize {
        self.encode_payload().len()
    }

    fn encode_params(&self) -> Vec<u8> {
        let mut enc = Encoder::new();
        enc.put_usize(self.params.num_trees);
        enc.put_usize(self.params.max_depth);
        enc.put_f64(self.params.sample_rate);
        enc.put_u64(self.params.seed);
        enc.into_bytes()
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut enc = Encoder::new();
        enc.put_u32(self.trees.len() as u32);
        for tree in &self.trees {
            tree.encode(&mut enc);
        }
        enc.into_bytes()
    }

    /// Rehydrate a fitted forest from an artifact's blocks.
    pub fn from_artifact(art: &ModelArtifact) -> api::Result<Spif> {
        let blk = |e| artifact::block_err("spif", e);
        let mut dec = Decoder::new(&art.params);
        let params = SpifParams {
            num_trees: dec.usize().map_err(blk)?,
            max_depth: dec.usize().map_err(blk)?,
            sample_rate: dec.f64().map_err(blk)?,
            seed: dec.u64().map_err(blk)?,
        };
        dec.finish().map_err(blk)?;
        params.validate().map_err(SparxError::InvalidParams)?;
        let mut dec = Decoder::new(&art.payload);
        let t = dec.u32().map_err(blk)? as usize;
        if t != params.num_trees {
            return Err(blk(format!(
                "payload has {t} trees, params declare {}",
                params.num_trees
            )));
        }
        let trees = (0..t)
            .map(|_| ITree::decode(&mut dec))
            .collect::<CodecResult<Vec<_>>>()
            .map_err(blk)?;
        dec.finish().map_err(blk)?;
        Ok(Spif { params, trees })
    }
}

/// [`Detector`] adapter. Fitting keeps SPIF's own (flawed) topology — the
/// per-tree subsample shuffle — under the unified contract; the adapter
/// only adds the dense-input guard the public implementation enforces by
/// crashing (§4.2.5).
pub struct SpifDetector {
    params: SpifParams,
}

impl SpifDetector {
    pub fn new(params: SpifParams) -> api::Result<Self> {
        params.validate().map_err(SparxError::InvalidParams)?;
        Ok(SpifDetector { params })
    }

    pub fn params(&self) -> &SpifParams {
        &self.params
    }
}

impl Detector for SpifDetector {
    fn name(&self) -> &'static str {
        "spif"
    }

    fn fit(&self, ctx: &ClusterContext, data: &Dataset) -> api::Result<Box<dyn FittedModel>> {
        api::ensure_dense(data, "SPIF")?;
        Ok(Box::new(Spif::fit(ctx, data, &self.params)?))
    }
}

impl FittedModel for Spif {
    fn name(&self) -> &'static str {
        "spif"
    }

    fn score(&self, ctx: &ClusterContext, data: &Dataset) -> api::Result<Vec<(u64, f64)>> {
        api::ensure_dense(data, "SPIF")?;
        // with the fit/score split the scored dataset can be narrower
        // than the fitted one — fail typed before path_length indexes
        // past a row's end
        if let Some(f) = self.trees.iter().filter_map(ITree::max_feature).max() {
            if data.dim() <= f as usize {
                return Err(SparxError::InvalidParams(format!(
                    "model splits on feature {f} but the dataset has only {} columns",
                    data.dim()
                )));
            }
        }
        Ok(self.score_dataset(ctx, data)?)
    }

    fn to_artifact(&self) -> api::Result<ModelArtifact> {
        Ok(ModelArtifact::new("spif", self.encode_params(), self.encode_payload()))
    }

    fn model_bytes(&self) -> usize {
        Spif::model_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterConfig, ClusterError};
    use crate::data::generators::GisetteGen;

    fn ctx() -> ClusterContext {
        ClusterConfig { num_partitions: 4, num_workers: 2, num_threads: 2, ..Default::default() }
            .build()
    }

    #[test]
    fn detects_planted_outliers() {
        let c = ctx();
        let ld = GisetteGen { n: 1500, d: 32, ..Default::default() }.generate(&c).unwrap();
        let p = SpifParams { num_trees: 50, max_depth: 10, sample_rate: 0.3, ..Default::default() };
        let model = Spif::fit(&c, &ld.dataset, &p).unwrap();
        let scores = model.score_dataset(&c, &ld.dataset).unwrap();
        let mut s = vec![0.0; 1500];
        for (id, sc) in scores {
            s[id as usize] = sc;
        }
        let auc = crate::metrics::auroc(&s, &ld.labels);
        assert!(auc > 0.55, "iForest above chance: {auc}");
    }

    #[test]
    fn fit_shuffles_data_to_workers() {
        let c = ctx();
        let ld = GisetteGen { n: 1000, d: 16, ..Default::default() }.generate(&c).unwrap();
        let before = c.ledger.bytes();
        let p = SpifParams { num_trees: 4, sample_rate: 0.5, ..Default::default() };
        let _ = Spif::fit(&c, &ld.dataset, &p).unwrap();
        let moved = c.ledger.bytes() - before;
        // roughly: trees × rate × n × rowbytes × (1 − 1/W) must have moved
        assert!(moved > 4 * 400 * 16, "SPIF must pay the subsample shuffle: {moved}B");
    }

    #[test]
    fn large_subsample_hits_memory_budget() {
        // reproduce Table 4's MEM ERR: single worker cannot hold a tree's
        // gathered subsample
        let c = ClusterConfig {
            num_partitions: 4,
            num_workers: 2,
            num_threads: 1,
            // data fits (≈512KB/worker) but one worker cannot also hold a
            // full gathered subsample (+1MB)
            worker_mem_bytes: 800 * 1024,
            ..Default::default()
        }
        .build();
        let ld = GisetteGen { n: 4000, d: 64, ..Default::default() }.generate(&c).unwrap();
        let p = SpifParams { num_trees: 2, sample_rate: 1.0, ..Default::default() };
        let r = Spif::fit(&c, &ld.dataset, &p);
        assert!(
            matches!(r, Err(ClusterError::MemExceeded { .. })),
            "expected MEM ERR, got {r:?}",
            r = r.err()
        );
    }

    #[test]
    fn scoring_covers_all_points_even_with_tiny_fit() {
        let c = ctx();
        let ld = GisetteGen { n: 2000, d: 16, ..Default::default() }.generate(&c).unwrap();
        let p = SpifParams { num_trees: 8, sample_rate: 0.02, ..Default::default() };
        let model = Spif::fit(&c, &ld.dataset, &p).unwrap();
        let scores = model.score_dataset(&c, &ld.dataset).unwrap();
        assert_eq!(scores.len(), 2000);
    }

    #[test]
    fn deterministic_given_seed() {
        let c = ctx();
        let ld = GisetteGen { n: 500, d: 8, ..Default::default() }.generate(&c).unwrap();
        let p = SpifParams { num_trees: 4, sample_rate: 0.5, ..Default::default() };
        let a = Spif::fit(&c, &ld.dataset, &p).unwrap().score_dataset(&c, &ld.dataset).unwrap();
        let b = Spif::fit(&c, &ld.dataset, &p).unwrap().score_dataset(&c, &ld.dataset).unwrap();
        assert_eq!(a, b);
    }
}
