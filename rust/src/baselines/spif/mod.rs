//! SPIF (Tao et al. 2018): Spark-based Isolation Forest with the
//! public implementation's topology (§4.1.2 baseline 2).
//!
//! The crucial property reproduced here is **model-parallelism without
//! data-parallelism**: during fitting, `<tree-ID, point>` pairs are
//! generated in a map phase and a `reduceByKey` shuffles *all points of a
//! tree's subsample to one worker* (the paper's "(!)"), which builds the
//! tree locally. "Code goes to data" is violated — data goes to code —
//! so network bytes and single-worker memory scale with `n · rate`,
//! which is exactly what detonates in Table 4 (MEM ERR → TIMEOUT).
//!
//! Scoring *is* data-parallel (forest broadcast, local map), as in SPIF.

pub mod forest;
pub mod tree;

pub use forest::{Spif, SpifDetector, SpifParams};
