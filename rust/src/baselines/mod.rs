//! The paper's comparators, each re-implemented on the same shared-nothing
//! substrate so that resource accounting is apples-to-apples:
//!
//! * [`spif`] — Spark-based Isolation Forest (Tao et al.), with its
//!   *model-parallel but not data-parallel* topology: every tree's
//!   subsample is shuffled to a single worker (the design flaw Table 4
//!   exposes).
//! * [`dbscout`] — cell-grid distance-based OD (Corain et al.): fast and
//!   accurate at d ≤ 3, exponentially doomed in d (Table 2), binary
//!   output only.
//! * [`xstream`] — the single-machine xStream reference, used as the
//!   speed-up denominator in Fig. 5.
//!
//! Each baseline also implements the unified [`crate::api::Detector`]
//! contract (`XStreamDetector`, `SpifDetector`, `DbscoutDetector`), so
//! the CLI and the experiment harnesses drive all methods — Sparx
//! included — through one fit/score codepath.

pub mod dbscout;
pub mod spif;
pub mod xstream;

pub use dbscout::{Dbscout, DbscoutDetector, DbscoutParams};
pub use spif::{Spif, SpifDetector, SpifParams};
pub use xstream::{XStream, XStreamDetector, XStreamParams};
