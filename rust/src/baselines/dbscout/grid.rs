//! DBSCOUT grid implementation. See `mod.rs` for the algorithm and the
//! scale-substitution story.

use std::collections::HashMap;

use crate::api::artifact::{self, ModelArtifact};
use crate::api::{self, validate, Detector, FittedModel, SparxError};
use crate::cluster::dist::Broadcast;
use crate::cluster::{ClusterContext, Result};
use crate::data::Dataset;
use crate::util::codec::{Decoder, Encoder};
use crate::util::SizeOf;

#[derive(Debug, Clone)]
pub struct DbscoutParams {
    /// DBSCAN eps (same units as the data).
    pub eps: f64,
    /// DBSCAN minPts.
    pub min_pts: usize,
    /// Cost model for the super-literal regime.
    pub cost: CostModel,
}

impl Default for DbscoutParams {
    fn default() -> Self {
        DbscoutParams { eps: 0.5, min_pts: 8, cost: CostModel::default() }
    }
}

impl DbscoutParams {
    /// Hyperparameter sanity rules, mirrored on the other detectors.
    pub fn validate(&self) -> std::result::Result<(), String> {
        validate::positive_finite(self.eps, "eps")?;
        validate::at_least_one(self.min_pts, "min_pts")?;
        Ok(())
    }
}

/// Calibrated cost model for the geometric neighbourhood enumeration at
/// dimensions where it cannot run literally. Charged per query cell:
/// `(2⌈√d⌉+1)^(d/2) · secs_per_unit / num_workers` job seconds and a
/// transient `(2⌈√d⌉+1)^(d/2) · bytes_per_unit` worker allocation —
/// calibrated against Table 2's published growth (11s → 3420s → 8h-timeout
/// over d = 2…11 under the scaled config-gen budget).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub literal_dim_max: usize,
    pub secs_per_unit: f64,
    pub bytes_per_unit: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { literal_dim_max: 4, secs_per_unit: 3e-6, bytes_per_unit: 1024.0 }
    }
}

impl CostModel {
    /// Geometric neighbourhood size: (2⌈√d⌉+1)^d (saturating).
    pub fn neighbourhood_cells(d: usize) -> f64 {
        let r = (d as f64).sqrt().ceil();
        (2.0 * r + 1.0).powi(d as i32)
    }

    /// Modelled per-query-cell work units in the super-literal regime.
    fn units(d: usize) -> f64 {
        Self::neighbourhood_cells(d).sqrt()
    }
}

/// Outcome of a DBSCOUT run: binary verdicts plus run diagnostics.
#[derive(Debug)]
pub struct DbscoutVerdict {
    /// `(id, is_outlier)` for every point.
    pub pred: Vec<(u64, bool)>,
    pub occupied_cells: usize,
    pub dense_cells: usize,
    pub query_cells: usize,
    /// Whether the decision path was the literal enumeration.
    pub literal: bool,
}

pub struct Dbscout;

type Cell = Vec<i32>;

impl Dbscout {
    /// Run DBSCOUT on dense data. Returns binary outlier verdicts.
    pub fn run(
        ctx: &ClusterContext,
        data: &Dataset,
        params: &DbscoutParams,
    ) -> Result<DbscoutVerdict> {
        let d = data.dim();
        if d == 0 {
            return Err(crate::cluster::ClusterError::Invalid("empty schema".into()));
        }
        let side = params.eps / (d as f64).sqrt();
        let radius = (d as f64).sqrt().ceil() as i32;

        // Pass 1 (data-parallel): cell counts via map + reduceByKey.
        let pairs = data.rows.map(ctx, |row| {
            let x = row.features.as_dense();
            let cell: Cell = x.iter().map(|&v| (v as f64 / side).floor() as i32).collect();
            (cell, 1u32)
        })?;
        let counts = pairs.reduce_by_key(ctx, |a, b| a + b)?.collect_as_map(ctx)?;
        let occupied_cells = counts.len();

        // Pass 2 (driver + workers): classify cells.
        let mut outlier_cells: HashMap<Cell, bool> = HashMap::with_capacity(counts.len());
        let cells: Vec<(&Cell, u32)> = counts.iter().map(|(c, &n)| (c, n)).collect();
        let dense: Vec<bool> =
            cells.iter().map(|&(_, n)| n as usize >= params.min_pts).collect();
        let dense_cells = dense.iter().filter(|&&b| b).count();
        let query_cells = occupied_cells - dense_cells;
        ctx.check_deadline()?;

        let literal = d <= params.cost.literal_dim_max;
        if literal {
            // literal geometric enumeration with early exit
            let mut offsets: Vec<Cell> = Vec::new();
            gen_offsets(d, radius, &mut vec![0; d], 0, &mut offsets);
            for (i, &(cell, n)) in cells.iter().enumerate() {
                if dense[i] {
                    continue;
                }
                let mut total = n as usize;
                for off in &offsets {
                    if off.iter().all(|&o| o == 0) {
                        continue;
                    }
                    let mut nb = cell.clone();
                    for (a, b) in nb.iter_mut().zip(off) {
                        *a += b;
                    }
                    if let Some(&c) = counts.get(&nb) {
                        total += c as usize;
                        if total >= params.min_pts {
                            break;
                        }
                    }
                }
                outlier_cells.insert(cell.clone(), total < params.min_pts);
            }
        } else {
            // super-literal regime: identical decision via occupied-cell
            // intersection; enumeration cost charged via the model
            let units = CostModel::units(d);
            let total_secs =
                query_cells as f64 * units * params.cost.secs_per_unit / ctx.cfg.num_workers as f64;
            ctx.ledger.add_virtual_secs(total_secs);
            // deadline first: the real system dies grinding through the
            // enumeration before its buffers peak (Table 2's d=11 row)
            ctx.check_deadline()?;
            let buf_bytes = (units * params.cost.bytes_per_unit) as usize;
            for w in 0..ctx.cfg.num_workers {
                ctx.charge_worker(w, buf_bytes)?;
            }
            // Chebyshev-ball counts over occupied cells (same output as
            // probing every geometric neighbour)
            for (i, &(cell, n)) in cells.iter().enumerate() {
                if dense[i] {
                    continue;
                }
                let mut total = n as usize;
                for &(other, m) in cells.iter() {
                    if std::ptr::eq(other, cell) {
                        continue;
                    }
                    let within = other
                        .iter()
                        .zip(cell)
                        .all(|(a, b)| (a - b).abs() <= radius);
                    if within {
                        total += m as usize;
                        if total >= params.min_pts {
                            break;
                        }
                    }
                }
                outlier_cells.insert(cell.clone(), total < params.min_pts);
            }
            for w in 0..ctx.cfg.num_workers {
                ctx.worker_mem[w].release(buf_bytes);
            }
        }
        ctx.check_deadline()?;

        // Pass 3 (data-parallel): label every point from its cell verdict.
        let bcast = Broadcast::new(ctx, CellVerdicts { outlier_cells })?;
        let pred = data
            .rows
            .map_partitions(ctx, |_, part| {
                let v = bcast.value();
                Ok(part
                    .iter()
                    .map(|row| {
                        let x = row.features.as_dense();
                        let cell: Cell =
                            x.iter().map(|&q| (q as f64 / side).floor() as i32).collect();
                        (row.id, *v.outlier_cells.get(&cell).unwrap_or(&false))
                    })
                    .collect())
            })?
            .collect(ctx)?;

        Ok(DbscoutVerdict { pred, occupied_cells, dense_cells, query_cells, literal })
    }
}

impl Dbscout {
    /// The paper's eps-selection procedure (§4.1.5): plot the sorted
    /// distance to the minPts-th neighbour and pick the upper "elbow".
    /// The paper notes this is quadratic (!) over all points; we run it on
    /// a subsample (documented substitution) and take the 90th percentile
    /// of the k-NN distance as the elbow's upper zone.
    pub fn choose_eps(
        ctx: &ClusterContext,
        data: &Dataset,
        min_pts: usize,
        sample_n: usize,
    ) -> Result<f64> {
        let n = data.len().max(1);
        let rate = (sample_n as f64 / n as f64).min(1.0);
        let sample = data.rows.sample(ctx, rate, 0xE95)?;
        let pts: Vec<Vec<f32>> = sample
            .collect(ctx)?
            .into_iter()
            .map(|r| r.features.as_dense().to_vec())
            .collect();
        if pts.len() < min_pts + 1 {
            return Ok(1.0);
        }
        let mut knn: Vec<f64> = Vec::with_capacity(pts.len());
        for (i, a) in pts.iter().enumerate() {
            let mut dists: Vec<f64> = pts
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i)
                .map(|(_, b)| {
                    a.iter()
                        .zip(b)
                        .map(|(x, y)| ((x - y) as f64).powi(2))
                        .sum::<f64>()
                        .sqrt()
                })
                .collect();
            dists.sort_by(|x, y| x.partial_cmp(y).unwrap());
            knn.push(dists[min_pts.min(dists.len()) - 1]);
        }
        knn.sort_by(|x, y| x.partial_cmp(y).unwrap());
        Ok(knn[(knn.len() as f64 * 0.9) as usize])
    }
}

/// [`Detector`] adapter. DBSCOUT is transductive — there is no trained
/// state — so `fit` only resolves eps (via the paper's elbow heuristic
/// when `auto_eps`) and `score` runs the grid algorithm, emitting 1.0
/// (outlier) / 0.0 (inlier): the binary verdict as a degenerate ranking.
pub struct DbscoutDetector {
    params: DbscoutParams,
    auto_eps: bool,
}

impl DbscoutDetector {
    /// `auto_eps = true` ⇒ eps is chosen from the data at fit time
    /// (§4.1.5's sorted-kNN-distance elbow) and `params.eps` is ignored.
    pub fn new(params: DbscoutParams, auto_eps: bool) -> api::Result<Self> {
        if !auto_eps {
            params.validate().map_err(SparxError::InvalidParams)?;
        } else if params.min_pts == 0 {
            return Err(SparxError::InvalidParams("min_pts must be ≥ 1".into()));
        }
        Ok(DbscoutDetector { params, auto_eps })
    }

    pub fn params(&self) -> &DbscoutParams {
        &self.params
    }
}

impl Detector for DbscoutDetector {
    fn name(&self) -> &'static str {
        "dbscout"
    }

    fn fit(&self, ctx: &ClusterContext, data: &Dataset) -> api::Result<Box<dyn FittedModel>> {
        api::ensure_dense(data, "DBSCOUT")?;
        let mut params = self.params.clone();
        if self.auto_eps {
            params.eps = Dbscout::choose_eps(ctx, data, params.min_pts, 400)?;
        }
        params.validate().map_err(SparxError::InvalidParams)?;
        Ok(Box::new(FittedDbscout { params }))
    }
}

/// The resolved DBSCOUT configuration (eps fixed at fit time).
pub struct FittedDbscout {
    params: DbscoutParams,
}

impl FittedDbscout {
    /// Adopt an already-resolved configuration (eps fixed) — how the
    /// ensemble layer builds dbscout members after running the same
    /// elbow heuristic [`DbscoutDetector::fit`] uses.
    pub(crate) fn from_params(params: DbscoutParams) -> api::Result<FittedDbscout> {
        params.validate().map_err(SparxError::InvalidParams)?;
        Ok(FittedDbscout { params })
    }

    /// The eps the grid runs with (chosen at fit time under `auto_eps`).
    pub fn eps(&self) -> f64 {
        self.params.eps
    }

    fn encode_params(&self) -> Vec<u8> {
        let mut enc = Encoder::new();
        enc.put_usize(self.params.min_pts);
        enc.put_usize(self.params.cost.literal_dim_max);
        enc.put_f64(self.params.cost.secs_per_unit);
        enc.put_f64(self.params.cost.bytes_per_unit);
        enc.into_bytes()
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut enc = Encoder::new();
        enc.put_f64(self.params.eps);
        enc.into_bytes()
    }

    /// Rehydrate from an artifact. DBSCOUT is transductive, so the whole
    /// fitted state is the resolved eps (the grid rebuilds per scoring
    /// pass) plus the grid parameters.
    pub fn from_artifact(art: &ModelArtifact) -> api::Result<FittedDbscout> {
        let blk = |e| artifact::block_err("dbscout", e);
        let mut dec = Decoder::new(&art.params);
        let min_pts = dec.usize().map_err(blk)?;
        let cost = CostModel {
            literal_dim_max: dec.usize().map_err(blk)?,
            secs_per_unit: dec.f64().map_err(blk)?,
            bytes_per_unit: dec.f64().map_err(blk)?,
        };
        dec.finish().map_err(blk)?;
        let mut dec = Decoder::new(&art.payload);
        let eps = dec.f64().map_err(blk)?;
        dec.finish().map_err(blk)?;
        let params = DbscoutParams { eps, min_pts, cost };
        params.validate().map_err(SparxError::InvalidParams)?;
        Ok(FittedDbscout { params })
    }
}

impl FittedModel for FittedDbscout {
    fn name(&self) -> &'static str {
        "dbscout"
    }

    fn score(&self, ctx: &ClusterContext, data: &Dataset) -> api::Result<Vec<(u64, f64)>> {
        api::ensure_dense(data, "DBSCOUT")?;
        let verdict = Dbscout::run(ctx, data, &self.params)?;
        Ok(verdict
            .pred
            .into_iter()
            .map(|(id, outlier)| (id, if outlier { 1.0 } else { 0.0 }))
            .collect())
    }

    fn to_artifact(&self) -> api::Result<ModelArtifact> {
        Ok(ModelArtifact::new("dbscout", self.encode_params(), self.encode_payload()))
    }

    /// The whole fitted state is the resolved eps — 8 payload bytes; the
    /// grid itself is rebuilt per scoring pass.
    fn model_bytes(&self) -> usize {
        self.encode_payload().len()
    }
}

struct CellVerdicts {
    outlier_cells: HashMap<Cell, bool>,
}

impl SizeOf for CellVerdicts {
    fn size_of(&self) -> usize {
        self.outlier_cells
            .iter()
            .map(|(k, _)| k.len() * 4 + 17)
            .sum::<usize>()
    }
}

fn gen_offsets(d: usize, radius: i32, cur: &mut Vec<i32>, dim: usize, out: &mut Vec<Cell>) {
    if dim == d {
        out.push(cur.clone());
        return;
    }
    for o in -radius..=radius {
        cur[dim] = o;
        gen_offsets(d, radius, cur, dim + 1, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterConfig, ClusterError, DistVec};
    use crate::data::{Row, Schema};

    fn ctx() -> ClusterContext {
        ClusterConfig { num_partitions: 4, num_workers: 2, ..Default::default() }.build()
    }

    fn make_ds(ctx: &ClusterContext, pts: Vec<Vec<f32>>) -> Dataset {
        let rows = DistVec::from_vec(
            ctx,
            pts.into_iter().enumerate().map(|(i, p)| Row::dense(i as u64, p)).collect(),
        )
        .unwrap();
        let d = 2;
        Dataset::new(Schema::positional(d), rows)
    }

    #[test]
    fn isolated_point_is_outlier() {
        let c = ctx();
        // 30 points in a tight cluster + 1 far away
        let mut pts: Vec<Vec<f32>> = (0..30)
            .map(|i| vec![(i % 6) as f32 * 0.01, (i / 6) as f32 * 0.01])
            .collect();
        pts.push(vec![100.0, 100.0]);
        let ds = make_ds(&c, pts);
        let v = Dbscout::run(
            &c,
            &ds,
            &DbscoutParams { eps: 1.0, min_pts: 5, ..Default::default() },
        )
        .unwrap();
        let outliers: Vec<u64> =
            v.pred.iter().filter(|(_, o)| *o).map(|(id, _)| *id).collect();
        assert_eq!(outliers, vec![30]);
        assert!(v.literal, "d=2 must take the literal path");
    }

    #[test]
    fn dense_cells_short_circuit() {
        let c = ctx();
        let pts: Vec<Vec<f32>> = (0..100).map(|_| vec![0.001, 0.001]).collect();
        let ds = make_ds(&c, pts);
        let v = Dbscout::run(
            &c,
            &ds,
            &DbscoutParams { eps: 1.0, min_pts: 5, ..Default::default() },
        )
        .unwrap();
        assert_eq!(v.dense_cells, 1);
        assert_eq!(v.query_cells, 0);
        assert!(v.pred.iter().all(|(_, o)| !o));
    }

    #[test]
    fn neighbouring_cells_count_towards_min_pts() {
        let c = ctx();
        // two adjacent small groups, each < minPts but together ≥ minPts
        let mut pts = Vec::new();
        for i in 0..4 {
            pts.push(vec![0.0 + i as f32 * 0.001, 0.0]);
            pts.push(vec![0.5 + i as f32 * 0.001, 0.0]); // next cell over (eps=1 → side .7)
        }
        let ds = make_ds(&c, pts);
        let v = Dbscout::run(
            &c,
            &ds,
            &DbscoutParams { eps: 1.0, min_pts: 6, ..Default::default() },
        )
        .unwrap();
        assert!(v.pred.iter().all(|(_, o)| !o), "{v:?}");
    }

    #[test]
    fn super_literal_matches_literal_decision() {
        // same 5-d data decided by both paths must agree
        let c1 = ctx();
        let mut rng = crate::util::Rng::new(3);
        let pts: Vec<Vec<f32>> = (0..150)
            .map(|i| {
                let far = i >= 145;
                (0..5)
                    .map(|_| if far { 50.0 + rng.f32() } else { rng.normal() as f32 })
                    .collect()
            })
            .collect();
        let mk = |c: &ClusterContext| {
            let rows = DistVec::from_vec(
                c,
                pts.clone().into_iter().enumerate().map(|(i, p)| Row::dense(i as u64, p)).collect(),
            )
            .unwrap();
            Dataset::new(Schema::positional(5), rows)
        };
        let lit = Dbscout::run(
            &c1,
            &mk(&c1),
            &DbscoutParams {
                eps: 3.0,
                min_pts: 4,
                cost: CostModel { literal_dim_max: 8, ..Default::default() },
            },
        )
        .unwrap();
        let c2 = ctx();
        let sup = Dbscout::run(
            &c2,
            &mk(&c2),
            &DbscoutParams {
                eps: 3.0,
                min_pts: 4,
                cost: CostModel { literal_dim_max: 4, ..Default::default() },
            },
        )
        .unwrap();
        assert!(lit.literal && !sup.literal);
        assert_eq!(lit.pred, sup.pred, "decision paths must agree");
    }

    #[test]
    fn virtual_cost_explodes_with_dimension() {
        let units_6 = CostModel::units(6);
        let units_10 = CostModel::units(10);
        let units_11 = CostModel::units(11);
        assert!(units_10 > units_6 * 50.0);
        assert!(units_11 > units_10 * 2.0);
    }

    #[test]
    fn high_dim_times_out_like_table2() {
        let c = ClusterConfig {
            num_partitions: 4,
            num_workers: 2,
            deadline_secs: Some(5.0),
            ..Default::default()
        }
        .build();
        let mut rng = crate::util::Rng::new(5);
        let d = 11;
        let rows = DistVec::from_vec(
            &c,
            (0..3000u64)
                .map(|i| Row::dense(i, (0..d).map(|_| rng.normal() as f32).collect()))
                .collect(),
        )
        .unwrap();
        let ds = Dataset::new(Schema::positional(d), rows);
        let r =
            Dbscout::run(&c, &ds, &DbscoutParams { eps: 2.0, min_pts: 8, ..Default::default() });
        assert!(
            matches!(r, Err(ClusterError::DeadlineExceeded { .. })),
            "expected TIMEOUT at d=11"
        );
    }
}
