//! DBSCOUT (Corain, Garza & Asudeh, ICDE 2021): density-based scalable
//! outlier detection via a cellular grid (§4.1.2 baseline 1).
//!
//! Definition (inherited from DBSCAN): a point is an **outlier** iff its
//! eps-neighbourhood holds fewer than `minPts` points. DBSCOUT
//! parallelises this with a grid of cells of side `eps/√d` so that any two
//! points in one cell are within eps:
//!
//! 1. map/reduce: count points per cell (data-parallel);
//! 2. cells with ≥ minPts points are *dense* — all their points are
//!    inliers immediately;
//! 3. every other ("query") cell must examine its geometric
//!    neighbourhood: all cells within Chebyshev radius R = ⌈√d⌉, i.e.
//!    **(2·⌈√d⌉+1)^d cells — exponential in d**. This is the cost that
//!    makes DBSCOUT unusable beyond d≈10 (Table 2) and it is why all of
//!    the original paper's experiments stop at 3 dimensions.
//!
//! Outputs are **binary** (outlier / inlier) — no ranking (§5) — so only
//! F1 is comparable.
//!
//! ## Scale substitution (DESIGN.md)
//!
//! At d ≤ `LITERAL_DIM_MAX` the neighbourhood enumeration runs literally.
//! Beyond that, a laptop cannot execute what a 512-core cluster needed
//! hours for, so the *decision* is computed by the equivalent
//! occupied-cell intersection (same Chebyshev-ball counts ⇒ same output)
//! while the *cost* of the geometric enumeration is charged to the job
//! clock and the worker memory meters through a calibrated model
//! ([`CostModel`]). Table 2's runtime/memory explosion and d=11 timeout
//! reproduce through that model.

pub mod grid;

pub use grid::{
    CostModel, Dbscout, DbscoutDetector, DbscoutParams, DbscoutVerdict, FittedDbscout,
};
