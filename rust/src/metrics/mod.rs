//! Evaluation metrics (§4.1.3): AUROC, AUPRC, F1, plus the resource
//! report that pairs them with time / memory / network for the
//! accuracy-vs-resources landscapes (Figs. 2–4).

pub mod ranking;
pub mod report;

pub use ranking::{auprc, auroc, f1_at_rate, f1_binary, RankMetrics};
pub use report::ResourceReport;
