//! Resource reports: the time / memory / network numbers that pair with
//! ranking metrics in every paper table. Extracted from a
//! [`ClusterContext`] after a run.

use crate::cluster::ClusterContext;
use crate::util::sizeof::human_bytes;

/// One run's resource footprint under the simulator's accounting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResourceReport {
    /// Wall-clock seconds of actual compute.
    pub wall_secs: f64,
    /// Modelled network seconds (bytes/bandwidth + per-record overhead).
    pub network_secs: f64,
    /// wall + network — the column reported as "Time(s)".
    pub job_secs: f64,
    /// Peak single-executor memory (paper's per-executor peak).
    pub peak_worker_bytes: usize,
    /// Sum of worker peaks + driver peak (paper's "total memory").
    pub total_peak_bytes: usize,
    /// Peak driver memory (Fig. 2's x-axis).
    pub peak_driver_bytes: usize,
    /// Bytes shuffled across workers.
    pub shuffle_bytes: u64,
    /// Records shuffled.
    pub shuffle_records: u64,
    /// Communication rounds (Sparx's two-pass claim is visible here).
    pub shuffle_rounds: u64,
}

impl ResourceReport {
    /// Snapshot the context's accounting.
    pub fn from_ctx(ctx: &ClusterContext) -> Self {
        let (bytes, records, rounds) = ctx.ledger.snapshot();
        ResourceReport {
            wall_secs: ctx.wall_secs(),
            network_secs: ctx.network_secs(),
            job_secs: ctx.job_secs(),
            peak_worker_bytes: ctx.peak_worker_bytes(),
            total_peak_bytes: ctx.total_peak_bytes(),
            peak_driver_bytes: ctx.driver_mem.peak(),
            shuffle_bytes: bytes,
            shuffle_records: records,
            shuffle_rounds: rounds,
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "time={:.2}s (wall {:.2}s + net {:.2}s) peak-exec={} total-mem={} driver={} \
             shuffled={} ({} recs, {} rounds)",
            self.job_secs,
            self.wall_secs,
            self.network_secs,
            human_bytes(self.peak_worker_bytes),
            human_bytes(self.total_peak_bytes),
            human_bytes(self.peak_driver_bytes),
            human_bytes(self.shuffle_bytes as usize),
            self.shuffle_records,
            self.shuffle_rounds,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    #[test]
    fn snapshot_reflects_ledger() {
        let ctx = ClusterConfig { network_bytes_per_sec: 1e6, ..Default::default() }.build();
        ctx.ledger.add(1_000_000, 5);
        ctx.ledger.add_round();
        let r = ResourceReport::from_ctx(&ctx);
        assert_eq!(r.shuffle_bytes, 1_000_000);
        assert_eq!(r.shuffle_rounds, 1);
        assert!(r.network_secs >= 1.0);
        assert!(r.summary().contains("rounds"));
    }
}
