//! Ranking-quality metrics. Scores follow the convention *higher = more
//! outlying*. Ties are handled properly (mid-rank for AUROC, grouped
//! thresholds for AUPRC/F1), which matters because CMS counts are integers
//! and produce heavily tied score distributions.
//!
//! **NaN policy:** a NaN score has no place in a ranking — `partial_cmp`
//! returns `None` against everything, so a comparison-sort's result (and
//! therefore the metric) would depend on the *input order* of the
//! unaffected points. [`auroc`] and [`auprc`] instead return `NaN`
//! whenever any score is NaN, matching the degenerate single-class
//! convention: the metric is undefined, deterministically, rather than
//! silently order-dependent. (±∞ is fine — infinities order totally.)

/// True if any score is NaN, in which case the ranking metrics are
/// undefined (see the module NaN policy).
fn any_nan(scores: &[f64]) -> bool {
    scores.iter().any(|s| s.is_nan())
}

/// Area under the ROC curve via the Mann–Whitney U statistic with
/// mid-ranks for ties. O(n log n). Returns NaN for single-class labels
/// or any NaN score.
pub fn auroc(scores: &[f64], labels: &[bool]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&l| l).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 || any_nan(scores) {
        return f64::NAN;
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal));
    // mid-rank sum of positives
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < order.len() {
        let mut j = i;
        while j + 1 < order.len() && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let mid_rank = (i + j) as f64 / 2.0 + 1.0; // 1-based
        for &idx in &order[i..=j] {
            if labels[idx] {
                rank_sum_pos += mid_rank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Area under the precision-recall curve (step-wise interpolation, the
/// `sklearn.metrics.average_precision_score` definition). Returns NaN
/// when there are no positives or any score is NaN.
pub fn auprc(scores: &[f64], labels: &[bool]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&l| l).count();
    if n_pos == 0 || any_nan(scores) {
        return f64::NAN;
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal));
    let mut ap = 0.0f64;
    let mut tp = 0usize;
    let mut seen = 0usize;
    let mut i = 0;
    // process tied groups together: precision measured at group boundary
    while i < order.len() {
        let mut j = i;
        while j + 1 < order.len() && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let group_pos = order[i..=j].iter().filter(|&&idx| labels[idx]).count();
        let prev_tp = tp;
        tp += group_pos;
        seen = j + 1;
        if group_pos > 0 {
            let precision = tp as f64 / seen as f64;
            ap += precision * (tp - prev_tp) as f64 / n_pos as f64;
        }
        i = j + 1;
    }
    debug_assert_eq!(seen, order.len());
    ap
}

/// F1 for an already-binary prediction (DBSCOUT outputs binary labels).
pub fn f1_binary(pred: &[bool], labels: &[bool]) -> f64 {
    assert_eq!(pred.len(), labels.len());
    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut fne = 0usize;
    for (&p, &l) in pred.iter().zip(labels) {
        match (p, l) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, true) => fne += 1,
            _ => {}
        }
    }
    if tp == 0 {
        return 0.0;
    }
    let prec = tp as f64 / (tp + fp) as f64;
    let rec = tp as f64 / (tp + fne) as f64;
    2.0 * prec * rec / (prec + rec)
}

/// F1 after thresholding scores at the contamination rate: the top
/// `rate·n` scored points are predicted outliers (standard protocol for
/// score-ranking detectors when a single F1 number is needed, ties broken
/// by index like numpy argsort).
pub fn f1_at_rate(scores: &[f64], labels: &[bool], rate: f64) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let k = ((scores.len() as f64 * rate).round() as usize).clamp(1, scores.len());
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut pred = vec![false; scores.len()];
    for &i in &order[..k] {
        pred[i] = true;
    }
    f1_binary(&pred, labels)
}

/// Bundle of all three metrics for the result tables.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RankMetrics {
    pub auroc: f64,
    pub auprc: f64,
    pub f1: f64,
}

impl RankMetrics {
    /// Compute at the dataset's true contamination rate (the paper's
    /// protocol: detectors are compared on ranking + top-rate F1).
    pub fn compute(scores: &[f64], labels: &[bool]) -> RankMetrics {
        let rate = labels.iter().filter(|&&l| l).count() as f64 / labels.len().max(1) as f64;
        RankMetrics {
            auroc: auroc(scores, labels),
            auprc: auprc(scores, labels),
            f1: f1_at_rate(scores, labels, rate.max(1e-9)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auroc_perfect_and_inverted() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [false, false, true, true];
        assert_eq!(auroc(&scores, &labels), 1.0);
        let inv = [false, false, true, true];
        let scores_inv = [0.9, 0.8, 0.2, 0.1];
        assert_eq!(auroc(&scores_inv, &inv), 0.0);
    }

    #[test]
    fn auroc_random_is_half() {
        // all scores equal → AUROC 0.5 by mid-rank convention
        let scores = [0.5; 100];
        let labels: Vec<bool> = (0..100).map(|i| i % 2 == 0).collect();
        assert!((auroc(&scores, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auroc_known_value() {
        // hand-computed: pos scores {3,1}, neg {2,0} → pairs won 3/4
        let scores = [3.0, 1.0, 2.0, 0.0];
        let labels = [true, true, false, false];
        assert!((auroc(&scores, &labels) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn auprc_perfect() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [false, false, true, true];
        assert!((auprc(&scores, &labels) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn auprc_baseline_is_prevalence() {
        // constant scores → AP equals prevalence
        let scores = [1.0; 1000];
        let labels: Vec<bool> = (0..1000).map(|i| i < 100).collect();
        assert!((auprc(&scores, &labels) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn auprc_known_value() {
        // ranking: pos, neg, pos, neg → AP = (1/1 + 2/3)/2
        let scores = [4.0, 3.0, 2.0, 1.0];
        let labels = [true, false, true, false];
        assert!((auprc(&scores, &labels) - (1.0 + 2.0 / 3.0) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn f1_binary_cases() {
        assert_eq!(f1_binary(&[true, true], &[true, true]), 1.0);
        assert_eq!(f1_binary(&[false, false], &[true, true]), 0.0);
        // tp=1 fp=1 fn=1 → p=0.5 r=0.5 → f1=0.5
        assert!((f1_binary(&[true, true, false], &[true, false, true]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_at_rate_selects_top_k() {
        let scores = [9.0, 8.0, 1.0, 0.5];
        let labels = [true, true, false, false];
        assert_eq!(f1_at_rate(&scores, &labels, 0.5), 1.0);
    }

    #[test]
    fn metrics_bundle() {
        let scores = [0.9, 0.8, 0.1, 0.2];
        let labels = [true, true, false, false];
        let m = RankMetrics::compute(&scores, &labels);
        assert_eq!(m.auroc, 1.0);
        assert_eq!(m.auprc, 1.0);
        assert_eq!(m.f1, 1.0);
    }

    #[test]
    fn degenerate_all_one_class() {
        assert!(auroc(&[1.0, 2.0], &[true, true]).is_nan());
        assert!(auprc(&[1.0, 2.0], &[false, false]).is_nan());
    }

    /// Regression: a NaN score used to make both metrics depend on the
    /// input order of the *other* points (`partial_cmp(..).unwrap_or(
    /// Equal)` leaves the comparison-sort order-dependent). The policy
    /// is now: any NaN score → the metric itself is NaN, regardless of
    /// where the NaN sits.
    #[test]
    fn nan_scores_yield_nan_not_an_order_dependent_ranking() {
        let labels = [true, false, true, false, true];
        // the same multiset of scores with the NaN at every position
        for at in 0..5 {
            let mut scores = [4.0, 3.0, 2.0, 1.0, 0.5];
            scores[at] = f64::NAN;
            assert!(auroc(&scores, &labels).is_nan(), "NaN at {at}");
            assert!(auprc(&scores, &labels).is_nan(), "NaN at {at}");
        }
        // infinities still rank totally — no NaN involved, defined result
        let scores = [f64::INFINITY, 1.0, 0.0, f64::NEG_INFINITY, 2.0];
        assert!(!auroc(&scores, &labels).is_nan());
        assert!(!auprc(&scores, &labels).is_nan());
        // and a clean ranking is unaffected by the guard
        assert_eq!(auroc(&[0.9, 0.1], &[true, false]), 1.0);
    }
}
