//! Half-space chains (§2.2.2, Eq. 4): multi-granular subspace histograms.
//!
//! A chain of length L halves the (projected) space along a randomly
//! re-sampled feature per level. The K-dimensional integer bin id of a
//! point at level l is computed incrementally; all points sharing a bin id
//! at level l sit in the same histogram cell of width Δ/2^(o(f,l)-1) along
//! each sampled feature.
//!
//! The numeric recurrence here is *the* contract shared by three
//! implementations which are cross-checked in tests:
//! * this native Rust path (request path),
//! * the Pallas kernel behind the AOT artifacts (`python/compile/kernels/
//!   chain.py`, loaded via [`crate::runtime`]),
//! * the pure-jnp oracle (`ref.py`).

use crate::util::{Rng, SizeOf};

/// Per-chain sampled parameters (shared by every worker — Algorithm 2).
#[derive(Debug, Clone, PartialEq)]
pub struct ChainParams {
    /// Sampled split feature per level, `fs[l] ∈ [0, K)`.
    pub fs: Vec<usize>,
    /// Random shift per projected feature, `shift[k] ∈ [0, Δ[k])`.
    pub shift: Vec<f32>,
    /// Initial bin widths Δ (half the projected range per feature).
    pub deltamax: Vec<f32>,
    /// `first[l]` ⇔ level `l` is the first occurrence of `fs[l]`
    /// (precomputed so the per-point hot loop allocates nothing — §Perf).
    first: Vec<bool>,
}

fn first_occurrences(fs: &[usize]) -> Vec<bool> {
    let mut seen = std::collections::HashSet::new();
    fs.iter().map(|&f| seen.insert(f)).collect()
}

impl ChainParams {
    /// Sample a chain: features uniformly with replacement, shifts
    /// uniform in [0, Δ).
    pub fn sample(deltamax: &[f32], depth: usize, rng: &mut Rng) -> Self {
        let k = deltamax.len();
        let fs: Vec<usize> = (0..depth).map(|_| rng.below(k as u64) as usize).collect();
        let shift = deltamax.iter().map(|&d| rng.f32() * d).collect();
        let first = first_occurrences(&fs);
        ChainParams { fs, shift, deltamax: deltamax.to_vec(), first }
    }

    /// Build from explicit parts (tests / deserialization).
    pub fn new(fs: Vec<usize>, shift: Vec<f32>, deltamax: Vec<f32>) -> Self {
        let first = first_occurrences(&fs);
        ChainParams { fs, shift, deltamax, first }
    }

    pub fn depth(&self) -> usize {
        self.fs.len()
    }

    pub fn k(&self) -> usize {
        self.deltamax.len()
    }

    /// Incremental bin ids of one sketch at every level: returns a
    /// row-major `[L][K]` i32 buffer. `scratch` must be `K` floats
    /// (avoids a per-point allocation on the hot path).
    pub fn bins_into(&self, s: &[f32], scratch: &mut [f32], out: &mut [i32]) {
        let k = self.k();
        let l = self.depth();
        debug_assert_eq!(s.len(), k);
        debug_assert_eq!(scratch.len(), k);
        debug_assert_eq!(out.len(), l * k);
        // prebin state starts at 0 (untouched features bin to 0)
        scratch.fill(0.0);
        for (lvl, &f) in self.fs.iter().enumerate() {
            let new = if self.first[lvl] {
                (s[f] + self.shift[f]) / self.deltamax[f]
            } else {
                2.0 * scratch[f] - self.shift[f] / self.deltamax[f]
            };
            scratch[f] = new;
            let row = &mut out[lvl * k..(lvl + 1) * k];
            for (j, v) in scratch.iter().enumerate() {
                row[j] = v.floor() as i32;
            }
        }
    }

    /// Allocating convenience wrapper around [`Self::bins_into`].
    pub fn bins(&self, s: &[f32]) -> Vec<i32> {
        let mut scratch = vec![0f32; self.k()];
        let mut out = vec![0i32; self.depth() * self.k()];
        self.bins_into(s, &mut scratch, &mut out);
        out
    }
}

impl SizeOf for ChainParams {
    fn size_of(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.fs.len() * 8
            + self.shift.len() * 4
            + self.deltamax.len() * 4
    }
}

/// Tile-level binning backend: maps a tile of `n` K-dim sketches to
/// `n × L × K` bin ids. The native implementation loops in Rust; the PJRT
/// implementation ([`crate::runtime::PjrtBinner`]) executes the AOT
/// Pallas artifact. Both must agree bit-for-bit (integration-tested).
pub trait Binner: Sync {
    fn tile_bins(&self, chain: &ChainParams, s: &[f32], n: usize) -> Vec<i32>;

    /// Multi-chain tiling: bin the *same* resident tile of `n` sketches
    /// against every chain in `chains`, returning a chain-major
    /// `[M][n][L][K]` buffer. The fused partition executors
    /// ([`crate::sparx::plan`]) use this so the sketch block is flattened
    /// once per partition visit instead of once per chain.
    fn tile_bins_multi(&self, chains: &[&ChainParams], s: &[f32], n: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(chains.iter().map(|c| n * c.depth() * c.k()).sum());
        for chain in chains {
            out.extend(self.tile_bins(chain, s, n));
        }
        out
    }
}

/// Pure-Rust binning.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBinner;

impl Binner for NativeBinner {
    fn tile_bins(&self, chain: &ChainParams, s: &[f32], n: usize) -> Vec<i32> {
        let k = chain.k();
        let l = chain.depth();
        debug_assert_eq!(s.len(), n * k);
        let mut out = vec![0i32; n * l * k];
        let mut scratch = vec![0f32; k];
        for i in 0..n {
            chain.bins_into(
                &s[i * k..(i + 1) * k],
                &mut scratch,
                &mut out[i * l * k..(i + 1) * l * k],
            );
        }
        out
    }

    /// Single allocation + shared scratch across all chains of the tile.
    fn tile_bins_multi(&self, chains: &[&ChainParams], s: &[f32], n: usize) -> Vec<i32> {
        let total: usize = chains.iter().map(|c| n * c.depth() * c.k()).sum();
        let mut out = vec![0i32; total];
        let kmax = chains.iter().map(|c| c.k()).max().unwrap_or(0);
        let mut scratch = vec![0f32; kmax];
        let mut off = 0;
        for chain in chains {
            let k = chain.k();
            let l = chain.depth();
            debug_assert_eq!(s.len(), n * k);
            for i in 0..n {
                chain.bins_into(
                    &s[i * k..(i + 1) * k],
                    &mut scratch[..k],
                    &mut out[off + i * l * k..off + (i + 1) * l * k],
                );
            }
            off += n * l * k;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_chain() -> ChainParams {
        ChainParams::new(vec![0, 0, 0], vec![0.0], vec![2.0])
    }

    #[test]
    fn halving_matches_hand_computation() {
        // widths per level: 2, 1, 0.5 (same case as the python kernel test)
        let c = simple_chain();
        assert_eq!(c.bins(&[0.9]), vec![0, 0, 1]);
        assert_eq!(c.bins(&[1.9]), vec![0, 1, 3]);
        assert_eq!(c.bins(&[3.9]), vec![1, 3, 7]);
    }

    #[test]
    fn shift_moves_boundaries() {
        let mut c = simple_chain();
        c.shift = vec![0.5];
        // (1.9 + 0.5)/2 = 1.2 → bin 1 at level 0 (without shift it was 0)
        assert_eq!(c.bins(&[1.9])[0], 1);
    }

    #[test]
    fn untouched_features_bin_zero() {
        let c = ChainParams::new(vec![1, 1], vec![0.3, 0.0], vec![1.0, 1.0]);
        let b = c.bins(&[5.0, 0.6]);
        // feature 0 never sampled → always floor(0) = 0
        assert_eq!(b[0], 0);
        assert_eq!(b[2], 0);
    }

    #[test]
    fn first_vs_repeat_occurrence() {
        // f=0 at levels 0 and 2, f=1 at level 1
        let c = ChainParams::new(vec![0, 1, 0], vec![0.0, 0.0], vec![4.0, 2.0]);
        let b = c.bins(&[6.0, 3.0]);
        // level 0: s0/4 = 1.5 → 1 ; level 1: s1/2 = 1.5 → 1
        assert_eq!(&b[0..2], &[1, 0]);
        assert_eq!(&b[2..4], &[1, 1]);
        // level 2: 2*1.5 = 3.0 → 3 (width now 2)
        assert_eq!(&b[4..6], &[3, 1]);
    }

    #[test]
    fn nearby_points_share_coarse_bins() {
        let mut rng = Rng::new(5);
        let c = ChainParams::sample(&[1.0, 1.0, 1.0], 12, &mut rng);
        let a = c.bins(&[0.50, 0.50, 0.50]);
        let b = c.bins(&[0.5005, 0.4995, 0.5002]);
        // identical at the first few levels (coarse granularity)
        let k = 3;
        assert_eq!(&a[..2 * k], &b[..2 * k]);
    }

    #[test]
    fn native_binner_matches_pointwise() {
        let mut rng = Rng::new(9);
        let c = ChainParams::sample(&[2.0, 3.0], 8, &mut rng);
        let pts: Vec<f32> = (0..20).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let tiled = NativeBinner.tile_bins(&c, &pts, 10);
        for i in 0..10 {
            let single = c.bins(&pts[i * 2..(i + 1) * 2]);
            assert_eq!(&tiled[i * 16..(i + 1) * 16], single.as_slice(), "point {i}");
        }
    }

    #[test]
    fn tile_bins_multi_matches_per_chain_concat() {
        let mut rng = Rng::new(21);
        let delta = vec![1.5f32, 0.75, 3.0];
        let chains: Vec<ChainParams> =
            (0..5).map(|_| ChainParams::sample(&delta, 7, &mut rng)).collect();
        let refs: Vec<&ChainParams> = chains.iter().collect();
        let n = 11;
        let s: Vec<f32> = (0..n * 3).map(|_| rng.normal() as f32 * 2.0).collect();
        let multi = NativeBinner.tile_bins_multi(&refs, &s, n);
        let mut concat = Vec::new();
        for c in &chains {
            concat.extend(NativeBinner.tile_bins(c, &s, n));
        }
        assert_eq!(multi, concat);
    }

    #[test]
    fn sample_respects_ranges() {
        let mut rng = Rng::new(11);
        let delta = vec![0.5, 2.0, 10.0];
        for _ in 0..20 {
            let c = ChainParams::sample(&delta, 6, &mut rng);
            assert!(c.fs.iter().all(|&f| f < 3));
            for (sh, d) in c.shift.iter().zip(&delta) {
                assert!(*sh >= 0.0 && sh < d, "shift {sh} vs delta {d}");
            }
        }
    }
}
