//! Half-space chains (§2.2.2, Eq. 4): multi-granular subspace histograms.
//!
//! A chain of length L halves the (projected) space along a randomly
//! re-sampled feature per level. The K-dimensional integer bin id of a
//! point at level l is computed incrementally; all points sharing a bin id
//! at level l sit in the same histogram cell of width Δ/2^(o(f,l)-1) along
//! each sampled feature.
//!
//! The numeric recurrence here is *the* contract shared by three
//! implementations which are cross-checked in tests:
//! * this native Rust path (request path),
//! * the Pallas kernel behind the AOT artifacts (`python/compile/kernels/
//!   chain.py`, loaded via [`crate::runtime`]),
//! * the pure-jnp oracle (`ref.py`).
//!
//! The native path itself has three tiers, all bit-identical:
//! * [`ChainParams::bins_into`] / [`tile_bins_reference`] — the plain
//!   per-point loop, kept as the oracle;
//! * the **floor-cache scalar kernel**: per level only the sampled
//!   feature's prebin value changes, so the K-wide floor loop collapses
//!   to one new floor plus a row copy (n·(K+L) floors instead of n·L·K);
//! * a runtime-detected **AVX2 block kernel** (8 points per register)
//!   behind `is_x86_feature_detected!`, selected by [`kernel_path`] and
//!   disabled with `SPARX_NO_AVX2=1`.

// One of the two modules whitelisted for `unsafe` (crate root denies it):
// the AVX2 block kernel below. Every unsafe block needs a `// SAFETY:`
// comment (enforced by `sparx_lint`).
#![allow(unsafe_code)]

use crate::cluster::Result;
use crate::util::{Rng, SizeOf};

/// Per-chain sampled parameters (shared by every worker — Algorithm 2).
#[derive(Debug, Clone, PartialEq)]
pub struct ChainParams {
    /// Sampled split feature per level, `fs[l] ∈ [0, K)`.
    pub fs: Vec<usize>,
    /// Random shift per projected feature, `shift[k] ∈ [0, Δ[k])`.
    pub shift: Vec<f32>,
    /// Initial bin widths Δ (half the projected range per feature).
    pub deltamax: Vec<f32>,
    /// `first[l]` ⇔ level `l` is the first occurrence of `fs[l]`
    /// (precomputed so the per-point hot loop allocates nothing — §Perf).
    first: Vec<bool>,
}

fn first_occurrences(fs: &[usize]) -> Vec<bool> {
    let mut seen = std::collections::HashSet::new();
    fs.iter().map(|&f| seen.insert(f)).collect()
}

impl ChainParams {
    /// Sample a chain: features uniformly with replacement, shifts
    /// uniform in [0, Δ).
    pub fn sample(deltamax: &[f32], depth: usize, rng: &mut Rng) -> Self {
        let k = deltamax.len();
        let fs: Vec<usize> = (0..depth).map(|_| rng.below(k as u64) as usize).collect();
        let shift = deltamax.iter().map(|&d| rng.f32() * d).collect();
        let first = first_occurrences(&fs);
        ChainParams { fs, shift, deltamax: deltamax.to_vec(), first }
    }

    /// Build from explicit parts (tests / deserialization).
    pub fn new(fs: Vec<usize>, shift: Vec<f32>, deltamax: Vec<f32>) -> Self {
        let first = first_occurrences(&fs);
        ChainParams { fs, shift, deltamax, first }
    }

    pub fn depth(&self) -> usize {
        self.fs.len()
    }

    pub fn k(&self) -> usize {
        self.deltamax.len()
    }

    /// Incremental bin ids of one sketch at every level: returns a
    /// row-major `[L][K]` i32 buffer. `scratch` must be `K` floats
    /// (avoids a per-point allocation on the hot path). This is the
    /// reference recurrence the blocked kernels are tested against.
    pub fn bins_into(&self, s: &[f32], scratch: &mut [f32], out: &mut [i32]) {
        let k = self.k();
        let l = self.depth();
        debug_assert_eq!(s.len(), k);
        debug_assert_eq!(scratch.len(), k);
        debug_assert_eq!(out.len(), l * k);
        // prebin state starts at 0 (untouched features bin to 0)
        scratch.fill(0.0);
        for (lvl, &f) in self.fs.iter().enumerate() {
            let new = if self.first[lvl] {
                (s[f] + self.shift[f]) / self.deltamax[f]
            } else {
                2.0 * scratch[f] - self.shift[f] / self.deltamax[f]
            };
            scratch[f] = new;
            let row = &mut out[lvl * k..(lvl + 1) * k];
            for (j, v) in scratch.iter().enumerate() {
                row[j] = v.floor() as i32;
            }
        }
    }

    /// Allocating convenience wrapper around [`Self::bins_into`].
    pub fn bins(&self, s: &[f32]) -> Vec<i32> {
        let mut scratch = vec![0f32; self.k()];
        let mut out = vec![0i32; self.depth() * self.k()];
        self.bins_into(s, &mut scratch, &mut out);
        out
    }
}

impl SizeOf for ChainParams {
    fn size_of(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.fs.len() * 8
            + self.shift.len() * 4
            + self.deltamax.len() * 4
    }
}

/// Reference tile binning: the straightforward per-point loop over
/// [`ChainParams::bins_into`]. The oracle the floor-cache and AVX2
/// kernels are property-tested (and benchmarked) against.
pub fn tile_bins_reference(chain: &ChainParams, s: &[f32], n: usize) -> Vec<i32> {
    let k = chain.k();
    let l = chain.depth();
    debug_assert_eq!(s.len(), n * k);
    let mut out = vec![0i32; n * l * k];
    let mut scratch = vec![0f32; k];
    for i in 0..n {
        chain.bins_into(
            &s[i * k..(i + 1) * k],
            &mut scratch,
            &mut out[i * l * k..(i + 1) * l * k],
        );
    }
    out
}

/// Force the scalar floor-cache kernel (no SIMD) — the bench A/B arm and
/// the property-test seam under the runtime-dispatched path.
pub fn tile_bins_scalar(chain: &ChainParams, s: &[f32], n: usize) -> Vec<i32> {
    let k = chain.k();
    let l = chain.depth();
    debug_assert_eq!(s.len(), n * k);
    let mut out = vec![0i32; n * l * k];
    tile_bins_scalar_into(chain, s, 0, n, &mut out);
    out
}

/// One point through the floor-cache kernel. Only `fs[lvl]`'s prebin
/// value changes per level, so `ibins` (the cached floors, point-major
/// `[K]`) needs exactly one update before the row copy — bit-identical
/// to `bins_into` because every untouched scratch value floors to the
/// same integer it did at the previous level.
fn bins_point_cached(
    chain: &ChainParams,
    s: &[f32],
    scratch: &mut [f32],
    ibins: &mut [i32],
    out: &mut [i32],
) {
    let k = chain.k();
    let l = chain.depth();
    debug_assert_eq!(s.len(), k);
    debug_assert_eq!(out.len(), l * k);
    scratch.fill(0.0);
    ibins.fill(0); // floor(0.0) = 0 for never-sampled features
    for (lvl, &f) in chain.fs.iter().enumerate() {
        let new = if chain.first[lvl] {
            (s[f] + chain.shift[f]) / chain.deltamax[f]
        } else {
            2.0 * scratch[f] - chain.shift[f] / chain.deltamax[f]
        };
        scratch[f] = new;
        ibins[f] = new.floor() as i32;
        out[lvl * k..(lvl + 1) * k].copy_from_slice(ibins);
    }
}

/// Floor-cache kernel over points `[from, n)` of the tile.
fn tile_bins_scalar_into(chain: &ChainParams, s: &[f32], from: usize, n: usize, out: &mut [i32]) {
    let k = chain.k();
    let l = chain.depth();
    let mut scratch = vec![0f32; k];
    let mut ibins = vec![0i32; k];
    for i in from..n {
        bins_point_cached(
            chain,
            &s[i * k..(i + 1) * k],
            &mut scratch,
            &mut ibins,
            &mut out[i * l * k..(i + 1) * l * k],
        );
    }
}

/// AVX2 prefix of the tile: bins as many full 8-point blocks as fit,
/// returning how many points were handled (0 when AVX2 is unavailable,
/// disabled via `SPARX_NO_AVX2`, or the chain is degenerate).
#[cfg(target_arch = "x86_64")]
fn tile_bins_simd_prefix(chain: &ChainParams, s: &[f32], n: usize, out: &mut [i32]) -> usize {
    if chain.k() == 0 || !avx2_enabled() {
        return 0;
    }
    let k = chain.k();
    let l = chain.depth();
    let lanes = avx2::LANES;
    let mut fscratch = vec![0f32; lanes * k];
    let mut ibins = vec![0i32; lanes * k];
    let mut done = 0;
    while done + lanes <= n {
        // SAFETY: `avx2_enabled` verified AVX2 support at runtime.
        unsafe {
            avx2::bins_block(
                chain,
                &s[done * k..(done + lanes) * k],
                &mut fscratch,
                &mut ibins,
                &mut out[done * l * k..(done + lanes) * l * k],
            );
        }
        done += lanes;
    }
    done
}

#[cfg(not(target_arch = "x86_64"))]
fn tile_bins_simd_prefix(_chain: &ChainParams, _s: &[f32], _n: usize, _out: &mut [i32]) -> usize {
    0
}

/// Runtime-dispatched tile binning: AVX2 blocks then the floor-cache
/// scalar kernel for the remainder.
fn tile_bins_into(chain: &ChainParams, s: &[f32], n: usize, out: &mut [i32]) {
    debug_assert_eq!(s.len(), n * chain.k());
    debug_assert_eq!(out.len(), n * chain.depth() * chain.k());
    let from = tile_bins_simd_prefix(chain, s, n, out);
    tile_bins_scalar_into(chain, s, from, n, out);
}

/// Which binning kernel [`NativeBinner`] dispatches to on this host:
/// `"avx2"` or `"scalar"`. Setting `SPARX_NO_AVX2=1` (checked once, at
/// first dispatch) forces the scalar path.
pub fn kernel_path() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            return "avx2";
        }
    }
    "scalar"
}

#[cfg(target_arch = "x86_64")]
fn avx2_enabled() -> bool {
    use std::sync::OnceLock;
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        std::env::var_os("SPARX_NO_AVX2").is_none() && is_x86_feature_detected!("avx2")
    })
}

/// The AVX2 block kernel: 8 points per register, prebin state held
/// feature-major so each level is one vector op chain, floored bins
/// cached point-major so the per-level row emit is a memcpy. Every
/// arithmetic step mirrors the scalar recurrence operation-for-operation
/// (IEEE 754 lane-wise ⇒ bit-identical), and the float→i32 conversion
/// reproduces Rust `as` cast semantics exactly (NaN → 0, saturation at
/// the i32 range).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::ChainParams;
    use std::arch::x86_64::*;

    /// Points per block: one AVX2 register of f32 lanes.
    pub(super) const LANES: usize = 8;

    /// `v.floor() as i32` per lane with Rust cast semantics: cvttps
    /// already saturates ≤ −2^31 to `i32::MIN` (its "indefinite" value);
    /// values ≥ 2^31 are blended to `i32::MAX` and NaNs to 0.
    ///
    /// # Safety
    /// The caller must have verified AVX2 support at runtime.
    #[inline]
    #[target_feature(enable = "avx2")]
    // On the MSRV (1.70) intrinsics are unsafe even inside a
    // target_feature fn, so `unsafe_op_in_unsafe_fn` demands the block;
    // on ≥1.87 they are safe in this context and the block is unused.
    #[allow(unused_unsafe)]
    unsafe fn floor_as_i32(v: __m256) -> __m256i {
        // SAFETY: the fn contract (caller verified AVX2) covers every
        // intrinsic below; none touch memory.
        unsafe {
            let fl = _mm256_floor_ps(v);
            let tr = _mm256_cvttps_epi32(fl);
            let high = _mm256_cmp_ps::<_CMP_GE_OQ>(fl, _mm256_set1_ps(2_147_483_648.0));
            let sat =
                _mm256_blendv_epi8(tr, _mm256_set1_epi32(i32::MAX), _mm256_castps_si256(high));
            let nan = _mm256_cmp_ps::<_CMP_UNORD_Q>(v, v);
            _mm256_blendv_epi8(sat, _mm256_setzero_si256(), _mm256_castps_si256(nan))
        }
    }

    /// Bin one 8-point block of `chain`: `s` is the block's sketches
    /// (point-major `[8][K]`), `lanes` is `[K][8]` feature-major prebin
    /// scratch, `ibins` is `[8][K]` point-major cached floors, `out` is
    /// the block's `[8][L][K]` slice of the tile buffer.
    ///
    /// # Safety
    /// The caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn bins_block(
        chain: &ChainParams,
        s: &[f32],
        lanes: &mut [f32],
        ibins: &mut [i32],
        out: &mut [i32],
    ) {
        let k = chain.k();
        let l = chain.depth();
        debug_assert_eq!(s.len(), LANES * k);
        debug_assert_eq!(lanes.len(), LANES * k);
        debug_assert_eq!(ibins.len(), LANES * k);
        debug_assert_eq!(out.len(), LANES * l * k);
        lanes.fill(0.0);
        ibins.fill(0);
        let mut floors = [0i32; LANES];
        // SAFETY: the caller passes buffers of exactly the sizes asserted
        // above (dispatch sites slice them from tile buffers), `f < k` by
        // `ChainParams` construction, and AVX2 is verified per the fn
        // contract — so every `get_unchecked`, raw-pointer lane access and
        // unaligned load/store below stays in bounds.
        unsafe {
            for (lvl, &f) in chain.fs.iter().enumerate() {
                let lane = lanes.as_mut_ptr().add(f * LANES);
                let new = if chain.first[lvl] {
                    // transpose the feature's column out of the point-major
                    // block, then (s + shift) / Δ lane-wise
                    let mut col = [0f32; LANES];
                    for (p, c) in col.iter_mut().enumerate() {
                        *c = *s.get_unchecked(p * k + f);
                    }
                    let sv = _mm256_loadu_ps(col.as_ptr());
                    let sh = _mm256_set1_ps(chain.shift[f]);
                    let dm = _mm256_set1_ps(chain.deltamax[f]);
                    _mm256_div_ps(_mm256_add_ps(sv, sh), dm)
                } else {
                    // 2·prebin − shift/Δ, the repeat-occurrence halving
                    let old = _mm256_loadu_ps(lane);
                    let c = _mm256_set1_ps(chain.shift[f] / chain.deltamax[f]);
                    _mm256_sub_ps(_mm256_mul_ps(_mm256_set1_ps(2.0), old), c)
                };
                _mm256_storeu_ps(lane, new);
                _mm256_storeu_si256(floors.as_mut_ptr() as *mut __m256i, floor_as_i32(new));
                for p in 0..LANES {
                    *ibins.get_unchecked_mut(p * k + f) = floors[p];
                }
                for p in 0..LANES {
                    let dst = (p * l + lvl) * k;
                    out[dst..dst + k].copy_from_slice(&ibins[p * k..p * k + k]);
                }
            }
        }
    }
}

/// Tile-level binning backend: maps a tile of `n` K-dim sketches to
/// `n × L × K` bin ids. The native implementation dispatches between the
/// scalar and AVX2 kernels (and never fails); the PJRT implementation
/// ([`crate::runtime::PjrtBinner`]) executes the AOT Pallas artifact and
/// surfaces engine failures as typed [`crate::cluster::ClusterError`]s
/// instead of panicking. All paths must agree bit-for-bit
/// (integration-tested).
pub trait Binner: Sync {
    fn tile_bins(&self, chain: &ChainParams, s: &[f32], n: usize) -> Result<Vec<i32>>;

    /// Multi-chain tiling: bin the *same* resident tile of `n` sketches
    /// against every chain in `chains`, returning a chain-major
    /// `[M][n][L][K]` buffer. The fused partition executors
    /// ([`crate::sparx::plan`]) use this so the sketch block is flattened
    /// once per partition visit instead of once per chain.
    fn tile_bins_multi(&self, chains: &[&ChainParams], s: &[f32], n: usize) -> Result<Vec<i32>> {
        let mut out = Vec::with_capacity(chains.iter().map(|c| n * c.depth() * c.k()).sum());
        for chain in chains {
            out.extend(self.tile_bins(chain, s, n)?);
        }
        Ok(out)
    }
}

/// Pure-Rust binning.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBinner;

impl Binner for NativeBinner {
    fn tile_bins(&self, chain: &ChainParams, s: &[f32], n: usize) -> Result<Vec<i32>> {
        let mut out = vec![0i32; n * chain.depth() * chain.k()];
        tile_bins_into(chain, s, n, &mut out);
        Ok(out)
    }

    /// Single allocation across all chains of the tile, each chain run
    /// through the dispatched kernel over the shared sketch block.
    fn tile_bins_multi(&self, chains: &[&ChainParams], s: &[f32], n: usize) -> Result<Vec<i32>> {
        let total: usize = chains.iter().map(|c| n * c.depth() * c.k()).sum();
        let mut out = vec![0i32; total];
        let mut off = 0;
        for chain in chains {
            let span = n * chain.depth() * chain.k();
            tile_bins_into(chain, s, n, &mut out[off..off + span]);
            off += span;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_chain() -> ChainParams {
        ChainParams::new(vec![0, 0, 0], vec![0.0], vec![2.0])
    }

    #[test]
    fn halving_matches_hand_computation() {
        // widths per level: 2, 1, 0.5 (same case as the python kernel test)
        let c = simple_chain();
        assert_eq!(c.bins(&[0.9]), vec![0, 0, 1]);
        assert_eq!(c.bins(&[1.9]), vec![0, 1, 3]);
        assert_eq!(c.bins(&[3.9]), vec![1, 3, 7]);
    }

    #[test]
    fn shift_moves_boundaries() {
        let mut c = simple_chain();
        c.shift = vec![0.5];
        // (1.9 + 0.5)/2 = 1.2 → bin 1 at level 0 (without shift it was 0)
        assert_eq!(c.bins(&[1.9])[0], 1);
    }

    #[test]
    fn untouched_features_bin_zero() {
        let c = ChainParams::new(vec![1, 1], vec![0.3, 0.0], vec![1.0, 1.0]);
        let b = c.bins(&[5.0, 0.6]);
        // feature 0 never sampled → always floor(0) = 0
        assert_eq!(b[0], 0);
        assert_eq!(b[2], 0);
    }

    #[test]
    fn first_vs_repeat_occurrence() {
        // f=0 at levels 0 and 2, f=1 at level 1
        let c = ChainParams::new(vec![0, 1, 0], vec![0.0, 0.0], vec![4.0, 2.0]);
        let b = c.bins(&[6.0, 3.0]);
        // level 0: s0/4 = 1.5 → 1 ; level 1: s1/2 = 1.5 → 1
        assert_eq!(&b[0..2], &[1, 0]);
        assert_eq!(&b[2..4], &[1, 1]);
        // level 2: 2*1.5 = 3.0 → 3 (width now 2)
        assert_eq!(&b[4..6], &[3, 1]);
    }

    #[test]
    fn nearby_points_share_coarse_bins() {
        let mut rng = Rng::new(5);
        let c = ChainParams::sample(&[1.0, 1.0, 1.0], 12, &mut rng);
        let a = c.bins(&[0.50, 0.50, 0.50]);
        let b = c.bins(&[0.5005, 0.4995, 0.5002]);
        // identical at the first few levels (coarse granularity)
        let k = 3;
        assert_eq!(&a[..2 * k], &b[..2 * k]);
    }

    #[test]
    fn native_binner_matches_pointwise() {
        let mut rng = Rng::new(9);
        let c = ChainParams::sample(&[2.0, 3.0], 8, &mut rng);
        let pts: Vec<f32> = (0..20).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let tiled = NativeBinner.tile_bins(&c, &pts, 10).unwrap();
        for i in 0..10 {
            let single = c.bins(&pts[i * 2..(i + 1) * 2]);
            assert_eq!(&tiled[i * 16..(i + 1) * 16], single.as_slice(), "point {i}");
        }
    }

    #[test]
    fn tile_bins_multi_matches_per_chain_concat() {
        let mut rng = Rng::new(21);
        let delta = vec![1.5f32, 0.75, 3.0];
        let chains: Vec<ChainParams> =
            (0..5).map(|_| ChainParams::sample(&delta, 7, &mut rng)).collect();
        let refs: Vec<&ChainParams> = chains.iter().collect();
        let n = 11;
        let s: Vec<f32> = (0..n * 3).map(|_| rng.normal() as f32 * 2.0).collect();
        let multi = NativeBinner.tile_bins_multi(&refs, &s, n).unwrap();
        let mut concat = Vec::new();
        for c in &chains {
            concat.extend(NativeBinner.tile_bins(c, &s, n).unwrap());
        }
        assert_eq!(multi, concat);
    }

    /// The dispatched kernels (floor-cache scalar and, where the host
    /// supports it, AVX2 blocks) agree bit-for-bit with the per-point
    /// oracle across edge shapes: k=1, n=0, n not a multiple of the lane
    /// width, and inputs that stress the float→i32 cast (NaN, ±∞, values
    /// past the i32 range).
    #[test]
    fn kernels_match_reference_on_edge_shapes() {
        let mut rng = Rng::new(33);
        for &k in &[1usize, 3, 8, 17] {
            for &depth in &[1usize, 4, 9] {
                let delta: Vec<f32> = (0..k).map(|_| 0.5 + rng.f32() * 3.0).collect();
                let c = ChainParams::sample(&delta, depth, &mut rng);
                for &n in &[0usize, 1, 5, 8, 13, 64] {
                    let mut s: Vec<f32> =
                        (0..n * k).map(|_| rng.normal() as f32 * 10.0).collect();
                    if s.len() >= 4 {
                        s[0] = f32::NAN;
                        s[1] = f32::INFINITY;
                        s[2] = -3.0e9;
                        s[3] = 2.0e9;
                    }
                    let expect = tile_bins_reference(&c, &s, n);
                    assert_eq!(
                        tile_bins_scalar(&c, &s, n),
                        expect,
                        "scalar k={k} depth={depth} n={n}"
                    );
                    assert_eq!(
                        NativeBinner.tile_bins(&c, &s, n).unwrap(),
                        expect,
                        "dispatched ({}) k={k} depth={depth} n={n}",
                        kernel_path()
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_path_reports_a_known_kernel() {
        assert!(matches!(kernel_path(), "avx2" | "scalar"));
    }

    #[test]
    fn sample_respects_ranges() {
        let mut rng = Rng::new(11);
        let delta = vec![0.5, 2.0, 10.0];
        for _ in 0..20 {
            let c = ChainParams::sample(&delta, 6, &mut rng);
            assert!(c.fs.iter().all(|&f| f < 3));
            for (sh, d) in c.shift.iter().zip(&delta) {
                assert!(*sh >= 0.0 && sh < d, "shift {sh} vs delta {d}");
            }
        }
    }
}
