//! The Sparx algorithm (the paper's contribution): distributed,
//! data-parallel xStream on the shared-nothing substrate.
//!
//! * [`projector`] — Step 1: hash-based sparse random projections (Eq. 2)
//! * [`chain`] — half-space chains and the binning recurrence (Eq. 4)
//! * [`cms`] — count-min sketches (per chain level)
//! * [`ensemble`] — Steps 2–3: distributed fit and scoring (Algs. 2–3, Eq. 5)
//! * [`plan`] — fused single-pass multi-chain executors ([`ExecMode`])
//! * [`stream`] — §3.5 deployment front-end for evolving streams: the
//!   Arc-shared read-only [`ServedEnsemble`] + per-scorer absorb state
//! * [`sharded`] — the concurrent front-end: ID-hash sharding of
//!   [`stream`] across pinned worker threads, one shared ensemble
//! * [`decay`] — logical-clock half-life/window schedules and the named
//!   multi-query state evaluated over one shared ingest stream
//! * [`checkpoint`] — durable absorb-state snapshots (`serve
//!   --checkpoint-out` / `--resume`)
//!
//! Most callers should not drive these pieces directly: the
//! [`crate::api`] module wraps them in the unified [`crate::api::Detector`]
//! contract (typed [`crate::api::SparxBuilder`] construction, crate-wide
//! error taxonomy). The raw `SparxModel` entry points remain public for
//! benchmarking and the cross-implementation equivalence tests.

pub mod chain;
pub mod checkpoint;
pub mod cms;
pub mod decay;
pub mod ensemble;
pub mod plan;
pub mod projector;
pub mod sharded;
pub mod stream;

pub use chain::{
    kernel_path, tile_bins_reference, tile_bins_scalar, Binner, ChainParams, NativeBinner,
};
pub use checkpoint::{AbsorbCheckpoint, AbsorbSnapshot, QueryRecord};
pub use cms::CountMinSketch;
pub use decay::{DecaySpec, QueryState};
pub use ensemble::{
    score_bins, score_bins_overlaid, score_bins_overlaid2, score_bins_tile, ScoreMode, SparxModel,
    SparxParams, TrainedChain,
};
pub use plan::{ChainSet, ExecMode};
pub use projector::{compute_deltamax, project_dataset, Projector, Sketch};
pub use sharded::{
    shard_of, MemberInfo, QueryInfo, ReplySink, ServeOptions, ShardCounters, ShardReply,
    ShardedReport, ShardedStats, ShardedStreamScorer, WouldBlock, ABSORB_EPOCH,
};
pub use stream::{ServedEnsemble, StreamScore, StreamScorer, SwapCarry};
