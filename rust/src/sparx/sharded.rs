//! Sharded concurrent serving (§3.5 scaled out): S shared-nothing
//! shards, each owning its own LRU + sketch state behind a bounded
//! ingest queue on a long-lived pinned worker thread.
//!
//! Updates route by `murmur(ID) % S`, so every update for a given ID
//! lands on the same shard, in arrival order. Because shards share
//! nothing — separate caches, separate CMS copies, separate scratch —
//! each shard behaves **bit-identically** to a single-threaded
//! [`StreamScorer`] fed that shard's sub-stream, regardless of thread
//! interleaving. While no shard evicts, per-ID score sequences are
//! additionally identical across shard counts (eviction resets a
//! sketch, and *when* an ID is evicted depends on which other IDs share
//! its LRU — the one part of the contract that is cache-sizing, not
//! sharding). Both statements are what the determinism harness in
//! `tests/sharded.rs` replays.
//!
//! Design notes:
//! * the feeder coalesces routed updates into small batches so queue
//!   synchronisation amortises (one lock round trip per [`BATCH`]
//!   updates, not per update);
//! * a full shard queue blocks the feeder ([`PinnedPool`] backpressure)
//!   — updates are never dropped;
//! * [`ShardedStreamScorer::finish`] flushes, closes the queues, joins
//!   the workers and merges per-shard counters into a [`ShardedReport`].

use crate::api::{Result, SparxError};
use crate::cluster::pool::PinnedPool;
use crate::data::UpdateTriple;
use crate::hash::murmur3_bytes;

use super::ensemble::SparxModel;
use super::stream::{StreamScore, StreamScorer};

/// Seed of the ID → shard murmur route. Fixed: shard assignment is part
/// of the serving contract (a restarted deployment must route every ID
/// to the same shard it lived on before).
const SHARD_ROUTE_SEED: u32 = 0x51AD_0C47;

/// Updates per channel message (feeder-side coalescing).
const BATCH: usize = 64;

/// Bound of each shard's ingest queue, in batches.
const QUEUE_CAP_BATCHES: usize = 64;

/// Shard index for `id` among `shards` shards.
#[inline]
pub fn shard_of(id: u64, shards: usize) -> usize {
    debug_assert!(shards >= 1);
    murmur3_bytes(&id.to_le_bytes(), SHARD_ROUTE_SEED) as usize % shards
}

/// Per-shard worker state: the shard's own single-threaded scorer plus
/// the counters the merged report is built from.
struct Shard {
    scorer: StreamScorer,
    worst: Option<StreamScore>,
    admitted: u64,
    recorded: Option<Vec<StreamScore>>,
}

/// Counters one shard reports after [`ShardedStreamScorer::finish`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardCounters {
    /// δ-updates this shard processed.
    pub processed: u64,
    /// IDs admitted to this shard's cache (`fresh` scores).
    pub admitted: u64,
    /// LRU evictions in this shard.
    pub evictions: u64,
    /// Sketches resident in this shard's cache at shutdown.
    pub cached_ids: usize,
}

/// The merged post-shutdown report: per-shard counters, the most
/// outlying update seen anywhere, and (in recording mode) every shard's
/// full score sequence in processing order.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    pub shards: Vec<ShardCounters>,
    pub worst: Option<StreamScore>,
    /// Per-shard score logs; empty unless the scorer was built with
    /// [`ShardedStreamScorer::recording`].
    pub scores: Vec<Vec<StreamScore>>,
}

impl ShardedReport {
    /// Total δ-updates processed across shards.
    pub fn processed(&self) -> u64 {
        self.shards.iter().map(|s| s.processed).sum()
    }

    /// Total LRU evictions across shards.
    pub fn evictions(&self) -> u64 {
        self.shards.iter().map(|s| s.evictions).sum()
    }

    /// Total cache admissions across shards.
    pub fn admitted(&self) -> u64 {
        self.shards.iter().map(|s| s.admitted).sum()
    }

    /// Total sketches resident across shards at shutdown.
    pub fn cached_ids(&self) -> usize {
        self.shards.iter().map(|s| s.cached_ids).sum()
    }
}

/// The multi-threaded §3.5 front-end. Build from a fitted model via
/// [`ShardedStreamScorer::new`] (or `FittedModel::stream_scorer_sharded`
/// through the api), [`submit`](Self::submit) the update stream, then
/// [`finish`](Self::finish) for the merged report.
pub struct ShardedStreamScorer {
    pool: PinnedPool<Vec<UpdateTriple>, Shard>,
    pending: Vec<Vec<UpdateTriple>>,
    shards: usize,
    submitted: u64,
    feature_names: Option<Vec<String>>,
}

impl ShardedStreamScorer {
    /// `shards` shared-nothing workers, each with an LRU of
    /// `cache_per_shard` IDs (total resident sketches:
    /// `shards × cache_per_shard`). Same model requirements as
    /// [`StreamScorer::new`].
    pub fn new(model: &SparxModel, shards: usize, cache_per_shard: usize) -> Result<Self> {
        Self::build(model, shards, cache_per_shard, false)
    }

    /// Test-harness constructor: every shard additionally records its
    /// full score sequence for later comparison. Memory grows with the
    /// stream — not for production serving.
    pub fn recording(model: &SparxModel, shards: usize, cache_per_shard: usize) -> Result<Self> {
        Self::build(model, shards, cache_per_shard, true)
    }

    fn build(
        model: &SparxModel,
        shards: usize,
        cache_per_shard: usize,
        record: bool,
    ) -> Result<Self> {
        if shards == 0 {
            return Err(SparxError::InvalidParams("shard count must be ≥ 1".into()));
        }
        if shards > 4096 {
            return Err(SparxError::InvalidParams(format!(
                "shard count {shards} exceeds the 4096-thread cap"
            )));
        }
        let mut states = Vec::with_capacity(shards);
        for _ in 0..shards {
            states.push(Shard {
                scorer: StreamScorer::new(model, cache_per_shard)?,
                worst: None,
                admitted: 0,
                recorded: record.then(Vec::new),
            });
        }
        let pool = PinnedPool::spawn(
            states,
            QUEUE_CAP_BATCHES,
            |shard: &mut Shard, batch: Vec<UpdateTriple>| {
                for u in batch {
                    let s = shard.scorer.update(&u);
                    if s.fresh {
                        shard.admitted += 1;
                    }
                    if s.more_outlying_than(shard.worst.as_ref()) {
                        shard.worst = Some(s.clone());
                    }
                    if let Some(log) = &mut shard.recorded {
                        log.push(s);
                    }
                }
            },
        );
        Ok(ShardedStreamScorer {
            pool,
            pending: vec![Vec::with_capacity(BATCH); shards],
            shards,
            submitted: 0,
            feature_names: model.projector.dense_schema().map(|n| n.to_vec()),
        })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Updates submitted so far (some may still be in flight — the
    /// per-shard `processed` counters are exact only after `finish`).
    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// See [`StreamScorer::feature_names`].
    pub fn feature_names(&self) -> Option<&[String]> {
        self.feature_names.as_deref()
    }

    /// Route one update to its shard. Blocks only when that shard's
    /// bounded ingest queue is full (backpressure, never loss — unless
    /// a shard worker has panicked, in which case its updates are
    /// discarded and [`finish`](Self::finish) re-raises the panic).
    pub fn submit(&mut self, u: UpdateTriple) {
        let s = shard_of(u.id(), self.shards);
        self.pending[s].push(u);
        self.submitted += 1;
        if self.pending[s].len() >= BATCH {
            let batch = std::mem::replace(&mut self.pending[s], Vec::with_capacity(BATCH));
            self.pool.send(s, batch);
        }
    }

    /// Flush the pending batches, close the queues, join the workers
    /// and merge the per-shard counters.
    pub fn finish(self) -> ShardedReport {
        let ShardedStreamScorer { pool, mut pending, .. } = self;
        for (s, buf) in pending.iter_mut().enumerate() {
            if !buf.is_empty() {
                pool.send(s, std::mem::take(buf));
            }
        }
        let shards = pool.join();
        let mut report = ShardedReport {
            shards: Vec::with_capacity(shards.len()),
            worst: None,
            scores: Vec::with_capacity(shards.len()),
        };
        for sh in shards {
            report.shards.push(ShardCounters {
                processed: sh.scorer.processed(),
                admitted: sh.admitted,
                evictions: sh.scorer.evictions(),
                cached_ids: sh.scorer.cached_ids(),
            });
            if let Some(w) = sh.worst {
                if w.more_outlying_than(report.worst.as_ref()) {
                    report.worst = Some(w);
                }
            }
            report.scores.push(sh.recorded.unwrap_or_default());
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::data::generators::GisetteGen;
    use crate::sparx::SparxParams;

    fn fitted() -> SparxModel {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 300, d: 16, ..Default::default() }.generate(&ctx).unwrap();
        SparxModel::fit(
            &ctx,
            &ld.dataset,
            &SparxParams { k: 8, num_chains: 6, depth: 5, ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        for shards in [1usize, 2, 3, 8] {
            for id in 0..500u64 {
                let s = shard_of(id, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(id, shards));
            }
        }
    }

    #[test]
    fn submit_finish_counts_every_update() {
        let model = fitted();
        let mut scorer = ShardedStreamScorer::new(&model, 3, 32).unwrap();
        for id in 0..200u64 {
            scorer.submit(UpdateTriple::Num { id, feature: "f0".into(), delta: 1.0 });
        }
        assert_eq!(scorer.submitted(), 200);
        let report = scorer.finish();
        assert_eq!(report.processed(), 200);
        assert_eq!(report.admitted(), 200, "every id is distinct → every update admits");
        assert_eq!(report.shards.len(), 3);
    }

    #[test]
    fn zero_shards_and_zero_cache_are_typed_errors() {
        let model = fitted();
        assert!(matches!(
            ShardedStreamScorer::new(&model, 0, 32),
            Err(SparxError::InvalidParams(_))
        ));
        assert!(matches!(
            ShardedStreamScorer::new(&model, 2, 0),
            Err(SparxError::InvalidParams(_))
        ));
    }

    #[test]
    fn drop_without_finish_shuts_down() {
        let model = fitted();
        let mut scorer = ShardedStreamScorer::new(&model, 2, 8).unwrap();
        scorer.submit(UpdateTriple::Num { id: 1, feature: "f0".into(), delta: 1.0 });
        drop(scorer); // error-path shutdown: close queues, join workers
    }

    #[test]
    fn recording_mode_captures_per_shard_logs() {
        let model = fitted();
        let mut scorer = ShardedStreamScorer::recording(&model, 2, 32).unwrap();
        for id in 0..10u64 {
            scorer.submit(UpdateTriple::Num { id, feature: "f0".into(), delta: 0.5 });
        }
        let report = scorer.finish();
        let logged: usize = report.scores.iter().map(Vec::len).sum();
        assert_eq!(logged, 10);
        for (s, log) in report.scores.iter().enumerate() {
            for rec in log {
                assert_eq!(shard_of(rec.id, 2), s, "score recorded on the wrong shard");
            }
        }
    }
}
