//! Sharded concurrent serving (§3.5 scaled out): S shards, each owning
//! its own **mutable** absorb state (LRU + absorbed CMS delta) behind a
//! bounded ingest queue on a long-lived pinned worker thread — while all
//! S shards share **one** read-only [`ServedEnsemble`] behind an `Arc`,
//! so the resident model footprint is 1× regardless of the shard count.
//!
//! Updates route by `murmur(ID) % S`, so every update for a given ID
//! lands on the same shard, in arrival order. Shards share no *mutable*
//! state — separate caches, separate absorbed deltas, separate scratch —
//! and scoring only reads the shared ensemble, so each shard behaves
//! **bit-identically** to a single-threaded [`StreamScorer`] fed that
//! shard's sub-stream, regardless of thread interleaving. While no shard
//! evicts (and absorb mode is off), per-ID score sequences are
//! additionally identical across shard counts (eviction resets a
//! sketch, and *when* an ID is evicted depends on which other IDs share
//! its LRU — the one part of the contract that is cache-sizing, not
//! sharding). Both statements are what the determinism harness in
//! `tests/sharded.rs` replays.
//!
//! Design notes:
//! * the feeder coalesces routed updates into small batches so queue
//!   synchronisation amortises (one lock round trip per [`BATCH`]
//!   updates, not per update); every update carries its global submit
//!   **sequence number**, so recorded per-shard score logs merge back
//!   into exact submit order ([`ShardedReport::merged_scores`]);
//! * a full shard queue blocks the feeder ([`PinnedPool`] backpressure)
//!   — updates are never dropped;
//! * the same queues carry the serving control plane: state snapshots
//!   for checkpointing ([`ShardedStreamScorer::checkpoint`]) and atomic
//!   ensemble swaps for hot reload
//!   ([`ShardedStreamScorer::swap_ensemble`]) are messages processed in
//!   stream order, so a checkpoint cut or a model swap lands at a
//!   deterministic point of every shard's sub-stream;
//! * [`ShardedStreamScorer::finish`] flushes, closes the queues, joins
//!   the workers and merges per-shard counters into a [`ShardedReport`].

use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;

use crate::api::{Result, SparxError};
use crate::cluster::pool::PinnedPool;
use crate::data::UpdateTriple;
use crate::hash::murmur3_bytes;

use super::checkpoint::{AbsorbCheckpoint, AbsorbSnapshot};
use super::ensemble::SparxModel;
use super::stream::{ServedEnsemble, StreamScore, StreamScorer, SwapCarry};

/// Seed of the ID → shard murmur route. Fixed: shard assignment is part
/// of the serving contract (a restarted deployment must route every ID
/// to the same shard it lived on before — which is also what lets a
/// checkpoint restore per-shard state onto the same layout).
const SHARD_ROUTE_SEED: u32 = 0x51AD_0C47;

/// Updates per channel message (feeder-side coalescing).
const BATCH: usize = 64;

/// Bound of each shard's ingest queue, in batches.
const QUEUE_CAP_BATCHES: usize = 64;

/// Shard index for `id` among `shards` shards.
#[inline]
pub fn shard_of(id: u64, shards: usize) -> usize {
    debug_assert!(shards >= 1);
    murmur3_bytes(&id.to_le_bytes(), SHARD_ROUTE_SEED) as usize % shards
}

/// Serving-mode switches for the sharded front-end.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeOptions {
    /// Record every (sequence, score) pair per shard for later merging —
    /// memory grows with the stream; for harnesses and `--score-log`,
    /// not steady-state production serving.
    pub record: bool,
    /// Absorb every update's point into its shard's delta overlay after
    /// scoring (the xStream online behaviour). The reported score stays
    /// the pre-absorb one. Note absorb couples IDs *within* a shard, so
    /// cross-shard-count score identity no longer holds — but per-shard
    /// state still checkpoints/merges exactly.
    pub absorb: bool,
}

/// What travels over a shard's ingest queue: data batches, plus the two
/// control messages of the serving lifecycle.
enum ShardMsg {
    /// Sequence-numbered updates, in submit order.
    Batch(Vec<(u64, UpdateTriple)>),
    /// Snapshot the shard's absorb state and send it back (checkpoint
    /// cut: lands after every update submitted before it).
    Snapshot(SyncSender<AbsorbSnapshot>),
    /// Atomically swap the shared ensemble (hot reload). The feeder
    /// validates compatibility *before* broadcasting, so the per-shard
    /// swap cannot fail.
    Swap(Arc<ServedEnsemble>),
}

/// Per-shard worker state: the shard's own single-threaded scorer plus
/// the counters the merged report is built from.
struct Shard {
    scorer: StreamScorer,
    worst: Option<StreamScore>,
    admitted: u64,
    recorded: Option<Vec<(u64, StreamScore)>>,
    absorb: bool,
}

/// Counters one shard reports after [`ShardedStreamScorer::finish`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardCounters {
    /// δ-updates this shard processed.
    pub processed: u64,
    /// IDs admitted to this shard's cache (`fresh` scores).
    pub admitted: u64,
    /// LRU evictions in this shard.
    pub evictions: u64,
    /// Sketches resident in this shard's cache at shutdown.
    pub cached_ids: usize,
    /// Points absorbed into this shard's delta overlay.
    pub absorbed: u64,
}

/// The merged post-shutdown report: per-shard counters, the most
/// outlying update seen anywhere, and (in recording mode) every shard's
/// full score sequence tagged with global submit sequence numbers.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    pub shards: Vec<ShardCounters>,
    pub worst: Option<StreamScore>,
    /// Per-shard `(submit sequence, score)` logs in shard processing
    /// order; empty unless the scorer was built with
    /// [`ServeOptions::record`]. Use
    /// [`merged_scores`](Self::merged_scores) for the global view.
    pub scores: Vec<Vec<(u64, StreamScore)>>,
}

impl ShardedReport {
    /// Total δ-updates processed across shards.
    pub fn processed(&self) -> u64 {
        self.shards.iter().map(|s| s.processed).sum()
    }

    /// Total LRU evictions across shards.
    pub fn evictions(&self) -> u64 {
        self.shards.iter().map(|s| s.evictions).sum()
    }

    /// Total cache admissions across shards.
    pub fn admitted(&self) -> u64 {
        self.shards.iter().map(|s| s.admitted).sum()
    }

    /// Total sketches resident across shards at shutdown.
    pub fn cached_ids(&self) -> usize {
        self.shards.iter().map(|s| s.cached_ids).sum()
    }

    /// Total points absorbed across shards.
    pub fn absorbed(&self) -> u64 {
        self.shards.iter().map(|s| s.absorbed).sum()
    }

    /// The recorded score logs interleaved back into **global submit
    /// order** by sequence number — bit-stable across shard counts and
    /// thread interleavings, which is what lets a resumed run's log be
    /// diffed against an uninterrupted one. Empty unless recording.
    pub fn merged_scores(&self) -> Vec<StreamScore> {
        let mut tagged: Vec<(u64, &StreamScore)> = self
            .scores
            .iter()
            .flatten()
            .map(|(seq, score)| (*seq, score))
            .collect();
        tagged.sort_unstable_by_key(|(seq, _)| *seq);
        tagged.into_iter().map(|(_, score)| score.clone()).collect()
    }
}

/// The multi-threaded §3.5 front-end. Build from a fitted model via
/// [`ShardedStreamScorer::new`] (or `FittedModel::stream_scorer_sharded`
/// through the api), or share an already-frozen ensemble with
/// [`ShardedStreamScorer::from_ensemble`]; [`submit`](Self::submit) the
/// update stream, then [`finish`](Self::finish) for the merged report.
pub struct ShardedStreamScorer {
    pool: PinnedPool<ShardMsg, Shard>,
    pending: Vec<Vec<(u64, UpdateTriple)>>,
    shards: usize,
    cache_per_shard: usize,
    submitted: u64,
    absorb: bool,
    ensemble: Arc<ServedEnsemble>,
}

impl ShardedStreamScorer {
    /// `shards` workers sharing one read-only ensemble, each with a
    /// private LRU of `cache_per_shard` IDs (total resident sketches:
    /// `shards × cache_per_shard`; resident model: **1×**, Arc-shared).
    /// Same model requirements as [`StreamScorer::new`].
    pub fn new(model: &SparxModel, shards: usize, cache_per_shard: usize) -> Result<Self> {
        Self::from_ensemble(
            Arc::new(ServedEnsemble::new(model)?),
            shards,
            cache_per_shard,
            ServeOptions::default(),
            None,
        )
    }

    /// Test-harness constructor: every shard additionally records its
    /// full score sequence for later comparison. Memory grows with the
    /// stream — not for production serving.
    pub fn recording(model: &SparxModel, shards: usize, cache_per_shard: usize) -> Result<Self> {
        Self::from_ensemble(
            Arc::new(ServedEnsemble::new(model)?),
            shards,
            cache_per_shard,
            ServeOptions { record: true, absorb: false },
            None,
        )
    }

    /// The full-control constructor: share `ensemble` across `shards`
    /// workers, optionally recording and/or absorbing
    /// ([`ServeOptions`]), optionally restoring a checkpoint so the
    /// stream continues exactly where a previous process left off.
    /// Resume is validated typed before any worker spawns: the
    /// checkpoint must carry the same model fingerprint, shard count and
    /// cache capacity it was taken under.
    pub fn from_ensemble(
        ensemble: Arc<ServedEnsemble>,
        shards: usize,
        cache_per_shard: usize,
        opts: ServeOptions,
        resume: Option<&AbsorbCheckpoint>,
    ) -> Result<Self> {
        if shards == 0 {
            return Err(SparxError::InvalidParams("shard count must be ≥ 1".into()));
        }
        if shards > 4096 {
            return Err(SparxError::InvalidParams(format!(
                "shard count {shards} exceeds the 4096-thread cap"
            )));
        }
        if let Some(ckpt) = resume {
            ckpt.validate_for(&ensemble, shards, cache_per_shard, opts.absorb)?;
        }
        let mut states = Vec::with_capacity(shards);
        for s in 0..shards {
            let mut scorer = StreamScorer::from_ensemble(ensemble.clone(), cache_per_shard)?;
            let mut admitted = 0;
            if let Some(ckpt) = resume {
                let snap = ckpt.snapshots.get(s).ok_or_else(|| {
                    SparxError::InvalidParams(format!(
                        "checkpoint carries {} snapshots but declares {shards} shards",
                        ckpt.snapshots.len()
                    ))
                })?;
                scorer.restore(snap)?;
                admitted = snap.admitted();
            }
            states.push(Shard {
                scorer,
                worst: None,
                admitted,
                recorded: opts.record.then(Vec::new),
                absorb: opts.absorb,
            });
        }
        let pool = PinnedPool::spawn(
            states,
            QUEUE_CAP_BATCHES,
            |shard: &mut Shard, msg: ShardMsg| match msg {
                ShardMsg::Batch(batch) => {
                    for (seq, u) in batch {
                        let s = shard.scorer.update(&u);
                        if s.fresh {
                            shard.admitted += 1;
                        }
                        if shard.absorb {
                            shard.scorer.absorb_only(s.id);
                        }
                        if s.more_outlying_than(shard.worst.as_ref()) {
                            shard.worst = Some(s.clone());
                        }
                        if let Some(log) = &mut shard.recorded {
                            log.push((seq, s));
                        }
                    }
                }
                ShardMsg::Snapshot(reply) => {
                    // a dropped receiver (feeder gone) is not an error
                    let _ = reply.send(shard.scorer.snapshot());
                }
                ShardMsg::Swap(ens) => {
                    // the feeder validated compatibility against the same
                    // shared ensemble every shard holds, so this cannot
                    // fail; a panic here would mean shards diverged, and
                    // crashing the worker (re-raised at `finish`) beats
                    // silently serving from mismatched models
                    shard
                        .scorer
                        .swap_ensemble(ens)
                        // lint:allow(no-panic-paths)
                        .expect("feeder validates swap compatibility");
                }
            },
        );
        Ok(ShardedStreamScorer {
            pool,
            pending: vec![Vec::with_capacity(BATCH); shards],
            shards,
            cache_per_shard,
            submitted: resume.map_or(0, |c| c.submitted),
            absorb: opts.absorb,
            ensemble,
        })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Updates submitted so far — across process restarts when resumed
    /// from a checkpoint (some may still be in flight; the per-shard
    /// `processed` counters are exact only after `finish`).
    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// The shared read-only ensemble all shards score against.
    pub fn ensemble(&self) -> &Arc<ServedEnsemble> {
        &self.ensemble
    }

    /// Bytes of the **one** resident ensemble all shards share — this
    /// does not scale with the shard count (the pre-refactor design held
    /// S independent copies).
    pub fn resident_ensemble_bytes(&self) -> usize {
        self.ensemble.resident_bytes()
    }

    /// See [`ServedEnsemble::feature_names`].
    pub fn feature_names(&self) -> Option<&[String]> {
        self.ensemble.feature_names()
    }

    /// Route one update to its shard, tagged with its global submit
    /// sequence number. Blocks only when that shard's bounded ingest
    /// queue is full (backpressure, never loss — unless a shard worker
    /// has panicked, in which case its updates are discarded and
    /// [`finish`](Self::finish) re-raises the panic).
    pub fn submit(&mut self, u: UpdateTriple) {
        let s = shard_of(u.id(), self.shards);
        let seq = self.submitted;
        self.submitted += 1;
        // `shard_of` reduces modulo the shard count, so the slot always
        // exists; `get_mut` keeps the path panic-free regardless.
        if let Some(buf) = self.pending.get_mut(s) {
            buf.push((seq, u));
            if buf.len() >= BATCH {
                let batch = std::mem::replace(buf, Vec::with_capacity(BATCH));
                self.pool.send(s, ShardMsg::Batch(batch));
            }
        }
    }

    /// Flush everything submitted so far to the shards.
    fn flush_pending(&mut self) {
        for (s, buf) in self.pending.iter_mut().enumerate() {
            if !buf.is_empty() {
                self.pool.send(s, ShardMsg::Batch(std::mem::take(buf)));
            }
        }
    }

    /// Cut a consistent checkpoint: flush the pending batches, ask every
    /// shard to snapshot its absorb state (the snapshot message lands
    /// *after* every update submitted before this call), and merge the S
    /// snapshots under one header. The stream can keep flowing
    /// afterwards — nothing is torn down.
    ///
    /// A shard worker that died (panicked) before answering its snapshot
    /// surfaces as a typed error — the caller decides whether to keep
    /// serving; [`finish`](Self::finish) re-raises the underlying panic.
    pub fn checkpoint(&mut self) -> Result<AbsorbCheckpoint> {
        self.flush_pending();
        let mut replies = Vec::with_capacity(self.shards);
        for s in 0..self.shards {
            let (tx, rx) = sync_channel(1);
            self.pool.send(s, ShardMsg::Snapshot(tx));
            replies.push(rx);
        }
        let mut snapshots = Vec::with_capacity(self.shards);
        for (s, rx) in replies.into_iter().enumerate() {
            let snap = rx.recv().map_err(|_| {
                SparxError::Io(format!("shard {s} worker died before answering the snapshot"))
            })?;
            snapshots.push(snap);
        }
        Ok(AbsorbCheckpoint::for_ensemble(
            &self.ensemble,
            self.shards as u32,
            self.cache_per_shard as u64,
            self.submitted,
            self.absorb,
            snapshots,
        ))
    }

    /// Hot model reload: validate the swap once at the feeder (typed
    /// rejection when the serving schemas differ — no shard is touched),
    /// flush, then broadcast the new `Arc` so every shard swaps at the
    /// same deterministic point of its sub-stream, carrying its absorb
    /// state forward per [`ServedEnsemble::swap_carry`].
    pub fn swap_ensemble(&mut self, new: Arc<ServedEnsemble>) -> Result<SwapCarry> {
        let carry = self.ensemble.swap_carry(&new)?;
        self.flush_pending();
        for s in 0..self.shards {
            self.pool.send(s, ShardMsg::Swap(new.clone()));
        }
        self.ensemble = new;
        Ok(carry)
    }

    /// Flush the pending batches, close the queues, join the workers
    /// and merge the per-shard counters.
    pub fn finish(mut self) -> ShardedReport {
        self.flush_pending();
        let ShardedStreamScorer { pool, .. } = self;
        let shards = pool.join();
        let mut report = ShardedReport {
            shards: Vec::with_capacity(shards.len()),
            worst: None,
            scores: Vec::with_capacity(shards.len()),
        };
        for sh in shards {
            report.shards.push(ShardCounters {
                processed: sh.scorer.processed(),
                admitted: sh.admitted,
                evictions: sh.scorer.evictions(),
                cached_ids: sh.scorer.cached_ids(),
                absorbed: sh.scorer.absorbed(),
            });
            if let Some(w) = sh.worst {
                if w.more_outlying_than(report.worst.as_ref()) {
                    report.worst = Some(w);
                }
            }
            report.scores.push(sh.recorded.unwrap_or_default());
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::data::generators::GisetteGen;
    use crate::sparx::SparxParams;

    fn fitted() -> SparxModel {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 300, d: 16, ..Default::default() }.generate(&ctx).unwrap();
        SparxModel::fit(
            &ctx,
            &ld.dataset,
            &SparxParams { k: 8, num_chains: 6, depth: 5, ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        for shards in [1usize, 2, 3, 8] {
            for id in 0..500u64 {
                let s = shard_of(id, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(id, shards));
            }
        }
    }

    #[test]
    fn submit_finish_counts_every_update() {
        let model = fitted();
        let mut scorer = ShardedStreamScorer::new(&model, 3, 32).unwrap();
        for id in 0..200u64 {
            scorer.submit(UpdateTriple::Num { id, feature: "f0".into(), delta: 1.0 });
        }
        assert_eq!(scorer.submitted(), 200);
        let report = scorer.finish();
        assert_eq!(report.processed(), 200);
        assert_eq!(report.admitted(), 200, "every id is distinct → every update admits");
        assert_eq!(report.shards.len(), 3);
    }

    #[test]
    fn zero_shards_and_zero_cache_are_typed_errors() {
        let model = fitted();
        assert!(matches!(
            ShardedStreamScorer::new(&model, 0, 32),
            Err(SparxError::InvalidParams(_))
        ));
        assert!(matches!(
            ShardedStreamScorer::new(&model, 2, 0),
            Err(SparxError::InvalidParams(_))
        ));
    }

    #[test]
    fn drop_without_finish_shuts_down() {
        let model = fitted();
        let mut scorer = ShardedStreamScorer::new(&model, 2, 8).unwrap();
        scorer.submit(UpdateTriple::Num { id: 1, feature: "f0".into(), delta: 1.0 });
        drop(scorer); // error-path shutdown: close queues, join workers
    }

    #[test]
    fn recording_mode_captures_per_shard_logs_with_submit_seqs() {
        let model = fitted();
        let mut scorer = ShardedStreamScorer::recording(&model, 2, 32).unwrap();
        for id in 0..10u64 {
            scorer.submit(UpdateTriple::Num { id, feature: "f0".into(), delta: 0.5 });
        }
        let report = scorer.finish();
        let logged: usize = report.scores.iter().map(Vec::len).sum();
        assert_eq!(logged, 10);
        for (s, log) in report.scores.iter().enumerate() {
            for (seq, rec) in log {
                assert_eq!(shard_of(rec.id, 2), s, "score recorded on the wrong shard");
                assert!(*seq < 10, "sequence numbers come from the submit counter");
            }
        }
        // the merged view is in exact submit order: seq 0..10, and since
        // ids were submitted in order, ids 0..10 in order too
        let merged = report.merged_scores();
        assert_eq!(merged.len(), 10);
        let ids: Vec<u64> = merged.iter().map(|s| s.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>(), "merge must restore submit order");
    }

    /// The Arc-sharing contract: S shards hold handles on one ensemble
    /// (S worker handles + the feeder's), and the reported resident
    /// footprint does not scale with S.
    #[test]
    fn shards_share_one_ensemble_at_one_x_footprint() {
        let model = fitted();
        let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
        let one = ShardedStreamScorer::from_ensemble(
            ens.clone(),
            1,
            16,
            ServeOptions::default(),
            None,
        )
        .unwrap();
        let bytes_s1 = one.resident_ensemble_bytes();
        drop(one.finish());
        let eight = ShardedStreamScorer::from_ensemble(
            ens.clone(),
            8,
            16,
            ServeOptions::default(),
            None,
        )
        .unwrap();
        assert_eq!(
            Arc::strong_count(&ens),
            1 + 1 + 8,
            "local + feeder + 8 shard handles on ONE ensemble"
        );
        assert_eq!(
            eight.resident_ensemble_bytes(),
            bytes_s1,
            "resident ensemble bytes must be independent of the shard count"
        );
        assert!(bytes_s1 > 0);
        drop(eight.finish());
        assert_eq!(Arc::strong_count(&ens), 1, "workers must release their handles at join");
    }

    /// Absorb mode: every update's point lands in its shard's delta; the
    /// per-shard absorbed counters sum to the stream length.
    #[test]
    fn absorb_mode_counts_and_reports() {
        let model = fitted();
        let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
        let mut scorer = ShardedStreamScorer::from_ensemble(
            ens,
            3,
            64,
            ServeOptions { record: false, absorb: true },
            None,
        )
        .unwrap();
        for id in 0..50u64 {
            scorer.submit(UpdateTriple::Num { id, feature: "f0".into(), delta: 0.5 });
        }
        let report = scorer.finish();
        assert_eq!(report.processed(), 50);
        assert_eq!(report.absorbed(), 50, "absorb mode must absorb every update");
    }
}
