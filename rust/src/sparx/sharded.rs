//! Sharded concurrent serving (§3.5 scaled out): S shards, each owning
//! its own **mutable** absorb state behind a bounded ingest queue on a
//! long-lived pinned worker thread — while all S shards share **one**
//! read-only [`ServedEnsemble`] behind an `Arc`, so the resident model
//! footprint is 1× regardless of the shard count.
//!
//! Updates route by `murmur(ID) % S`, so every update for a given ID
//! lands on the same shard, in arrival order. Shards share no *mutable*
//! state; what makes the pool **bit-identical across shard counts** is
//! that the two cross-ID couplings are lifted out of the shards and
//! driven by the (single-threaded) feeder, as a pure function of the
//! global submit sequence:
//!
//! * **Global eviction.** `--cache` is a *total* budget. The feeder
//!   keeps a global recency directory (`ID → last-touch sequence`) of
//!   exactly that capacity; when an admission overflows it, the feeder
//!   enqueues an explicit [`ShardItem::Evict`] for the globally
//!   least-recent ID *before* the admitting update — the same victim,
//!   at the same stream position, a single-threaded scorer with the
//!   same budget would pick. Per-shard caches are sized to the full
//!   budget so they never self-evict.
//! * **Epoch-published absorb.** In absorb mode, shard-local absorbs
//!   land in an invisible *pending* overlay; every [`ABSORB_EPOCH`]
//!   submits the feeder drains all pendings (a queue barrier), sums the
//!   increments (saturating adds commute — order can't matter), and
//!   broadcasts the merged batch back, so what any score "has seen" is
//!   a function of the submit sequence alone, never of the shard
//!   layout. (A plain [`StreamScorer`] publishes immediately; the
//!   sharded reference for absorb-mode bit-identity is therefore the
//!   pool at S = 1, which shares the epoch schedule.)
//!
//! Both invariants are what lets [`checkpoint`][ShardedStreamScorer::checkpoint]
//! persist *global* state (v4 format, see [`super::checkpoint`]) and
//! [`reshard`][ShardedStreamScorer::reshard] re-partition it live:
//! resume and reshard may change the shard count freely and the per-ID
//! score sequences continue bit-identically.
//!
//! Design notes:
//! * the feeder coalesces routed items into small batches so queue
//!   synchronisation amortises (one lock round trip per [`BATCH`]
//!   items); every update carries its global submit **sequence
//!   number**, so recorded per-shard score logs merge back into exact
//!   submit order ([`ShardedReport::merged_scores`]);
//! * a full shard queue blocks [`submit`][ShardedStreamScorer::submit]
//!   ([`PinnedPool`] backpressure — updates are never dropped), while
//!   [`try_submit`][ShardedStreamScorer::try_submit] surfaces the same
//!   condition as a typed [`WouldBlock`] without consuming the update's
//!   sequence number — the TCP ingress uses it to push backpressure to
//!   slow clients instead of stalling the accept loop;
//! * updates can carry a per-item [`ReplySink`]; shard workers send the
//!   score back through it and *never block doing so* (the sink is an
//!   unbounded sender), so one slow consumer cannot stall a shard;
//! * the same queues carry the serving control plane — snapshots,
//!   pending-drain barriers, visible-overlay publishes, counter probes
//!   and atomic ensemble swaps are messages processed in stream order,
//!   so each lands at a deterministic point of every shard's sub-stream.

use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Sender, SyncSender};
use std::sync::Arc;

use crate::api::{Result, SparxError};
use crate::cluster::pool::PinnedPool;
use crate::data::UpdateTriple;
use crate::hash::murmur3_bytes;
use crate::util::LruCache;

use super::checkpoint::{AbsorbCheckpoint, AbsorbSnapshot, QueryRecord};
use super::decay::{validate_query_name, DecaySpec, QueryState, MAX_QUERIES};
use super::ensemble::SparxModel;
use super::stream::{ServedEnsemble, StreamScore, StreamScorer, SwapCarry};

/// Seed of the ID → shard murmur route. Fixed: shard assignment is part
/// of the serving contract (every update for an ID must land on the
/// shard that owns its sketch). Since v4 checkpoints the route is *not*
/// part of the persistence contract — resume re-partitions by the new
/// shard count.
const SHARD_ROUTE_SEED: u32 = 0x51AD_0C47;

/// Items per channel message (feeder-side coalescing).
const BATCH: usize = 64;

/// Bound of each shard's ingest queue, in batches.
const QUEUE_CAP_BATCHES: usize = 64;

/// Absorb-mode publish period, in submitted updates: pendings are
/// drained, merged and republished every time the global submit counter
/// crosses a multiple of this. Part of the serving contract — changing
/// it changes absorb-mode scores (but never their S-independence).
pub const ABSORB_EPOCH: u64 = 256;

/// Shard index for `id` among `shards` shards.
#[inline]
pub fn shard_of(id: u64, shards: usize) -> usize {
    debug_assert!(shards >= 1);
    murmur3_bytes(&id.to_le_bytes(), SHARD_ROUTE_SEED) as usize % shards
}

/// Serving options for the sharded front-end: **one** builder-style
/// struct shared by CLI parsing (`--shards/--cache/--absorb/
/// --half-life/--window`), [`FittedModel::stream_scorer_sharded`] and
/// checkpoint resume, so new serving knobs widen this struct instead of
/// every positional signature on the path.
///
/// ```no_run
/// # use sparx::sparx::ServeOptions;
/// let opts = ServeOptions::new().shards(4).cache(1 << 16).absorb(true);
/// ```
///
/// [`FittedModel::stream_scorer_sharded`]: crate::api::FittedModel::stream_scorer_sharded
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeOptions {
    /// Shard worker count — pure parallelism, never affects scores
    /// (≥ 1, ≤ 4096).
    pub shards: usize,
    /// **Total** resident-sketch budget across all shards (the global
    /// LRU directory's capacity).
    pub cache_total: usize,
    /// Record every (sequence, score) pair per shard for later merging —
    /// memory grows with the stream; for harnesses and `--score-log`,
    /// not steady-state production serving.
    pub record: bool,
    /// Absorb every update's point into the ensemble's density counts
    /// after scoring (the xStream online behaviour). Increments become
    /// visible at epoch boundaries (see [`ABSORB_EPOCH`]), so scores
    /// stay bit-identical across shard counts.
    pub absorb: bool,
    /// Logical-clock decay of the absorbed overlays (`--half-life` /
    /// `--window`). Requires `absorb`; boundaries are driven feeder-side
    /// as pure functions of the submit sequence, so decayed scores stay
    /// bit-identical across shard counts and resume cuts.
    pub decay: DecaySpec,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            shards: 1,
            cache_total: 4096,
            record: false,
            absorb: false,
            decay: DecaySpec::default(),
        }
    }
}

impl ServeOptions {
    /// Start from the defaults (1 shard, 4096-sketch cache, no
    /// recording, no absorb, no decay).
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the shard worker count.
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Set the total resident-sketch budget.
    pub fn cache(mut self, cache_total: usize) -> Self {
        self.cache_total = cache_total;
        self
    }

    /// Toggle per-shard score recording.
    pub fn record(mut self, on: bool) -> Self {
        self.record = on;
        self
    }

    /// Toggle absorb mode.
    pub fn absorb(mut self, on: bool) -> Self {
        self.absorb = on;
        self
    }

    /// Set the decay schedule (requires absorb mode when enabled).
    pub fn decay(mut self, decay: DecaySpec) -> Self {
        self.decay = decay;
        self
    }
}

/// A score flowing back to whoever submitted the update or query. The
/// sink is deliberately an *unbounded* sender: shard workers must never
/// block on a slow reply consumer (that would couple one consumer's
/// backpressure to every ID on the shard). Bounding the in-flight window
/// is the submitter's job — the TCP connection layer stops *reading*
/// when its window fills.
pub type ReplySink = Sender<ShardReply>;

/// What a shard sends back through a [`ReplySink`].
#[derive(Debug, Clone, PartialEq)]
pub enum ShardReply {
    /// The scored outcome of a submitted update, in per-ID submit order.
    Update(StreamScore),
    /// Answer to a read-only [`query_score`][ShardedStreamScorer::query_score]:
    /// `None` when the ID is not resident.
    Query { id: u64, score: Option<f64> },
    /// Answer to a named-query probe
    /// ([`score_named`][ShardedStreamScorer::score_named]): the ID scored
    /// against that query's decayed overlay instead of the primary one.
    QueryNamed { id: u64, name: String, score: Option<f64> },
}

/// Typed backpressure: the target shard's queue was full, the update was
/// **not** accepted and its submit sequence was not consumed. Retry
/// later (or block via [`ShardedStreamScorer::submit`]).
#[derive(Debug, Clone, PartialEq)]
pub struct WouldBlock(pub UpdateTriple);

/// One work item inside a shard's batch, in global submit order.
enum ShardItem {
    /// Apply a δ-update and (optionally) reply with the score.
    Update { seq: u64, u: UpdateTriple, reply: Option<ReplySink> },
    /// Drop `id`'s sketch: the feeder's global directory evicted it.
    Evict { id: u64 },
    /// Read-only score probe; replies `None` when not resident.
    Query { id: u64, reply: ReplySink },
    /// Read-only score probe against a caller-supplied overlay (the
    /// feeder ships the named query's combined levels); replies `None`
    /// when not resident.
    QueryWith { id: u64, name: String, levels: Arc<Vec<HashMap<u32, u32>>>, reply: ReplySink },
}

/// What travels over a shard's ingest queue: data batches plus the
/// control messages of the serving lifecycle.
enum ShardMsg {
    /// Sequence-ordered work items.
    Batch(Vec<ShardItem>),
    /// Snapshot the shard's state — entries in LRU→MRU order plus the
    /// *pending* overlay and counters — and send it back (checkpoint or
    /// reshard barrier; lands after everything submitted before it).
    Snapshot(SyncSender<AbsorbSnapshot>),
    /// Drain the pending absorb overlay (epoch barrier).
    CollectPending(SyncSender<Vec<HashMap<u32, u32>>>),
    /// Publish merged epoch increments into the visible overlay. Every
    /// shard receives the same `Arc`, so visible state stays identical
    /// across shards.
    ApplyVisible(Arc<Vec<Vec<(u32, u32)>>>),
    /// Window boundary: rotate the visible overlay into the `prev` block
    /// (broadcast to every shard at the same submit watermark).
    Rotate,
    /// Half-life boundary: floor-halve both overlay blocks (broadcast).
    Halve,
    /// Report live counters (cheap `STATS` probe — no sketch copying).
    Stats(SyncSender<ShardCounters>),
    /// Atomically swap the shared ensemble (hot reload). The feeder
    /// validates compatibility *before* broadcasting, so the per-shard
    /// swap cannot fail.
    Swap(Arc<ServedEnsemble>),
}

/// Per-shard worker state: the shard's own single-threaded scorer plus
/// the counters the merged report is built from.
struct Shard {
    scorer: StreamScorer,
    worst: Option<StreamScore>,
    admitted: u64,
    recorded: Option<Vec<(u64, StreamScore)>>,
    absorb: bool,
}

impl Shard {
    fn counters(&self) -> ShardCounters {
        ShardCounters {
            processed: self.scorer.processed(),
            admitted: self.admitted,
            evictions: self.scorer.evictions(),
            cached_ids: self.scorer.cached_ids(),
            absorbed: self.scorer.absorbed(),
        }
    }
}

/// The per-shard message handler (a named `fn` so every worker clones a
/// zero-sized value).
fn shard_handler(shard: &mut Shard, msg: ShardMsg) {
    match msg {
        ShardMsg::Batch(items) => {
            for item in items {
                match item {
                    ShardItem::Update { seq, u, reply } => {
                        let score = shard.scorer.update(&u);
                        if score.fresh {
                            shard.admitted += 1;
                        }
                        if shard.absorb {
                            shard.scorer.absorb_pending(score.id);
                        }
                        if score.more_outlying_than(shard.worst.as_ref()) {
                            shard.worst = Some(score.clone());
                        }
                        if let Some(log) = &mut shard.recorded {
                            log.push((seq, score.clone()));
                        }
                        if let Some(tx) = reply {
                            // a gone consumer is not the shard's problem
                            let _ = tx.send(ShardReply::Update(score));
                        }
                    }
                    ShardItem::Evict { id } => {
                        shard.scorer.evict(id);
                    }
                    ShardItem::Query { id, reply } => {
                        let _ = reply.send(ShardReply::Query {
                            id,
                            score: shard.scorer.score_id(id),
                        });
                    }
                    ShardItem::QueryWith { id, name, levels, reply } => {
                        let _ = reply.send(ShardReply::QueryNamed {
                            id,
                            name,
                            score: shard.scorer.score_id_with(id, &levels),
                        });
                    }
                }
            }
        }
        ShardMsg::Snapshot(reply) => {
            // a dropped receiver (feeder gone) is not an error
            let _ = reply.send(shard.scorer.snapshot_with_pending());
        }
        ShardMsg::CollectPending(reply) => {
            let _ = reply.send(shard.scorer.take_pending());
        }
        ShardMsg::ApplyVisible(inc) => {
            shard.scorer.apply_visible(&inc);
        }
        ShardMsg::Rotate => {
            shard.scorer.rotate_window();
        }
        ShardMsg::Halve => {
            shard.scorer.decay_halve();
        }
        ShardMsg::Stats(reply) => {
            let _ = reply.send(shard.counters());
        }
        ShardMsg::Swap(ens) => {
            // the feeder validated compatibility against the same shared
            // ensemble every shard holds, so this cannot fail; a panic
            // here would mean shards diverged, and crashing the worker
            // (re-raised at `finish`) beats silently serving from
            // mismatched models
            shard
                .scorer
                .swap_ensemble(ens)
                // lint:allow(no-panic-paths)
                .expect("feeder validates swap compatibility");
        }
    }
}

/// Counters one shard reports (live via `STATS`, final via
/// [`ShardedStreamScorer::finish`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardCounters {
    /// δ-updates this shard processed.
    pub processed: u64,
    /// IDs admitted to this shard's cache (`fresh` scores).
    pub admitted: u64,
    /// Sketches evicted on this shard (by the global directory).
    pub evictions: u64,
    /// Sketches resident in this shard's cache.
    pub cached_ids: usize,
    /// Points absorbed into this shard's overlays.
    pub absorbed: u64,
}

/// The merged post-shutdown report: per-shard counters, the most
/// outlying update seen anywhere, and (in recording mode) every shard's
/// full score sequence tagged with global submit sequence numbers.
///
/// After a live [`reshard`][ShardedStreamScorer::reshard], `shards`
/// reflects the final generation (counter aggregates carry across the
/// transition on shard 0) and `scores` holds the retired generations'
/// logs alongside the final ones — [`merged_scores`][Self::merged_scores]
/// interleaves them all back into submit order.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    pub shards: Vec<ShardCounters>,
    pub worst: Option<StreamScore>,
    /// Per-shard `(submit sequence, score)` logs; empty unless the
    /// scorer was built with [`ServeOptions::record`]. Use
    /// [`merged_scores`](Self::merged_scores) for the global view.
    pub scores: Vec<Vec<(u64, StreamScore)>>,
}

impl ShardedReport {
    /// Total δ-updates processed across shards.
    pub fn processed(&self) -> u64 {
        self.shards.iter().map(|s| s.processed).sum()
    }

    /// Total evictions across shards.
    pub fn evictions(&self) -> u64 {
        self.shards.iter().map(|s| s.evictions).sum()
    }

    /// Total cache admissions across shards.
    pub fn admitted(&self) -> u64 {
        self.shards.iter().map(|s| s.admitted).sum()
    }

    /// Total sketches resident across shards at shutdown.
    pub fn cached_ids(&self) -> usize {
        self.shards.iter().map(|s| s.cached_ids).sum()
    }

    /// Total points absorbed across shards.
    pub fn absorbed(&self) -> u64 {
        self.shards.iter().map(|s| s.absorbed).sum()
    }

    /// The recorded score logs interleaved back into **global submit
    /// order** by sequence number — bit-stable across shard counts,
    /// thread interleavings and live reshards, which is what lets a
    /// resumed or resharded run's log be diffed against an uninterrupted
    /// one. Empty unless recording.
    pub fn merged_scores(&self) -> Vec<StreamScore> {
        let mut tagged: Vec<(u64, &StreamScore)> = self
            .scores
            .iter()
            .flatten()
            .map(|(seq, score)| (*seq, score))
            .collect();
        tagged.sort_unstable_by_key(|(seq, _)| *seq);
        tagged.into_iter().map(|(_, score)| score.clone()).collect()
    }
}

/// One row of `QUERY LIST` / the per-query `STATS` and metrics output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueryInfo {
    pub name: String,
    pub half_life: u64,
    pub window: u64,
    /// Named-score probes served against this query.
    pub scored: u64,
}

/// Per-member provenance row for ensemble models on the serving plane
/// (`STATS` / `METRICS`): the member's canonical spec, its measured
/// calibration-slice cost, the pool worker its full fit was assigned
/// to, distillation lineage, and whether it answers the serve path.
/// Single-method models report an empty member list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemberInfo {
    /// Canonical member spec (e.g. `sparx:depth=6`).
    pub spec: String,
    /// Member method kind (`sparx`, `xstream`, `spif`, `dbscout`).
    pub kind: String,
    /// Calibration-slice fit cost, in µs of worker CPU time.
    pub fit_micros: u64,
    /// Calibration-slice score cost, in µs of worker CPU time.
    pub score_micros: u64,
    /// Pool worker the full fit ran on (cost-balanced assignment).
    pub worker: usize,
    /// For a distilled student: the spec of the expensive teacher member
    /// whose scores it was fit to approximate.
    pub distilled_from: Option<String>,
    /// Whether this member is the one answering the streaming serve path.
    pub serving: bool,
}

/// Live counters for the `STATS` verb: the per-shard counters a running
/// pool reports without stopping, plus the feeder-side aggregates.
#[derive(Debug, Clone)]
pub struct ShardedStats {
    pub shards: Vec<ShardCounters>,
    /// Updates submitted so far (≥ the processed sum while in flight).
    pub submitted: u64,
    /// IDs resident in the global recency directory.
    pub resident_ids: usize,
    /// Bytes of the one Arc-shared ensemble.
    pub resident_ensemble_bytes: usize,
    /// Bytes of the resident sketches (`resident_ids × K × 4`).
    pub resident_sketch_bytes: usize,
    /// Registered named queries, in registration order.
    pub queries: Vec<QueryInfo>,
    /// Ensemble member provenance (empty for single-method models).
    pub members: Vec<MemberInfo>,
}

impl ShardedStats {
    pub fn processed(&self) -> u64 {
        self.shards.iter().map(|s| s.processed).sum()
    }

    pub fn admitted(&self) -> u64 {
        self.shards.iter().map(|s| s.admitted).sum()
    }

    pub fn evictions(&self) -> u64 {
        self.shards.iter().map(|s| s.evictions).sum()
    }

    pub fn absorbed(&self) -> u64 {
        self.shards.iter().map(|s| s.absorbed).sum()
    }

    /// Total resident bytes surfaced to `STATS`/metrics consumers.
    pub fn resident_bytes(&self) -> usize {
        self.resident_ensemble_bytes + self.resident_sketch_bytes
    }
}

/// Sort each level's increment map into the canonical `(bucket, count)`
/// pair order the checkpoint codec and [`StreamScorer::apply_visible`]
/// expect.
fn sorted_levels(maps: &[HashMap<u32, u32>]) -> Vec<Vec<(u32, u32)>> {
    maps.iter()
        .map(|map| {
            let mut lvl: Vec<(u32, u32)> = map.iter().map(|(&b, &c)| (b, c)).collect();
            lvl.sort_unstable();
            lvl
        })
        .collect()
}

/// Sum sorted increment levels into per-level maps (saturating — the
/// same arithmetic the scorer's visible overlay uses).
fn add_levels(into: &mut [HashMap<u32, u32>], levels: &[Vec<(u32, u32)>]) {
    for (map, lvl) in into.iter_mut().zip(levels) {
        for &(bucket, count) in lvl {
            let slot = map.entry(bucket).or_insert(0);
            *slot = slot.saturating_add(count);
        }
    }
}

/// The multi-threaded §3.5 front-end. Build from a fitted model via
/// [`ShardedStreamScorer::new`] (or `FittedModel::stream_scorer_sharded`
/// through the api), or share an already-frozen ensemble with
/// [`ShardedStreamScorer::from_ensemble`]; [`submit`](Self::submit) the
/// update stream, then [`finish`](Self::finish) for the merged report.
pub struct ShardedStreamScorer {
    pool: PinnedPool<ShardMsg, Shard>,
    pending: Vec<Vec<ShardItem>>,
    shards: usize,
    cache_total: usize,
    /// Global recency directory: ID → last-touch submit sequence, LRU
    /// order == submit order of last touches. Its capacity *is* the
    /// serving cache budget; overflow here drives explicit shard evicts.
    dir: LruCache<u64, u64>,
    /// Feeder master copy of the visible absorb overlay (identical on
    /// every shard) — what a checkpoint persists.
    visible: Vec<HashMap<u32, u32>>,
    /// Feeder master copy of the previous window block (identical on
    /// every shard; all-empty while `decay.window == 0`).
    visible_prev: Vec<HashMap<u32, u32>>,
    /// Named `(half_life, window)` queries, feeder-side only: they read
    /// published increments and never touch the shards' own overlays.
    queries: Vec<QueryState>,
    submitted: u64,
    opts: ServeOptions,
    ensemble: Arc<ServedEnsemble>,
    /// Per-member provenance of the model being served (empty unless the
    /// artifact was an ensemble; see [`MemberInfo`]).
    member_info: Vec<MemberInfo>,
    /// Recorded score logs of generations retired by a live reshard.
    archive: Vec<Vec<(u64, StreamScore)>>,
    /// Worst score across retired generations.
    carried_worst: Option<StreamScore>,
}

impl ShardedStreamScorer {
    /// `shards` workers sharing one read-only ensemble and one **total**
    /// budget of `cache_total` resident sketches (resident model: 1×,
    /// Arc-shared). Same model requirements as [`StreamScorer::new`].
    pub fn new(model: &SparxModel, shards: usize, cache_total: usize) -> Result<Self> {
        Self::from_ensemble(
            Arc::new(ServedEnsemble::new(model)?),
            ServeOptions::new().shards(shards).cache(cache_total),
            None,
        )
    }

    /// The full-control constructor: share `ensemble` across
    /// `opts.shards` workers under one `opts.cache_total` budget,
    /// optionally recording and/or absorbing ([`ServeOptions`]),
    /// optionally restoring a checkpoint so the stream continues exactly
    /// where a previous process left off.
    ///
    /// Resume is validated typed before any worker spawns, and — from
    /// checkpoint format v4 — is **layout-free**: `opts.shards` and
    /// `opts.cache_total` may differ from the capture-time values. The
    /// checkpoint's global LRU→MRU entry order rebuilds the recency
    /// directory; a smaller budget evicts from the LRU side on the spot.
    pub fn from_ensemble(
        ensemble: Arc<ServedEnsemble>,
        opts: ServeOptions,
        resume: Option<&AbsorbCheckpoint>,
    ) -> Result<Self> {
        let ServeOptions { shards, cache_total, .. } = opts;
        if shards == 0 {
            return Err(SparxError::InvalidParams("shard count must be ≥ 1".into()));
        }
        if shards > 4096 {
            return Err(SparxError::InvalidParams(format!(
                "shard count {shards} exceeds the 4096-thread cap"
            )));
        }
        if cache_total == 0 {
            return Err(SparxError::InvalidParams(
                "serving cache budget must be ≥ 1 (it bounds the resident sketches)".into(),
            ));
        }
        if opts.decay.enabled() && !opts.absorb {
            return Err(SparxError::InvalidParams(
                "half-life/window decay applies to absorbed counts — it requires absorb mode"
                    .into(),
            ));
        }
        let levels = ensemble.num_chains() * ensemble.depth();
        let mut dir = LruCache::new(cache_total);
        let mut visible: Vec<HashMap<u32, u32>> = vec![HashMap::new(); levels];
        let mut visible_prev: Vec<HashMap<u32, u32>> = vec![HashMap::new(); levels];
        let mut queries: Vec<QueryState> = Vec::new();
        let states;
        let submitted;
        if let Some(ckpt) = resume {
            ckpt.validate_for(&ensemble, opts.absorb, opts.decay)?;
            // a smaller budget than capture time sheds the least-recent
            // entries right here, exactly as live admissions would
            let shed = ckpt.entries.len().saturating_sub(cache_total);
            let kept = ckpt.entries.get(shed..).unwrap_or_default();
            for (id, seq, _) in kept {
                dir.put(*id, *seq);
            }
            add_levels(&mut visible, &ckpt.visible);
            add_levels(&mut visible_prev, &ckpt.prev_visible);
            for record in &ckpt.queries {
                let mut q = QueryState::new(
                    record.name.clone(),
                    DecaySpec::new(record.half_life, record.window),
                    levels,
                );
                add_levels(&mut q.cur, &record.cur);
                add_levels(&mut q.prev, &record.prev);
                q.scored = record.scored;
                queries.push(q);
            }
            states = restored_states(
                &ensemble,
                shards,
                cache_total,
                &opts,
                kept,
                &ckpt.visible,
                &ckpt.prev_visible,
                &ckpt.pending,
                ckpt.processed,
                ckpt.evicted + shed as u64,
                ckpt.absorbed,
            )?;
            submitted = ckpt.submitted;
        } else {
            let mut fresh = Vec::with_capacity(shards);
            for _ in 0..shards {
                fresh.push(Shard {
                    scorer: StreamScorer::from_ensemble(ensemble.clone(), cache_total)?,
                    worst: None,
                    admitted: 0,
                    recorded: opts.record.then(Vec::new),
                    absorb: opts.absorb,
                });
            }
            states = fresh;
            submitted = 0;
        }
        let pool = PinnedPool::spawn(states, QUEUE_CAP_BATCHES, shard_handler);
        Ok(ShardedStreamScorer {
            pool,
            pending: (0..shards).map(|_| Vec::with_capacity(BATCH)).collect(),
            shards,
            cache_total,
            dir,
            visible,
            visible_prev,
            queries,
            submitted,
            opts,
            ensemble,
            member_info: Vec::new(),
            archive: Vec::new(),
            carried_worst: None,
        })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Attach per-member provenance (set by `serve` when the loaded
    /// artifact is an ensemble) so `STATS` / `METRICS` can report it.
    pub fn set_member_info(&mut self, members: Vec<MemberInfo>) {
        self.member_info = members;
    }

    /// Per-member provenance of the served model (empty for
    /// single-method models).
    pub fn member_info(&self) -> &[MemberInfo] {
        &self.member_info
    }

    /// The pool-wide resident-sketch budget.
    pub fn cache_total(&self) -> usize {
        self.cache_total
    }

    /// Updates submitted so far — across process restarts when resumed
    /// from a checkpoint (some may still be in flight; the per-shard
    /// `processed` counters are exact only after `finish`).
    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// The shared read-only ensemble all shards score against.
    pub fn ensemble(&self) -> &Arc<ServedEnsemble> {
        &self.ensemble
    }

    /// Bytes of the **one** resident ensemble all shards share — this
    /// does not scale with the shard count.
    pub fn resident_ensemble_bytes(&self) -> usize {
        self.ensemble.resident_bytes()
    }

    /// See [`ServedEnsemble::feature_names`].
    pub fn feature_names(&self) -> Option<&[String]> {
        self.ensemble.feature_names()
    }

    /// Route one update to its shard, tagged with its global submit
    /// sequence number. Blocks only when that shard's bounded ingest
    /// queue is full (backpressure, never loss — unless a shard worker
    /// has panicked, in which case its updates are discarded and
    /// [`finish`](Self::finish) re-raises the panic).
    pub fn submit(&mut self, u: UpdateTriple) {
        self.submit_with_reply(u, None);
    }

    /// [`submit`](Self::submit), with the score sent back through
    /// `reply` once the owning shard processes the update. Replies for
    /// one ID arrive in submit order (one shard, FIFO queue); replies
    /// across IDs on different shards may interleave.
    pub fn submit_with_reply(&mut self, u: UpdateTriple, reply: Option<ReplySink>) {
        let seq = self.submitted;
        self.submitted += 1;
        self.route(seq, u, reply, true);
        self.maybe_merge_epoch();
    }

    /// Non-blocking submit: accepts the update exactly like
    /// [`submit_with_reply`](Self::submit_with_reply) unless the target
    /// shard's queue is full *and* its batch buffer is at capacity, in
    /// which case the update is handed back as [`WouldBlock`] — nothing
    /// was enqueued and the submit sequence was not consumed, so a later
    /// retry lands at exactly the stream position it is retried at.
    pub fn try_submit(
        &mut self,
        u: UpdateTriple,
        reply: Option<ReplySink>,
    ) -> std::result::Result<(), WouldBlock> {
        let s = shard_of(u.id(), self.shards);
        // make room up front so acceptance is all-or-nothing
        if self.pending.get(s).is_some_and(|b| b.len() >= BATCH) && !self.try_flush_shard(s) {
            return Err(WouldBlock(u));
        }
        let seq = self.submitted;
        self.submitted += 1;
        self.route(seq, u, reply, false);
        self.maybe_merge_epoch();
        Ok(())
    }

    /// Read-only score probe for `id`, answered through `reply` as
    /// [`ShardReply::Query`] after everything submitted before this
    /// call. Does not touch the global recency directory — probing is
    /// not an update, so it cannot perturb eviction determinism.
    pub fn query_score(&mut self, id: u64, reply: ReplySink) {
        let s = shard_of(id, self.shards);
        self.push_item(s, ShardItem::Query { id, reply }, true);
        // probes answer promptly even on an idle stream
        self.flush_shard(s);
    }

    /// Register a named `(half_life, window)` view over the shared
    /// ingest stream (`QUERY ADD`). The query starts empty and
    /// accumulates epoch increments published *after* registration,
    /// rotating/halving on its own schedule — without perturbing the
    /// primary score sequence. `(0, 0)` is valid: an undecayed
    /// cumulative view for A/B comparison against decayed ones.
    pub fn query_add(&mut self, name: &str, half_life: u64, window: u64) -> Result<()> {
        if !self.opts.absorb {
            return Err(SparxError::InvalidParams(
                "named queries read absorbed increments — they require absorb mode".into(),
            ));
        }
        validate_query_name(name)?;
        if self.queries.iter().any(|q| q.name == name) {
            return Err(SparxError::InvalidParams(format!(
                "query {name:?} is already registered (DROP it first to change its schedule)"
            )));
        }
        if self.queries.len() >= MAX_QUERIES {
            return Err(SparxError::InvalidParams(format!(
                "query cap reached ({MAX_QUERIES} registered)"
            )));
        }
        let levels = self.ensemble.num_chains() * self.ensemble.depth();
        self.queries.push(QueryState::new(
            name.to_string(),
            DecaySpec::new(half_life, window),
            levels,
        ));
        Ok(())
    }

    /// Drop a named query (`QUERY DROP`); typed error when unknown.
    pub fn query_drop(&mut self, name: &str) -> Result<()> {
        let Some(at) = self.queries.iter().position(|q| q.name == name) else {
            return Err(SparxError::InvalidParams(format!("no query named {name:?}")));
        };
        self.queries.remove(at);
        Ok(())
    }

    /// Registered queries in registration order (`QUERY LIST`).
    pub fn query_list(&self) -> Vec<QueryInfo> {
        self.queries
            .iter()
            .map(|q| QueryInfo {
                name: q.name.clone(),
                half_life: q.spec.half_life,
                window: q.spec.window,
                scored: q.scored,
            })
            .collect()
    }

    /// Score `id` against the named query's decayed overlay instead of
    /// the primary one (`SCORE <id> <name>`), answered through `reply`
    /// as [`ShardReply::QueryNamed`]. The feeder ships the query's
    /// combined `cur + prev` levels to the owning shard; like
    /// [`query_score`](Self::query_score) this is read-only and cannot
    /// perturb eviction or absorb determinism. Typed error when no such
    /// query is registered.
    pub fn score_named(&mut self, id: u64, name: &str, reply: ReplySink) -> Result<()> {
        let Some(q) = self.queries.iter_mut().find(|q| q.name == name) else {
            return Err(SparxError::InvalidParams(format!("no query named {name:?}")));
        };
        q.scored += 1;
        let levels = Arc::new(q.combined_levels());
        let s = shard_of(id, self.shards);
        self.push_item(s, ShardItem::QueryWith { id, name: name.to_string(), levels, reply }, true);
        self.flush_shard(s);
        Ok(())
    }

    /// Push everything buffered feeder-side into the shard queues
    /// (blocking on full queues). Reply-carrying updates submitted
    /// before a `flush` are guaranteed to reach their shards.
    pub fn flush(&mut self) {
        for s in 0..self.shards {
            self.flush_shard(s);
        }
    }

    // ------------------------------------------------------- internals

    /// Global eviction decision + routed enqueue for one update.
    fn route(&mut self, seq: u64, u: UpdateTriple, reply: Option<ReplySink>, blocking: bool) {
        let id = u.id();
        let s = shard_of(id, self.shards);
        if let Some((victim, _)) = self.dir.put(id, seq) {
            let vs = shard_of(victim, self.shards);
            // the evict must precede the admitting update on its own
            // shard; cross-shard order is irrelevant (disjoint IDs)
            self.push_item(vs, ShardItem::Evict { id: victim }, blocking);
        }
        self.push_item(s, ShardItem::Update { seq, u, reply }, blocking);
    }

    fn push_item(&mut self, s: usize, item: ShardItem, blocking: bool) {
        let full = match self.pending.get_mut(s) {
            Some(buf) => {
                buf.push(item);
                buf.len() >= BATCH
            }
            None => false,
        };
        if full {
            if blocking {
                self.flush_shard(s);
            } else {
                // opportunistic: a full queue leaves the batch buffered
                // (accepted, flushed on the next opportunity) — only
                // `try_submit`'s own pre-check turns fullness into a
                // typed rejection
                let _ = self.try_flush_shard(s);
            }
        }
    }

    fn flush_shard(&mut self, s: usize) {
        let batch = match self.pending.get_mut(s) {
            Some(buf) if !buf.is_empty() => std::mem::take(buf),
            _ => return,
        };
        self.pool.send(s, ShardMsg::Batch(batch));
    }

    /// Returns whether the shard's buffer is now empty (true also when
    /// there was nothing to flush).
    fn try_flush_shard(&mut self, s: usize) -> bool {
        let batch = match self.pending.get_mut(s) {
            Some(buf) if !buf.is_empty() => std::mem::take(buf),
            _ => return true,
        };
        match self.pool.try_send(s, ShardMsg::Batch(batch)) {
            Ok(()) => true,
            Err(ShardMsg::Batch(batch)) => {
                // put it back untouched; the feeder is single-threaded,
                // so nothing pushed in between
                if let Some(buf) = self.pending.get_mut(s) {
                    *buf = batch;
                }
                false
            }
            Err(_) => true,
        }
    }

    /// Epoch and decay boundaries, driven off the global submit counter
    /// right after it advances. A decay boundary forces an epoch publish
    /// *first* — absorbed-but-unpublished increments belong to the
    /// period that just closed, so they must land in `visible` before it
    /// rotates or halves. The order at a combined boundary is therefore
    /// fixed: publish → rotate → halve, feeder masters and shard
    /// broadcasts in lockstep. Named-query boundaries run last and never
    /// force a publish of their own (they only re-slice increments
    /// already published), so registering or dropping a query cannot
    /// move the primary score sequence by a bit.
    fn maybe_merge_epoch(&mut self) {
        if !self.opts.absorb {
            return;
        }
        let submitted = self.submitted;
        let rotate = self.opts.decay.rotate_due(submitted);
        let halve = self.opts.decay.halve_due(submitted);
        if submitted % ABSORB_EPOCH == 0 || rotate || halve {
            self.merge_epoch();
        }
        if rotate {
            self.visible_prev = std::mem::replace(
                &mut self.visible,
                vec![HashMap::new(); self.visible_prev.len()],
            );
            for s in 0..self.shards {
                self.pool.send(s, ShardMsg::Rotate);
            }
        }
        if halve {
            for lvl in self.visible.iter_mut().chain(self.visible_prev.iter_mut()) {
                super::cms::decay_halve_overlay(lvl);
            }
            for s in 0..self.shards {
                self.pool.send(s, ShardMsg::Halve);
            }
        }
        for q in &mut self.queries {
            q.at_boundary(submitted);
        }
    }

    /// Epoch publish: drain every shard's pending overlay (a barrier —
    /// lands after everything submitted this epoch), sum the increments
    /// (saturating adds commute, so the merge is order-independent →
    /// deterministic), then broadcast the merged batch so every shard's
    /// *visible* overlay stays bit-identical. The feeder's master copy
    /// advances in lockstep — it is what checkpoints persist.
    fn merge_epoch(&mut self) {
        self.flush();
        let mut replies = Vec::with_capacity(self.shards);
        for s in 0..self.shards {
            let (tx, rx) = sync_channel(1);
            self.pool.send(s, ShardMsg::CollectPending(tx));
            replies.push(rx);
        }
        let levels = self.ensemble.num_chains() * self.ensemble.depth();
        let mut merged: Vec<HashMap<u32, u32>> = vec![HashMap::new(); levels];
        let mut any = false;
        for rx in replies {
            // a dead worker's pending increments are gone; its panic
            // resurfaces at finish/join
            let Ok(maps) = rx.recv() else { continue };
            for (slot, map) in maps.into_iter().enumerate() {
                if map.is_empty() {
                    continue;
                }
                any = true;
                if let Some(m) = merged.get_mut(slot) {
                    for (bucket, count) in map {
                        let c = m.entry(bucket).or_insert(0);
                        *c = c.saturating_add(count);
                    }
                }
            }
        }
        if !any {
            return;
        }
        let inc = sorted_levels(&merged);
        add_levels(&mut self.visible, &inc);
        for q in &mut self.queries {
            q.on_publish(&inc);
        }
        let inc = Arc::new(inc);
        for s in 0..self.shards {
            self.pool.send(s, ShardMsg::ApplyVisible(inc.clone()));
        }
    }

    /// Flush + snapshot barrier: every shard's entries, pending overlay
    /// and counters, consistent at the current submit watermark.
    fn collect_snapshots(&mut self) -> Result<Vec<AbsorbSnapshot>> {
        self.flush();
        let mut replies = Vec::with_capacity(self.shards);
        for s in 0..self.shards {
            let (tx, rx) = sync_channel(1);
            self.pool.send(s, ShardMsg::Snapshot(tx));
            replies.push(rx);
        }
        let mut snaps = Vec::with_capacity(self.shards);
        for (s, rx) in replies.into_iter().enumerate() {
            let snap = rx.recv().map_err(|_| {
                SparxError::Io(format!("shard {s} worker died before answering the snapshot"))
            })?;
            snaps.push(snap);
        }
        Ok(snaps)
    }

    /// Assemble the global view from per-shard snapshots: entries in the
    /// directory's LRU→MRU order (each ID's sketch joined from its
    /// owning shard), pendings merged, counters summed.
    #[allow(clippy::type_complexity)]
    fn assemble_global(
        &self,
        snaps: Vec<AbsorbSnapshot>,
    ) -> Result<(Vec<(u64, u64, Vec<f32>)>, Vec<Vec<(u32, u32)>>, u64, u64, u64)> {
        let levels = self.ensemble.num_chains() * self.ensemble.depth();
        let mut processed = 0u64;
        let mut evicted = 0u64;
        let mut absorbed = 0u64;
        let mut pending: Vec<HashMap<u32, u32>> = vec![HashMap::new(); levels];
        let mut sketches: HashMap<u64, Vec<f32>> = HashMap::new();
        for snap in snaps {
            processed += snap.processed;
            evicted += snap.evicted;
            absorbed += snap.absorbed;
            add_levels(&mut pending, &snap.delta);
            for (id, sketch) in snap.entries {
                sketches.insert(id, sketch);
            }
        }
        let mut entries = Vec::with_capacity(self.dir.len());
        for (id, seq) in self.dir.iter_lru_to_mru() {
            let sketch = sketches.remove(id).ok_or_else(|| {
                SparxError::Io(format!(
                    "shard snapshots are missing the sketch for resident id {id} — \
                     the pool's directory and shard caches diverged"
                ))
            })?;
            entries.push((*id, *seq, sketch));
        }
        Ok((entries, sorted_levels(&pending), processed, evicted, absorbed))
    }

    /// Cut a consistent, **layout-independent** checkpoint: flush, ask
    /// every shard to snapshot (the message lands *after* every update
    /// submitted before this call), and assemble the global v4 state —
    /// entries in global recency order, the visible overlay, the merged
    /// pending overlay. The stream can keep flowing afterwards — nothing
    /// is torn down, and a mid-epoch cut does **not** publish pending
    /// increments early.
    ///
    /// A shard worker that died (panicked) before answering surfaces as
    /// a typed error; [`finish`](Self::finish) re-raises the panic.
    pub fn checkpoint(&mut self) -> Result<AbsorbCheckpoint> {
        let snaps = self.collect_snapshots()?;
        let (entries, pending, processed, evicted, absorbed) = self.assemble_global(snaps)?;
        let mut ckpt = AbsorbCheckpoint::for_ensemble(
            &self.ensemble,
            self.shards as u32,
            self.cache_total as u64,
            self.submitted,
            self.opts.absorb,
            self.opts.decay,
        );
        ckpt.processed = processed;
        ckpt.evicted = evicted;
        ckpt.absorbed = absorbed;
        ckpt.entries = entries;
        ckpt.visible = sorted_levels(&self.visible);
        ckpt.prev_visible = sorted_levels(&self.visible_prev);
        ckpt.pending = pending;
        ckpt.queries = self
            .queries
            .iter()
            .map(|q| QueryRecord {
                name: q.name.clone(),
                half_life: q.spec.half_life,
                window: q.spec.window,
                scored: q.scored,
                cur: sorted_levels(&q.cur),
                prev: sorted_levels(&q.prev),
            })
            .collect();
        Ok(ckpt)
    }

    /// **Live re-shard**: drain to a barrier, snapshot every shard,
    /// re-partition the global state across `new_shards` workers and
    /// respawn — without dropping a single queued update (everything
    /// buffered is flushed into the old generation first, and the
    /// barrier waits for it to be processed).
    ///
    /// The global invariants (recency directory, visible overlay, epoch
    /// schedule) live feeder-side and are untouched, so per-ID score
    /// sequences continue bit-identically across the transition.
    /// Recorded score logs of the retired generation are archived and
    /// resurface in the final [`ShardedReport`].
    ///
    /// On error (a dead worker, a snapshot/restore mismatch) the old
    /// generation keeps serving — the pool is only swapped once the new
    /// one is fully built.
    pub fn reshard(&mut self, new_shards: usize) -> Result<()> {
        if new_shards == 0 {
            return Err(SparxError::InvalidParams("shard count must be ≥ 1".into()));
        }
        if new_shards > 4096 {
            return Err(SparxError::InvalidParams(format!(
                "shard count {new_shards} exceeds the 4096-thread cap"
            )));
        }
        if new_shards == self.shards {
            return Ok(());
        }
        let snaps = self.collect_snapshots()?;
        let (entries, pending, processed, evicted, absorbed) = self.assemble_global(snaps)?;
        let visible = sorted_levels(&self.visible);
        let prev = sorted_levels(&self.visible_prev);
        let states = restored_states(
            &self.ensemble,
            new_shards,
            self.cache_total,
            &self.opts,
            &entries,
            &visible,
            &prev,
            &pending,
            processed,
            evicted,
            absorbed,
        )?;
        let new_pool = PinnedPool::spawn(states, QUEUE_CAP_BATCHES, shard_handler);
        let old_pool = std::mem::replace(&mut self.pool, new_pool);
        self.pending = (0..new_shards).map(|_| Vec::with_capacity(BATCH)).collect();
        self.shards = new_shards;
        // retire the old generation: join (queues already drained to the
        // barrier), archive its logs, carry its worst forward
        for sh in old_pool.join() {
            if let Some(w) = sh.worst {
                if w.more_outlying_than(self.carried_worst.as_ref()) {
                    self.carried_worst = Some(w);
                }
            }
            if let Some(log) = sh.recorded {
                if !log.is_empty() {
                    self.archive.push(log);
                }
            }
        }
        Ok(())
    }

    /// Live counter probe (the `STATS` verb): flush, then collect every
    /// shard's counters through a lightweight barrier — no sketch or
    /// overlay copying. A dead worker surfaces typed.
    pub fn stats(&mut self) -> Result<ShardedStats> {
        self.flush();
        let mut replies = Vec::with_capacity(self.shards);
        for s in 0..self.shards {
            let (tx, rx) = sync_channel(1);
            self.pool.send(s, ShardMsg::Stats(tx));
            replies.push(rx);
        }
        let mut shards = Vec::with_capacity(self.shards);
        for (s, rx) in replies.into_iter().enumerate() {
            let counters = rx.recv().map_err(|_| {
                SparxError::Io(format!("shard {s} worker died before answering the stats probe"))
            })?;
            shards.push(counters);
        }
        Ok(ShardedStats {
            shards,
            submitted: self.submitted,
            resident_ids: self.dir.len(),
            resident_ensemble_bytes: self.ensemble.resident_bytes(),
            resident_sketch_bytes: self.dir.len() * self.ensemble.k() * std::mem::size_of::<f32>(),
            queries: self.query_list(),
            members: self.member_info.clone(),
        })
    }

    /// Hot model reload: validate the swap once at the feeder (typed
    /// rejection when the serving schemas differ — no shard is touched),
    /// flush, then broadcast the new `Arc` so every shard swaps at the
    /// same deterministic point of its sub-stream, carrying its absorb
    /// state forward per [`ServedEnsemble::swap_carry`].
    pub fn swap_ensemble(&mut self, new: Arc<ServedEnsemble>) -> Result<SwapCarry> {
        let carry = self.ensemble.swap_carry(&new)?;
        self.flush();
        if carry == SwapCarry::SketchesOnly {
            // shard scorers reset their overlays on a schema-only swap;
            // the feeder's master copies (and the named queries, which
            // accumulate in the same bucket space) reset in lockstep
            for lvl in self.visible.iter_mut().chain(self.visible_prev.iter_mut()) {
                lvl.clear();
            }
            for q in &mut self.queries {
                for lvl in q.cur.iter_mut().chain(q.prev.iter_mut()) {
                    lvl.clear();
                }
            }
        }
        for s in 0..self.shards {
            self.pool.send(s, ShardMsg::Swap(new.clone()));
        }
        self.ensemble = new;
        Ok(carry)
    }

    /// Flush the pending batches, close the queues, join the workers
    /// and merge the per-shard counters (plus anything archived by live
    /// reshards).
    pub fn finish(mut self) -> ShardedReport {
        self.flush();
        let ShardedStreamScorer { pool, archive, carried_worst, .. } = self;
        let states = pool.join();
        let mut report = ShardedReport {
            shards: Vec::with_capacity(states.len()),
            worst: carried_worst,
            scores: archive,
        };
        for sh in states {
            report.shards.push(sh.counters());
            if let Some(w) = sh.worst {
                if w.more_outlying_than(report.worst.as_ref()) {
                    report.worst = Some(w);
                }
            }
            report.scores.push(sh.recorded.unwrap_or_default());
        }
        report
    }
}

/// Build `shards` worker states restored from global state: entries are
/// partitioned by `shard_of(id, shards)` preserving global LRU→MRU
/// order, every shard receives the identical visible overlay (and the
/// identical previous window block when decay has rotated one), shard 0
/// carries the aggregate counters and the merged pending overlay (so
/// pool-wide sums — and the next epoch merge — come out exact).
#[allow(clippy::too_many_arguments)]
fn restored_states(
    ensemble: &Arc<ServedEnsemble>,
    shards: usize,
    cache_total: usize,
    opts: &ServeOptions,
    entries: &[(u64, u64, Vec<f32>)],
    visible: &[Vec<(u32, u32)>],
    prev: &[Vec<(u32, u32)>],
    pending: &[Vec<(u32, u32)>],
    processed: u64,
    evicted: u64,
    absorbed: u64,
) -> Result<Vec<Shard>> {
    let mut states = Vec::with_capacity(shards);
    for s in 0..shards {
        let mut scorer = StreamScorer::from_ensemble(ensemble.clone(), cache_total)?;
        let first = s == 0;
        let snap = AbsorbSnapshot {
            processed: if first { processed } else { 0 },
            evicted: if first { evicted } else { 0 },
            absorbed: if first { absorbed } else { 0 },
            entries: entries
                .iter()
                .filter(|(id, _, _)| shard_of(*id, shards) == s)
                .map(|(id, _, sketch)| (*id, sketch.clone()))
                .collect(),
            delta: visible.to_vec(),
        };
        scorer.restore(&snap)?;
        // v4 checkpoints carry no prev block (empty vec) — leave the
        // scorer's freshly-reset one alone
        if !prev.is_empty() {
            scorer.restore_prev(prev)?;
        }
        if first {
            scorer.restore_pending(pending)?;
        }
        states.push(Shard {
            scorer,
            worst: None,
            // aggregate bookkeeping rides on shard 0 (admitted − evicted
            // == resident holds pool-wide, not per shard)
            admitted: if first { evicted + entries.len() as u64 } else { 0 },
            recorded: opts.record.then(Vec::new),
            absorb: opts.absorb,
        });
    }
    Ok(states)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::data::generators::GisetteGen;
    use crate::sparx::SparxParams;

    fn fitted() -> SparxModel {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 300, d: 16, ..Default::default() }.generate(&ctx).unwrap();
        SparxModel::fit(
            &ctx,
            &ld.dataset,
            &SparxParams { k: 8, num_chains: 6, depth: 5, ..Default::default() },
        )
        .unwrap()
    }

    /// Churny update stream: ids recycle (mod `ids`) so a small cache
    /// budget evicts constantly; features and deltas vary per step.
    fn churn(n: usize, ids: u64) -> Vec<UpdateTriple> {
        (0..n)
            .map(|i| UpdateTriple::Num {
                id: (i as u64).wrapping_mul(7).wrapping_add(3) % ids,
                feature: format!("f{}", i % 16),
                delta: ((i % 13) as f64 - 6.0) * 0.25,
            })
            .collect()
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        for shards in [1usize, 2, 3, 8] {
            for id in 0..500u64 {
                let s = shard_of(id, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(id, shards));
            }
        }
    }

    #[test]
    fn submit_finish_counts_every_update() {
        let model = fitted();
        let mut scorer = ShardedStreamScorer::new(&model, 3, 256).unwrap();
        for id in 0..200u64 {
            scorer.submit(UpdateTriple::Num { id, feature: "f0".into(), delta: 1.0 });
        }
        assert_eq!(scorer.submitted(), 200);
        let report = scorer.finish();
        assert_eq!(report.processed(), 200);
        assert_eq!(report.admitted(), 200, "every id is distinct → every update admits");
        assert_eq!(report.shards.len(), 3);
    }

    #[test]
    fn zero_shards_and_zero_cache_are_typed_errors() {
        let model = fitted();
        assert!(matches!(
            ShardedStreamScorer::new(&model, 0, 32),
            Err(SparxError::InvalidParams(_))
        ));
        assert!(matches!(
            ShardedStreamScorer::new(&model, 2, 0),
            Err(SparxError::InvalidParams(_))
        ));
    }

    #[test]
    fn drop_without_finish_shuts_down() {
        let model = fitted();
        let mut scorer = ShardedStreamScorer::new(&model, 2, 8).unwrap();
        scorer.submit(UpdateTriple::Num { id: 1, feature: "f0".into(), delta: 1.0 });
        drop(scorer); // error-path shutdown: close queues, join workers
    }

    #[test]
    fn recording_mode_captures_per_shard_logs_with_submit_seqs() {
        let model = fitted();
        let mut scorer = ShardedStreamScorer::from_ensemble(
            Arc::new(ServedEnsemble::new(&model).unwrap()),
            ServeOptions::new().shards(2).cache(32).record(true),
            None,
        )
        .unwrap();
        for id in 0..10u64 {
            scorer.submit(UpdateTriple::Num { id, feature: "f0".into(), delta: 0.5 });
        }
        let report = scorer.finish();
        let logged: usize = report.scores.iter().map(Vec::len).sum();
        assert_eq!(logged, 10);
        // no reshard happened → no archived generations; logs line up
        // with final shard indices
        for (s, log) in report.scores.iter().enumerate() {
            for (seq, rec) in log {
                assert_eq!(shard_of(rec.id, 2), s, "score recorded on the wrong shard");
                assert!(*seq < 10, "sequence numbers come from the submit counter");
            }
        }
        let merged = report.merged_scores();
        assert_eq!(merged.len(), 10);
        let ids: Vec<u64> = merged.iter().map(|s| s.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>(), "merge must restore submit order");
    }

    /// The tentpole invariant, absorb off: under a shared global cache
    /// budget the sharded pool — at ANY shard count — produces the
    /// bit-identical score sequence of a single-threaded [`StreamScorer`]
    /// with the same budget, under heavy eviction churn.
    #[test]
    fn global_eviction_reproduces_single_threaded_scores() {
        let model = fitted();
        let updates = churn(600, 48);
        let cache = 16usize;
        // reference: plain single-threaded scorer
        let mut reference = StreamScorer::new(&model, cache).unwrap();
        let expected: Vec<StreamScore> = updates.iter().map(|u| reference.update(u)).collect();
        assert!(reference.evictions() > 0, "harness must actually churn");
        for shards in [1usize, 2, 5] {
            let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
            let mut pool = ShardedStreamScorer::from_ensemble(
                ens,
                ServeOptions::new().shards(shards).cache(cache).record(true),
                None,
            )
            .unwrap();
            for u in &updates {
                pool.submit(u.clone());
            }
            let report = pool.finish();
            assert_eq!(report.evictions(), reference.evictions(), "S={shards}");
            assert_eq!(
                report.merged_scores(),
                expected,
                "S={shards} must mirror the single-threaded stream bit-for-bit"
            );
        }
    }

    /// Absorb mode: epoch-published increments make every shard count's
    /// merged score log bit-identical to the S=1 pool (the absorb-mode
    /// reference), still under eviction churn.
    #[test]
    fn absorb_epochs_are_shard_count_invariant() {
        let model = fitted();
        let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
        let updates = churn(700, 40);
        let run = |shards: usize| {
            let mut pool = ShardedStreamScorer::from_ensemble(
                ens.clone(),
                ServeOptions::new().shards(shards).cache(24).record(true).absorb(true),
                None,
            )
            .unwrap();
            for u in &updates {
                pool.submit(u.clone());
            }
            pool.finish()
        };
        let reference = run(1);
        assert_eq!(reference.absorbed(), 700);
        assert!(reference.evictions() > 0);
        let expected = reference.merged_scores();
        for shards in [2usize, 4] {
            let report = run(shards);
            assert_eq!(report.absorbed(), 700);
            assert_eq!(report.merged_scores(), expected, "S={shards}");
        }
    }

    /// try_submit: rejected updates are handed back unconsumed and a
    /// retry loop loses nothing.
    #[test]
    fn try_submit_never_loses_updates() {
        let model = fitted();
        let mut scorer = ShardedStreamScorer::new(&model, 2, 64).unwrap();
        let mut rejected = 0u64;
        for u in churn(2_000, 64) {
            let mut item = u;
            loop {
                match scorer.try_submit(item, None) {
                    Ok(()) => break,
                    Err(WouldBlock(back)) => {
                        rejected += 1;
                        item = back;
                        std::thread::yield_now();
                    }
                }
            }
        }
        assert_eq!(scorer.submitted(), 2_000);
        let report = scorer.finish();
        assert_eq!(report.processed(), 2_000, "{rejected} rejections must not lose updates");
    }

    /// Replies: per-ID scores arrive through the sink in submit order
    /// and match what a read-only query then reports.
    #[test]
    fn reply_sinks_and_queries_agree() {
        let model = fitted();
        let mut scorer = ShardedStreamScorer::new(&model, 3, 32).unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        for step in 0..4 {
            scorer.submit_with_reply(
                UpdateTriple::Num { id: 7, feature: format!("f{step}"), delta: 1.0 },
                Some(tx.clone()),
            );
        }
        scorer.flush();
        let mut last = None;
        for step in 0..4 {
            match rx.recv().unwrap() {
                ShardReply::Update(score) => {
                    assert_eq!(score.id, 7);
                    assert_eq!(score.fresh, step == 0, "only the first update admits");
                    last = Some(score.outlierness);
                }
                other => panic!("expected an update reply, got {other:?}"),
            }
        }
        scorer.query_score(7, tx.clone());
        match rx.recv().unwrap() {
            ShardReply::Query { id, score } => {
                assert_eq!(id, 7);
                assert_eq!(score, last, "query must report the post-update score");
            }
            other => panic!("expected a query reply, got {other:?}"),
        }
        scorer.query_score(999, tx);
        match rx.recv().unwrap() {
            ShardReply::Query { id, score } => {
                assert_eq!((id, score), (999, None), "unknown ids answer None");
            }
            other => panic!("expected a query reply, got {other:?}"),
        }
        drop(scorer.finish());
    }

    /// Live reshard mid-stream: zero drops (submitted == processed) and
    /// the merged score log is bit-identical to an uninterrupted S=1 run
    /// — under churn with absorb on, crossing epoch boundaries and two
    /// reshards (2→4→1).
    #[test]
    fn live_reshard_is_lossless_and_deterministic() {
        let model = fitted();
        let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
        let updates = churn(900, 40);
        let opts = ServeOptions::new().cache(24).record(true).absorb(true);
        let mut reference =
            ShardedStreamScorer::from_ensemble(ens.clone(), opts.shards(1), None).unwrap();
        for u in &updates {
            reference.submit(u.clone());
        }
        let expected = reference.finish();
        assert!(expected.evictions() > 0);

        let mut pool = ShardedStreamScorer::from_ensemble(ens, opts.shards(2), None).unwrap();
        for (i, u) in updates.iter().enumerate() {
            if i == 300 {
                pool.reshard(4).unwrap();
                assert_eq!(pool.shards(), 4);
            }
            if i == 650 {
                pool.reshard(1).unwrap();
            }
            pool.submit(u.clone());
        }
        let report = pool.finish();
        assert_eq!(report.processed(), 900, "reshard must not drop queued updates");
        assert_eq!(report.evictions(), expected.evictions());
        assert_eq!(report.absorbed(), expected.absorbed());
        assert_eq!(report.merged_scores(), expected.merged_scores());
        assert_eq!(report.worst, expected.worst, "worst must carry across generations");
    }

    /// The Arc-sharing contract: S shards hold handles on one ensemble
    /// (S worker handles + the feeder's), and the reported resident
    /// footprint does not scale with S.
    #[test]
    fn shards_share_one_ensemble_at_one_x_footprint() {
        let model = fitted();
        let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
        let one = ShardedStreamScorer::from_ensemble(
            ens.clone(),
            ServeOptions::new().shards(1).cache(16),
            None,
        )
        .unwrap();
        let bytes_s1 = one.resident_ensemble_bytes();
        drop(one.finish());
        let eight = ShardedStreamScorer::from_ensemble(
            ens.clone(),
            ServeOptions::new().shards(8).cache(16),
            None,
        )
        .unwrap();
        assert_eq!(
            Arc::strong_count(&ens),
            1 + 1 + 8,
            "local + feeder + 8 shard handles on ONE ensemble"
        );
        assert_eq!(
            eight.resident_ensemble_bytes(),
            bytes_s1,
            "resident ensemble bytes must be independent of the shard count"
        );
        assert!(bytes_s1 > 0);
        drop(eight.finish());
        assert_eq!(Arc::strong_count(&ens), 1, "workers must release their handles at join");
    }

    /// Absorb mode: every update absorbs; the stats probe sees the live
    /// counters and resident accounting mid-stream.
    #[test]
    fn absorb_mode_counts_and_stats_probe() {
        let model = fitted();
        let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
        let mut scorer = ShardedStreamScorer::from_ensemble(
            ens,
            ServeOptions::new().shards(3).cache(64).absorb(true),
            None,
        )
        .unwrap();
        for id in 0..50u64 {
            scorer.submit(UpdateTriple::Num { id, feature: "f0".into(), delta: 0.5 });
        }
        let stats = scorer.stats().unwrap();
        assert_eq!(stats.submitted, 50);
        assert_eq!(stats.processed(), 50, "stats barrier lands after the flush");
        assert_eq!(stats.absorbed(), 50);
        assert_eq!(stats.resident_ids, 50);
        assert!(stats.resident_bytes() > stats.resident_ensemble_bytes);
        let report = scorer.finish();
        assert_eq!(report.processed(), 50);
        assert_eq!(report.absorbed(), 50, "absorb mode must absorb every update");
    }
}
